"""Fig. 6: uneven expert activation distribution (per-layer skew stats)."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common


def run(out_rows):
    cfg, params, lm = common.get_model()
    t0 = time.time()
    rec, _ = common.get_profile(cfg, params, lm)
    res = {}
    for l in range(cfg.num_layers):
        s = rec.activation_skew(l)
        res[f"layer{l}"] = {k: v for k, v in s.items() if k != "counts"}
        res[f"layer{l}"]["counts_top8"] = sorted(
            s["counts"].tolist(), reverse=True)[:8]
        print(f"  layer {l}: gini {s['gini']:.3f} top1 {s['top1_share']:.3f} "
              f"top8 {s['top8_share']:.3f} (uniform top8 = {8/64:.3f})")
    mean_gini = float(np.mean([rec.activation_skew(l)["gini"]
                               for l in range(cfg.num_layers)]))
    out_rows.append(("skew.mean_gini", (time.time() - t0) * 1e6,
                     f"{mean_gini:.4f}"))
    common.write_results("skew.json", res, config="skew", seed=0, t0=t0)
    return res
