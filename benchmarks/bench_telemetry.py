"""Telemetry benchmark: cost-model calibration + prefetch-predictor meters.

Exercises the flight recorder (runtime/telemetry.py + runtime/trace.py)
end-to-end on the serving stack and reports the two numbers the telemetry
exists to produce:

  * calibration residuals per miss-outcome class — for every resolved miss
    the engine records the PREDICTED stall (the cost model's fetch ETA at
    decision time, or the quality price it charged for buddy/degraded/drop)
    against the REALIZED stall on the simulated transfer timeline. A well-
    calibrated cost model has near-zero fetch residuals (the ETA and the
    timeline share the bandwidth model) and the four-way arm populates all
    reachable outcome classes;

  * prefetch precision / recall / expected-stall-saved per predictor —
    issued vs landed-in-time vs actually-used prefetches for each of the
    stock predictors (prev-step, top-freq, cross-layer) on the SAME
    workload, plus the cost ranker's expected-saving estimate summed over
    issued transfers.

Also exports the four-way arm's trace both ways for the Perfetto
quickstart (README "Observability"):

  results/bench/telemetry_trace.jsonl   lossless JSONL event log
  results/bench/telemetry_trace.json    Chrome trace_event JSON — load at
                                        https://ui.perfetto.dev or
                                        chrome://tracing

  PYTHONPATH=src python -m benchmarks.bench_telemetry --smoke
  PYTHONPATH=src python -m benchmarks.bench_telemetry --smoke --seed 7
"""
from __future__ import annotations

import argparse
import os
import time

from benchmarks import common
from benchmarks.bench_serving import (PROMPT_HI, _probe_step_s, _setup,
                                      _workload)
from repro.core import BuddyPolicy
from repro.runtime.cache import ExpertCache
from repro.runtime.prefetch import (CrossLayerPredictor, PrevStepPredictor,
                                    TopFreqPredictor)
from repro.runtime.telemetry import Telemetry
from repro.runtime.tiers import TIER_BITS, TieredExpertStore
from repro.runtime.trace import export_trace
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import (ContinuousScheduler, RequestQueue,
                                     SLOConfig)


def _serve(eng: ServeEngine, lm, *, num_requests: int, rate: float,
           max_new: int, slo: SLOConfig, slots: int, seed: int) -> dict:
    cs = ContinuousScheduler(eng, slots=slots, prefill_chunk=4)
    return cs.run(RequestQueue(_workload(lm, num_requests, rate, max_new,
                                         slo, seed=seed + 1)))


def run(out_rows, *, smoke: bool = True, num_requests: int = 12,
        slots: int = 4, max_new: int = 6, prefetch_k: int = 2,
        cache_rate: float = 0.25, seed: int = 0,
        quant_tier: str = "int8") -> dict:
    t0 = time.time()
    cfg, params, lm, tables = _setup(smoke)
    l, e = cfg.num_layers, cfg.moe.num_experts
    results: dict = {"seed": seed, "predictors": {}}

    # arrival rate / SLO anchored to a measured unloaded step, exactly as
    # bench_serving does (the hardware model's pure-compute step is not a
    # usable anchor in the transfer-bound regime)
    probe = ServeEngine(
        cfg, params, tables=tables,
        policy=BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8, mode="none"),
        cache=ExpertCache(l, e, cache_rate, seed=seed),
        predictor=PrevStepPredictor(l, e), prefetch_k=prefetch_k, seed=seed)
    step_s = _probe_step_s(probe, lm, slots)
    req_tokens = PROMPT_HI + max_new
    rate = 0.8 * slots / (req_tokens * step_s)
    slo = SLOConfig(ttft_s=2 * PROMPT_HI * step_s, tpot_s=2 * step_s,
                    deadline_s=3 * req_tokens * step_s)
    serve_kw = dict(num_requests=num_requests, rate=rate, max_new=max_new,
                    slo=slo, slots=slots, seed=seed)

    # -- per-predictor prefetch meters: same workload seed, mode='none' so
    # every residual miss pays a real fetch and prefetch coverage is the
    # only lever — precision (used / issued), recall (used-in-time /
    # miss-or-use opportunities), expected stall saved by the ranker.
    # In the transfer-bound smoke regime most prefetches ESCALATE before
    # landing (demand catches the in-flight transfer), so the late column
    # dominates used_in_time — exactly the diagnosis the meter exists to
    # surface (a late prefetch still shortens the stall, but is never
    # credited as covering the miss).
    predictors = {
        "prev_step": lambda: PrevStepPredictor(l, e),
        "top_freq": lambda: TopFreqPredictor(l, e),
        "cross_layer": lambda: CrossLayerPredictor(l, e),
    }
    for label, mk in predictors.items():
        tele = Telemetry.with_trace(predictor_label=label,
                                    num_layers=l, num_experts=e)
        # miss_policy='cost' turns on the expected-stall-saved prefetch
        # ranker (engine._rank_prefetch), whose per-submission score feeds
        # the meter's expected_stall_saved_s column; with mode='none' and
        # no tier the scorer still resolves every miss as a fetch
        eng = ServeEngine(
            cfg, params, tables=tables,
            policy=BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8, mode="none",
                               miss_policy="cost"),
            cache=ExpertCache(l, e, cache_rate, seed=seed),
            predictor=mk(), prefetch_k=prefetch_k, seed=seed,
            telemetry=tele)
        s = _serve(eng, lm, **serve_kw)
        if label == "prev_step":
            tele_trace = tele      # exported below: richest trace (fetch
            #                        stalls + prefetch transfer spans)
        pf = tele.prefetch.summary()
        cal = tele.calibration.summary()
        results["predictors"][label] = {
            "prefetch": pf, "calibration": cal,
            "goodput_rps": s["goodput_rps"],
            "p99_token_latency_ms": s["token_latency_s"]["p99"] * 1e3}
        cf = cal.get("fetch", {"n": 0})
        print(f"  [{label:11s}] prefetch precision {pf['precision']:.3f} "
              f"recall {pf['recall']:.3f} issued {pf['issued']:4d} "
              f"used {pf['used_in_time']:4d} late {pf['late']:3d} "
              f"uncovered {pf['uncovered_miss']:3d} expected-saved "
              f"{pf['expected_stall_saved_s']*1e3:.2f}ms; fetch calib "
              f"n={cf['n']} |resid| "
              f"{cf.get('residual_abs_mean_s', 0.0)*1e3:.4f}ms")
        out_rows.append((f"telemetry.prefetch_precision.{label}",
                         pf["precision"], f"recall={pf['recall']:.3f}"))
        out_rows.append((f"telemetry.prefetch_late.{label}",
                         float(pf["late"]), f"issued={pf['issued']}"))

    # -- four-way arm: tiered store + unified cost scorer so every outcome
    # class (buddy / degraded / fetch / drop) is reachable, giving the
    # calibration meter all four residual columns. Prefetch-free: the miss
    # path itself is what's being metered.
    tele4 = Telemetry.with_trace(predictor_label="prev_step",
                                 num_layers=l, num_experts=e)
    tier = TieredExpertStore(l, e, cache_rate, bits=TIER_BITS[quant_tier],
                             d_model=cfg.d_model, d_ff=cfg.moe.d_ff,
                             seed=seed)
    eng4 = ServeEngine(
        cfg, params, tables=tables,
        policy=BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8, mode="buddy",
                           quant_tier=quant_tier, miss_policy="cost"),
        tier=tier, predictor=PrevStepPredictor(l, e), prefetch_k=0,
        seed=seed, upgrade_degraded=False, telemetry=tele4)
    s4 = _serve(eng4, lm, **serve_kw)
    cal4 = tele4.calibration.summary()
    results["four_way"] = {
        "quant_tier": quant_tier, "calibration": cal4,
        "metrics": tele4.metrics.snapshot(),
        "expert_stats": (tele4.expert_stats.summary()
                         if tele4.expert_stats is not None else None),
        "goodput_rps": s4["goodput_rps"]}
    print("  [four-way  ] calibration residuals per outcome class:")
    for outcome in ("buddy", "degraded", "fetch", "drop"):
        c = cal4.get(outcome, {"n": 0})
        if not c["n"]:
            print(f"    {outcome:9s} n=0")
            continue
        print(f"    {outcome:9s} n={c['n']:5d} predicted "
              f"{c['predicted_mean_s']*1e3:8.4f}ms realized "
              f"{c['realized_mean_s']*1e3:8.4f}ms |resid| "
              f"{c['residual_abs_mean_s']*1e3:8.4f}ms quality-cost "
              f"{c['quality_cost_mean']*1e3:8.4f}ms")
        out_rows.append((f"telemetry.residual_abs_ms.{outcome}",
                         c["residual_abs_mean_s"] * 1e3, f"n={c['n']}"))

    # -- buddy arm: plain buddy substitution (the paper's headline path) so
    # the 'buddy' calibration class is populated — on the tiny smoke config
    # the four-way cost scorer always prefers the higher-fidelity int8
    # replica and leaves buddy at n=0 there
    tele_b = Telemetry(num_layers=l, num_experts=e)
    eng_b = ServeEngine(
        cfg, params, tables=tables,
        policy=BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8, mode="buddy"),
        cache=ExpertCache(l, e, cache_rate, seed=seed),
        predictor=PrevStepPredictor(l, e), prefetch_k=0, seed=seed,
        telemetry=tele_b)
    _serve(eng_b, lm, **serve_kw)
    cal_b = tele_b.calibration.summary()
    results["buddy_arm"] = {"calibration": cal_b}
    cb = cal_b.get("buddy", {"n": 0})
    print(f"  [buddy arm ] buddy n={cb.get('n', 0)} quality-cost "
          f"{cb.get('quality_cost_mean', 0.0)*1e3:.4f}ms")
    if cb.get("n"):
        out_rows.append(("telemetry.quality_cost_ms.buddy",
                         cb["quality_cost_mean"] * 1e3, f"n={cb['n']}"))

    # -- drop arm: fallback='drop' forces the drop class so its calibration
    # column is populated even when the cost scorer never picks it
    tele_d = Telemetry(num_layers=l, num_experts=e)
    eng_d = ServeEngine(
        cfg, params, tables=tables,
        policy=BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8, fallback="drop",
                           mode="none"),
        cache=ExpertCache(l, e, cache_rate, seed=seed),
        predictor=PrevStepPredictor(l, e), prefetch_k=0, seed=seed,
        telemetry=tele_d)
    _serve(eng_d, lm, **serve_kw)
    cal_d = tele_d.calibration.summary()
    results["drop_arm"] = {"calibration": cal_d}
    cd = cal_d.get("drop", {"n": 0})
    print(f"  [drop arm  ] drop n={cd.get('n', 0)} quality-cost "
          f"{cd.get('quality_cost_mean', 0.0)*1e3:.4f}ms")

    # -- trace export: the prev_step predictor arm's recorder carries all
    # four track families — request lifecycle spans (emitted by the
    # scheduler's summary()), layer compute/stall spans and outcome
    # instants, transfer spans (prefetches escalating + demand fetches),
    # and engine step spans. The four-way arm is prefetch-free and
    # degraded-absorbing, so its transfers track would be empty.
    os.makedirs(common.CACHE_DIR, exist_ok=True)
    p_jsonl = os.path.join(common.CACHE_DIR, "telemetry_trace.jsonl")
    p_perf = os.path.join(common.CACHE_DIR, "telemetry_trace.json")
    n_jsonl = export_trace(tele_trace.trace, p_jsonl)
    n_perf = export_trace(tele_trace.trace, p_perf)
    results["trace"] = {"jsonl": os.path.basename(p_jsonl),
                        "perfetto": os.path.basename(p_perf),
                        "jsonl_events": n_jsonl, "perfetto_events": n_perf}
    print(f"  trace: {n_jsonl} events -> {p_jsonl}; {n_perf} trace_events "
          f"-> {p_perf} (load at https://ui.perfetto.dev)")

    path = common.write_results(
        "telemetry.json", results,
        config=f"smoke={smoke} quant_tier={quant_tier} "
               f"cache_rate={cache_rate} prefetch_k={prefetch_k}",
        seed=seed, t0=t0)
    print(f"  (total {time.time()-t0:.1f}s; wrote {path})")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny random model (CI)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--num-requests", type=int, default=12)
    ap.add_argument("--prefetch-k", type=int, default=2)
    ap.add_argument("--cache-rate", type=float, default=0.25)
    ap.add_argument("--quant-tier", choices=["int8", "int4"], default="int8",
                    help="replica tier for the four-way calibration arm")
    args = ap.parse_args()
    rows = []
    run(rows, smoke=args.smoke, num_requests=args.num_requests,
        prefetch_k=args.prefetch_k, cache_rate=args.cache_rate,
        seed=args.seed, quant_tier=args.quant_tier)
    print("\nname,value,derived")
    for name, v, derived in rows:
        print(f"{name},{v:.4f},{derived}")
