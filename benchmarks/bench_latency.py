"""Table 1: per-scenario expert-access latency under the hardware model.

  Baseline (on demand)  ~bytes/PCIe_bw + fixed   lossless
  Prefetch hit          ~0 (overlapped)          lossless
  Prefetch miss         same as on-demand        lossless
  BuddyMoE hit          ~0 (substitution)        minimal loss
  BuddyMoE miss         fallback = on-demand     lossless

Scenario latencies derive from runtime/memory.HardwareModel for the paper's
models (DeepSeek-V2-Lite expert and Mixtral-8x7B expert sizes); the
substitution decision overhead is MEASURED (the Alg. 1 kernel on CPU).
"""
from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops
from repro.runtime.memory import DEFAULT_HW, expert_nbytes
from repro.runtime.transfers import TransferScheduler


def _timeline_scenarios(nbytes, compute_s):
    """Play each Table-1 scenario on the event-driven scheduler so the
    reported latencies come from the same timeline the engine uses.

    Returns stalls (s) for: on-demand fetch, prefetch landed early (fully
    overlapped), prefetch issued one layer early (late -> tail stall)."""
    # on demand: nothing in flight when the layer needs the expert
    s = TransferScheduler(DEFAULT_HW)
    t = s.submit(0, 0, nbytes, "demand")
    on_demand = s.run_until_done(t) - 0.0

    # prefetch hit: issued long before the layer -> no stall
    s = TransferScheduler(DEFAULT_HW)
    t = s.submit(0, 0, nbytes, "prefetch")
    s.advance(2 * on_demand)
    hit = 0.0 if t.state == "done" else (s.run_until_done(t) - 2 * on_demand)

    # late prefetch: issued only `compute_s` (one layer) ahead -> the layer
    # stalls for the remaining tail, not the full transfer
    s = TransferScheduler(DEFAULT_HW)
    t = s.submit(0, 0, nbytes, "prefetch")
    s.advance(compute_s)
    s.escalate(t)
    late = max(0.0, s.run_until_done(t) - compute_s)
    return on_demand, hit, late


def run(out_rows):
    t0 = time.time()
    models = {
        "deepseek-v2-lite": expert_nbytes(2048, 1408),
        "mixtral-8x7b": expert_nbytes(4096, 14336),
    }
    active_params = {
        # active params per token (shared + routed top-k), paper models
        "deepseek-v2-lite": (int(2.4e9), 27),
        "mixtral-8x7b": (int(12.9e9), 32),
    }
    res = {}
    for name, nbytes in models.items():
        act, n_layers = active_params[name]
        # a prefetch issued one layer ahead overlaps ONE layer's compute
        compute_s = DEFAULT_HW.decode_compute_time(act, 1) / n_layers
        on_demand, hit, late = _timeline_scenarios(nbytes, compute_s)
        res[name] = {
            "expert_bytes": nbytes,
            "on_demand_ms": on_demand * 1e3,
            "prefetch_hit_ms": hit * 1e3,
            "prefetch_miss_ms": on_demand * 1e3,
            "late_prefetch_stall_ms": late * 1e3,
            "buddy_hit_ms": 0.0,
            "buddy_miss_ms": on_demand * 1e3,
            "decode_layer_compute_ms": compute_s * 1e3,
        }
        print(f"  {name}: expert {nbytes/1e6:.1f}MB -> on-demand "
              f"{on_demand*1e3:.2f}ms; late prefetch tail {late*1e3:.2f}ms; "
              f"hit/substitution ~0ms")

    # measured substitution-decision overhead (Alg. 1, 256 tokens x top-6)
    rng = np.random.default_rng(0)
    t, e, k, r = 256, 64, 6, 16
    s = np.stack([rng.choice(e, k, replace=False) for _ in range(t)]).astype(np.int32)
    gate = rng.random(t) < 0.8
    resident = rng.random(e) < 0.5
    table = rng.integers(0, e, (e, r)).astype(np.int32)
    q = rng.random((e, r)).astype(np.float32)
    us = common.timer(lambda: ops.buddy_substitute(
        jnp.asarray(s), jnp.asarray(gate), jnp.asarray(resident),
        jnp.asarray(table), jnp.asarray(q), h=8, rho=3), repeats=5)
    res["substitution_overhead_us"] = us
    print(f"  Alg.1 substitution decision (256 tok x top-6, CPU interpret): "
          f"{us:.0f}us  — vs ~{res['mixtral-8x7b']['on_demand_ms']:.1f}ms fetch")
    out_rows.append(("latency.substitute_us", us,
                     f"fetch_ms={res['mixtral-8x7b']['on_demand_ms']:.2f}"))
    common.write_results("latency.json", res, config="latency", seed=0,
                         t0=t0)
    print(f"  (total {time.time()-t0:.1f}s)")
    return res


if __name__ == "__main__":          # CI smoke entry point
    os.makedirs(common.CACHE_DIR, exist_ok=True)
    rows = []
    run(rows)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
