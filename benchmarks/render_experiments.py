"""Render EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
results/dryrun/*.json (run after repro.launch.dryrun)."""
from __future__ import annotations

import glob
import json
import os

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def rows(mesh):
    out = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN, f"*_{mesh}.json"))):
        if mesh == "16x16" and "2x16x16" in fn:
            continue
        out.append(json.load(open(fn)))
    return out


def render_dryrun(mesh="16x16"):
    print(f"\n### Dry-run ({mesh})\n")
    print("| arch | shape | compile s | args GB/dev | temp GB/dev | "
          "FLOPs/dev | HBM bytes/dev | coll bytes/dev | collectives |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows(mesh):
        ma, ha = r["memory_analysis"], r["hlo_analysis"]
        counts = ",".join(f"{k.replace('all-','a').replace('reduce-scatter','rs').replace('collective-permute','cp')}:{v}"
                          for k, v in sorted(ha["coll_counts"].items()))
        print(f"| {r['arch']} | {r['shape']} | {r['compile_s']:.1f} "
              f"| {ma['argument_size_bytes']/1e9:.2f} "
              f"| {ma['temp_size_bytes']/1e9:.2f} "
              f"| {ha['flops']:.2e} | {ha['traffic_bytes']:.2e} "
              f"| {ha['coll_bytes']:.2e} | {counts} |")


def render_roofline(mesh="16x16"):
    print(f"\n### Roofline ({mesh})\n")
    print("| arch | shape | compute ms | memory ms | collective ms | "
          "dominant | MODEL_FLOPS | useful ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows(mesh):
        ro = r["roofline"]
        print(f"| {ro['arch']} | {ro['shape']} | {ro['compute_s']*1e3:.2f} "
              f"| {ro['memory_s']*1e3:.2f} | {ro['collective_s']*1e3:.2f} "
              f"| **{ro['dominant']}** | {ro['model_flops']:.2e} "
              f"| {ro['useful_flop_ratio']:.1%} |")


if __name__ == "__main__":
    render_dryrun("16x16")
    render_roofline("16x16")
    render_dryrun("2x16x16")
