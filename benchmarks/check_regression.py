"""Benchmark-regression gate (CI). Two kinds, selected by ``--kind``:

  serving (default) — compares a fresh ``results/bench/serving.json``
    against the committed ``benchmarks/baselines/serving_smoke.json``.
    Tracked metrics (per sweep key, e.g. ``c0.5_load1.0``):

      p99_token_latency_ms.*   continuous arm + tier / cost-policy arms
                               (lower is better)
      goodput_rps.*            continuous + cost-policy arms (higher better)
      nll_absdelta.*           |NLL - full-residency reference| of the tier
                               and cost-policy arms (lower is better)

  mesh — compares the ``results["mesh"]`` section of a fresh
    ``results/bench/serving.json`` (from ``bench_serving --smoke
    --n-devices 4``) against ``benchmarks/baselines/mesh_smoke.json``:

      mesh_d<D>.p99_token_latency_ms.{peer_on,peer_off}
                               the expert-parallel A/B arms (lower better)
      mesh_d<D>.peer_share     fraction of served slots resolved by
                               peer-HBM borrow (higher is better — a
                               collapse means the fifth outcome stopped
                               firing)

  prefix — compares the ``results["prefix"]`` section of a fresh
    ``results/bench/serving.json`` (from ``bench_serving --smoke
    --prefix-ab``) against ``benchmarks/baselines/prefix_smoke.json``:

      prefix.followup_ttft_p99_ms.{on,off}
                               follow-up-turn TTFT of the prefix-cache
                               A/B arms at equal HBM (lower is better)
      prefix.hit_token_share   fraction of prefill tokens served from the
                               radix cache (higher is better — a collapse
                               means admission stopped matching)
      prefix.strict_p99_win    1.0 when the on arm's p99 is STRICTLY below
                               the off arm's (higher is better; a flip to
                               0.0 fails the gate outright)

  placement — compares the ``results["placement"]`` section of a fresh
    ``results/bench/serving.json`` (from ``bench_serving --smoke
    --placement-ab``) against ``benchmarks/baselines/placement_smoke.json``:

      placement.p99_token_latency_ms.{live,frozen}
                               the live-placement A/B arms on the drifting
                               workload (lower is better)
      placement.degraded_share.{live,frozen}
                               degraded-token share of each arm (lower is
                               better; the live arm's is the headline —
                               replication must keep absorbing the drift)
      placement.live_p99_no_worse / placement.live_degraded_win
                               1.0 when live placement holds p99 no worse
                               than frozen / serves a strictly lower
                               degraded share (boolean gates; a flip to
                               0.0 fails outright)

  kernels — compares a fresh ``results/bench/kernels.json`` (from
    ``bench_kernels --smoke``) against
    ``benchmarks/baselines/kernels_smoke.json``. Only the fused-vs-unfused
    decode ``step_time_ratio`` metrics are gated (ratios of medians on the
    same host are CI-robust; raw microsecond timings are not):

      decode_step.step_time_ratio.{zero_miss,mixed25,mixed50}
                               fused / unfused jitted step time (lower is
                               better; <= ~1.0 at zero miss, < 1 at >=25%
                               mixed-outcome slots)

A metric regressing by more than ``--threshold`` (default 15%) fails
(exit 1), printing a per-metric delta table either way.

The serving simulation is deterministic given ``--seed`` (modeled
latencies, seeded workload/cache/PRNGs), so its baseline is tight
run-to-run; small absolute floors (see ``FLOORS``) keep the RELATIVE
threshold from tripping on float-level noise when a baseline value is near
zero. The kernels ratios get a larger floor (0.15 absolute) to damp CI
timing jitter.

Comparison rules:
  * a metric present in the baseline but missing from the current run FAILS
    (a silently dropped arm must not pass the gate);
  * a metric new in the current run is reported and ignored (add it to the
    baseline with --write-baseline when it should be gated).

  # gate a fresh smoke run
  PYTHONPATH=src python -m benchmarks.check_regression

  # refresh the committed baseline after an intentional change
  PYTHONPATH=src python -m benchmarks.check_regression --write-baseline

  # prove the gate trips (CI does this): inflate latency/NLL 1.3x
  PYTHONPATH=src python -m benchmarks.check_regression --inject-regression 1.3
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, Tuple

HERE = os.path.dirname(os.path.abspath(__file__))

# --kind -> (default current results file, default committed baseline)
KIND_PATHS = {
    "serving": (os.path.join(HERE, "..", "results", "bench", "serving.json"),
                os.path.join(HERE, "baselines", "serving_smoke.json")),
    "mesh": (os.path.join(HERE, "..", "results", "bench", "serving.json"),
             os.path.join(HERE, "baselines", "mesh_smoke.json")),
    "prefix": (os.path.join(HERE, "..", "results", "bench", "serving.json"),
               os.path.join(HERE, "baselines", "prefix_smoke.json")),
    "placement": (os.path.join(HERE, "..", "results", "bench",
                               "serving.json"),
                  os.path.join(HERE, "baselines", "placement_smoke.json")),
    "kernels": (os.path.join(HERE, "..", "results", "bench", "kernels.json"),
                os.path.join(HERE, "baselines", "kernels_smoke.json")),
}

# direction: is a LARGER current value worse?
LOWER_IS_BETTER = "lower"
HIGHER_IS_BETTER = "higher"

# absolute change floors per metric family — a relative regression smaller
# than this in absolute terms is measurement noise, not a regression
FLOORS = {
    "p99_token_latency_ms": 0.01,    # modeled ms
    "goodput_rps": 0.05,             # requests / simulated second
    "nll_absdelta": 0.02,            # nats on the smoke NLL probe
    "step_time_ratio": 0.15,         # fused/unfused ratio — wall-clock jitter
    "sim_step_ratio": 0.01,          # telemetry-on/off SIMULATED time ratio:
                                     # deterministic clock, must stay 1.0 —
                                     # the floor only absorbs float residue
    "peer_share": 0.002,             # fraction of served slots peer-borrowed
    "followup_ttft_p99_ms": 0.005,   # modeled ms (deterministic clock)
    "hit_token_share": 0.01,         # fraction of prefill tokens from cache
    "strict_p99_win": 0.1,           # boolean gate — any flip is a fail
    "degraded_share": 0.01,          # fraction of tokens served degraded
    "live_p99_no_worse": 0.1,        # boolean gate — any flip is a fail
    "live_degraded_win": 0.1,        # boolean gate — any flip is a fail
}


def _family(metric: str) -> str:
    for fam in FLOORS:
        if f".{fam}." in metric or metric.endswith(fam):
            return fam
    return ""


def _direction(metric: str) -> str:
    return (HIGHER_IS_BETTER
            if _family(metric) in ("goodput_rps", "peer_share",
                                   "hit_token_share", "strict_p99_win",
                                   "live_p99_no_worse", "live_degraded_win")
            else LOWER_IS_BETTER)


def extract_metrics(results: dict) -> Dict[str, float]:
    """Flatten a bench_serving results dict into {metric_name: value}."""
    out: Dict[str, float] = {}
    for key, row in results.items():
        if not isinstance(row, dict) or "continuous" not in row:
            continue
        cont = row["continuous"]
        out[f"{key}.p99_token_latency_ms.continuous"] = \
            cont["token_latency_s"]["p99"] * 1e3
        out[f"{key}.goodput_rps.continuous"] = cont["goodput_rps"]
        if "tiered" in row:
            td = row["tiered"]
            out[f"{key}.p99_token_latency_ms.tier"] = \
                td["summary"]["token_latency_s"]["p99"] * 1e3
            out[f"{key}.nll_absdelta.tier"] = \
                abs(td["nll"]["tier"] - td["nll"]["full_residency"])
        if "cost_policy" in row:
            cp = row["cost_policy"]
            out[f"{key}.p99_token_latency_ms.cost_policy"] = \
                cp["cost"]["token_latency_s"]["p99"] * 1e3
            out[f"{key}.goodput_rps.cost_policy"] = \
                cp["cost"]["goodput_rps"]
            out[f"{key}.nll_absdelta.cost_policy"] = \
                abs(cp["nll"]["cost"] - cp["nll"]["full_residency"])
    # telemetry overhead gate: the flight recorder must not move the
    # SIMULATED clock — bench_serving's on/off A/B reports the ratio of
    # simulated elapsed times, which is 1.0 exactly when telemetry is a
    # pure observer (the committed baseline pins it there)
    to = results.get("telemetry_overhead")
    if isinstance(to, dict) and "sim_step_ratio" in to:
        out["telemetry_overhead.sim_step_ratio"] = to["sim_step_ratio"]
    return out


def extract_kernel_metrics(results: dict) -> Dict[str, float]:
    """Gateable metrics from a bench_kernels results dict: the decode-step
    fused/unfused ratios only — raw interp/XLA microsecond timings vary too
    much across CI hosts to gate, but a ratio of medians on one host holds."""
    out: Dict[str, float] = {}
    for name, row in results.get("decode_step", {}).items():
        if isinstance(row, dict) and "step_time_ratio" in row:
            out[f"decode_step.step_time_ratio.{name}"] = \
                row["step_time_ratio"]
    return out


def extract_mesh_metrics(results: dict) -> Dict[str, float]:
    """Gateable metrics from the expert-parallel mesh A/B arm of a
    bench_serving results dict (present when run with --n-devices > 1):
    both peer arms' p99 token latency, and the peer-borrow hit share —
    a collapse there means misses stopped resolving over ICI even if the
    latency happens to hold on a small workload."""
    out: Dict[str, float] = {}
    m = results.get("mesh")
    if not isinstance(m, dict):
        return out
    d = m["n_devices"]
    out[f"mesh_d{d}.p99_token_latency_ms.peer_on"] = \
        m["p99_tok_ms"]["peer_on"]
    out[f"mesh_d{d}.p99_token_latency_ms.peer_off"] = \
        m["p99_tok_ms"]["peer_off"]
    out[f"mesh_d{d}.peer_share"] = m["peer_share"]
    return out


def extract_prefix_metrics(results: dict) -> Dict[str, float]:
    """Gateable metrics from the shared-prefix A/B arm of a bench_serving
    results dict (present when run with --prefix-ab): follow-up-turn p99
    TTFT of both arms, the prefix-hit token share (a collapse means
    admission stopped matching the radix tree even if latency holds on a
    small workload), and the strict-win boolean itself — the on arm must
    beat the off arm OUTRIGHT at equal HBM, not merely stay within the
    relative threshold of its own baseline."""
    out: Dict[str, float] = {}
    p = results.get("prefix")
    if not isinstance(p, dict):
        return out
    out["prefix.followup_ttft_p99_ms.on"] = p["followup_ttft_ms"]["on"]["p99"]
    out["prefix.followup_ttft_p99_ms.off"] = \
        p["followup_ttft_ms"]["off"]["p99"]
    out["prefix.hit_token_share"] = p["hit_token_share"]
    out["prefix.strict_p99_win"] = 1.0 if p["prefix_lower_p99"] else 0.0
    return out


def extract_placement_metrics(results: dict) -> Dict[str, float]:
    """Gateable metrics from the live-placement A/B arm of a bench_serving
    results dict (present when run with --placement-ab): p99 token latency
    and degraded-token share of both arms on the drifting workload, plus
    the two acceptance booleans themselves — live placement must hold p99
    NO WORSE than frozen and serve a STRICTLY lower degraded share at
    equal HBM, not merely stay within the relative threshold of its own
    baseline."""
    out: Dict[str, float] = {}
    p = results.get("placement")
    if not isinstance(p, dict):
        return out
    out["placement.p99_token_latency_ms.live"] = p["p99_tok_ms"]["live"]
    out["placement.p99_token_latency_ms.frozen"] = p["p99_tok_ms"]["frozen"]
    out["placement.degraded_share.live"] = p["degraded_share"]["live"]
    out["placement.degraded_share.frozen"] = p["degraded_share"]["frozen"]
    out["placement.live_p99_no_worse"] = \
        1.0 if p["live_p99_no_worse"] else 0.0
    out["placement.live_degraded_win"] = \
        1.0 if p["live_lower_degraded"] else 0.0
    return out


EXTRACTORS = {"serving": extract_metrics, "mesh": extract_mesh_metrics,
              "kernels": extract_kernel_metrics,
              "prefix": extract_prefix_metrics,
              "placement": extract_placement_metrics}


def inject_regression(metrics: Dict[str, float],
                      factor: float) -> Dict[str, float]:
    """Synthetically worsen every metric by ``factor`` (latency/NLL up,
    goodput down) — the gate's self-test."""
    out = {}
    for m, v in metrics.items():
        if _direction(m) == HIGHER_IS_BETTER:
            out[m] = v / factor
        else:
            out[m] = v * factor + FLOORS.get(_family(m), 0.0) * factor
    return out


def compare(baseline: Dict[str, float], current: Dict[str, float],
            threshold: float = 0.15) -> Tuple[list, bool]:
    """Returns ([(metric, base, cur, delta_frac, status)], any_regression).
    delta_frac is SIGNED so that positive = worse regardless of direction."""
    rows = []
    bad = False
    for m in sorted(set(baseline) | set(current)):
        if m not in current:
            rows.append((m, baseline[m], None, None, "MISSING"))
            bad = True
            continue
        if m not in baseline:
            rows.append((m, None, current[m], None, "new"))
            continue
        b, c = baseline[m], current[m]
        worse = (b - c) if _direction(m) == HIGHER_IS_BETTER else (c - b)
        frac = worse / max(abs(b), 1e-12)
        floor = FLOORS.get(_family(m), 0.0)
        if frac > threshold and abs(worse) > floor:
            rows.append((m, b, c, frac, "REGRESSION"))
            bad = True
        elif frac < -threshold and abs(worse) > floor:
            rows.append((m, b, c, frac, "improved"))
        else:
            rows.append((m, b, c, frac, "ok"))
    return rows, bad


def _fmt(v) -> str:
    return "      --" if v is None else f"{v:12.4f}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", choices=sorted(KIND_PATHS), default="serving",
                    help="which benchmark's results to gate")
    ap.add_argument("--current", default=None,
                    help="results JSON of the run under test "
                         "(default: per --kind)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline metrics JSON "
                         "(default: per --kind)")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated relative regression per metric")
    ap.add_argument("--write-baseline", action="store_true",
                    help="extract the current run's metrics into --baseline "
                         "instead of comparing")
    ap.add_argument("--inject-regression", type=float, default=0.0,
                    metavar="FACTOR",
                    help="self-test: worsen every current metric by FACTOR "
                         "before comparing (the gate must then fail)")
    args = ap.parse_args(argv)
    if args.current is None:
        args.current = KIND_PATHS[args.kind][0]
    if args.baseline is None:
        args.baseline = KIND_PATHS[args.kind][1]

    with open(args.current) as f:
        current = EXTRACTORS[args.kind](json.load(f))
    if not current:
        print(f"no gateable metrics found in {args.current}", file=sys.stderr)
        return 1

    if args.write_baseline:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=1, sort_keys=True)
        print(f"wrote {len(current)} baseline metrics to {args.baseline}")
        return 0

    if args.inject_regression:
        assert args.inject_regression > 1.0, \
            "--inject-regression FACTOR must be > 1"
        current = inject_regression(current, args.inject_regression)
        print(f"[self-test] injected a {args.inject_regression:.2f}x "
              f"regression into every metric")

    with open(args.baseline) as f:
        baseline = json.load(f)

    rows, bad = compare(baseline, current, args.threshold)
    w = max(len(m) for m, *_ in rows)
    print(f"{'metric':<{w}}  {'baseline':>12}  {'current':>12}  "
          f"{'delta':>8}  status")
    for m, b, c, frac, status in rows:
        d = "      --" if frac is None else f"{frac:+8.1%}"
        print(f"{m:<{w}}  {_fmt(b)}  {_fmt(c)}  {d}  {status}")
    n_reg = sum(1 for r in rows if r[4] in ("REGRESSION", "MISSING"))
    if bad:
        print(f"\nFAIL: {n_reg} metric(s) regressed beyond "
              f"{args.threshold:.0%} (or went missing) vs {args.baseline}")
        return 1
    print(f"\nOK: {len(rows)} metric(s) within {args.threshold:.0%} "
          f"of the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
