"""Serving-under-load benchmark: StaticBatcher vs continuous batching.

The paper's harness decodes fixed static batches; this bench puts the same
engine behind a Poisson arrival stream and compares the llama.cpp-style
StaticServer (batch-formation barrier, lockstep decode, stragglers hold the
batch) against the token-level ContinuousScheduler (requests join/retire
mid-step on the per-layer transfer timeline, prefetch budget adapted from
queue depth + stall attribution).

Reported per (arrival rate x cache rate): p50/p95/p99 TTFT, p99 token
latency (arrival->token gaps), goodput (SLO-satisfying requests/s), modeled
tokens/s, and the engine's stall attribution. A third arm runs the
continuous scheduler with CHUNKED prefill (--prefill-chunk > 1: joining
prompts ingested C tokens per fused step instead of token-by-token), and
the TTFT column compares chunked vs token-by-token at equal arrival rates.

--quant-tier int8/int4 adds the TIERED arm (runtime/tiers.py): at the SAME
total HBM budget, low-precision replicas of every expert stay resident and
displace full-precision cache slots, so a buddy-less miss computes degraded
instead of stalling. The arm sweeps the accuracy-vs-stall frontier: p99 TPOT
vs a fetch-on-miss arm sized to the tier's ACTUAL footprint (when the split
clamps — tier + 1 mandatory slot overshooting the nominal budget — the
fetch baseline gets the same extra bytes, so the comparison never hands the
tier free HBM), and a teacher-forced NLL probe vs full residency compared
against the drop-on-miss accuracy cliff.

--cost-policy (with a --quant-tier) adds the COST-POLICY arm: the same
four-way tier config run twice — once with the fixed precedence chain
(buddy before degraded before fetch), once with the unified expected-cost
argmin (runtime/costs.py) — differing ONLY in policy.miss_policy
(degraded-then-upgrade pinned off in BOTH arms so the scorer alone is
measured). The cost arm must hold p99 token latency and tighten
the |NLL delta| against the full-residency reference (recorded as
cost_no_worse_both / cost_strictly_better_one in serving.json; the CI
regression gate in benchmarks/check_regression.py tracks the raw metrics
against a committed baseline).

--n-devices 2..8 adds the MESH arm: the same engine sharded expert-parallel
across D devices, run twice — peer-HBM borrowing over ICI on vs off — on an
identical workload. The peer-on arm must resolve residual misses by
borrowing from the owning device's HBM (fifth miss outcome) and hold a
lower p99 token latency than the peer-off twin, whose misses all pay host
PCIe. Per-link utilization and the peer-borrow share are recorded under
results["mesh"] and gated by check_regression.py --kind mesh.

--prefix-ab adds the PREFIX arm: two identical paged-KV engines (equal HBM
— the pool defaults to the exact ring-buffer footprint), radix-tree prefix
cache on vs off, serving the same multi-turn session workload (turn j's
prompt extends turn j-1's; ``session``/``parent`` threaded onto requests).
The on arm admits follow-up turns by adopting the cached prefix blocks and
prefilling only the novel suffix; gated on follow-up-turn p99 TTFT and the
prefix-hit token share under results["prefix"] (check_regression.py
--kind prefix).

--placement-ab adds the PLACEMENT arm: two identical int8-tier engines at
equal HBM (same displaced-budget split, partial replica coverage) on a
DRIFTING workload — mid-run the traffic becomes a calibrated "hot prompt
storm" whose routing mass lands on a covered (replica-only) expert the
initial placement left cold, so the hot expert set the placement was
right for moves away. The live arm runs a PlacementController
(runtime/placement.py: coverage re-picks + background replication on the
event clock) that installs a full-precision replica of the newly-hot
expert while the int8 replica absorbs its misses; the frozen arm keeps
the initial placement and serves it degraded forever. Live must hold p99
token latency no worse and serve a strictly lower degraded-token share;
gated under results["placement"] by check_regression.py --kind placement.

--seed makes sweeps reproducible run-to-run: it drives the workload draw,
the cache placement, and every engine PRNG, and is recorded per arm in
results/bench/serving.json.

  PYTHONPATH=src python -m benchmarks.bench_serving --smoke
  PYTHONPATH=src python -m benchmarks.bench_serving --rates 0.5,0.8 \
      --cache-rates 0.5,0.75 --num-requests 32 --prefill-chunk 8 \
      --quant-tier int8 --seed 7
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks import common
from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.core import BuddyPolicy, build_buddy_lists
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.placement import PlacementController
from repro.runtime.prefetch import (AdaptiveBudgetController,
                                    PrevStepPredictor)
from repro.runtime.telemetry import Telemetry
from repro.runtime.tiers import TIER_BITS, TieredExpertStore
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import (ContinuousScheduler, PoissonArrivals,
                                     RequestQueue, ServeRequest, SLOConfig,
                                     StaticServer, make_requests, percentiles)
from repro.training.data import MarkovLM


def _setup(smoke: bool):
    """(cfg, params, lm, tables): tiny random model for --smoke, the trained
    benchmark model otherwise."""
    if smoke:
        cfg = reduced()
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        lm = MarkovLM(cfg.vocab_size, seed=0)
        rng = np.random.default_rng(0)
        q = rng.random((cfg.num_layers, cfg.moe.num_experts,
                        cfg.moe.num_experts))
        tables = build_buddy_lists(q, alpha=0.95,
                                   k_max=cfg.moe.num_experts - 1)
        return cfg, params, lm, tables
    cfg, params, lm = common.get_model()
    rec, q = common.get_profile(cfg, params, lm)
    tables = common.get_tables(cfg, q, rec, 0.95, 16)
    return cfg, params, lm, tables


def _engine(cfg, params, tables, cache_rate: float, prefetch_k: int,
            seed: int = 0, fallback: str = "fetch",
            mode: str = "buddy") -> ServeEngine:
    l, e = cfg.num_layers, cfg.moe.num_experts
    return ServeEngine(
        cfg, params, tables=tables,
        policy=BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8, fallback=fallback,
                           mode=mode),
        cache=ExpertCache(l, e, cache_rate, seed=seed),
        predictor=PrevStepPredictor(l, e),
        prefetch_k=prefetch_k, seed=seed)


def _tier_engine(cfg, params, tables, cache_rate: float, prefetch_k: int,
                 quant_tier: str, seed: int = 0, mode: str = "buddy",
                 miss_policy: str = "precedence") -> ServeEngine:
    """Tiered arm at EQUAL total HBM budget: the resident replica tier
    displaces full-precision cache slots from the same cache_rate budget.
    ``miss_policy='cost'`` swaps the fixed buddy->degraded->fetch chain for
    the unified expected-cost argmin (runtime/costs.py). Degraded-then-
    upgrade is pinned OFF in both arms so the cost-vs-precedence pair
    differs in the SCORER alone — the engine would otherwise auto-enable
    upgrades exactly in the cost arm and confound the A/B (upgrades are
    exercised by the serve launcher and tests/test_costs.py)."""
    l, e = cfg.num_layers, cfg.moe.num_experts
    tier = TieredExpertStore(l, e, cache_rate, bits=TIER_BITS[quant_tier],
                             d_model=cfg.d_model, d_ff=cfg.moe.d_ff,
                             seed=seed)
    return ServeEngine(
        cfg, params, tables=tables,
        policy=BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8, mode=mode,
                           quant_tier=quant_tier, miss_policy=miss_policy),
        tier=tier, predictor=PrevStepPredictor(l, e),
        prefetch_k=prefetch_k, seed=seed, upgrade_degraded=False)


PROMPT_LO, PROMPT_HI = 12, 25       # prompt-length range (rng.integers)


def _workload(lm, n: int, rate: float, max_new: int, slo: SLOConfig,
              seed: int = 1, prompt_lo: int = PROMPT_LO,
              prompt_hi: int = PROMPT_HI):
    """Poisson arrivals, varied prompt/output lengths (output-length spread
    is what makes lockstep batches pay the straggler barrier; prompts long
    enough that prefill ingestion dominates TTFT under load)."""
    rng = np.random.default_rng(seed)
    prompts = [lm.sample(1, int(rng.integers(prompt_lo, prompt_hi)))[0]
               for _ in range(n)]
    new_toks = rng.integers(2, 2 * max_new + 1, n)
    return make_requests(prompts, PoissonArrivals(rate, seed=seed + 1),
                         new_toks, slo)


def _session_workload(lm, *, n_sessions: int, n_turns: int, opener: int,
                      turn_lo: int, turn_hi: int, gap_s: float,
                      stagger_s: float, max_new: int, slo: SLOConfig,
                      seed: int):
    """Multi-turn chat sessions: turn j's prompt extends turn j-1's prompt
    verbatim (shared opener + growing history) and arrives one think-time
    ``gap_s`` later — the shared-prefix traffic the radix cache targets.
    ``session``/``parent`` are threaded onto each request (the same fields
    ``requests_from_trace`` accepts on trace-replay rows). Requests are
    returned in arrival order with their original rids, so ``parent`` links
    stay valid."""
    rng = np.random.default_rng(seed)
    reqs = []
    for s in range(n_sessions):
        hist = list(lm.sample(1, opener)[0])
        parent = None
        for t in range(n_turns):
            hist = hist + list(
                lm.sample(1, int(rng.integers(turn_lo, turn_hi)))[0])
            r = ServeRequest(rid=len(reqs),
                             prompt=np.array(hist, np.int64),
                             max_new_tokens=max_new,
                             arrival_s=t * gap_s + s * stagger_s,
                             slo=slo, session=s, parent=parent)
            reqs.append(r)
            parent = r.rid
    reqs.sort(key=lambda r: (r.arrival_s, r.rid))
    return reqs


def _probe_step_s(eng: ServeEngine, lm, slots: int) -> float:
    """Measured per-step time (compute + stalls) of an unloaded engine —
    the anchor for both the arrival-rate sweep and the SLO targets. The
    hardware model's pure-compute step underestimates badly in the
    transfer-bound regime (which is the paper's whole point)."""
    eng.generate(lm.sample(slots, 4), max_new_tokens=8)
    return eng.stats.sim_time_s / max(1, eng.stats.steps)


def run(out_rows, *, smoke: bool = False, loads=(0.5, 0.8),
        cache_rates=(0.5,), num_requests: int = 24, slots: int = 4,
        max_new: int = 8, prefetch_k: int = 2,
        prefill_chunk: int = 8, seed: int = 0,
        quant_tier: str = "off", cost_policy: bool = False,
        n_devices: int = 1, ici_gbps=None,
        prefix_ab: bool = False, kv_block: int = 8,
        placement_ab: bool = False) -> dict:
    t0 = time.time()
    assert not cost_policy or quant_tier != "off", \
        "--cost-policy compares the four-way miss tree: pick a --quant-tier"
    cfg, params, lm, tables = _setup(smoke)
    results = {"seed": seed}
    for cache_rate in cache_rates:
        probe = _engine(cfg, params, tables, cache_rate, prefetch_k,
                        seed=seed)
        step_s = _probe_step_s(probe, lm, slots)
        req_tokens = (PROMPT_LO + PROMPT_HI - 1) // 2 + max_new
        capacity = slots / (req_tokens * step_s)
        for load in loads:
            rate = load * capacity
            # SLO anchored to the measured unloaded step: first token within
            # ~a prompt's worth of steps + slack, deadline 3x ideal service
            slo = SLOConfig(ttft_s=2 * PROMPT_HI * step_s, tpot_s=2 * step_s,
                            deadline_s=3 * req_tokens * step_s)

            st_eng = _engine(cfg, params, tables, cache_rate, prefetch_k,
                             seed=seed)
            st = StaticServer(st_eng, batch_size=slots)
            s_static = st.run(_workload(lm, num_requests, rate, max_new, slo,
                                        seed=seed + 1))

            def _continuous(eng, chunk, adaptive=True):
                # the adaptive controller would re-enable prefetch on the
                # deliberately prefetch-free tiered pair — skip it there
                ctrl = AdaptiveBudgetController(
                    prefetch_k=prefetch_k, lookahead=1,
                    max_k=max(4, 2 * prefetch_k)) if adaptive else None
                cs = ContinuousScheduler(eng, slots=slots, controller=ctrl,
                                         prefill_chunk=chunk)
                return cs.run(RequestQueue(
                    _workload(lm, num_requests, rate, max_new, slo,
                              seed=seed + 1)))

            s_cont = _continuous(                 # token-by-token prefill
                _engine(cfg, params, tables, cache_rate, prefetch_k,
                        seed=seed), 1)
            s_chunk = _continuous(
                _engine(cfg, params, tables, cache_rate, prefetch_k,
                        seed=seed), prefill_chunk)

            key = f"c{cache_rate}_load{load}"
            results[key] = {"arrival_rate_rps": rate,
                            "prefill_chunk": prefill_chunk,
                            "seed": seed,
                            "static": s_static, "continuous": s_cont,
                            "continuous_chunked": s_chunk}
            arms = [("static", s_static), ("cont/tok", s_cont),
                    (f"cont/C={prefill_chunk}", s_chunk)]

            if quant_tier != "off":
                # -- tiered arm: same HBM budget, misses compute degraded --
                # The trio (tier / fetch@eq / drop) runs mode='none' and
                # PREFETCH-FREE so it measures the miss-path FALLBACK
                # frontier itself: with buddies or a good predictor active
                # the tiny smoke config has no residual misses left to
                # compare on (buddy absorption is the paper's headline and
                # is benchmarked by the other arms).
                t_eng = _tier_engine(cfg, params, tables, cache_rate,
                                     0, quant_tier, seed=seed, mode="none")
                split = t_eng.tier.budget_split()
                # matched-footprint fetch baseline: when the split clamps
                # (tier + 1 mandatory slot overshoot the nominal budget),
                # comparing against the nominal-rate arm would hand the tier
                # free HBM — size the fetch arm to the NEAREST whole-slot
                # footprint of the tier's actual bytes. Slots are integral,
                # so an exact byte match is impossible; the residual
                # mismatch is measured and recorded (fetch_eq_deficit_frac,
                # positive = baseline holds fewer bytes) rather than hidden.
                e_n = cfg.moe.num_experts
                tier_bytes = (split["cache_bytes_per_layer"]
                              + split["quant_bytes_per_layer"])
                eq_slots = min(e_n, int(round(tier_bytes
                                              / t_eng.tier.full_bytes)))
                eq_rate = eq_slots / e_n    # round-trips exactly in the cache
                eq_bytes = eq_slots * t_eng.tier.full_bytes
                eq_deficit = (tier_bytes - eq_bytes) / tier_bytes
                s_tier = _continuous(t_eng, 1, adaptive=False)
                s_fetch_eq = _continuous(
                    _engine(cfg, params, tables, eq_rate, 0, seed=seed,
                            mode="none"), 1, adaptive=False)
                arms.append((f"tier/{quant_tier}", s_tier))
                arms.append(("fetch@eq", s_fetch_eq))
                # accuracy side of the frontier: fallback-only NLL probe
                # (mode='none' -> EVERY miss hits the fallback) vs full
                # residency, against the drop-on-miss accuracy cliff
                probe_toks = lm.sample(2, 12)
                nll_tier = _tier_engine(
                    cfg, params, tables, cache_rate, 0, quant_tier,
                    seed=seed, mode="none").teacher_forced_nll(probe_toks)
                nll_drop = _engine(cfg, params, tables, cache_rate, 0,
                                   seed=seed, fallback="drop",
                                   mode="none").teacher_forced_nll(
                                       probe_toks)
                nll_full = _engine(cfg, params, tables, 1.0, 0,
                                   seed=seed).teacher_forced_nll(probe_toks)
                results[key]["tiered"] = {
                    "quant_tier": quant_tier, "summary": s_tier,
                    "tier": s_tier["engine"]["tier"],
                    "budget_clamped": split["clamped"],
                    "fetch_equal_footprint_rate": eq_rate,
                    "tier_bytes_per_layer": tier_bytes,
                    "fetch_eq_bytes_per_layer": eq_bytes,
                    "fetch_eq_deficit_frac": eq_deficit,
                    "fetch_equal_footprint": s_fetch_eq,
                    "nll": {"full_residency": nll_full, "tier": nll_tier,
                            "drop": nll_drop}}

            if cost_policy:
                # -- unified cost-policy arm: argmin scorer vs the fixed
                # precedence chain. The pair shares the EXACT tier config
                # (buddies on, prefetch-free, same seeds) and differs only
                # in policy.miss_policy, so any gap is the scorer's: the
                # cost arm must hold p99 token latency (both resolve misses
                # transfer-free) and tighten |NLL delta| by preferring the
                # calibrated replica over mediocre buddies — and high-q
                # buddies over low-fidelity replicas — per slot.
                s_prec = _continuous(
                    _tier_engine(cfg, params, tables, cache_rate, 0,
                                 quant_tier, seed=seed), 1, adaptive=False)
                s_cost = _continuous(
                    _tier_engine(cfg, params, tables, cache_rate, 0,
                                 quant_tier, seed=seed,
                                 miss_policy="cost"), 1, adaptive=False)
                arms.append(("prec/4way", s_prec))
                arms.append(("cost/4way", s_cost))
                # NLL probe: reuse the tiered arm's tokens and full-residency
                # reference (drawing fresh ones would advance the shared
                # MarkovLM RNG and silently change every later sweep key's
                # workload at the same --seed)
                nll_prec = _tier_engine(
                    cfg, params, tables, cache_rate, 0, quant_tier,
                    seed=seed).teacher_forced_nll(probe_toks)
                nll_cost = _tier_engine(
                    cfg, params, tables, cache_rate, 0, quant_tier,
                    seed=seed, miss_policy="cost").teacher_forced_nll(
                        probe_toks)
                results[key]["cost_policy"] = {
                    "quant_tier": quant_tier,
                    "precedence": s_prec, "cost": s_cost,
                    "nll": {"full_residency": nll_full,
                            "precedence": nll_prec, "cost": nll_cost}}
            for tag, s in arms:
                print(f"  [{key}] {tag:11s} TTFT mean "
                      f"{s['ttft_s']['mean']*1e3:7.2f}ms  p99 "
                      f"{s['ttft_s']['p99']*1e3:7.2f}ms  p99 tok "
                      f"{s['token_latency_s']['p99']*1e3:7.2f}ms  goodput "
                      f"{s['goodput_rps']:7.1f} req/s  SLO-met "
                      f"{s['slo_met_frac']*100:3.0f}%")
            better_p99 = (s_cont["token_latency_s"]["p99"]
                          <= s_static["token_latency_s"]["p99"])
            better_good = (s_cont["goodput_rps"] >= s_static["goodput_rps"])
            better_ttft = (s_chunk["ttft_s"]["mean"]
                           < s_cont["ttft_s"]["mean"])
            print(f"  [{key}] continuous better: p99 token latency "
                  f"{better_p99}, goodput {better_good}; chunked prefill "
                  f"lowers mean TTFT: {better_ttft}")
            out_rows.append((
                f"serving.{key}.p99_tok_ms_cont",
                s_cont["token_latency_s"]["p99"] * 1e3,
                f"static={s_static['token_latency_s']['p99']*1e3:.2f}"))
            out_rows.append((
                f"serving.{key}.goodput_rps_cont", s_cont["goodput_rps"],
                f"static={s_static['goodput_rps']:.1f}"))
            out_rows.append((
                f"serving.{key}.ttft_mean_ms_chunk{prefill_chunk}",
                s_chunk["ttft_s"]["mean"] * 1e3,
                f"chunk1={s_cont['ttft_s']['mean']*1e3:.2f}"))
            if quant_tier != "off":
                td = results[key]["tiered"]
                # honest comparison: the fetch arm holds the SAME actual HBM
                # footprint as the (possibly clamped) tier split. Two axes:
                # p99 TPOT (steady-state decode; in tiny-E smoke configs the
                # hot set fits the eq-footprint cache and both arms tie at
                # pure compute) and p99 TOKEN latency, which carries the
                # prefill-phase demand stalls — the tier must never lose
                # TPOT and must win token latency; at full expert counts
                # decode misses persist and the TPOT gap opens too.
                tier_p99 = s_tier["tpot_s"]["p99"]
                fetch_p99 = s_fetch_eq["tpot_s"]["p99"]
                tier_tok = s_tier["token_latency_s"]["p99"]
                fetch_tok = s_fetch_eq["token_latency_s"]["p99"]
                stall_win = tier_p99 <= fetch_p99 and tier_tok < fetch_tok
                # |deviation| from the lossless full-residency reference —
                # on a barely-trained probe a big perturbation (drop) can
                # land on either side of the reference; magnitude is the
                # fidelity metric
                d_tier = abs(td["nll"]["tier"] - td["nll"]["full_residency"])
                d_drop = abs(td["nll"]["drop"] - td["nll"]["full_residency"])
                clamp = " [budget clamped]" if td["budget_clamped"] else ""
                if abs(eq_deficit) > 1e-9:
                    clamp += f" [baseline {eq_deficit:+.1%} byte mismatch]"
                print(f"  [{key}] tiered ({quant_tier}) vs "
                      f"fetch@{eq_rate:.2f}: p99 TPOT "
                      f"{tier_p99*1e3:.3f}/{fetch_p99*1e3:.3f}ms, p99 tok "
                      f"{tier_tok*1e3:.3f}/{fetch_tok*1e3:.3f}ms "
                      f"(stall win: {stall_win}); |NLL delta| "
                      f"{d_tier:.4f} vs drop {d_drop:.4f} "
                      f"(smaller: {d_tier < d_drop}); degraded "
                      f"{td['tier']['degraded_tokens']} slots{clamp}")
                out_rows.append((
                    f"serving.{key}.p99_tpot_ms_tier_{quant_tier}",
                    tier_p99 * 1e3,
                    f"fetch@{eq_rate:.2f}={fetch_p99*1e3:.3f}"))
                out_rows.append((
                    f"serving.{key}.p99_tok_ms_tier_{quant_tier}",
                    tier_tok * 1e3,
                    f"fetch@{eq_rate:.2f}={fetch_tok*1e3:.3f}"))
                out_rows.append((
                    f"serving.{key}.nll_absdelta_tier_{quant_tier}",
                    d_tier, f"drop={d_drop:.4f}"))
            if cost_policy:
                cp = results[key]["cost_policy"]
                p99_prec = cp["precedence"]["token_latency_s"]["p99"]
                p99_cost = cp["cost"]["token_latency_s"]["p99"]
                d_prec = abs(cp["nll"]["precedence"]
                             - cp["nll"]["full_residency"])
                d_cost = abs(cp["nll"]["cost"] - cp["nll"]["full_residency"])
                # acceptance: no worse on BOTH axes, strictly better on one
                # (tiny float tolerance on the latency tie — the arms share
                # the deterministic modeled timeline)
                tol = 1e-12
                no_worse = (p99_cost <= p99_prec + tol
                            and d_cost <= d_prec + tol)
                strictly = (p99_cost < p99_prec - tol
                            or d_cost < d_prec - tol)
                cp["p99_token_latency_s"] = {"precedence": p99_prec,
                                             "cost": p99_cost}
                cp["nll_absdelta"] = {"precedence": d_prec, "cost": d_cost}
                cp["cost_no_worse_both"] = bool(no_worse)
                cp["cost_strictly_better_one"] = bool(strictly)
                print(f"  [{key}] cost-policy vs precedence (scorer only, "
                      f"upgrades off): p99 tok "
                      f"{p99_cost*1e3:.3f}/{p99_prec*1e3:.3f}ms; "
                      f"|NLL delta| {d_cost:.4f} vs {d_prec:.4f}; "
                      f"no-worse-both {no_worse}, strictly-better "
                      f"{strictly}")
                out_rows.append((
                    f"serving.{key}.p99_tok_ms_costpolicy",
                    p99_cost * 1e3, f"precedence={p99_prec*1e3:.3f}"))
                out_rows.append((
                    f"serving.{key}.goodput_rps_costpolicy",
                    cp["cost"]["goodput_rps"],
                    f"precedence={cp['precedence']['goodput_rps']:.1f}"))
                out_rows.append((
                    f"serving.{key}.nll_absdelta_costpolicy",
                    d_cost, f"precedence={d_prec:.4f}"))

    if n_devices > 1:
        # -- expert-parallel mesh A/B: identical D-device engines, peer-HBM
        # borrowing on vs off. mode='none' and prefetch-free, like the
        # tiered trio, so the arm measures the MISS PATH itself: peer-off
        # resolves every residual miss over host PCIe, peer-on borrows
        # peer-owned experts over ICI. A FRESH MarkovLM + rng drive the
        # workload — drawing from the shared ``lm`` would advance its RNG
        # and silently change every sweep above at the same --seed.
        mesh_lm = MarkovLM(cfg.vocab_size, seed=seed + 211)
        cr = cache_rates[0]
        l, e = cfg.num_layers, cfg.moe.num_experts

        def _mesh_eng(peer: bool) -> ServeEngine:
            return ServeEngine(
                cfg, params, tables=tables,
                policy=BuddyPolicy(mode="none"),
                cache=ExpertCache(l, e, cr, seed=seed),
                predictor=PrevStepPredictor(l, e), prefetch_k=0, seed=seed,
                n_devices=n_devices, ici_gbps=ici_gbps, peer_borrow=peer)

        step_s = _probe_step_s(_mesh_eng(False), mesh_lm, slots)
        req_tokens = (PROMPT_LO + PROMPT_HI - 1) // 2 + max_new
        rate = loads[-1] * slots / (req_tokens * step_s)
        slo = SLOConfig(ttft_s=2 * PROMPT_HI * step_s, tpot_s=2 * step_s,
                        deadline_s=3 * req_tokens * step_s)
        # one prompt/budget draw shared by both arms (re-sampling between
        # arms would hand them different workloads)
        mrng = np.random.default_rng(seed + 3)
        mesh_prompts = [mesh_lm.sample(1, int(mrng.integers(PROMPT_LO,
                                                            PROMPT_HI)))[0]
                        for _ in range(num_requests)]
        mesh_new = mrng.integers(2, 2 * max_new + 1, num_requests)

        def _mesh_run(peer: bool):
            cs = ContinuousScheduler(_mesh_eng(peer), slots=slots,
                                     prefill_chunk=1)
            return cs.run(RequestQueue(make_requests(
                mesh_prompts, PoissonArrivals(rate, seed=seed + 4),
                mesh_new, slo)))

        s_peer = _mesh_run(True)
        s_nopeer = _mesh_run(False)
        m_on = s_peer["engine"]["mesh"]
        p99_on = s_peer["token_latency_s"]["p99"]
        p99_off = s_nopeer["token_latency_s"]["p99"]
        results["mesh"] = {
            "n_devices": n_devices, "cache_rate": cr,
            "arrival_rate_rps": rate, "seed": seed,
            "peer_on": s_peer, "peer_off": s_nopeer,
            "p99_tok_ms": {"peer_on": p99_on * 1e3,
                           "peer_off": p99_off * 1e3},
            "n_peer_borrow": m_on["n_peer_borrow"],
            "peer_share": m_on["peer_share"],
            "peer_stall_s": m_on["peer_stall_s"],
            "links": m_on["links"],
            "peer_lower_p99": bool(p99_on <= p99_off),
        }
        print(f"  [mesh D={n_devices}] peer-borrow on/off p99 tok "
              f"{p99_on*1e3:.3f}/{p99_off*1e3:.3f}ms  "
              f"borrows {m_on['n_peer_borrow']} "
              f"({m_on['peer_share']*100:.1f}% of served slots)  "
              f"peer lowers p99: {results['mesh']['peer_lower_p99']}")
        for u in m_on["links"]:
            print(f"  [mesh D={n_devices}]   {u['name']}: busy "
                  f"{u['busy_s']*1e3:.3f}ms  total "
                  f"{u['total_bytes']/1e6:.2f}MB")
        out_rows.append((f"serving.mesh_d{n_devices}.p99_tok_ms_peer",
                         p99_on * 1e3, f"peer_off={p99_off*1e3:.3f}"))
        out_rows.append((f"serving.mesh_d{n_devices}.peer_share",
                         m_on["peer_share"],
                         f"n_borrow={m_on['n_peer_borrow']}"))

    if prefix_ab:
        # -- shared-prefix A/B: identical paged-KV engines at EQUAL HBM
        # (same pool size — the default sizes the pool to the exact ring
        # footprint), radix prefix cache on vs off, on an identical
        # multi-turn session workload. The headline metric is p99 TTFT over
        # FOLLOW-UP turns (requests with a parent — the traffic the cache
        # targets); the session openers pay the engine's one-time streaming
        # warm-up in both arms and would pin the percentile at an identical
        # cold value. Sessions == slots so a follow-up can only admit after
        # a turn retired — i.e. after its parent donated its blocks.
        # A FRESH MarkovLM per arm keeps the workload identical without
        # advancing the shared ``lm`` RNG (same discipline as the mesh arm).
        px_sessions, px_turns, px_chunk = 3, 5, 4
        cr = cache_rates[0]
        l, e = cfg.num_layers, cfg.moe.num_experts
        slo = SLOConfig(ttft_s=0.5, tpot_s=0.05, deadline_s=2.0)

        def _px_workload():
            return _session_workload(
                MarkovLM(cfg.vocab_size, seed=seed + 307),
                n_sessions=px_sessions, n_turns=px_turns, opener=8,
                turn_lo=9, turn_hi=13, gap_s=4e-3, stagger_s=1e-3,
                max_new=4, slo=slo, seed=seed + 308)

        def _px_run(on: bool):
            eng = ServeEngine(
                cfg, params, tables=tables,
                policy=BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8),
                cache=ExpertCache(l, e, cr, seed=seed),
                predictor=PrevStepPredictor(l, e),
                prefetch_k=prefetch_k, seed=seed,
                paged_kv=True, kv_block=kv_block, prefix_cache=on)
            cs = ContinuousScheduler(eng, slots=px_sessions,
                                     prefill_chunk=px_chunk)
            s = cs.run(RequestQueue(_px_workload()))
            follow = percentiles([r.ttft() for r in cs.completed
                                  if r.parent is not None])
            return s, follow

        s_px_off, f_off = _px_run(False)
        s_px_on, f_on = _px_run(True)
        px = s_px_on["engine"]["prefix"]
        tot = px["hit_tokens"] + px["novel_tokens"]
        hit_share = px["hit_tokens"] / tot if tot else 0.0
        results["prefix"] = {
            "cache_rate": cr, "kv_block": kv_block, "seed": seed,
            "n_sessions": px_sessions, "n_turns": px_turns,
            "prefill_chunk": px_chunk,
            "on": s_px_on, "off": s_px_off,
            "followup_ttft_ms": {
                "on": {k: v * 1e3 for k, v in f_on.items()},
                "off": {k: v * 1e3 for k, v in f_off.items()}},
            "hits": px["hits"], "hit_tokens": px["hit_tokens"],
            "novel_tokens": px["novel_tokens"],
            "hit_token_share": hit_share,
            "pool": px["pool"], "tree": px.get("tree"),
            "prefix_lower_p99": bool(f_on["p99"] < f_off["p99"]),
        }
        print(f"  [prefix kb={kv_block}] follow-up TTFT p99 on/off "
              f"{f_on['p99']*1e3:.3f}/{f_off['p99']*1e3:.3f}ms  mean "
              f"{f_on['mean']*1e3:.3f}/{f_off['mean']*1e3:.3f}ms  hits "
              f"{px['hits']} ({hit_share*100:.0f}% of prefill tokens)  "
              f"prefix lowers p99: {results['prefix']['prefix_lower_p99']}")
        out_rows.append(("serving.prefix.followup_ttft_p99_ms",
                         f_on["p99"] * 1e3,
                         f"off={f_off['p99']*1e3:.3f}"))
        out_rows.append(("serving.prefix.followup_ttft_p99_ms_off",
                         f_off["p99"] * 1e3,
                         f"on={f_on['p99']*1e3:.3f}"))
        out_rows.append(("serving.prefix.hit_token_share", hit_share,
                         f"hits={px['hits']}"))

    if placement_ab:
        # -- live-placement A/B on a DRIFTING workload: identical int8-tier
        # engines at equal HBM (same displaced-budget split, PARTIAL replica
        # coverage so WHICH experts hold replicas matters), live
        # PlacementController vs frozen placement. The drift is a "hot
        # prompt storm": the first half of the requests repeat prompts the
        # covered (replica-only) experts never see — the cache settles on
        # the OTHER experts and the covered ones go cold and non-resident —
        # then the second half hammers one trending prompt whose routing
        # mass lands exactly on a covered expert at every layer. Frozen
        # placement serves that expert degraded FOREVER (a covered miss is
        # absorbed by the replica, so nothing ever promotes it — the tier's
        # self-inhibition); the live arm sees its EMA rise, finds the would-
        # be eviction victim cold (admission margin), and installs a full-
        # precision replica in the background while the int8 replica keeps
        # absorbing misses — the drift is healed with ZERO added stalls.
        # Which prompts those are is CALIBRATED, not hardcoded: a throwaway
        # probe engine measures per-layer expert shares of repeated-token
        # prompts (cache.freq deltas) and picks the storm token (max
        # covered-expert share across all layers) and a phase-A pool
        # (near-zero covered-expert share). The trio discipline matches the
        # tiered arm: mode='none' and prefetch-free, so the A/B measures
        # the PLACEMENT loop itself, not buddy absorption or predictor
        # quality.
        l, e = cfg.num_layers, cfg.moe.num_experts
        pl_cr, pl_cov = 1.0, 0.25
        covered = TieredExpertStore(
            l, e, pl_cr, bits=8, d_model=cfg.d_model, d_ff=cfg.moe.d_ff,
            coverage=pl_cov, seed=seed).covered
        cal = ServeEngine(
            cfg, params, tables=tables, policy=BuddyPolicy(mode="none"),
            cache=ExpertCache(l, e, 1.0, seed=seed),
            predictor=PrevStepPredictor(l, e), prefetch_k=0, seed=seed)
        cov_share = {}
        prev_freq = cal.cache.freq.astype(float).copy()
        for t in range(7, cfg.vocab_size, max(1, cfg.vocab_size // 16)):
            cal.generate(np.full((slots, 10), t, np.int64), max_new_tokens=2)
            f = cal.cache.freq.astype(float) - prev_freq
            prev_freq = cal.cache.freq.astype(float).copy()
            share = f / np.maximum(f.sum(axis=1, keepdims=True), 1.0)
            cov_share[t] = (share * covered).sum(axis=1)
        hot_tok = max(cov_share, key=lambda t: float(cov_share[t].min()))
        pl_pool = sorted(cov_share, key=lambda t: float(cov_share[t].max()))
        pl_pool = [t for t in pl_pool[:4] if t != hot_tok]

        def _pl_eng(live: bool, interval_s: float) -> ServeEngine:
            tier = TieredExpertStore(l, e, pl_cr, bits=8, d_model=cfg.d_model,
                                     d_ff=cfg.moe.d_ff, coverage=pl_cov,
                                     seed=seed)
            # hot_top_k=2: a repeated prompt splits routing ~50/50 over two
            # experts per layer, and top-1 would flap between the tied pair
            # and never build the hysteresis streak
            ctrl = (PlacementController(refresh_interval_s=interval_s,
                                        hot_windows=2, hot_top_k=2)
                    if live else None)
            return ServeEngine(
                cfg, params, tables=tables,
                policy=BuddyPolicy(mode="none", quant_tier="int8"),
                tier=tier, predictor=PrevStepPredictor(l, e),
                prefetch_k=0, seed=seed, upgrade_degraded=False,
                placement=ctrl)

        step_s = _probe_step_s(_pl_eng(False, 1.0), lm, slots)
        req_tokens = (PROMPT_LO + PROMPT_HI - 1) // 2 + max_new
        rate = loads[-1] * slots / (req_tokens * step_s)
        slo = SLOConfig(ttft_s=2 * PROMPT_HI * step_s, tpot_s=2 * step_s,
                        deadline_s=3 * req_tokens * step_s)
        # one workload draw shared by both arms: phase A then phase B
        prng = np.random.default_rng(seed + 5)
        n_a = num_requests // 2
        pl_prompts = [np.full(int(prng.integers(PROMPT_LO, PROMPT_HI)),
                              pl_pool[i % len(pl_pool)], np.int64)
                      for i in range(n_a)]
        pl_prompts += [np.full(int(prng.integers(PROMPT_LO, PROMPT_HI)),
                               hot_tok, np.int64)
                       for _ in range(num_requests - n_a)]
        pl_new = prng.integers(2, 2 * max_new + 1, num_requests)
        # refresh every few fused steps so the controller sees several
        # windows per phase — a wall-clock-style fixed interval would be
        # meaningless against the modeled step time
        pl_interval = 4 * step_s

        def _pl_run(live: bool):
            cs = ContinuousScheduler(_pl_eng(live, pl_interval), slots=slots,
                                     prefill_chunk=1)
            return cs.run(RequestQueue(make_requests(
                pl_prompts, PoissonArrivals(rate, seed=seed + 6),
                pl_new, slo)))

        def _deg_share(s) -> float:
            st, t = s["engine"]["stats"], s["engine"]["tier"]
            total = (st["n_hit"] + st["n_sub"] + st["n_miss_fetch"]
                     + t["degraded_tokens"])
            return t["degraded_tokens"] / max(1, total)

        s_live = _pl_run(True)
        s_frozen = _pl_run(False)
        p99_live = s_live["token_latency_s"]["p99"]
        p99_frozen = s_frozen["token_latency_s"]["p99"]
        deg_live, deg_frozen = _deg_share(s_live), _deg_share(s_frozen)
        pl = s_live["engine"]["placement"]
        tol = 1e-12
        results["placement"] = {
            "cache_rate": pl_cr, "coverage": pl_cov, "seed": seed,
            "arrival_rate_rps": rate, "refresh_interval_s": pl_interval,
            "storm_token": int(hot_tok),
            "phase_a_pool": [int(t) for t in pl_pool],
            "live": s_live, "frozen": s_frozen,
            "p99_tok_ms": {"live": p99_live * 1e3,
                           "frozen": p99_frozen * 1e3},
            "degraded_share": {"live": deg_live, "frozen": deg_frozen},
            "n_ticks": pl["n_ticks"],
            "coverage_repicks": pl["coverage_repicks"],
            "replicas_issued": pl["replicas_issued"],
            "replicas_reclaimed": pl["replicas_reclaimed"],
            "live_p99_no_worse": bool(p99_live <= p99_frozen + tol),
            "live_lower_degraded": bool(deg_live < deg_frozen - tol),
        }
        print(f"  [placement cov={pl_cov}] live/frozen p99 tok "
              f"{p99_live*1e3:.3f}/{p99_frozen*1e3:.3f}ms  degraded share "
              f"{deg_live*100:.2f}%/{deg_frozen*100:.2f}%  "
              f"({pl['n_ticks']} ticks, {pl['coverage_repicks']} re-picks, "
              f"{pl['replicas_issued']} replicas)  live no-worse p99: "
              f"{results['placement']['live_p99_no_worse']}, lower "
              f"degraded: {results['placement']['live_lower_degraded']}")
        out_rows.append(("serving.placement.p99_tok_ms_live",
                         p99_live * 1e3, f"frozen={p99_frozen*1e3:.3f}"))
        out_rows.append(("serving.placement.degraded_share_live",
                         deg_live, f"frozen={deg_frozen:.4f}"))

    # -- telemetry overhead A/B: the flight recorder is a pure observer of
    # the SIMULATED timeline, so a telemetry-on engine must agree with a
    # telemetry-off twin on the simulated clock EXACTLY (sim_step_ratio ==
    # 1.0 — gated against the committed baseline by check_regression.py)
    # and on every generated token. A FRESH MarkovLM drives the probe:
    # extra draws from the shared ``lm`` would advance its RNG and silently
    # change every sweep above at the same --seed.
    probe_toks = MarkovLM(cfg.vocab_size, seed=seed + 101).sample(slots, 10)

    def _ab_run(tele):
        eng = _engine(cfg, params, tables, cache_rates[0], prefetch_k,
                      seed=seed)
        if tele is not None:
            eng.telemetry = tele
            eng._wire_telemetry()
        out = eng.generate(probe_toks, max_new_tokens=max_new)
        return np.asarray(out), eng

    out_off, eng_off = _ab_run(None)
    out_on, eng_on = _ab_run(Telemetry.with_trace(
        predictor_label="prev_step", num_layers=cfg.num_layers,
        num_experts=cfg.moe.num_experts))
    s_off, s_on = eng_off.summary(), dict(eng_on.summary())
    s_on.pop("telemetry", None)
    identical = bool(np.array_equal(out_off, out_on) and s_off == s_on)
    off_s, on_s = eng_off.stats.sim_time_s, eng_on.stats.sim_time_s
    results["telemetry_overhead"] = {
        "sim_elapsed_off_s": off_s, "sim_elapsed_on_s": on_s,
        "sim_step_ratio": on_s / max(off_s, 1e-12),
        "summaries_bit_identical": identical}
    print(f"  telemetry A/B: sim_step_ratio "
          f"{results['telemetry_overhead']['sim_step_ratio']:.6f} "
          f"(bit-identical: {identical})")

    path = common.write_results(
        "serving.json", results,
        config=f"smoke={smoke} loads={loads} cache_rates={cache_rates} "
               f"quant_tier={quant_tier} cost_policy={cost_policy} "
               f"n_devices={n_devices} placement_ab={placement_ab}",
        seed=seed, t0=t0)
    print(f"  (total {time.time()-t0:.1f}s; wrote {path})")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny random model, one load point (CI)")
    ap.add_argument("--rates", default="0.5,0.8",
                    help="comma-separated utilization loads (x capacity)")
    ap.add_argument("--cache-rates", default="0.5")
    ap.add_argument("--num-requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="chunk size for the chunked-prefill arm (compared "
                         "against token-by-token at equal arrival rates)")
    ap.add_argument("--quant-tier", choices=["off", "int8", "int4"],
                    default="off",
                    help="adds the tiered arm: resident compressed replicas "
                         "at equal HBM budget (misses compute degraded)")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload + engine + cache-placement seed, recorded "
                         "per arm in results/bench/serving.json")
    ap.add_argument("--cost-policy", action="store_true",
                    help="adds the unified cost-policy arm: the expected-"
                         "cost argmin (runtime/costs.py) vs the fixed "
                         "precedence chain on the same tiered config "
                         "(requires --quant-tier)")
    ap.add_argument("--n-devices", type=int, default=1,
                    help="adds the expert-parallel mesh arm at this device "
                         "count (2-8): peer-HBM borrowing over ICI on vs "
                         "off on identical sharded engines")
    ap.add_argument("--ici-gbps", type=float, default=0.0,
                    help="per-ICI-link bandwidth in GB/s for the mesh arm "
                         "(0: hardware model default)")
    ap.add_argument("--prefix-ab", action="store_true",
                    help="adds the shared-prefix arm: paged-KV engines at "
                         "equal HBM, radix prefix cache on vs off, on a "
                         "multi-turn session workload (follow-up-turn TTFT)")
    ap.add_argument("--kv-block", type=int, default=8,
                    help="paged-KV block size (tokens) for the prefix arm")
    ap.add_argument("--placement-ab", action="store_true",
                    help="adds the live-placement arm: int8-tier engines at "
                         "equal HBM on a drifting workload, live "
                         "PlacementController vs frozen placement")
    args = ap.parse_args()
    if args.cost_policy and args.quant_tier == "off":
        ap.error("--cost-policy compares the four-way miss tree: "
                 "pick a --quant-tier (int8/int4)")
    if not 1 <= args.n_devices <= 8:
        ap.error("--n-devices must be in 1..8")
    ici = args.ici_gbps if args.ici_gbps > 0 else None
    rows = []
    if args.smoke:
        run(rows, smoke=True, loads=(1.0,), cache_rates=(0.5,),
            num_requests=16, max_new=6, prefill_chunk=args.prefill_chunk,
            seed=args.seed, quant_tier=args.quant_tier,
            cost_policy=args.cost_policy, n_devices=args.n_devices,
            ici_gbps=ici, prefix_ab=args.prefix_ab, kv_block=args.kv_block,
            placement_ab=args.placement_ab)
    else:
        run(rows,
            loads=tuple(float(x) for x in args.rates.split(",")),
            cache_rates=tuple(float(x) for x in args.cache_rates.split(",")),
            num_requests=args.num_requests, slots=args.slots,
            max_new=args.max_new, prefill_chunk=args.prefill_chunk,
            seed=args.seed, quant_tier=args.quant_tier,
            cost_policy=args.cost_policy, n_devices=args.n_devices,
            ici_gbps=ici, prefix_ab=args.prefix_ab, kv_block=args.kv_block,
            placement_ab=args.placement_ab)
    print("\nname,value,derived")
    for name, v, derived in rows:
        print(f"{name},{v:.2f},{derived}")
