"""Serving-under-load benchmark: StaticBatcher vs continuous batching.

The paper's harness decodes fixed static batches; this bench puts the same
engine behind a Poisson arrival stream and compares the llama.cpp-style
StaticServer (batch-formation barrier, lockstep decode, stragglers hold the
batch) against the token-level ContinuousScheduler (requests join/retire
mid-step on the per-layer transfer timeline, prefetch budget adapted from
queue depth + stall attribution).

Reported per (arrival rate x cache rate): p50/p95/p99 TTFT, p99 token
latency (arrival->token gaps), goodput (SLO-satisfying requests/s), modeled
tokens/s, and the engine's stall attribution. A third arm runs the
continuous scheduler with CHUNKED prefill (--prefill-chunk > 1: joining
prompts ingested C tokens per fused step instead of token-by-token), and
the TTFT column compares chunked vs token-by-token at equal arrival rates.

  PYTHONPATH=src python -m benchmarks.bench_serving --smoke
  PYTHONPATH=src python -m benchmarks.bench_serving --rates 0.5,0.8 \
      --cache-rates 0.5,0.75 --num-requests 32 --prefill-chunk 8
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks import common
from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.core import BuddyPolicy, build_buddy_lists
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.prefetch import (AdaptiveBudgetController,
                                    PrevStepPredictor)
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import (ContinuousScheduler, PoissonArrivals,
                                     RequestQueue, SLOConfig, StaticServer,
                                     make_requests)
from repro.training.data import MarkovLM


def _setup(smoke: bool):
    """(cfg, params, lm, tables): tiny random model for --smoke, the trained
    benchmark model otherwise."""
    if smoke:
        cfg = reduced()
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        lm = MarkovLM(cfg.vocab_size, seed=0)
        rng = np.random.default_rng(0)
        q = rng.random((cfg.num_layers, cfg.moe.num_experts,
                        cfg.moe.num_experts))
        tables = build_buddy_lists(q, alpha=0.95,
                                   k_max=cfg.moe.num_experts - 1)
        return cfg, params, lm, tables
    cfg, params, lm = common.get_model()
    rec, q = common.get_profile(cfg, params, lm)
    tables = common.get_tables(cfg, q, rec, 0.95, 16)
    return cfg, params, lm, tables


def _engine(cfg, params, tables, cache_rate: float, prefetch_k: int,
            seed: int = 0) -> ServeEngine:
    l, e = cfg.num_layers, cfg.moe.num_experts
    return ServeEngine(
        cfg, params, tables=tables,
        policy=BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8),
        cache=ExpertCache(l, e, cache_rate, seed=seed),
        predictor=PrevStepPredictor(l, e),
        prefetch_k=prefetch_k, seed=seed)


PROMPT_LO, PROMPT_HI = 12, 25       # prompt-length range (rng.integers)


def _workload(lm, n: int, rate: float, max_new: int, slo: SLOConfig,
              seed: int = 1, prompt_lo: int = PROMPT_LO,
              prompt_hi: int = PROMPT_HI):
    """Poisson arrivals, varied prompt/output lengths (output-length spread
    is what makes lockstep batches pay the straggler barrier; prompts long
    enough that prefill ingestion dominates TTFT under load)."""
    rng = np.random.default_rng(seed)
    prompts = [lm.sample(1, int(rng.integers(prompt_lo, prompt_hi)))[0]
               for _ in range(n)]
    new_toks = rng.integers(2, 2 * max_new + 1, n)
    return make_requests(prompts, PoissonArrivals(rate, seed=seed + 1),
                         new_toks, slo)


def _probe_step_s(eng: ServeEngine, lm, slots: int) -> float:
    """Measured per-step time (compute + stalls) of an unloaded engine —
    the anchor for both the arrival-rate sweep and the SLO targets. The
    hardware model's pure-compute step underestimates badly in the
    transfer-bound regime (which is the paper's whole point)."""
    eng.generate(lm.sample(slots, 4), max_new_tokens=8)
    return eng.stats.sim_time_s / max(1, eng.stats.steps)


def run(out_rows, *, smoke: bool = False, loads=(0.5, 0.8),
        cache_rates=(0.5,), num_requests: int = 24, slots: int = 4,
        max_new: int = 8, prefetch_k: int = 2,
        prefill_chunk: int = 8) -> dict:
    t0 = time.time()
    cfg, params, lm, tables = _setup(smoke)
    results = {}
    for cache_rate in cache_rates:
        probe = _engine(cfg, params, tables, cache_rate, prefetch_k)
        step_s = _probe_step_s(probe, lm, slots)
        req_tokens = (PROMPT_LO + PROMPT_HI - 1) // 2 + max_new
        capacity = slots / (req_tokens * step_s)
        for load in loads:
            rate = load * capacity
            # SLO anchored to the measured unloaded step: first token within
            # ~a prompt's worth of steps + slack, deadline 3x ideal service
            slo = SLOConfig(ttft_s=2 * PROMPT_HI * step_s, tpot_s=2 * step_s,
                            deadline_s=3 * req_tokens * step_s)

            st_eng = _engine(cfg, params, tables, cache_rate, prefetch_k)
            st = StaticServer(st_eng, batch_size=slots)
            s_static = st.run(_workload(lm, num_requests, rate, max_new, slo))

            def _continuous(chunk):
                eng = _engine(cfg, params, tables, cache_rate, prefetch_k)
                ctrl = AdaptiveBudgetController(
                    prefetch_k=prefetch_k, lookahead=1,
                    max_k=max(4, 2 * prefetch_k))
                cs = ContinuousScheduler(eng, slots=slots, controller=ctrl,
                                         prefill_chunk=chunk)
                return cs.run(RequestQueue(
                    _workload(lm, num_requests, rate, max_new, slo)))

            s_cont = _continuous(1)             # token-by-token prefill
            s_chunk = _continuous(prefill_chunk)

            key = f"c{cache_rate}_load{load}"
            results[key] = {"arrival_rate_rps": rate,
                            "prefill_chunk": prefill_chunk,
                            "static": s_static, "continuous": s_cont,
                            "continuous_chunked": s_chunk}
            for tag, s in (("static", s_static), ("cont/tok", s_cont),
                           (f"cont/C={prefill_chunk}", s_chunk)):
                print(f"  [{key}] {tag:11s} TTFT mean "
                      f"{s['ttft_s']['mean']*1e3:7.2f}ms  p99 "
                      f"{s['ttft_s']['p99']*1e3:7.2f}ms  p99 tok "
                      f"{s['token_latency_s']['p99']*1e3:7.2f}ms  goodput "
                      f"{s['goodput_rps']:7.1f} req/s  SLO-met "
                      f"{s['slo_met_frac']*100:3.0f}%")
            better_p99 = (s_cont["token_latency_s"]["p99"]
                          <= s_static["token_latency_s"]["p99"])
            better_good = (s_cont["goodput_rps"] >= s_static["goodput_rps"])
            better_ttft = (s_chunk["ttft_s"]["mean"]
                           < s_cont["ttft_s"]["mean"])
            print(f"  [{key}] continuous better: p99 token latency "
                  f"{better_p99}, goodput {better_good}; chunked prefill "
                  f"lowers mean TTFT: {better_ttft}")
            out_rows.append((
                f"serving.{key}.p99_tok_ms_cont",
                s_cont["token_latency_s"]["p99"] * 1e3,
                f"static={s_static['token_latency_s']['p99']*1e3:.2f}"))
            out_rows.append((
                f"serving.{key}.goodput_rps_cont", s_cont["goodput_rps"],
                f"static={s_static['goodput_rps']:.1f}"))
            out_rows.append((
                f"serving.{key}.ttft_mean_ms_chunk{prefill_chunk}",
                s_chunk["ttft_s"]["mean"] * 1e3,
                f"chunk1={s_cont['ttft_s']['mean']*1e3:.2f}"))

    os.makedirs(common.CACHE_DIR, exist_ok=True)
    with open(os.path.join(common.CACHE_DIR, "serving.json"), "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"  (total {time.time()-t0:.1f}s)")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny random model, one load point (CI)")
    ap.add_argument("--rates", default="0.5,0.8",
                    help="comma-separated utilization loads (x capacity)")
    ap.add_argument("--cache-rates", default="0.5")
    ap.add_argument("--num-requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="chunk size for the chunked-prefill arm (compared "
                         "against token-by-token at equal arrival rates)")
    args = ap.parse_args()
    rows = []
    if args.smoke:
        run(rows, smoke=True, loads=(1.0,), cache_rates=(0.5,),
            num_requests=16, max_new=6, prefill_chunk=args.prefill_chunk)
    else:
        run(rows,
            loads=tuple(float(x) for x in args.rates.split(",")),
            cache_rates=tuple(float(x) for x in args.cache_rates.split(",")),
            num_requests=args.num_requests, slots=args.slots,
            max_new=args.max_new, prefill_chunk=args.prefill_chunk)
    print("\nname,value,derived")
    for name, v, derived in rows:
        print(f"{name},{v:.2f},{derived}")
