"""Kernel microbenches: Pallas (interpret on CPU — functional timing, not TPU
perf) vs the pure-jnp oracle, across paper-relevant shapes."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops, ref


def run(out_rows):
    rng = np.random.default_rng(0)

    # buddy_substitute @ DeepSeek-V2-Lite decode batch
    t, e, k, r = 256, 64, 6, 16
    s = np.stack([rng.choice(e, k, replace=False) for _ in range(t)]).astype(np.int32)
    gate = rng.random(t) < 0.8
    res = rng.random(e) < 0.5
    table = rng.integers(0, e, (e, r)).astype(np.int32)
    q = rng.random((e, r)).astype(np.float32)
    a = [jnp.asarray(x) for x in (s, gate, res, table, q)]
    us_k = common.timer(lambda: ops.buddy_substitute(*a, h=8, rho=3))
    us_r = common.timer(lambda: ref.ref_buddy_substitute(s, gate, res, table,
                                                         q, h=8, rho=3),
                        repeats=2)
    out_rows.append(("kernel.buddy_substitute", us_k, f"ref_us={us_r:.0f}"))
    print(f"  buddy_substitute: pallas(interp) {us_k:.0f}us, "
          f"python-ref {us_r:.0f}us")

    # topk_gate @ prefill tile
    z = jnp.asarray(rng.normal(size=(2048, 64)).astype(np.float32))
    us_k = common.timer(lambda: ops.topk_gate(z, 0.4, k=6))
    us_r = common.timer(lambda: ref.ref_topk_gate(z, 0.4, k=6))
    out_rows.append(("kernel.topk_gate", us_k, f"ref_us={us_r:.0f}"))
    print(f"  topk_gate: pallas(interp) {us_k:.0f}us, jnp-ref {us_r:.0f}us")

    # expert_ffn @ small dispatch buffer
    e_n, c, d, f = 8, 128, 256, 512
    x = jnp.asarray((rng.normal(size=(e_n, c, d)) * 0.1).astype(np.float32))
    w1 = jnp.asarray((rng.normal(size=(e_n, d, f)) * 0.05).astype(np.float32))
    w3 = jnp.asarray((rng.normal(size=(e_n, d, f)) * 0.05).astype(np.float32))
    w2 = jnp.asarray((rng.normal(size=(e_n, f, d)) * 0.05).astype(np.float32))
    us_k = common.timer(lambda: ops.expert_ffn(x, w1, w3, w2), repeats=3)
    us_r = common.timer(lambda: ref.ref_expert_ffn(x, w1, w3, w2))
    out_rows.append(("kernel.expert_ffn", us_k, f"ref_us={us_r:.0f}"))
    print(f"  expert_ffn: pallas(interp) {us_k:.0f}us, jnp-ref {us_r:.0f}us")
    return {}
