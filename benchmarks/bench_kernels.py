"""Kernel microbenches + the fused-dispatch decode step-time comparison.

Two timing arms per kernel, labeled for what they actually measure on this
CPU-only container:

  interp_us   the Pallas kernel in INTERPRET mode — functional validation
              timing only (the kernel body runs in Python/XLA-CPU); NOT a
              TPU kernel-performance number.
  xla_ref_us  the pure-jnp oracle (kernels/ref.py) under jax.jit — a real
              compiled-XLA timing, the honest CPU reference arm.

The decode-step section times the thing the grouped kernel exists for: one
jitted ``moe_forward`` decode step, three-dispatch (``use_fused_dispatch``
off: full-precision path + buddy replicas + separate degraded pass) vs
single-dispatch (knob on, jnp megastep), at 0% / ~25% / ~50% mixed-outcome
slots. ``step_time_ratio = fused / unfused`` (lower is better) feeds the CI
regression gate via ``check_regression --kind kernels``.

Everything is seeded (``--seed``) and recorded to
``results/bench/kernels.json``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs.base import MoEConfig
from repro.core.policy import BuddyPolicy
from repro.core.quantize import quantize_expert_ffn
from repro.core.substitute import substitute
from repro.kernels import ops, ref
from repro.models import moe as M

OUT_PATH = os.path.join(common.CACHE_DIR, "kernels.json")

INTERP_NOTE = ("interp_us times Pallas INTERPRET mode (functional "
               "validation on CPU, not TPU kernel perf); xla_ref_us times "
               "the jitted jnp oracle — the compiled-XLA reference arm")


def _record(out_rows, results, name, interp_us, xla_us):
    results["kernels"][name] = {"interp_us": interp_us,
                                "xla_ref_us": xla_us}
    out_rows.append((f"kernel.{name}", interp_us,
                     f"xla_ref_us={xla_us:.0f}"))
    print(f"  {name}: pallas(interp) {interp_us:.0f}us, "
          f"jit-xla-ref {xla_us:.0f}us")


def _bench_kernels(out_rows, results, rng, smoke: bool):
    rep_i = 2 if smoke else 3          # interpret arm is slow; median of few
    rep_x = 5

    # buddy_substitute @ DeepSeek-V2-Lite decode batch. The jitted XLA
    # reference is core.substitute (the in-model path), NOT the numpy-loop
    # oracle — a python loop timing is not a reference arm.
    t, e, k, r = (64, 16, 4, 8) if smoke else (256, 64, 6, 16)
    s = np.stack([rng.choice(e, k, replace=False)
                  for _ in range(t)]).astype(np.int32)
    gate = rng.random(t) < 0.8
    res = rng.random(e) < 0.5
    table = rng.integers(0, e, (e, r)).astype(np.int32)
    q = rng.random((e, r)).astype(np.float32)
    a = [jnp.asarray(v) for v in (s, gate, res, table, q)]
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=3, H=8)
    logits = jnp.asarray(rng.normal(size=(t, k)).astype(np.float32))
    sub_jit = jax.jit(lambda si, lo, re, ta, qq: substitute(
        si, lo, re, ta, qq, pol))
    us_k = common.timer(lambda: ops.buddy_substitute(*a, h=8, rho=3),
                        repeats=rep_i)
    us_r = common.timer(lambda: sub_jit(a[0], logits, a[2], a[3], a[4]),
                        repeats=rep_x)
    _record(out_rows, results, "buddy_substitute", us_k, us_r)

    # topk_gate @ prefill tile
    tg = 512 if smoke else 2048
    z = jnp.asarray(rng.normal(size=(tg, e)).astype(np.float32))
    ref_topk = jax.jit(lambda zz: ref.ref_topk_gate(zz, 0.4, k=k))
    us_k = common.timer(lambda: ops.topk_gate(z, 0.4, k=k), repeats=rep_i)
    us_r = common.timer(lambda: ref_topk(z), repeats=rep_x)
    _record(out_rows, results, "topk_gate", us_k, us_r)

    # shared SwiGLU shapes for the three FFN kernels
    e_n, c, d, f = (4, 32, 64, 128) if smoke else (8, 128, 256, 512)
    x = jnp.asarray((rng.normal(size=(e_n, c, d)) * 0.1).astype(np.float32))
    w1 = jnp.asarray((rng.normal(size=(e_n, d, f)) * 0.05).astype(np.float32))
    w3 = jnp.asarray((rng.normal(size=(e_n, d, f)) * 0.05).astype(np.float32))
    w2 = jnp.asarray((rng.normal(size=(e_n, f, d)) * 0.05).astype(np.float32))
    quant = quantize_expert_ffn(w1, w3, w2, 8)
    qargs = (quant["w1_q"], quant["w1_s"], quant["w3_q"], quant["w3_s"],
             quant["w2_q"], quant["w2_s"])

    ref_ffn = jax.jit(ref.ref_expert_ffn)
    us_k = common.timer(lambda: ops.expert_ffn(x, w1, w3, w2), repeats=rep_i)
    us_r = common.timer(lambda: ref_ffn(x, w1, w3, w2), repeats=rep_x)
    _record(out_rows, results, "expert_ffn", us_k, us_r)

    ref_qffn = jax.jit(ref.ref_quant_ffn)
    us_k = common.timer(lambda: ops.quant_ffn(x, *qargs), repeats=rep_i)
    us_r = common.timer(lambda: ref_qffn(x, *qargs), repeats=rep_x)
    _record(out_rows, results, "quant_ffn", us_k, us_r)

    # grouped_ffn: 2E groups (fp + degraded halves of the same experts)
    xg = jnp.concatenate([x, x * 0.5], axis=0)                 # [2E, C, D]
    ref_gffn = jax.jit(ref.ref_grouped_ffn)
    us_k = common.timer(lambda: ops.grouped_ffn(xg, w1, w3, w2, *qargs),
                        repeats=rep_i)
    us_r = common.timer(lambda: ref_gffn(xg, w1, w3, w2, *qargs),
                        repeats=rep_x)
    _record(out_rows, results, "grouped_ffn", us_k, us_r)


def _decode_step_bench(out_rows, results, rng, smoke: bool):
    """Fused vs unfused jitted decode step at three miss mixes."""
    e_n, k_n, d, f = (16, 4, 64, 128) if smoke else (32, 6, 128, 256)
    b = 8 if smoke else 16                                 # decode rows
    cfg = MoEConfig(num_experts=e_n, top_k=k_n, d_ff=f)
    key = jax.random.PRNGKey(int(rng.integers(0, 2 ** 31)))
    params = M.init_moe(key, d, cfg, jnp.float32)
    params["quant"] = quantize_expert_ffn(params["w1"], params["w3"],
                                          params["w2"], 8)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, 1, d)) * 0.5
    # ring buddy table: expert i's buddies are the next experts (mod E)
    table = jnp.asarray(np.stack([np.roll(np.arange(e_n), -i - 1)[:4]
                                  for i in range(e_n)]), jnp.int32)
    qtab = jnp.full((e_n, 4), 0.3, jnp.float32)
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=1, H=4, quant_tier="int8")
    pol_fused = dataclasses.replace(pol, use_fused_dispatch=True)

    def scenario(name, miss_frac):
        n_miss = int(round(miss_frac * e_n))
        resident = np.ones(e_n, bool)
        if n_miss:
            resident[rng.choice(e_n, n_miss, replace=False)] = False
        # half the non-resident experts may serve degraded; rho=1 caps
        # buddy reroutes so fetch-resolved misses survive too
        quant_ok = ~resident & (np.arange(e_n) % 2 == 0)
        buddy = M.BuddyState(resident=jnp.asarray(resident), table=table,
                             q=qtab, hop=jnp.zeros((e_n,), jnp.int32),
                             quant_ok=jnp.asarray(quant_ok))
        step_u = jax.jit(lambda p, xx: M.moe_forward(
            p, xx, cfg, policy=pol, buddy=buddy)[0])
        step_f = jax.jit(lambda p, xx: M.moe_forward(
            p, xx, cfg, policy=pol_fused, buddy=buddy)[0])
        us_u = common.timer(lambda: step_u(params, x), repeats=7)
        us_f = common.timer(lambda: step_f(params, x), repeats=7)
        _, aux = M.moe_forward(params, x, cfg, policy=pol, buddy=buddy)
        n_slots = b * k_n
        mix = {"slots": n_slots,
               "substituted": int(aux.n_substituted),
               "degraded": int(aux.n_degraded),
               "fetch_missed": int(aux.n_missed),
               "outcome_frac": float(
                   (int(aux.n_substituted) + int(aux.n_degraded)
                    + int(aux.n_missed)) / n_slots)}
        ratio = us_f / us_u
        results["decode_step"][name] = {
            "unfused_us": us_u, "fused_us": us_f,
            "step_time_ratio": ratio, "mix": mix}
        out_rows.append((f"decode_step.{name}.fused", us_f,
                         f"unfused_us={us_u:.0f} ratio={ratio:.3f}"))
        print(f"  {name}: unfused {us_u:.0f}us, fused {us_f:.0f}us, "
              f"ratio {ratio:.3f} (outcome slots: {mix['outcome_frac']:.0%})")

    results["decode_step"]["shape"] = {
        "num_experts": e_n, "top_k": k_n, "d_model": d, "d_ff": f,
        "decode_rows": b, "quant_tier": "int8"}
    scenario("zero_miss", 0.0)
    scenario("mixed25", 0.3)   # ~25%+ of slots carry a non-hit outcome
    scenario("mixed50", 0.5)


def run(out_rows, seed: int = 0, smoke: bool = False):
    t0 = time.time()
    rng = np.random.default_rng(seed)
    results = {"seed": seed, "smoke": smoke, "interpret_note": INTERP_NOTE,
               "kernels": {}, "decode_step": {}}
    _bench_kernels(out_rows, results, rng, smoke)
    print("  -- decode step: three-dispatch vs single-dispatch (jit XLA) --")
    _decode_step_bench(out_rows, results, rng, smoke)
    common.write_results("kernels.json", results,
                         config="smoke" if smoke else "full", seed=seed,
                         t0=t0)
    print(f"  wrote {os.path.normpath(OUT_PATH)}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / fewer repeats (CI smoke matrix)")
    args = ap.parse_args()
    rows = []
    run(rows, seed=args.seed, smoke=args.smoke)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
