"""Fig. 8: PCIe bandwidth/bytes — Base (on-demand) vs BuddyMoE.

The paper reports ~20% lower PCIe read traffic for BuddyMoE because buddy
hits stay inside GPU memory. We decode the same token stream under both
policies at c=0.5 and compare ledger bytes.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks import common
from repro.core import BuddyPolicy
from repro.runtime.cache import ExpertCache
from repro.serving.engine import ServeEngine

STEPS = 24
BATCH = 4


def _bytes_for(cfg, params, lm, tables, policy, rate=0.5):
    from repro.configs.deepseek_v2_lite_buddy import CONFIG as FULL_DS
    from repro.runtime.prefetch import PrevStepPredictor
    eng = ServeEngine(cfg, params, tables=tables, policy=policy,
                      cache=ExpertCache(cfg.num_layers, cfg.moe.num_experts,
                                        rate, seed=2), seed=2,
                      predictor=PrevStepPredictor(cfg.num_layers,
                                                  cfg.moe.num_experts),
                      prefetch_k=4, latency_cfg=FULL_DS)
    eng.generate(lm.sample(BATCH, 4), max_new_tokens=STEPS)
    return eng.ledger.summary(), eng.stats


def run(out_rows):
    cfg, params, lm = common.get_model()
    rec, q = common.get_profile(cfg, params, lm)
    tables = common.get_tables(cfg, q, rec, 0.95, 16)

    t0 = time.time()
    base_led, base_stats = _bytes_for(cfg, params, lm, tables,
                                      BuddyPolicy(mode="none"))
    # bounded policy (rho=3, TAE-gated, fetch fallback) — the paper's
    # deployed setting; unbounded substitution would trivially reach -100%
    buddy_led, buddy_stats = _bytes_for(
        cfg, params, lm, tables, BuddyPolicy(tau=0.2, beta=0.6, rho=3, H=16))
    us = (time.time() - t0) * 1e6 / (2 * STEPS)

    b0, b1 = base_led["total_bytes"], buddy_led["total_bytes"]
    reduction = 1.0 - b1 / max(b0, 1)
    res = {
        "base_bytes": b0, "buddy_bytes": b1, "reduction": reduction,
        "base_sync_stall_s": base_led["sync_stall_s"],
        "buddy_sync_stall_s": buddy_led["sync_stall_s"],
        "base_stall_breakdown": base_led["stall_breakdown"],
        "buddy_stall_breakdown": buddy_led["stall_breakdown"],
        "buddy_subs": buddy_stats.n_sub,
        "buddy_late_prefetches": buddy_stats.n_late_prefetch,
    }
    print(f"  PCIe bytes: base {b0/1e6:.1f}MB buddy {b1/1e6:.1f}MB "
          f"(-{reduction:.1%}); stalls {base_led['sync_stall_s']:.3f}s -> "
          f"{buddy_led['sync_stall_s']:.3f}s")
    for tag, led in (("base", base_led), ("buddy", buddy_led)):
        bd = led["stall_breakdown"]
        print(f"    {tag}: demand {bd['demand_stall_s']:.3f}s  "
              f"late-prefetch {bd['late_prefetch_stall_s']:.3f}s  "
              f"overlapped {bd['overlapped_s']:.3f}s")
    out_rows.append(("pcie.reduction", us, f"{reduction:.4f}"))
    common.write_results("pcie.json", res, config="pcie", seed=0, t0=t0)
    return res
