"""Tables 2-4: accuracy vs throughput at cache rates c in {0.75, 0.50, 0.375}.

Methods: Original (on-demand, lossless), Random substitution, BuddyMoE at
several (alpha -> |B|, rho) settings — mirroring the paper's sweep. Accuracy
is eval quality on held-out synthetic data: cross-entropy and top-1 agreement
with the full-residency model (ARC needs pretrained weights; DESIGN.md §7).
Throughput is the modeled tokens/s from the transfer ledger + compute model.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import BuddyPolicy, make_random_table
from repro.core.buddies import BuddyTables
from repro.runtime.cache import ExpertCache
from repro.serving.engine import ServeEngine

CACHE_RATES = [0.75, 0.50, 0.375]
EVAL_BATCH, EVAL_LEN = 4, 24


def _random_tables(cfg, k_max=16):
    rt, rq = make_random_table(jax.random.PRNGKey(7), cfg.moe.num_experts,
                               k_max)
    return BuddyTables(
        table=np.tile(np.asarray(rt)[None], (cfg.num_layers, 1, 1)),
        q=np.tile(np.asarray(rq)[None], (cfg.num_layers, 1, 1)),
        sizes=np.full((cfg.num_layers, cfg.moe.num_experts), k_max, np.int32))


def _run_method(cfg, params, lm, tables, policy, rate, eval_data, ref_top1):
    from repro.configs.deepseek_v2_lite_buddy import CONFIG as FULL_DS
    eng = ServeEngine(cfg, params, tables=tables, policy=policy,
                      cache=ExpertCache(cfg.num_layers, cfg.moe.num_experts,
                                        rate, seed=1), seed=1,
                      latency_cfg=FULL_DS)
    b, s = eval_data.shape
    caches = eng.init_caches(b, s)
    nll, n, agree = 0.0, 0, 0
    import jax.numpy as jnp
    for pos in range(s - 1):
        logits, caches = eng.step(jnp.asarray(eval_data[:, pos]), caches, pos)
        lp = np.asarray(jax.nn.log_softmax(logits.astype(jnp.float32), -1))
        tgt = eval_data[:, pos + 1]
        nll += float(-np.take_along_axis(lp, tgt[:, None], 1).sum())
        agree += int((lp.argmax(-1) == ref_top1[:, pos]).sum())
        n += b
    return {
        "nll": nll / n,
        "top1_agree": agree / n,
        "tokens_per_s": eng.stats.tokens_per_s,
        "n_sub": eng.stats.n_sub,
        "n_miss_fetch": eng.stats.n_miss_fetch,
        "pcie_bytes": eng.ledger.total_bytes,
        "stall_breakdown": eng.stall_breakdown(),
    }


def run(out_rows):
    t0_all = time.time()
    cfg, params, lm = common.get_model()
    rec, q = common.get_profile(cfg, params, lm)
    sims = common.get_sims(cfg, params, lm)
    eval_data = lm.sample(EVAL_BATCH, EVAL_LEN)

    # reference top-1 from the full-residency model
    from repro.models import transformer
    import jax.numpy as jnp
    ref_logits, _ = jax.jit(
        lambda p, t: transformer.forward_train(p, cfg, t))(
            params, jnp.asarray(eval_data))
    ref_top1 = np.asarray(ref_logits.argmax(-1))

    t95 = common.get_tables(cfg, q, rec, 0.95, 16, output_sim=sims)
    methods = [
        ("original", None, BuddyPolicy(mode="none")),
        ("random", _random_tables(cfg),
         BuddyPolicy(tau=0.05, beta=1.1, rho=6, H=16, fallback="drop")),
        ("buddy_a0.75_B4",
         common.get_tables(cfg, q, rec, 0.75, 4, output_sim=sims),
         BuddyPolicy(tau=0.05, beta=1.1, rho=6, H=4, fallback="drop")),
        ("buddy_a0.95_B16", t95,
         BuddyPolicy(tau=0.05, beta=1.1, rho=6, H=16, fallback="drop")),
        ("buddy_a0.95_B16_rho3", t95,
         BuddyPolicy(tau=0.05, beta=1.1, rho=3, H=16)),
        ("buddy_a0.95_B16_rho4", t95,
         BuddyPolicy(tau=0.05, beta=1.1, rho=4, H=16)),
    ]

    results = {}
    for rate in CACHE_RATES:
        for name, tables, pol in methods:
            t0 = time.time()
            r = _run_method(cfg, params, lm, tables, pol, rate, eval_data,
                            ref_top1)
            key = f"tables.c{rate}.{name}"
            results[key] = r
            out_rows.append((key, (time.time() - t0) * 1e6 / (EVAL_LEN - 1),
                             f"nll={r['nll']:.4f};agree={r['top1_agree']:.3f};"
                             f"tps={r['tokens_per_s']:.1f}"))
            print(f"  c={rate} {name:22s} nll {r['nll']:.4f} "
                  f"agree {r['top1_agree']:.3f} t/s {r['tokens_per_s']:8.1f} "
                  f"sub {r['n_sub']:4d} fetch {r['n_miss_fetch']:4d}")
    common.write_results("tables234.json", results, config="tables234",
                         seed=0, t0=t0_all)
    return results
