"""Ablations: (a) the (tau, beta, H) deployment trade-off (§3.4) and
(b) prefetcher comparison + BuddyMoE complementarity (§2.3 / Table 1).

(a) sweeps each gate knob at c=0.5 and reports substitution counts, sync
fetches and agreement — conservative settings trade transfers for accuracy,
exactly the §3.4 'deployment-time trade-offs' table.

(b) measures prefetch hit-rates for the §2.3 predictor families
(frequency-based, temporal, cross-layer gate signals) and shows BuddyMoE
stacking on TOP of a prefetcher: residual misses after prefetching are the
ones substitution absorbs.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import BuddyPolicy
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.prefetch import (CrossLayerPredictor, NoisyOraclePredictor,
                                    PrevStepPredictor, TopFreqPredictor)
from repro.serving.engine import ServeEngine


def _engine(cfg, params, tables, policy, rate=0.5, predictor=None,
            prefetch_k=0, seed=3):
    from repro.configs.deepseek_v2_lite_buddy import CONFIG as FULL_DS
    return ServeEngine(cfg, params, tables=tables, policy=policy,
                       cache=ExpertCache(cfg.num_layers, cfg.moe.num_experts,
                                         rate, seed=seed),
                       predictor=predictor, prefetch_k=prefetch_k,
                       seed=seed, latency_cfg=FULL_DS)


def _agreement(cfg, params, eng, eval_data, ref_top1):
    b, s = eval_data.shape
    caches = eng.init_caches(b, s)
    agree, n = 0, 0
    for pos in range(s - 1):
        logits, caches = eng.step(jnp.asarray(eval_data[:, pos]), caches, pos)
        agree += int((np.asarray(logits).argmax(-1) == ref_top1[:, pos]).sum())
        n += b
    return agree / n


def run(out_rows):
    cfg, params, lm = common.get_model()
    rec, q = common.get_profile(cfg, params, lm)
    sims = common.get_sims(cfg, params, lm)
    tables = common.get_tables(cfg, q, rec, 0.95, 16, output_sim=sims)
    eval_data = lm.sample(3, 16)
    ref_logits, _ = jax.jit(lambda p, t: transformer.forward_train(p, cfg, t))(
        params, jnp.asarray(eval_data))
    ref_top1 = np.asarray(ref_logits.argmax(-1))
    res = {}
    t0_all = time.time()

    # ---- (a) gate knob sweeps ----
    t0 = time.time()
    print("  -- gate ablation (c=0.5) --")
    sweeps = ([("tau", tau, BuddyPolicy(tau=tau, beta=1.1, rho=4, H=16))
               for tau in (0.0, 0.5, 0.9, 1.0)]
              + [("beta", beta, BuddyPolicy(tau=0.05, beta=beta, rho=4, H=16))
                 for beta in (0.2, 0.6, 1.1)]
              + [("H", h, BuddyPolicy(tau=0.05, beta=1.1, rho=4, H=h))
                 for h in (1, 4, 16)])
    for knob, val, pol in sweeps:
        eng = _engine(cfg, params, tables, pol)
        agree = _agreement(cfg, params, eng, eval_data, ref_top1)
        key = f"ablation.{knob}={val}"
        res[key] = {"agree": agree, "n_sub": eng.stats.n_sub,
                    "n_fetch": eng.stats.n_miss_fetch,
                    "tps": eng.stats.tokens_per_s}
        print(f"    {knob}={val:<4}: agree {agree:.3f} sub "
              f"{eng.stats.n_sub:4d} fetch {eng.stats.n_miss_fetch:4d} "
              f"t/s {eng.stats.tokens_per_s:7.1f}")
    out_rows.append(("ablation.gates", (time.time() - t0) * 1e6 / len(sweeps),
                     "see bench/ablation.json"))

    # monotonicity sanity: tau=1 means no substitutions
    assert res["ablation.tau=1.0"]["n_sub"] == 0
    assert res["ablation.beta=0.2"]["n_sub"] <= res["ablation.beta=1.1"]["n_sub"]

    # ---- (b) prefetchers + complementarity ----
    t0 = time.time()
    print("  -- prefetchers (c=0.5, k=16) --")
    l_n, e_n = cfg.num_layers, cfg.moe.num_experts
    preds = {
        "none": None,
        "topfreq": TopFreqPredictor(l_n, e_n),
        "prevstep": PrevStepPredictor(l_n, e_n),
        "crosslayer": CrossLayerPredictor(l_n, e_n),
        "oracle90": NoisyOraclePredictor(l_n, e_n, accuracy=0.9),
    }
    for name, pred in preds.items():
        for policy_name, pol in [("original", BuddyPolicy(mode="none")),
                                 ("buddy", BuddyPolicy(tau=0.05, beta=1.1,
                                                       rho=4, H=16))]:
            eng = _engine(cfg, params, tables, pol,
                          predictor=pred.__class__(l_n, e_n)
                          if pred is not None else None,
                          prefetch_k=16 if pred is not None else 0)
            eng.generate(lm.sample(2, 4), max_new_tokens=10)
            key = f"prefetch.{name}.{policy_name}"
            res[key] = {"sync_fetches": eng.stats.n_miss_fetch,
                        "subs": eng.stats.n_sub,
                        "pcie_bytes": eng.ledger.total_bytes,
                        "tps": eng.stats.tokens_per_s}
            print(f"    {name:10s}+{policy_name:8s}: fetches "
                  f"{eng.stats.n_miss_fetch:4d} subs {eng.stats.n_sub:4d} "
                  f"bytes {eng.ledger.total_bytes/1e6:7.1f}MB "
                  f"t/s {eng.stats.tokens_per_s:7.1f}")
    out_rows.append(("ablation.prefetchers", (time.time() - t0) * 1e6 / 10,
                     "see bench/ablation.json"))

    common.write_results("ablation.json", res, config="ablation", seed=0,
                         t0=t0_all)
    return res
