"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus human-readable sections).

  bench_latency   Table 1   miss-latency scenarios
  bench_tables    Tables 2-4  accuracy vs throughput at c={.75,.5,.375}
  bench_skew      Fig. 6    uneven expert activation
  bench_coact     Figs. 7/9 co-activation structure + CFT compactness
  bench_pcie      Fig. 8    PCIe bytes: base vs BuddyMoE
  bench_kernels   (impl)    Pallas kernel microbenches
  bench_roofline  §Roofline dry-run derived terms
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_ablation, bench_coact, bench_kernels,
                            bench_latency, bench_pcie, bench_roofline,
                            bench_skew, bench_tables)
    sections = [
        ("Table 1 (latency scenarios)", bench_latency),
        ("Fig. 6 (activation skew)", bench_skew),
        ("Figs. 7/9 (co-activation)", bench_coact),
        ("Fig. 8 (PCIe bytes)", bench_pcie),
        ("Tables 2-4 (accuracy vs throughput)", bench_tables),
        ("Ablations (gates / prefetchers)", bench_ablation),
        ("Kernels", bench_kernels),
        ("Roofline (dry-run)", bench_roofline),
    ]
    rows = []
    failed = []
    for title, mod in sections:
        print(f"\n=== {title} ===")
        try:
            mod.run(rows)
        except Exception as e:  # noqa: BLE001
            failed.append((title, repr(e)))
            traceback.print_exc(limit=4)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        print(f"\nFAILED sections: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
