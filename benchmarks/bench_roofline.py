"""Roofline table from the dry-run artifacts (results/dryrun/*.json) —
the §Roofline section generator."""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def load_reports(mesh: str = "16x16"):
    reps = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json"))):
        with open(fn) as f:
            reps.append(json.load(f))
    return reps


def run(out_rows):
    reps = load_reports()
    if not reps:
        print("  (no dry-run artifacts found — run repro.launch.dryrun)")
        return {}
    print(f"  {'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
          f"{'coll':>10s}  dominant    useful")
    for r in reps:
        ro = r["roofline"]
        print(f"  {ro['arch']:24s} {ro['shape']:12s} "
              f"{ro['compute_s']*1e3:8.2f}ms {ro['memory_s']*1e3:8.2f}ms "
              f"{ro['collective_s']*1e3:8.2f}ms  {ro['dominant']:10s} "
              f"{ro['useful_flop_ratio']:6.1%}")
        out_rows.append((
            f"roofline.{ro['arch']}.{ro['shape']}",
            ro["compute_s"] * 1e6,
            f"mem_us={ro['memory_s']*1e6:.0f};coll_us="
            f"{ro['collective_s']*1e6:.0f};dom={ro['dominant']}"))
    doms = {}
    for r in reps:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
    print(f"  dominant-term histogram: {doms}")
    return {"count": len(reps), "dominant_histogram": doms}
