"""Shared benchmark substrate: a small trained DeepSeek-V2-Lite-family MoE
(64 experts, top-6 — the paper's §5.1 routing regime), its co-activation
profile and CFT buddy tables. Trained once and cached on disk."""
from __future__ import annotations

import json
import os
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.deepseek_v2_lite_buddy import profiling
from repro.core import CoactivationRecorder, build_buddy_lists
from repro.core.buddies import BuddyTables, load_tables, save_tables
from repro.checkpoint.io import load_pytree, save_pytree
from repro.models import transformer
from repro.training.data import MarkovLM
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
TRAIN_STEPS = 400


def get_model(verbose: bool = True):
    """Returns (cfg, params, lm). Trains ~TRAIN_STEPS steps once, then caches."""
    os.makedirs(CACHE_DIR, exist_ok=True)
    cfg = profiling()
    lm = MarkovLM(cfg.vocab_size, num_blocks=8, seed=0)
    ckpt = os.path.join(CACHE_DIR, "model.npz")
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    if os.path.exists(ckpt):
        params = load_pytree(ckpt, params)
    else:
        t0 = time.time()
        opt = AdamWConfig(lr=2e-3, total_steps=TRAIN_STEPS, warmup_steps=10)
        params, hist = train(cfg, opt, lm.batches(8, 64, TRAIN_STEPS),
                             log_every=20,
                             log_fn=print if verbose else lambda s: None)
        if verbose:
            print(f"[bench] trained {TRAIN_STEPS} steps in "
                  f"{time.time() - t0:.0f}s, "
                  f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
        save_pytree(ckpt, params)
    return cfg, params, lm


def get_profile(cfg, params, lm, steps: int = 8, verbose: bool = True):
    """Returns (recorder, q [L,E,E]). Cached on disk."""
    path = os.path.join(CACHE_DIR, "coact.npz")
    if os.path.exists(path):
        rec = CoactivationRecorder.load(path)
    else:
        rec = CoactivationRecorder(cfg.num_layers, cfg.moe.num_experts)
        fwd = jax.jit(lambda p, t: transformer.forward_train(p, cfg, t,
                                                             record=True))
        for i in range(steps):
            _, aux = fwd(params, jnp.asarray(lm.sample(8, 64)))
            per = aux["recorded"][0]
            for l in range(cfg.num_layers):
                rec.update(l, np.asarray(per["indices"][l]),
                           np.asarray(per["probs"][l]))
            rec.step_done()
        rec.save(path)
    q = np.stack([rec.conditional(l) for l in range(cfg.num_layers)])
    return rec, q


def get_sims(cfg, params, lm):
    """[L, E, E] expert output-similarity matrices (cached)."""
    from repro.core.similarity import all_layer_similarities
    path = os.path.join(CACHE_DIR, "sims.npy")
    if os.path.exists(path):
        return np.load(path)
    sims = all_layer_similarities(cfg, params, jnp.asarray(lm.sample(4, 64)))
    np.save(path, sims)
    return sims


def get_tables(cfg, q, rec, alpha: float, k_max: int,
               output_sim=None) -> BuddyTables:
    tag = "s" if output_sim is not None else ""
    path = os.path.join(CACHE_DIR, f"tables_a{alpha}_k{k_max}{tag}.npz")
    if os.path.exists(path):
        return load_tables(path)
    t = build_buddy_lists(q, alpha=alpha, k_max=k_max, activity=rec.A,
                          output_sim=output_sim)
    save_tables(path, t)
    return t


def git_sha() -> str:
    """Short commit SHA of the working tree: git first, the CI-provided
    GITHUB_SHA as fallback (artifact-only checkouts), else 'unknown'."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return os.environ.get("GITHUB_SHA", "unknown")[:12] or "unknown"


def write_results(filename: str, results: dict, *, config: str = "",
                  seed=None, t0=None) -> str:
    """Provenance-stamped bench-result writer: every ``results/bench/*.json``
    goes through here so each file records WHERE it came from — git SHA,
    config/arm name, seed, and the bench's wall-clock duration (``t0`` from
    ``time.time()`` at run start). Returns the written path."""
    results = dict(results)
    results["provenance"] = {
        "git_sha": git_sha(),
        "config": config,
        "seed": seed,
        "wall_s": round(time.time() - t0, 3) if t0 is not None else None,
        "bench": os.path.basename(filename),
    }
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, filename)
    with open(path, "w") as f:
        json.dump(results, f, indent=2, default=str)
    return path


def timer(fn, *args, repeats: int = 5, warmup: int = 1):
    """Median wall time per call in microseconds (CPU functional timing)."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r) if hasattr(r, "block_until_ready") or \
            isinstance(r, (tuple, list)) else None
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, r)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)
