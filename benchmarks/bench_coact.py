"""Figs. 7/9: expert co-activation structure — sparse, concentrated pairs.

Reports, per layer: top-r coverage of q_{j|i} (r = 4, 8), matrix sparsity
(share of mass in the densest 10% of cells), and buddy-list size stats at
the paper's alpha settings (compactness check, §3.3).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from repro.core.buddies import list_size_stats


def run(out_rows):
    cfg, params, lm = common.get_model()
    t0 = time.time()
    rec, q = common.get_profile(cfg, params, lm)
    res = {}
    for l in range(cfg.num_layers):
        ql = q[l]
        flat = np.sort(ql.reshape(-1))[::-1]
        top10 = flat[:max(1, len(flat) // 10)].sum() / max(flat.sum(), 1e-30)
        res[f"layer{l}"] = {
            "top4_coverage_mean": float(rec.topr_coverage(l, 4).mean()),
            "top8_coverage_mean": float(rec.topr_coverage(l, 8).mean()),
            "mass_in_top10pct_cells": float(top10),
        }
        print(f"  layer {l}: top4 cover {res[f'layer{l}']['top4_coverage_mean']:.3f} "
              f"top8 {res[f'layer{l}']['top8_coverage_mean']:.3f} "
              f"top-10%-cells mass {top10:.3f} (uniform = 0.10)")
    for alpha, kmax in [(0.75, 4), (0.95, 16)]:
        t = common.get_tables(cfg, q, rec, alpha, kmax)
        res[f"list_sizes_a{alpha}"] = list_size_stats(t)
        print(f"  buddy-list sizes @alpha={alpha}: {list_size_stats(t)}")
    cov = float(np.mean([rec.topr_coverage(l, 8).mean()
                         for l in range(cfg.num_layers)]))
    out_rows.append(("coact.top8_coverage", (time.time() - t0) * 1e6,
                     f"{cov:.4f}"))
    common.write_results("coact.json", res, config="coact", seed=0,
                         t0=t0)
    return res
