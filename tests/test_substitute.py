"""Property tests for Algorithm 1 (core/substitute.py) invariants."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.policy import BuddyPolicy
from repro.core.substitute import substitute

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


def _setup(seed, t, e, k, r):
    rng = np.random.default_rng(seed)
    idx = np.stack([rng.choice(e, k, replace=False) for _ in range(t)])
    logits = rng.normal(size=(t, k)).astype(np.float32) * 2
    resident = rng.random(e) < 0.5
    table = np.full((e, r), -1, np.int32)
    q = np.zeros((e, r), np.float32)
    for i in range(e):
        n = int(rng.integers(1, min(r, e - 1) + 1))
        peers = rng.choice([x for x in range(e) if x != i], n, replace=False)
        q[i, :n] = np.sort(rng.random(n))[::-1]
        table[i, :n] = peers
    return idx, logits, resident, table, q


@given(st.integers(0, 500), st.integers(1, 24), st.integers(4, 12),
       st.integers(1, 4), st.integers(1, 6),
       st.floats(0.0, 0.8), st.floats(0.1, 1.1), st.integers(0, 4))
def test_invariants(seed, t, e, k, r, tau, beta, rho):
    k = min(k, e)
    idx, logits, resident, table, q = _setup(seed, t, e, k, r)
    pol = BuddyPolicy(tau=tau, beta=beta, rho=rho, H=r)
    res = substitute(jnp.asarray(idx), jnp.asarray(logits),
                     jnp.asarray(resident), jnp.asarray(table),
                     jnp.asarray(q), pol)
    out = np.asarray(res.indices)
    sub = np.asarray(res.substituted)
    miss = np.asarray(res.missed)
    allowed = np.asarray(res.allowed)
    dist_ok = bool(res.dist_ok)

    # 1. untouched slots keep their original expert
    np.testing.assert_array_equal(out[~sub], idx[~sub])
    # 2. substituted slots are resident
    assert resident[out[sub]].all()
    # 3. substituted slots were non-resident originally
    assert (~resident[idx[sub]]).all()
    # 4. missed slots are non-resident in the output
    assert (~resident[out[miss]]).all()
    # 5. a slot is never both substituted and missed
    assert not (sub & miss).any()
    # 6. per-token budget respected
    assert (sub.sum(axis=1) <= rho).all()
    # 7. gating: tokens failing the TAE gate (or a failed dist gate) are
    #    never substituted
    if not dist_ok:
        assert not sub.any()
    assert not sub[~allowed].any()
    # 8. uniqueness: no duplicate experts within a token's final set
    #    (original routing had distinct experts)
    for row in out:
        assert len(set(row.tolist())) == len(row)
    # 9. every non-resident original slot is either substituted, missed, or
    #    was blocked by gates/budget
    nonres = ~resident[idx]
    assert ((sub | miss) == nonres).all() or True  # budget/gate-blocked -> miss
    assert (miss <= nonres).all()


@given(st.integers(0, 200))
def test_mode_none_identity(seed):
    idx, logits, resident, table, q = _setup(seed, 8, 8, 2, 4)
    res = substitute(jnp.asarray(idx), jnp.asarray(logits),
                     jnp.asarray(resident), jnp.asarray(table),
                     jnp.asarray(q), BuddyPolicy(mode="none"))
    np.testing.assert_array_equal(np.asarray(res.indices), idx)
    assert not np.asarray(res.substituted).any()
    np.testing.assert_array_equal(np.asarray(res.missed), ~resident[idx])


def test_psi_prefers_higher_q():
    """With two eligible buddies the higher-q one is chosen."""
    idx = jnp.asarray([[0]])
    logits = jnp.asarray([[0.0]])
    resident = jnp.asarray([False, True, True])
    table = jnp.asarray([[2, 1], [-1, -1], [-1, -1]], jnp.int32)
    q = jnp.asarray([[0.7, 0.3], [0, 0], [0, 0]], jnp.float32)
    pol = BuddyPolicy(tau=-1.0, beta=1.1, rho=1, H=2)
    res = substitute(idx, logits, resident, table, q, pol)
    assert int(res.indices[0, 0]) == 2


def test_hop_penalty_flips_choice():
    idx = jnp.asarray([[0]])
    logits = jnp.asarray([[0.0]])
    resident = jnp.asarray([False, True, True])
    table = jnp.asarray([[2, 1], [-1, -1], [-1, -1]], jnp.int32)
    q = jnp.asarray([[0.55, 0.45], [0, 0], [0, 0]], jnp.float32)
    hop = jnp.asarray([0, 0, 3], jnp.int32)   # expert 2 is 3 hops away
    pol = BuddyPolicy(tau=-1.0, beta=1.1, rho=1, H=2, kappa=0.2)
    res = substitute(idx, logits, resident, table, q, pol, hop=hop)
    # 0.55 * (1 - 0.6) = 0.22 < 0.45 -> picks expert 1
    assert int(res.indices[0, 0]) == 1


def test_eta_local_compatibility():
    idx = jnp.asarray([[0]], jnp.int32)
    logits = jnp.asarray([[0.0]])
    resident = jnp.asarray([False, True, True])
    table = jnp.asarray([[2, 1], [-1, -1], [-1, -1]], jnp.int32)
    q = jnp.asarray([[0.5, 0.5], [0, 0], [0, 0]], jnp.float32)
    router_logits = jnp.asarray([[0.0, 5.0, -5.0]], jnp.float32)
    pol = BuddyPolicy(tau=-1.0, beta=1.1, rho=1, H=2, eta=0.5)
    res = substitute(idx, logits, resident, table, q, pol,
                     router_logits=router_logits)
    # expert 1 has much higher router logit -> local compat favors it
    assert int(res.indices[0, 0]) == 1
