"""Paged KV + radix-tree prefix cache.

Four contracts:

  * OFF-KNOB BIT-IDENTITY — ``paged_kv=False`` is the exact pre-refactor
    ring-buffer engine: the frozen serving scenario in
    ``tests/data/pre_paged_serving.json`` (written before the paged path
    existed) must match byte-for-byte, token-by-token AND chunked.
  * PAGED-VS-RING PARITY — the paged read/write path computes the same
    attention: ``generate`` emits identical tokens, prefill logits agree.
  * PREFIX-HIT EXACTNESS — admitting by adopting cached prefix blocks and
    prefilling only the novel suffix yields BITWISE-identical logits to
    recomputing the whole prompt (adopted KV is the same values the row
    would have written; CoW keeps tree contents frozen).
  * ALLOCATOR/TREE INVARIANTS — property tests over random request
    lifecycles: refcount conservation (row tables + tree listings),
    free-list consistency, no leaks after retire/preempt/evict.
"""
import json
import os
from types import SimpleNamespace

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.paged_kv import PagedKVPool
from repro.runtime.prefetch import PrefetchBudget
from repro.runtime.telemetry import Telemetry
from repro.serving.engine import ServeEngine
from repro.serving.prefix import PrefixTree
from repro.serving.scheduler import (ContinuousScheduler, RequestQueue,
                                     SLOConfig, ServeRequest)

from tests._paged_golden import GOLDEN_PATH, golden_summary

settings.register_profile("paged", max_examples=12)
settings.load_profile("paged")


def _cfg_params():
    cfg = reduced()
    return cfg, transformer.init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, *, paged: bool, prefix: bool = False,
            kv_block: int = 8, cache_rate: float = 1.0) -> ServeEngine:
    l, e = cfg.num_layers, cfg.moe.num_experts
    return ServeEngine(cfg, params,
                       cache=ExpertCache(l, e, cache_rate, seed=0), seed=0,
                       paged_kv=paged, kv_block=kv_block,
                       prefix_cache=prefix)


# ===========================================================================
# off-knob bit-identity vs the frozen pre-refactor capture
# ===========================================================================
def test_off_knob_bit_identical_to_frozen_capture():
    """paged_kv=False must BE the pre-paged engine — the committed golden
    summaries (written at the commit before this subsystem landed) match
    byte-for-byte for both serving loops."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    for c in (1, 4):
        got = golden_summary(c, paged_kv=False, prefix_cache=False)
        assert got == golden[f"chunk{c}"], (
            f"ring path diverged from the pre-paged capture at chunk={c}")


# ===========================================================================
# paged vs ring numerical parity
# ===========================================================================
def test_paged_generate_matches_ring():
    cfg, params = _cfg_params()
    rng = np.random.default_rng(5)
    prompts = rng.integers(0, cfg.vocab_size, (3, 9))
    out_ring = _engine(cfg, params, paged=False).generate(
        prompts, max_new_tokens=6)
    out_paged = _engine(cfg, params, paged=True).generate(
        prompts, max_new_tokens=6)
    assert np.array_equal(np.asarray(out_ring), np.asarray(out_paged))


def test_paged_prefill_logits_match_ring():
    cfg, params = _cfg_params()
    rng = np.random.default_rng(6)
    b, n, c = 2, 12, 4
    prompts = rng.integers(0, cfg.vocab_size, (b, n))
    active = np.ones(b, bool)
    logits = {}
    for paged in (False, True):
        eng = _engine(cfg, params, paged=paged)
        caches = eng.init_caches(b, n)
        out = []
        for s in range(0, n, c):
            toks = jnp.asarray(prompts[:, s:s + c], jnp.int32)
            lg, caches = eng.prefill_rows(
                toks, active, caches,
                base_pos=np.full(b, s, np.int32),
                tok_valid=np.ones((b, c), bool))
            out.append(np.asarray(lg))
        logits[paged] = np.concatenate(out, axis=1)
    assert np.max(np.abs(logits[True] - logits[False])) < 1e-5


# ===========================================================================
# prefix-hit admission: bitwise-identical logits vs full recompute
# ===========================================================================
def _chunked_prefill_row(eng, caches, row, b, prompt, start, chunk=4):
    """Feed prompt[start:] into ``row`` in fused chunks; returns (caches,
    last-token logits)."""
    last = None
    pos = start
    n = len(prompt)
    while pos < n:
        c = min(chunk, n - pos)
        toks = np.zeros((b, chunk), np.int64)
        valid = np.zeros((b, chunk), bool)
        toks[row, :c] = prompt[pos:pos + c]
        valid[row, :c] = True
        active = np.zeros(b, bool)
        active[row] = True
        base = np.zeros(b, np.int32)
        base[row] = pos
        lg, caches = eng.prefill_rows(jnp.asarray(toks, jnp.int32), active,
                                      caches, base_pos=base, tok_valid=valid)
        last = np.asarray(lg[row, c - 1])
        pos += c
    return caches, last


def test_prefix_hit_logits_bitwise_equal_recompute():
    cfg, params = _cfg_params()
    rng = np.random.default_rng(9)
    b, cap, bs = 2, 32, 8
    donor = rng.integers(0, cfg.vocab_size, 24)          # 3 full blocks
    adopter = np.concatenate([donor[:20],
                              rng.integers(0, cfg.vocab_size, 4)])

    # arm 1: donor prefills + donates; adopter admits via the radix tree
    eng = _engine(cfg, params, paged=True, prefix=True, kv_block=bs)
    caches = eng.init_caches(b, cap)
    caches, _ = _chunked_prefill_row(eng, caches, 0, b, donor, 0)
    eng.insert_prefix(0, donor)
    m = eng.adopt_prefix(1, adopter)
    assert m == 20, f"expected a 20-token prefix hit, got {m}"
    caches, lg_hit = _chunked_prefill_row(eng, caches, 1, b, adopter, m)
    assert eng.kv_pool.cow_copies >= 1     # shared mid-fill block was CoW'd

    # arm 2: identical engine recomputes the whole adopter prompt
    eng2 = _engine(cfg, params, paged=True, prefix=True, kv_block=bs)
    caches2 = eng2.init_caches(b, cap)
    caches2, lg_cold = _chunked_prefill_row(eng2, caches2, 1, b, adopter, 0)

    assert np.array_equal(lg_hit, lg_cold), (
        "prefix-hit admission must be bitwise-identical to full recompute")
    # the donated chain is still intact in the tree
    eng.kv_pool.check(eng.prefix_tree.block_holders())


# ===========================================================================
# end-to-end: scheduler admission, preemption, telemetry
# ===========================================================================
def _session_requests(cfg, rng, slo):
    base = rng.integers(0, cfg.vocab_size, 16)
    reqs = []
    for i in range(4):
        p = np.concatenate([base, rng.integers(0, cfg.vocab_size, 4 + i)])
        reqs.append(ServeRequest(rid=i, prompt=p.astype(np.int64),
                                 max_new_tokens=3,
                                 arrival_s=i * 5e-3, slo=slo))
    return reqs


def test_scheduler_prefix_admission_and_trace():
    cfg, params = _cfg_params()
    rng = np.random.default_rng(13)
    slo = SLOConfig(ttft_s=0.5, tpot_s=0.05, deadline_s=2.0)
    eng = _engine(cfg, params, paged=True, prefix=True)
    eng.telemetry = Telemetry.with_trace(
        predictor_label="prev_step", num_layers=cfg.num_layers,
        num_experts=cfg.moe.num_experts)
    eng._wire_telemetry()
    cs = ContinuousScheduler(eng, slots=2, prefill_chunk=4)
    s = cs.run(RequestQueue(_session_requests(cfg, rng, slo)))
    assert s["completed"] == 4
    px = s["engine"]["prefix"]
    assert px["hits"] >= 1 and px["hit_tokens"] >= 16
    assert px["tree"]["nodes"] >= 1
    # telemetry: counters + gauges + both trace instants fired
    snap = eng.telemetry.metrics.snapshot()
    assert {"prefix_tokens", "kv_pool_used_blocks",
            "prefix_tree_nodes"} <= set(snap)
    assert sum(snap["prefix_tokens"].values()) == \
        px["hit_tokens"] + px["novel_tokens"]
    kinds = {(e["track"], e["kind"]) for e in eng.telemetry.trace.events}
    assert ("engine", "prefix_hit") in kinds
    assert ("requests", "prefix_hit") in kinds
    # retired rows returned their pages; only tree listings keep blocks
    eng.kv_pool.check(eng.prefix_tree.block_holders())
    assert eng.kv_pool.used_blocks == len(eng.prefix_tree.block_holders())


class _PreemptOnce:
    """Controller stub: preempts the target rid the first step it is seen
    mid-prefill (the AdaptiveBudgetController protocol surface the
    scheduler's _feedback hook calls)."""

    def __init__(self, rid: int):
        self.rid = rid
        self.sched = None
        self.queue = None
        self.fired = False
        self.budget = PrefetchBudget(0, 1, 0)   # summary() snapshots these
        self.trace = []

    def observe_step(self, *a, **k):
        pass

    def apply(self, eng):
        if self.fired:
            return
        s = self.sched
        for i, r in enumerate(s._slot):
            if (r is not None and r.rid == self.rid and not r.tokens
                    and s._pos[i] < len(r.prompt)):
                s.preempt(i, self.queue)
                self.fired = True
                return


def test_preempt_and_readmit_with_warm_prefix():
    cfg, params = _cfg_params()
    rng = np.random.default_rng(17)
    slo = SLOConfig(ttft_s=0.5, tpot_s=0.05, deadline_s=2.0)
    base = rng.integers(0, cfg.vocab_size, 16)
    reqs = [
        ServeRequest(rid=0, prompt=base.astype(np.int64), max_new_tokens=3,
                     arrival_s=0.0, slo=slo),
        # arrives after rid 0 retires (and donates); long enough to still
        # be prefilling after its first fused step
        ServeRequest(rid=1,
                     prompt=np.concatenate(
                         [base, rng.integers(0, cfg.vocab_size, 12)]
                     ).astype(np.int64),
                     max_new_tokens=3, arrival_s=20e-3, slo=slo),
    ]
    eng = _engine(cfg, params, paged=True, prefix=True)
    ctrl = _PreemptOnce(rid=1)
    cs = ContinuousScheduler(eng, slots=2, prefill_chunk=4, controller=ctrl)
    queue = RequestQueue(reqs)
    ctrl.sched, ctrl.queue = cs, queue
    s = cs.run(queue)
    assert ctrl.fired, "the stub never found rid 1 mid-prefill"
    assert s["completed"] == 2
    r1 = next(r for r in cs.completed if r.rid == 1)
    assert r1.prefix_hit_tokens >= 16   # re-admitted against the warm tree
    assert len(r1.tokens) == 3
    eng.kv_pool.check(eng.prefix_tree.block_holders())


def test_effective_chunk_shrinks_under_tpot_pressure():
    sched = ContinuousScheduler(SimpleNamespace(), slots=2,
                                prefill_chunk=8, adaptive_chunk=True)
    decode = ServeRequest(rid=0, prompt=np.arange(4), max_new_tokens=4,
                          arrival_s=0.0,
                          slo=SLOConfig(ttft_s=1.0, tpot_s=0.05))
    joiner = ServeRequest(rid=1, prompt=np.arange(16), max_new_tokens=4,
                          arrival_s=0.0, slo=None)
    slot, pos = [decode, joiner], np.array([4, 0])   # rid 0 is decoding
    sched._est_step_s = 0.16                         # 3.2x the TPOT budget
    assert sched._effective_chunk(slot, pos) == 2    # halved twice
    sched._est_step_s = 0.64
    assert sched._effective_chunk(slot, pos) == 1    # floors at 1
    sched._est_step_s = 0.04
    assert sched._effective_chunk(slot, pos) == 8    # under budget: full
    sched.adaptive_chunk = False
    sched._est_step_s = 0.64
    assert sched._effective_chunk(slot, pos) == 8    # knob off: unchanged
    # no decode rows resident -> nothing to protect
    sched.adaptive_chunk = True
    assert sched._effective_chunk([None, joiner], np.array([0, 0])) == 8


# ===========================================================================
# allocator + radix-tree property tests
# ===========================================================================
def _serve_once(pool, tree, rng, row, vocab, live):
    """One full request lifecycle against the pool/tree pair, mirroring the
    engine: match -> adopt -> CoW-write the suffix -> donate -> maybe keep
    resident (returned in ``live``) or retire immediately."""
    bs = pool.block_size
    n = int(rng.integers(1, pool.max_blocks * bs + 1))
    toks = [int(t) for t in rng.integers(0, vocab, n)]
    m, chain = tree.match(toks, cap=n - 1)
    assert m <= n - 1 and len(chain) == -(-m // bs) if m else not chain
    if m:
        pool.adopt(row, chain)
    pool.ensure_range(row, m, n)
    pool.drain_copies()
    covered = (n // bs) * bs
    if covered:
        tree.insert(toks[:covered], pool.row_blocks(row, covered))
    if rng.random() < 0.5:
        pool.free_row(row)
    else:
        live.add(row)


@given(st.integers(0, 2**32 - 1))
def test_pool_tree_refcount_conservation(seed):
    rng = np.random.default_rng(seed)
    bs = int(rng.integers(2, 6))
    batch = int(rng.integers(2, 5))
    max_blocks = int(rng.integers(2, 6))
    pool = PagedKVPool(batch * max_blocks + 8, bs, batch, max_blocks)
    tree = PrefixTree(pool)
    vocab = 3                                  # tiny vocab: prefixes collide
    live = set()
    for _ in range(25):
        free_rows = [r for r in range(batch) if r not in live]
        op = rng.random()
        if op < 0.2 and live:                  # retire a resident row
            r = int(rng.choice(sorted(live)))
            pool.free_row(r)
            live.discard(r)
        elif op < 0.3:
            tree.evict_lru_leaf()
        elif free_rows:
            _serve_once(pool, tree, rng, int(rng.choice(free_rows)),
                        vocab, live)
        pool.check(tree.block_holders())       # every op preserves it
    # teardown: retire everything, evict the tree dry -> zero leaks
    for r in list(live):
        pool.free_row(r)
    while tree.evict_lru_leaf():
        pool.check(tree.block_holders())
    assert tree.n_nodes == 0 and not tree.block_holders()
    assert pool.used_blocks == 0 and pool.free_blocks == pool.n_blocks


@given(st.integers(0, 2**32 - 1))
def test_tree_match_returns_inserted_prefix(seed):
    rng = np.random.default_rng(seed)
    bs = int(rng.integers(2, 5))
    pool = PagedKVPool(64, bs, 4, 8)
    tree = PrefixTree(pool)
    n = int(rng.integers(bs, 8 * bs + 1))
    toks = [int(t) for t in rng.integers(0, 4, n)]
    pool.ensure_range(0, 0, n)
    covered = (n // bs) * bs
    donated = pool.row_blocks(0, covered)
    tree.insert(toks[:covered], donated)
    pool.free_row(0)
    # an identical prompt matches every donated token (cap permitting)
    m, chain = tree.match(toks, cap=n - 1)
    assert m == min(covered, n - 1)
    assert chain == donated[:len(chain)]
    # a prompt diverging at position d matches exactly d tokens
    d = int(rng.integers(0, covered))
    probe = toks[:d] + [(toks[d] + 1) % 4] + toks[d + 1:]
    m2, _ = tree.match(probe, cap=n - 1)
    assert m2 == d
    pool.check(tree.block_holders())


def test_pool_exhaustion_raises_and_eviction_recovers():
    pool = PagedKVPool(2, 4, 2, 4)
    pool.ensure_range(0, 0, 8)                 # both blocks to row 0
    try:
        pool.ensure_range(1, 0, 4)
        assert False, "expected exhaustion"
    except RuntimeError as e:
        assert "exhausted" in str(e)
    # with a tree holding the blocks instead, pressure evicts and recovers
    pool2 = PagedKVPool(2, 4, 2, 4)
    tree = PrefixTree(pool2)
    pool2.ensure_range(0, 0, 8)
    tree.insert([0, 1, 2, 3, 0, 1, 2, 3], pool2.row_blocks(0, 8))
    pool2.free_row(0)
    pool2.ensure_range(1, 0, 8)                # evicts the leaf, reuses
    assert pool2.evictions >= 1 and tree.n_evicted == 1
    pool2.check(tree.block_holders())
