import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

try:  # optional dependency: fall back to fixed, seeded examples
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub
    _hypothesis_stub.install()

import jax  # noqa: E402

# CPU tests must see exactly 1 device (the dry-run subprocess sets its own
# XLA_FLAGS); keep everything deterministic and in f32.
jax.config.update("jax_enable_x64", False)
