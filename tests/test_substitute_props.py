"""Property-style invariants of substitute()'s five-way outcome masks.

The fused dispatch consumes these masks as a PARTITION — every non-resident
routed slot must resolve to exactly one of {substituted, degraded, peered
(peer-HBM borrow), missed (fetch), dropped}, and a substituted slot's final
id must be resident. Checked under both miss_policy='precedence' and 'cost'
over randomized shapes/residency/tables (hypothesis, or the seeded fallback
in tests/_hypothesis_stub.py), plus deterministic tie-break edge cases of
the cost argmin."""
import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.policy import BuddyPolicy
from repro.core.substitute import substitute

settings.register_profile("props", max_examples=25)
settings.load_profile("props")


def _random_case(rng, t, e, k, r):
    idx = np.stack([rng.choice(e, k, replace=False)
                    for _ in range(t)]).astype(np.int32)
    logits = rng.normal(size=(t, k)).astype(np.float32)
    resident = rng.random(e) < rng.uniform(0.1, 0.9)
    table = np.full((e, r), -1, np.int32)
    q = np.zeros((e, r), np.float32)
    for i in range(e):
        n = int(rng.integers(0, min(r, e - 1) + 1))
        if n:
            peers = rng.choice([x for x in range(e) if x != i], n,
                               replace=False)
            table[i, :n] = peers
            q[i, :n] = np.sort(rng.random(n).astype(np.float32))[::-1]
    return idx, logits, resident, table, q


def _masks(res):
    sub = np.asarray(res.substituted)
    missed = np.asarray(res.missed)
    deg = np.asarray(res.degraded)
    drp = (np.asarray(res.dropped) if res.dropped is not None
           else np.zeros_like(missed))
    peer = (np.asarray(res.peered) if res.peered is not None
            else np.zeros_like(missed))
    return sub, missed, deg, drp, peer


def _check_partition(res, idx, resident, rho):
    """The shared invariant block for every drawn case."""
    sub, missed, deg, drp, peer = _masks(res)
    nonres = ~resident[idx]
    # pairwise disjoint
    for i, a in enumerate((sub, missed, deg, drp, peer)):
        for b in (sub, missed, deg, drp, peer)[i + 1:]:
            assert not (a & b).any(), "outcome masks overlap"
    # union covers every non-resident slot and nothing else
    np.testing.assert_array_equal(sub | missed | deg | drp | peer, nonres)
    # substituted => final id is resident; untouched otherwise
    final = np.asarray(res.indices)
    assert resident[final[sub]].all()
    np.testing.assert_array_equal(final[~sub], idx[~sub])
    # degraded / peered / dropped slots keep their TRUE (non-resident) id
    assert (~resident[final[deg]]).all() if deg.any() else True
    assert (~resident[final[peer]]).all() if peer.any() else True
    # the rho budget bounds substitutions per token
    assert (sub.sum(axis=1) <= rho).all()


@given(st.data())
def test_precedence_masks_partition(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31 - 1)))
    t = data.draw(st.integers(1, 12))
    e = data.draw(st.integers(2, 16))
    k = data.draw(st.integers(1, min(4, e)))
    r = data.draw(st.integers(1, 6))
    rho = data.draw(st.integers(0, k))
    with_tier = data.draw(st.booleans())
    with_peer = data.draw(st.booleans())
    idx, logits, resident, table, q = _random_case(rng, t, e, k, r)
    quant_ok = (rng.random(e) < 0.5) if with_tier else None
    peer_ok = (rng.random(e) < 0.5) if with_peer else None
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=rho, H=max(r, 1))
    res = substitute(jnp.asarray(idx), jnp.asarray(logits),
                     jnp.asarray(resident), jnp.asarray(table),
                     jnp.asarray(q), pol,
                     quant_ok=None if quant_ok is None
                     else jnp.asarray(quant_ok),
                     peer_ok=None if peer_ok is None
                     else jnp.asarray(peer_ok))
    _check_partition(res, idx, resident, rho)
    if quant_ok is None:
        assert not np.asarray(res.degraded).any()
    if peer_ok is None:
        assert res.peered is None or not np.asarray(res.peered).any()
    elif np.asarray(res.peered).any():
        # precedence chain: a peered slot is borrowable and NOT degradable
        # (degraded sits earlier in the chain and claims its slots first)
        peer = np.asarray(res.peered)
        assert peer_ok[idx[peer]].all()
        if quant_ok is not None:
            assert not quant_ok[idx[peer]].any()


@given(st.data())
def test_cost_masks_partition(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31 - 1)))
    t = data.draw(st.integers(1, 12))
    e = data.draw(st.integers(2, 16))
    k = data.draw(st.integers(1, min(4, e)))
    r = data.draw(st.integers(1, 6))
    rho = data.draw(st.integers(0, k))
    idx, logits, resident, table, q = _random_case(rng, t, e, k, r)
    # finite fetch always; fid sometimes infinite (no usable replica)
    fetch = rng.uniform(0.0, 0.1, e).astype(np.float32)
    fid = np.where(rng.random(e) < 0.5,
                   rng.uniform(0.0, 0.1, e), np.inf).astype(np.float32)
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=rho, H=max(r, 1),
                      miss_policy="cost",
                      stall_per_quality=float(rng.uniform(0.01, 0.1)),
                      drop_loss=float(rng.uniform(0.0, 2.0)))
    res = substitute(jnp.asarray(idx), jnp.asarray(logits),
                     jnp.asarray(resident), jnp.asarray(table),
                     jnp.asarray(q), pol,
                     fid_cost=jnp.asarray(fid), fetch_cost=jnp.asarray(fetch))
    _check_partition(res, idx, resident, rho)


@given(st.data())
def test_mode_none_masks_partition(data):
    """mode='none' (no rerouting) still partitions misses across the
    degraded tier and the fallback, in both miss policies."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31 - 1)))
    t = data.draw(st.integers(1, 10))
    e = data.draw(st.integers(2, 12))
    k = data.draw(st.integers(1, min(3, e)))
    cost = data.draw(st.booleans())
    idx, logits, resident, table, q = _random_case(rng, t, e, k, 2)
    kw = {}
    if cost:
        pol = BuddyPolicy(mode="none", miss_policy="cost")
        kw = dict(fid_cost=jnp.asarray(
                      rng.uniform(0.0, 0.1, e).astype(np.float32)),
                  fetch_cost=jnp.asarray(
                      rng.uniform(0.0, 0.1, e).astype(np.float32)))
    else:
        pol = BuddyPolicy(mode="none")
        kw = dict(quant_ok=jnp.asarray(rng.random(e) < 0.5))
    res = substitute(jnp.asarray(idx), jnp.asarray(logits),
                     jnp.asarray(resident), jnp.asarray(table),
                     jnp.asarray(q), pol, **kw)
    _check_partition(res, idx, resident, rho=0)
    assert not np.asarray(res.substituted).any()


def _one_slot_cost_case(q_top, fid, fetch, drop_loss=1.0, resident_buddy=True):
    """One token: slot 0 routes to non-resident expert 0 (buddy = expert 1);
    slot 1 routes to resident expert 2 (inert, keeps the TAE gate open —
    a single-slot token has zero activation entropy)."""
    idx = jnp.asarray([[0, 2]], jnp.int32)
    logits = jnp.asarray([[2.0, 0.0]], jnp.float32)
    resident = jnp.asarray([False, resident_buddy, True])
    table = jnp.asarray([[1], [-1], [-1]], jnp.int32)
    q = jnp.asarray([[q_top], [0.0], [0.0]], jnp.float32)
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=1, H=1, miss_policy="cost",
                      stall_per_quality=0.05, drop_loss=drop_loss)
    res = substitute(idx, logits, resident, table, q, pol,
                     fid_cost=jnp.asarray([fid, jnp.inf, jnp.inf],
                                          jnp.float32),
                     fetch_cost=jnp.asarray([fetch] * 3, jnp.float32))
    assert bool(res.allowed.all()), "TAE gate unexpectedly closed"
    # only slot 0 is under test; slot 1 must stay untouched
    for m in _masks(res):
        assert not m[:, 1].any()
    return res


def test_cost_tiebreak_prefers_earlier_outcome():
    """At exactly equal cost the argmin must resolve toward the EARLIER
    outcome: buddy > degraded > fetch > drop (the transfer-free reroute
    wins a tie; fetch beats a lossy drop)."""
    # q=0 -> buddy cost = 0.05 exactly; all four options cost 0.05
    res = _one_slot_cost_case(q_top=0.0, fid=0.05, fetch=0.05, drop_loss=1.0)
    sub, missed, deg, drp, peer = _masks(res)
    assert sub[0, 0] and not (missed | deg | drp)[0, 0]
    # no eligible buddy: degraded wins the three-way tie
    res = _one_slot_cost_case(q_top=0.0, fid=0.05, fetch=0.05,
                              resident_buddy=False)
    sub, missed, deg, drp, peer = _masks(res)
    assert deg[0, 0] and not (sub | missed | drp)[0, 0]
    # no replica either: fetch beats drop at equal cost
    res = _one_slot_cost_case(q_top=0.0, fid=float("inf"), fetch=0.05,
                              resident_buddy=False)
    sub, missed, deg, drp, peer = _masks(res)
    assert missed[0, 0] and not (sub | deg | drp)[0, 0]


def test_cost_strict_preference_overrides_order():
    """A strictly cheaper LATER outcome must win (the tie-break is only a
    tie-break): a nearly-landed prefetch beats a worse buddy."""
    res = _one_slot_cost_case(q_top=0.4, fid=float("inf"), fetch=0.001)
    sub, missed, deg, drp, peer = _masks(res)
    assert missed[0, 0] and not (sub | deg | drp)[0, 0]
    # and an effectively-free drop beats an expensive fetch
    res = _one_slot_cost_case(q_top=0.0, fid=float("inf"), fetch=1.0,
                              drop_loss=0.001, resident_buddy=False)
    sub, missed, deg, drp, peer = _masks(res)
    assert drp[0, 0] and not (sub | missed | deg)[0, 0]
