"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs ref.py oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _buddy_setup(rng, t, e, k, r):
    s = np.stack([rng.choice(e, k, replace=False) for _ in range(t)]).astype(np.int32)
    gate = rng.random(t) < 0.7
    resident = rng.random(e) < 0.5
    table = np.full((e, r), -1, np.int32)
    q = np.zeros((e, r), np.float32)
    for i in range(e):
        n = int(rng.integers(1, r + 1))
        peers = rng.choice([x for x in range(e) if x != i], n, replace=False)
        table[i, :n] = peers
        q[i, :n] = np.sort(rng.random(n))[::-1]
    return s, gate, resident, table, q


@pytest.mark.parametrize("t,e,k,r,h,rho", [
    (1, 4, 1, 2, 2, 1),
    (17, 8, 2, 4, 4, 2),
    (100, 16, 4, 6, 4, 2),
    (256, 64, 6, 16, 8, 3),     # the paper's DeepSeek-V2-Lite regime
    (300, 8, 2, 8, 8, 8),
])
def test_buddy_substitute_sweep(t, e, k, r, h, rho):
    rng = np.random.default_rng(t * 1000 + e)
    s, gate, resident, table, q = _buddy_setup(rng, t, e, k, r)
    got = ops.buddy_substitute(jnp.asarray(s), jnp.asarray(gate),
                               jnp.asarray(resident), jnp.asarray(table),
                               jnp.asarray(q), h=h, rho=rho)
    want = ref.ref_buddy_substitute(s, gate, resident, table, q, h=h, rho=rho)
    for g, w, name in zip(got, want, ("indices", "substituted", "missed")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


@pytest.mark.parametrize("t,e,k", [(1, 4, 1), (64, 8, 2), (300, 64, 6),
                                   (1000, 16, 4)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_topk_gate_sweep(t, e, k, dtype):
    rng = np.random.default_rng(t + e)
    z = rng.normal(size=(t, e)).astype(dtype)
    tau = 0.4
    got = ops.topk_gate(jnp.asarray(z), tau, k=k)
    want = ref.ref_topk_gate(jnp.asarray(z), tau, k=k)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got[3]), np.asarray(want[3]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(got[4]), np.asarray(want[4]))


@pytest.mark.parametrize("e,c,d,f,bc,bf", [
    (1, 8, 32, 64, 8, 32),
    (4, 96, 128, 384, 32, 128),
    (8, 100, 64, 200, 64, 64),    # non-divisible c/f -> padding path
])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                       (jnp.bfloat16, 5e-2)])
def test_expert_ffn_sweep(e, c, d, f, bc, bf, dtype, tol):
    rng = np.random.default_rng(e * 100 + c)
    x = (rng.normal(size=(e, c, d)) * 0.1).astype(np.float32)
    w1 = (rng.normal(size=(e, d, f)) * 0.05).astype(np.float32)
    w3 = (rng.normal(size=(e, d, f)) * 0.05).astype(np.float32)
    w2 = (rng.normal(size=(e, f, d)) * 0.05).astype(np.float32)
    args = [jnp.asarray(a, dtype) for a in (x, w1, w3, w2)]
    got = ops.expert_ffn(*args, block_c=bc, block_f=bf)
    want = ref.ref_expert_ffn(*args)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_topk_gate_ties_stable():
    """Equal logits: kernel and lax.top_k must both pick lowest index first."""
    z = np.zeros((4, 8), np.float32)
    got = ops.topk_gate(jnp.asarray(z), 0.5, k=3)
    want = ref.ref_topk_gate(jnp.asarray(z), 0.5, k=3)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))


def test_buddy_kernel_matches_core_substitute():
    """Kernel path == core.substitute (the in-model reference) when gates are
    computed the same way."""
    import jax
    from repro.core.gates import token_gate, distribution_gate
    from repro.core.policy import BuddyPolicy
    from repro.core.substitute import substitute

    rng = np.random.default_rng(42)
    t, e, k, r = 50, 16, 4, 6
    s, _, resident, table, q = _buddy_setup(rng, t, e, k, r)
    logits = rng.normal(size=(t, k)).astype(np.float32)
    pol = BuddyPolicy(tau=0.3, beta=0.9, rho=k, H=r)

    res = substitute(jnp.asarray(s), jnp.asarray(logits), jnp.asarray(resident),
                     jnp.asarray(table), jnp.asarray(q), pol)
    allow = token_gate(jnp.asarray(logits), pol.tau)
    dist = distribution_gate(jnp.asarray(s), jnp.asarray(resident), pol.beta)
    gate = np.asarray(allow) & bool(dist)
    got = ops.buddy_substitute(jnp.asarray(s), jnp.asarray(gate),
                               jnp.asarray(resident), jnp.asarray(table),
                               jnp.asarray(q), h=pol.H, rho=pol.rho)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(res.indices))
    np.testing.assert_array_equal(np.asarray(got[1]),
                                  np.asarray(res.substituted))


def _grouped_setup(rng, e, c, d, f, dtype=jnp.float32):
    from repro.core.quantize import quantize_expert_ffn
    x = jnp.asarray((rng.normal(size=(2 * e, c, d)) * 0.1), dtype)
    w1 = (rng.normal(size=(e, d, f)) * 0.05).astype(np.float32)
    w3 = (rng.normal(size=(e, d, f)) * 0.05).astype(np.float32)
    w2 = (rng.normal(size=(e, f, d)) * 0.05).astype(np.float32)
    quant = quantize_expert_ffn(jnp.asarray(w1), jnp.asarray(w3),
                                jnp.asarray(w2), 8)
    fp = tuple(jnp.asarray(w, dtype) for w in (w1, w3, w2))
    q = (quant["w1_q"], quant["w1_s"], quant["w3_q"], quant["w3_s"],
         quant["w2_q"], quant["w2_s"])
    return x, fp, q


@pytest.mark.parametrize("e,c,d,f,bc,bf", [
    (1, 8, 32, 64, 8, 32),
    (4, 96, 128, 384, 32, 128),
    (8, 100, 64, 200, 64, 64),    # non-divisible c/f -> padding path
])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                       (jnp.bfloat16, 5e-2)])
def test_grouped_ffn_sweep(e, c, d, f, bc, bf, dtype, tol):
    """Groups [0, E) must match the fp expert_ffn numerics, [E, 2E) the
    quant_ffn numerics — one launch, both outcome classes."""
    rng = np.random.default_rng(e * 77 + c)
    x, fp, q = _grouped_setup(rng, e, c, d, f, dtype)
    got = ops.grouped_ffn(x, *fp, *q, block_c=bc, block_f=bf)
    want = ref.ref_grouped_ffn(x, *fp, *q)
    assert got.shape == (2 * e, c, d) and got.dtype == x.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_grouped_ffn_matches_single_class_kernels():
    """The fused kernel's two halves equal the standalone kernels on the
    same operands (class mix must not perturb either class's math)."""
    rng = np.random.default_rng(5)
    e, c, d, f = 4, 32, 48, 96
    x, fp, q = _grouped_setup(rng, e, c, d, f)
    got = ops.grouped_ffn(x, *fp, *q, block_c=16, block_f=32)
    full = ops.expert_ffn(x[:e], *fp, block_c=16, block_f=32)
    deg = ops.quant_ffn(x[e:], *q, block_c=16, block_f=32)
    np.testing.assert_allclose(np.asarray(got[:e]), np.asarray(full),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got[e:]), np.asarray(deg),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("empty", ["fp", "degraded", "both"])
def test_grouped_ffn_empty_groups(empty):
    """All-zero rows (unbinned capacity slots / an outcome class with no
    slots this step) must produce exactly zero output — the dispatch gather
    relies on it."""
    rng = np.random.default_rng(9)
    e, c, d, f = 2, 16, 32, 64
    x, fp, q = _grouped_setup(rng, e, c, d, f)
    mask = np.ones((2 * e, 1, 1), np.float32)
    if empty in ("fp", "both"):
        mask[:e] = 0.0
    if empty in ("degraded", "both"):
        mask[e:] = 0.0
    x = x * jnp.asarray(mask)
    got = np.asarray(ops.grouped_ffn(x, *fp, *q, block_c=16, block_f=32))
    want = np.asarray(ref.ref_grouped_ffn(x, *fp, *q))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    zeroed = np.where(mask[:, 0, 0] == 0.0)[0]
    np.testing.assert_array_equal(got[zeroed], 0.0)


@pytest.mark.parametrize("bh,n,c,d", [(1, 1, 32, 64), (3, 4, 32, 64),
                                      (2, 2, 32, 128), (4, 8, 16, 32)])
def test_wkv_chunk_sweep(bh, n, c, d):
    rng = np.random.default_rng(bh * 100 + n)
    rt = rng.normal(size=(bh, n, c, d)).astype(np.float32)
    kt = rng.normal(size=(bh, n, c, d)).astype(np.float32)
    v = rng.normal(size=(bh, n, c, d)).astype(np.float32)
    ke = rng.normal(size=(bh, n, c, d)).astype(np.float32)
    lae = -np.abs(rng.normal(size=(bh, n, d))).astype(np.float32)
    dg = rng.normal(size=(bh, n, c)).astype(np.float32)
    s0 = (rng.normal(size=(bh, d, d)) * 0.1).astype(np.float32)
    args = [jnp.asarray(x) for x in (rt, kt, v, ke, lae, dg, s0)]
    o1, s1 = ops.wkv_chunk(*args)
    o2, s2 = ref.ref_wkv_chunk(*args)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=3e-4, atol=3e-4)
