"""Property tests for the three decision metrics (§3.1)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import gates

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")

logits_arrays = hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                                        min_side=2, max_side=8),
                           elements=st.floats(-20, 20, width=32))


@given(logits_arrays)
def test_tae_in_unit_interval(z):
    t = np.asarray(gates.tae_from_logits(jnp.asarray(z)))
    assert np.all(t >= -1e-6) and np.all(t <= 1 + 1e-6)


@given(st.integers(2, 8), st.floats(5, 30))
def test_tae_peaky_vs_diffuse(k, gap):
    peaky = np.zeros((1, k), np.float32)
    peaky[0, 0] = gap
    diffuse = np.zeros((1, k), np.float32)
    tp = float(gates.tae_from_logits(jnp.asarray(peaky))[0])
    td = float(gates.tae_from_logits(jnp.asarray(diffuse))[0])
    assert td > tp
    assert abs(td - 1.0) < 1e-5  # uniform -> max entropy
    assert tp < 0.5 or gap < 8   # strong peak -> low TAE


@given(logits_arrays)
def test_tae_from_probs_consistent(z):
    p = np.exp(z - z.max(1, keepdims=True))
    p = p / p.sum(1, keepdims=True)
    t1 = np.asarray(gates.tae_from_logits(jnp.asarray(z)))
    t2 = np.asarray(gates.tae_from_probs(jnp.asarray(p)))
    np.testing.assert_allclose(t1, t2, rtol=1e-3, atol=1e-4)


def test_tae_k1_is_zero():
    z = np.random.default_rng(0).normal(size=(5, 1)).astype(np.float32)
    assert np.all(np.asarray(gates.tae_from_logits(jnp.asarray(z))) == 0)


@given(st.data())
def test_distribution_delta(data):
    e = data.draw(st.integers(2, 16))
    t = data.draw(st.integers(1, 32))
    k = data.draw(st.integers(1, min(4, e)))
    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    idx = rng.integers(0, e, size=(t, k))
    resident = rng.random(e) < 0.5
    d = float(gates.distribution_delta(jnp.asarray(idx), jnp.asarray(resident)))
    req = np.unique(idx)
    expected = (~resident[req]).sum() / len(req)
    assert abs(d - expected) < 1e-6
    assert 0.0 <= d <= 1.0


def test_distribution_gate_threshold():
    idx = jnp.asarray([[0, 1], [2, 3]])
    resident = jnp.asarray([True, True, False, False])  # delta = 0.5
    assert bool(gates.distribution_gate(idx, resident, beta=0.6))
    assert not bool(gates.distribution_gate(idx, resident, beta=0.5))
    assert not bool(gates.distribution_gate(idx, resident, beta=0.4))


@given(hnp.arrays(np.float32, st.integers(50, 200),
                  elements=st.floats(0, 1, width=32)),
       st.floats(5, 30))
def test_calibrate_tau_percentile(samples, p):
    tau = gates.calibrate_tau(samples, p)
    frac_below = (samples <= tau + 1e-9).mean()
    assert frac_below >= p / 100 - 0.02


def test_margin_gate():
    z = jnp.asarray([[10.0, 0.0], [0.1, 0.0]])
    # margin co-gate: peaky margin forbids even with high tau pass
    allow = gates.token_gate(z, tau=-0.1, margin_gamma=0.5)
    assert not bool(allow[0])   # huge margin -> forbidden
    assert bool(allow[1])       # small margin + high TAE -> allowed
