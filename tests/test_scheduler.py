"""Continuous-batching scheduler: arrivals, SLO-aware admission, mid-step
retirement, percentile/goodput math, and the adaptive prefetch-budget
feedback loop (serving/scheduler.py + runtime/prefetch.py)."""
import jax
import numpy as np
import pytest

from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.core import BuddyPolicy, build_buddy_lists
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.prefetch import AdaptiveBudgetController, PrevStepPredictor
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import (FINISHED, REJECTED, BurstyArrivals,
                                     ContinuousScheduler, PoissonArrivals,
                                     ReplayArrivals, RequestQueue, SLOConfig,
                                     ServeRequest, StaticServer,
                                     make_requests, percentiles)
from repro.training.data import MarkovLM


@pytest.fixture(scope="module")
def setup():
    cfg = reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    lm = MarkovLM(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)
    e = cfg.moe.num_experts
    q = rng.random((cfg.num_layers, e, e))
    tables = build_buddy_lists(q, alpha=0.95, k_max=e - 1)
    return cfg, params, lm, tables


def _engine(cfg, params, tables, seed=0, prefetch_k=2):
    l, e = cfg.num_layers, cfg.moe.num_experts
    return ServeEngine(cfg, params, tables=tables,
                       policy=BuddyPolicy(tau=0.1, beta=0.9, rho=3),
                       cache=ExpertCache(l, e, 0.5, seed=seed),
                       predictor=PrevStepPredictor(l, e),
                       prefetch_k=prefetch_k, seed=seed)


def _prompts(lm, n, rng):
    return [lm.sample(1, int(rng.integers(4, 8)))[0] for _ in range(n)]


# ===========================================================================
# Arrival processes
# ===========================================================================
def test_poisson_arrivals_rate_and_determinism():
    p = PoissonArrivals(rate=100.0, seed=3)
    t1, t2 = p.times(2000), p.times(2000)
    np.testing.assert_array_equal(t1, t2)          # seeded -> reproducible
    assert np.all(np.diff(t1) > 0) or np.all(np.diff(t1) >= 0)
    mean_gap = float(np.diff(t1).mean())
    assert 0.8 / 100.0 < mean_gap < 1.25 / 100.0   # ~1/rate


def test_bursty_arrivals_burstier_than_poisson():
    rate = 50.0
    b = BurstyArrivals(rate=rate, burst_size=5, burstiness=10.0, seed=0)
    t = b.times(1000)
    assert np.all(np.diff(t) >= 0)
    gaps = np.diff(t)
    # long-run rate roughly preserved, but gap dispersion far above Poisson
    assert 0.5 / rate < gaps.mean() < 2.0 / rate
    assert gaps.std() > 1.5 * gaps.mean()          # CV >> 1 (Poisson CV = 1)


def test_replay_arrivals():
    r = ReplayArrivals([0.3, 0.1, 0.2])
    np.testing.assert_allclose(r.times(3), [0.1, 0.2, 0.3])
    with pytest.raises(AssertionError):
        r.times(4)


# ===========================================================================
# SLO state + percentile math
# ===========================================================================
def test_percentile_math():
    assert percentiles([]) == {"p50": 0.0, "p95": 0.0, "p99": 0.0,
                               "mean": 0.0}
    p = percentiles([1.0, 2.0, 3.0, 4.0])
    assert p["p50"] == pytest.approx(2.5)
    assert p["mean"] == pytest.approx(2.5)
    xs = list(range(1, 101))
    p = percentiles(xs)
    assert p["p50"] == pytest.approx(50.5)
    assert p["p95"] == pytest.approx(95.05)
    assert p["p99"] == pytest.approx(99.01)


def test_request_slo_accounting():
    r = ServeRequest(rid=0, prompt=np.arange(4), max_new_tokens=3,
                     arrival_s=1.0,
                     slo=SLOConfig(ttft_s=0.5, tpot_s=0.2, deadline_s=2.0))
    r.state = FINISHED
    r.first_token_s = 1.4
    r.token_times = [1.4, 1.5, 1.7]
    r.tokens = [7, 8, 9]
    r.finished_s = 1.7
    assert r.ttft() == pytest.approx(0.4)
    assert r.tpot() == pytest.approx(0.15)         # (1.7-1.4)/2
    assert r.e2e() == pytest.approx(0.7)
    assert r.token_gaps() == pytest.approx([0.4, 0.1, 0.2])
    assert r.slo_ok()
    r.first_token_s = 1.6                          # TTFT 0.6 > 0.5
    assert not r.slo_ok()


# ===========================================================================
# RequestQueue: backlog + SLO-aware admission
# ===========================================================================
def _mk(rid, arrival, deadline=None):
    return ServeRequest(rid=rid, prompt=np.arange(4), max_new_tokens=4,
                        arrival_s=arrival,
                        slo=SLOConfig(deadline_s=deadline))


def test_queue_release_order_and_depth():
    reqs = [_mk(0, 0.5), _mk(1, 0.1), _mk(2, 0.3), _mk(3, 9.0)]
    q = RequestQueue(reqs)
    assert q.depth(0.0) == 0
    assert q.depth(0.4) == 2                       # rid 1, 2 arrived
    assert q.next_arrival() == pytest.approx(0.5)
    got = [q.pop(0.6).rid for _ in range(3)]
    assert got == [1, 2, 0]                        # FCFS by arrival time
    assert q.pop(0.6) is None                      # rid 3 still in the future
    assert not q.exhausted
    assert q.peak_depth >= 2


def test_slo_admission_sheds_doomed_requests():
    # rid 0's deadline has no chance given the service estimate; rid 1's does
    q = RequestQueue([_mk(0, 0.0, deadline=0.1), _mk(1, 0.0, deadline=10.0)],
                     admission="slo")
    r = q.pop(5.0, est_service_fn=lambda rq: 1.0)
    assert r.rid == 1
    assert [x.rid for x in q.rejected] == [0]
    assert q.rejected[0].state == REJECTED
    # fcfs mode never sheds
    q2 = RequestQueue([_mk(0, 0.0, deadline=0.1)], admission="fcfs")
    assert q2.pop(5.0, est_service_fn=lambda rq: 1.0).rid == 0


# ===========================================================================
# Adaptive prefetch budget (regression: shrink on late-prefetch dominance)
# ===========================================================================
def test_budget_shrinks_when_late_prefetch_dominates():
    c = AdaptiveBudgetController(prefetch_k=4, lookahead=1, min_k=1, max_k=8,
                                 window=1)
    late = {"demand_stall_s": 0.0, "late_prefetch_stall_s": 0.0,
            "overlapped_s": 0.0}
    for i in range(1, 4):
        late = {"demand_stall_s": 0.001 * i,
                "late_prefetch_stall_s": 0.050 * i, "overlapped_s": 0.0}
        c.update(late, queue_depth=8)
    assert c.budget.prefetch_k == 1                # 4 -> 3 -> 2 -> 1
    assert c.budget.lookahead > 1                  # issue earlier instead
    assert c.budget.max_inflight == c.budget.prefetch_k
    assert len(c.trace) == 3


def test_budget_grows_on_demand_stalls_capped_by_queue():
    c = AdaptiveBudgetController(prefetch_k=2, lookahead=1, min_k=1, max_k=8,
                                 window=1, deep_queue=4)
    demand = {"demand_stall_s": 0.05, "late_prefetch_stall_s": 0.0,
              "overlapped_s": 0.0}
    c.update(demand, queue_depth=8)                # deep queue: may grow
    assert c.budget.prefetch_k == 3
    # shallow queue caps the budget at max_k // 2
    for i in range(2, 10):
        c.update({"demand_stall_s": 0.05 * i, "late_prefetch_stall_s": 0.0,
                  "overlapped_s": 0.0}, queue_depth=0)
    assert c.budget.prefetch_k == 4                # max_k // 2


def test_budget_apply_actuates_engine_knobs():
    class _Sched:
        max_inflight_prefetch = 4

        def set_prefetch_cap(self, n):
            self.max_inflight_prefetch = n

    class _Eng:
        prefetch_k, lookahead, scheduler = 8, 1, _Sched()

    c = AdaptiveBudgetController(prefetch_k=3, lookahead=2, max_k=8)
    eng = _Eng()
    c.apply(eng)
    assert (eng.prefetch_k, eng.lookahead) == (3, 2)
    assert eng.scheduler.max_inflight_prefetch == 3


# ===========================================================================
# Continuous batching end-to-end (the engine-driven paths)
# ===========================================================================
def test_admission_backlog_midstep_retirement_slot_reuse(setup):
    cfg, params, lm, tables = setup
    rng = np.random.default_rng(1)
    n = 6
    reqs = make_requests(_prompts(lm, n, rng), ReplayArrivals([0.0] * n),
                         max_new_tokens=list(rng.integers(2, 7, n)))
    eng = _engine(cfg, params, tables)
    queue = RequestQueue(reqs)
    sched = ContinuousScheduler(eng, slots=2)
    s = sched.run(queue)

    assert s["completed"] == n and queue.exhausted
    assert queue.peak_depth >= n - 2               # backlog: only 2 slots
    done = sched.completed
    by_rid = sorted(done, key=lambda r: r.rid)
    # FCFS: same-arrival requests admitted in rid order
    admits = [r.admitted_s for r in by_rid]
    assert admits == sorted(admits)
    for r in done:
        assert r.state == FINISHED
        assert len(r.tokens) == r.max_new_tokens
        assert r.first_token_s <= r.finished_s
        assert all(b >= a for a, b in zip(r.token_times, r.token_times[1:]))
    # mid-step retirement: budgets differ, so finish times differ...
    finishes = sorted(r.finished_s for r in done)
    assert finishes[0] < finishes[-1]
    # ...and a freed slot is reused: someone is admitted only after (or at)
    # the first retirement, while the engine kept stepping
    assert max(admits) >= finishes[0]
    assert s["mean_occupancy"] > 1.0


def test_continuous_beats_static_under_backlog(setup):
    """The acceptance regime: same arrival trace, same engine config —
    continuous batching retires rows early and back-fills, static pays the
    formation + straggler barriers."""
    cfg, params, lm, tables = setup
    rng = np.random.default_rng(2)
    n, slots = 8, 4
    new_toks = list(rng.integers(2, 11, n))
    arrivals = ReplayArrivals([0.0] * n)           # all queued at t=0
    prompts = _prompts(lm, n, rng)

    st_eng = _engine(cfg, params, tables, seed=0)
    s_static = StaticServer(st_eng, batch_size=slots).run(
        make_requests(prompts, arrivals, new_toks))

    ct_eng = _engine(cfg, params, tables, seed=0)
    sched = ContinuousScheduler(ct_eng, slots=slots)
    s_cont = sched.run(RequestQueue(
        make_requests(prompts, arrivals, new_toks)))

    assert s_cont["completed"] == s_static["completed"] == n
    assert s_cont["elapsed_s"] < s_static["elapsed_s"]
    assert s_cont["e2e_s"]["p99"] < s_static["e2e_s"]["p99"]
    assert s_cont["goodput_rps"] > s_static["goodput_rps"]
    # stall attribution flows through both summaries
    for s in (s_cont, s_static):
        bd = s["engine"]["stall_breakdown"]
        assert set(bd) == {"demand_stall_s", "late_prefetch_stall_s",
                           "overlapped_s"}


def test_adaptive_budget_in_the_loop(setup):
    """Wired end-to-end: the controller observes real stall deltas and its
    budget lands on the engine's prefetch knobs."""
    cfg, params, lm, tables = setup
    rng = np.random.default_rng(3)
    eng = _engine(cfg, params, tables, prefetch_k=3)
    ctrl = AdaptiveBudgetController(prefetch_k=3, lookahead=1, max_k=4,
                                    window=2)
    reqs = make_requests(_prompts(lm, 4, rng), ReplayArrivals([0.0] * 4), 4)
    sched = ContinuousScheduler(eng, slots=2, controller=ctrl)
    s = sched.run(RequestQueue(reqs))
    assert s["completed"] == 4
    assert len(ctrl.trace) > 0                     # feedback happened
    assert eng.prefetch_k == ctrl.budget.prefetch_k
    assert eng.lookahead == ctrl.budget.lookahead
    assert eng.scheduler.max_inflight_prefetch == ctrl.budget.max_inflight
    assert s["budget"]["prefetch_k"] == ctrl.budget.prefetch_k
    # summary carries the SLO/goodput block the bench reports
    for key in ("ttft_s", "tpot_s", "e2e_s", "token_latency_s"):
        assert set(s[key]) == {"p50", "p95", "p99", "mean"}
    import json
    json.dumps(s, default=str)


def test_trace_loader_roundtrip(tmp_path):
    """JSONL trace -> ReplayArrivals + per-request token budgets (satellite
    of the tiered-store PR; ROADMAP workload-replay follow-up)."""
    from repro.serving.scheduler import load_trace, requests_from_trace
    p = tmp_path / "trace.jsonl"
    p.write_text(
        "# recorded serving trace\n"
        '{"t_arrival": 0.02, "prompt_len": 3, "max_new_tokens": 5}\n'
        "\n"
        '{"t_arrival": 0.00, "prompt_len": 6, "max_new_tokens": 2}\n'
        '{"t_arrival": 0.01, "prompt_len": 4, "max_new_tokens": 9}\n')
    rows = load_trace(str(p))
    assert [r["t_arrival"] for r in rows] == [0.00, 0.01, 0.02]  # sorted
    assert [r["prompt_len"] for r in rows] == [6, 4, 3]

    rng = np.random.default_rng(0)
    reqs = requests_from_trace(
        str(p), lambda n: rng.integers(0, 100, n), limit=3)
    assert [len(r.prompt) for r in reqs] == [6, 4, 3]
    assert [r.max_new_tokens for r in reqs] == [2, 9, 5]   # per-request
    assert [r.arrival_s for r in reqs] == [0.00, 0.01, 0.02]


def test_trace_loader_rejects_bad_rows(tmp_path):
    from repro.serving.scheduler import load_trace
    p = tmp_path / "bad.jsonl"
    p.write_text('{"t_arrival": 0.0, "prompt_len": 3}\n')
    with pytest.raises(ValueError, match="bad trace row"):
        load_trace(str(p))
    p.write_text("")
    with pytest.raises(AssertionError, match="empty trace"):
        load_trace(str(p))


def test_trace_drives_continuous_scheduler(setup, tmp_path):
    """A replayed trace serves end-to-end with each request's own budget."""
    cfg, params, lm, tables = setup
    p = tmp_path / "t.jsonl"
    p.write_text("".join(
        '{"t_arrival": %g, "prompt_len": %d, "max_new_tokens": %d}\n'
        % (i * 0.001, 3 + i, 2 + i) for i in range(3)))
    from repro.serving.scheduler import requests_from_trace
    reqs = requests_from_trace(str(p), lambda n: lm.sample(1, n)[0])
    eng = _engine(cfg, params, tables)
    s = ContinuousScheduler(eng, slots=2).run(RequestQueue(reqs))
    assert s["completed"] == 3
    by_rid = sorted(reqs, key=lambda r: r.rid)
    assert [len(r.tokens) for r in by_rid] == [2, 3, 4]
