"""Chunkwise-parallel scans (§Perf B1/B2) vs their sequential oracles.

These are the beyond-paper optimizations that cut the SSM-family memory
roofline ~8x; any numerical drift here silently corrupts rwkv6/zamba2.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.mamba import ssd_chunked
from repro.models.rwkv import wkv_chunked, wkv_scan

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def _wkv_inputs(key, b, s, h, d, decay_scale=1.0):
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    w = jnp.exp(-jax.nn.softplus(jax.random.normal(ks[3], (b, s, h, d))
                                 * decay_scale))
    u = jax.random.normal(ks[4], (h, d)) * 0.1
    s0 = jax.random.normal(ks[5], (b, h, d, d)) * 0.1
    return r, k, v, w, u, s0


@pytest.mark.parametrize("s,chunk", [(32, 32), (64, 32), (256, 32), (96, 32)])
def test_wkv_chunked_matches_scan(s, chunk):
    r, k, v, w, u, s0 = _wkv_inputs(jax.random.PRNGKey(s), 2, s, 3, 8)
    o1, st1 = wkv_scan(r, k, v, w, u, s0)
    o2, st2 = wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st1),
                               rtol=2e-3, atol=2e-3)


@given(st.integers(0, 100), st.floats(0.3, 2.5))
def test_wkv_chunked_property(seed, decay_scale):
    """Random shapes + decay sharpness (the numerical-range stressor)."""
    key = jax.random.PRNGKey(seed)
    r, k, v, w, u, s0 = _wkv_inputs(key, 1, 64, 2, 4,
                                    decay_scale=decay_scale)
    o1, st1 = wkv_scan(r, k, v, w, u, s0)
    o2, st2 = wkv_chunked(r, k, v, w, u, s0, chunk=32)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st1),
                               rtol=5e-3, atol=5e-3)


def _ssd_ref(a, xh, bt, ct, dt, h0):
    def step(h, inp):
        a_, x_, b_, dt_ = inp
        dx = (dt_[..., None] * x_)[..., None] * b_[:, None, None, :]
        h_new = a_[..., None, None] * h + dx
        return h_new, h_new

    hN, hs = jax.lax.scan(step, h0,
                          (a.swapaxes(0, 1), xh.swapaxes(0, 1),
                           bt.swapaxes(0, 1), dt.swapaxes(0, 1)))
    return jnp.einsum("sbhdn,bsn->bshd", hs, ct), hN


def _ssd_inputs(key, b, s, h, hd, n):
    ks = jax.random.split(key, 6)
    a = jnp.exp(-jax.nn.softplus(jax.random.normal(ks[0], (b, s, h))))
    xh = jax.random.normal(ks[1], (b, s, h, hd))
    bt = jax.random.normal(ks[2], (b, s, n))
    ct = jax.random.normal(ks[3], (b, s, n))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (b, s, h)))
    h0 = jax.random.normal(ks[5], (b, h, hd, n)) * 0.1
    return a, xh, bt, ct, dt, h0


@pytest.mark.parametrize("s", [32, 64, 160])
def test_ssd_chunked_matches_scan(s):
    a, xh, bt, ct, dt, h0 = _ssd_inputs(jax.random.PRNGKey(s), 2, s, 3, 8, 4)
    y1, h1 = _ssd_ref(a, xh, bt, ct, dt, h0)
    y2, h2 = ssd_chunked(a, xh, bt, ct, dt, h0, chunk=32)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h1),
                               rtol=2e-3, atol=2e-3)


@given(st.integers(0, 100))
def test_ssd_chunked_property(seed):
    a, xh, bt, ct, dt, h0 = _ssd_inputs(jax.random.PRNGKey(seed), 1, 64, 2,
                                        4, 3)
    y1, h1 = _ssd_ref(a, xh, bt, ct, dt, h0)
    y2, h2 = ssd_chunked(a, xh, bt, ct, dt, h0, chunk=32)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h1),
                               rtol=5e-3, atol=5e-3)


def test_rwkv_block_consistency_chunked_vs_step():
    """Full-sequence (chunked path, S=32) must match step-by-step decode
    through the whole rwkv block stack."""
    from repro.configs.base import get_reduced
    from repro.models import transformer
    cfg = get_reduced("rwkv6-1.6b")
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    b, s = 1, 32   # multiple of 32 -> forward uses wkv_chunked
    tokens = np.asarray(jax.random.randint(key, (b, s), 0, cfg.vocab_size))
    full_logits, _ = transformer.forward_train(params, cfg,
                                               jnp.asarray(tokens))
    caches = transformer.init_caches(cfg, b, s)
    for pos in range(s - 1):
        lg, caches, _ = transformer.decode_step(
            params, cfg, jnp.asarray(tokens[:, pos]), caches,
            jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, pos]),
                                   rtol=5e-4, atol=5e-4)
