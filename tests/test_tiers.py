"""Tiered expert store: budget split, the degrade-vs-wait decision, the
degraded substitution mask, and the serving engine's four-way miss path
(buddy / degraded / fetch / drop)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.core import BuddyPolicy, build_buddy_lists
from repro.core.substitute import substitute
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.memory import expert_nbytes, quant_expert_nbytes
from repro.runtime.tiers import TieredExpertStore
from repro.serving.engine import ServeEngine
from repro.training.data import MarkovLM


@pytest.fixture(scope="module")
def setup():
    cfg = reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    lm = MarkovLM(cfg.vocab_size, seed=0)
    e = cfg.moe.num_experts
    rng = np.random.default_rng(0)
    q = rng.random((cfg.num_layers, e, e))
    tables = build_buddy_lists(q, alpha=0.95, k_max=e - 1)
    return cfg, params, lm, tables


def _tier(cfg, rate=0.5, bits=8, **kw):
    return TieredExpertStore(cfg.num_layers, cfg.moe.num_experts, rate,
                             bits=bits, d_model=cfg.d_model,
                             d_ff=cfg.moe.d_ff, **kw)


# ---------------------------------------------------------------------------
# budget split
# ---------------------------------------------------------------------------
def test_budget_split_displaces_slots(setup):
    cfg, *_ = setup
    e = cfg.moe.num_experts
    full = expert_nbytes(cfg.d_model, cfg.moe.d_ff)
    rep4 = quant_expert_nbytes(cfg.d_model, cfg.moe.d_ff, 4)
    t4 = _tier(cfg, rate=1.0, bits=4)
    want = int((1.0 * e * full - e * rep4) // full)
    assert t4.cache.capacity == want
    assert not t4.clamped
    split = t4.budget_split()
    assert split["cache_slots_per_layer"] == want
    assert split["quant_bytes_per_layer"] == e * rep4
    # int8 replicas + scale overhead exceed a 0.5 budget entirely: the store
    # keeps one mandatory full slot and reports the split as clamped
    t8 = _tier(cfg, rate=0.5, bits=8)
    assert t8.cache.capacity == 1 and t8.clamped
    # quant replicas are strictly smaller than full experts
    assert rep4 < quant_expert_nbytes(cfg.d_model, cfg.moe.d_ff, 8) < full


# ---------------------------------------------------------------------------
# degrade-vs-wait decision
# ---------------------------------------------------------------------------
def test_degraded_ok_trades_stall_for_fidelity(setup):
    cfg, *_ = setup
    t = _tier(cfg, stall_per_fidelity=0.1)
    l, e = cfg.num_layers, cfg.moe.num_experts
    resident = np.zeros((l, e), bool)
    resident[0, 0] = True
    fid = np.full((l, e), 0.01)
    fid[1, 1] = np.inf                       # uncalibrated -> never degrade
    t.attach_fidelity(fid)
    eta = np.full((l, e), 0.01)              # 10ms expected stall
    ok = t.degraded_ok(resident, eta)
    assert not ok[0, 0], "resident experts never degrade"
    assert not ok[1, 1], "unknown fidelity never degrades"
    assert ok[0, 1] and ok[1, 0], "10ms stall >= 0.01 * 0.1s threshold"
    # a nearly-landed in-flight prefetch (tiny ETA) is waited for instead
    eta[0, 1] = 1e-5
    assert not t.degraded_ok(resident, eta)[0, 1]


def test_default_fidelity_is_conservative(setup):
    cfg, *_ = setup
    t = _tier(cfg)
    ok = t.degraded_ok(np.zeros((cfg.num_layers, cfg.moe.num_experts), bool),
                       np.full((cfg.num_layers, cfg.moe.num_experts), 1.0))
    assert not ok.any(), "no calibration -> no degradation"


# ---------------------------------------------------------------------------
# substitute: degraded sits between buddy and fetch/drop
# ---------------------------------------------------------------------------
def test_substitute_degraded_mask():
    # experts 0/2 resident; 1 has no buddy; quant tier allows 1 and 3
    idx = jnp.asarray([[1, 3], [0, 2]], jnp.int32)
    logits = jnp.zeros((2, 2), jnp.float32)
    resident = jnp.asarray([True, False, True, False])
    table = jnp.full((4, 2), -1, jnp.int32)
    q = jnp.zeros((4, 2), jnp.float32)
    quant_ok = jnp.asarray([False, True, False, True])
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=2, H=2, quant_tier="int8")
    res = substitute(idx, logits, resident, table, q, pol,
                     quant_ok=quant_ok)
    np.testing.assert_array_equal(np.asarray(res.degraded),
                                  [[True, True], [False, False]])
    assert not np.asarray(res.missed).any(), \
        "tier-served slots must leave the missed mask"
    # without the tier the same slots are plain misses
    res0 = substitute(idx, logits, resident, table, q,
                      BuddyPolicy(tau=0.0, beta=1.1, rho=2, H=2))
    np.testing.assert_array_equal(np.asarray(res0.missed),
                                  [[True, True], [False, False]])
    assert not np.asarray(res0.degraded).any()


def test_substitute_buddy_wins_over_degraded():
    """An eligible resident buddy is preferred (zero fidelity cost); the
    tier only catches slots the buddy search could not serve."""
    idx = jnp.asarray([[1, 2]], jnp.int32)
    logits = jnp.zeros((1, 2), jnp.float32)
    resident = jnp.asarray([True, False, True, False])
    table = jnp.asarray([[2], [0], [1], [-1]], jnp.int32)
    q = jnp.full((4, 1), 0.5, jnp.float32)
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=2, H=1, quant_tier="int8")
    res = substitute(idx, logits, resident, table, q, pol,
                     quant_ok=jnp.asarray([True, True, True, True]))
    assert bool(res.substituted[0, 0]) and not bool(res.degraded[0, 0])
    assert int(res.indices[0, 0]) == 0
    assert not np.asarray(res.degraded).any()


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------
def test_engine_tier_absorbs_stalls(setup):
    """Same HBM budget: the tiered engine converts residual-miss stalls into
    degraded computes — zero demand transfers for tier-served slots."""
    cfg, params, lm, tables = setup
    prompts = lm.sample(2, 4)
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=2, H=3)

    base = ServeEngine(cfg, params, tables=tables, policy=pol,
                       cache=ExpertCache(cfg.num_layers, cfg.moe.num_experts,
                                         0.5, seed=0), seed=0)
    base.generate(prompts, max_new_tokens=6)

    tier = _tier(cfg, rate=0.5, bits=8)
    eng = ServeEngine(cfg, params, tables=tables,
                      policy=BuddyPolicy(tau=0.0, beta=1.1, rho=2, H=3,
                                         quant_tier="int8"),
                      tier=tier, seed=0)
    eng.generate(prompts, max_new_tokens=6)

    s = eng.summary()
    assert s["tier"]["degraded_tokens"] > 0
    assert s["tier"]["quant_bytes"] == tier.quant_bytes
    assert s["tier"]["tier_budget_split"]["cache_slots_per_layer"] >= 1
    assert s["ledger"]["events"]["degraded"] == s["tier"]["degraded_tokens"]
    # every degraded slot is a transfer (and stall) that never happened
    assert eng.stats.n_miss_fetch <= base.stats.n_miss_fetch
    assert s["stall_breakdown"]["demand_stall_s"] \
        <= base.summary()["stall_breakdown"]["demand_stall_s"]
    # outputs are finite at degraded fidelity
    nll = ServeEngine(cfg, params, tables=tables,
                      policy=BuddyPolicy(tau=0.0, beta=1.1, rho=2, H=3,
                                         quant_tier="int8"),
                      tier=_tier(cfg, rate=0.5, bits=8),
                      seed=0).teacher_forced_nll(lm.sample(2, 6))
    assert np.isfinite(nll)


def test_engine_tier_off_is_strictly_additive(setup):
    """quant_tier='off' (the default) must not change the engine: no tier
    key in summary(), no quant params attached, identical outputs."""
    cfg, params, lm, tables = setup
    prompts = lm.sample(2, 4)
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=2, H=3)

    def mk():
        return ServeEngine(cfg, params, tables=tables, policy=pol,
                           cache=ExpertCache(cfg.num_layers,
                                             cfg.moe.num_experts, 0.5,
                                             seed=0), seed=0)
    eng = mk()
    out = eng.generate(prompts, max_new_tokens=4)
    s = eng.summary()
    assert "tier" not in s
    assert "degraded" not in s["ledger"]["events"]
    assert "quant" not in eng.params["groups"][0]["moe"]
    np.testing.assert_array_equal(out, mk().generate(prompts,
                                                     max_new_tokens=4))
    # mismatched policy/tier wiring is rejected loudly
    with pytest.raises(AssertionError):
        ServeEngine(cfg, params, tables=tables,
                    policy=BuddyPolicy(quant_tier="int8"), seed=0)
    with pytest.raises(AssertionError):
        ServeEngine(cfg, params, tables=tables, policy=pol,
                    tier=_tier(cfg), seed=0)


def test_engine_tier_reset_runtime(setup):
    """reset_runtime keeps the tier wired: counters cleared, upload re-paid,
    the fresh cache repointed."""
    cfg, params, lm, tables = setup
    tier = _tier(cfg, rate=0.5, bits=8)
    eng = ServeEngine(cfg, params, tables=tables,
                      policy=BuddyPolicy(tau=0.0, beta=1.1, rho=2, H=3,
                                         quant_tier="int8"),
                      tier=tier, seed=0)
    eng.generate(lm.sample(1, 3), max_new_tokens=3)
    assert tier.degraded_tokens > 0
    eng.reset_runtime()
    assert tier.degraded_tokens == 0
    assert eng.cache is tier.cache
    assert eng.ledger.bytes_by_cause["tier_upload"] == tier.quant_bytes
    eng.generate(lm.sample(1, 3), max_new_tokens=3)
    assert tier.degraded_tokens > 0


def test_degraded_output_close_to_full_precision(setup):
    """The degraded path computes the TRUE expert at int8 fidelity: its NLL
    probe sits near the full-residency reference (it is not a drop)."""
    cfg, params, lm, tables = setup
    data = lm.sample(2, 8)
    nll_full = ServeEngine(
        cfg, params, tables=tables, policy=BuddyPolicy(mode="none"),
        cache=ExpertCache(cfg.num_layers, cfg.moe.num_experts, 1.0, seed=0),
        seed=0).teacher_forced_nll(data)
    # tier with no buddies: every miss degrades (mode none + quant tier)
    eng = ServeEngine(cfg, params, tables=tables,
                      policy=BuddyPolicy(mode="none", quant_tier="int8"),
                      tier=_tier(cfg, rate=0.5, bits=8), seed=0)
    nll_tier = eng.teacher_forced_nll(data)
    nll_drop = ServeEngine(
        cfg, params, tables=tables,
        policy=BuddyPolicy(mode="none", fallback="drop"),
        cache=ExpertCache(cfg.num_layers, cfg.moe.num_experts, 0.5, seed=0),
        seed=0).teacher_forced_nll(data)
    assert eng.tier.degraded_tokens > 0
    assert np.isfinite(nll_tier)
    # degraded compute tracks the true expert far better than dropping it
    assert abs(nll_tier - nll_full) < abs(nll_drop - nll_full) + 0.05
    assert abs(nll_tier - nll_full) < 0.1
