"""Unified expected-cost miss policy (runtime/costs.py): the four-outcome
argmin, its cost-model edges, P(use) x lateness-risk prefetch ranking, and
degraded-then-upgrade accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.core import BuddyPolicy, build_buddy_lists
from repro.core.substitute import substitute
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.costs import (BUDDY, DEGRADED, DROP, FETCH, PEER,
                                 MissCostModel, best_resident_q)
from repro.runtime.memory import DEFAULT_HW
from repro.runtime.prefetch import (AdaptiveBudgetController,
                                    CrossLayerPredictor, NoisyOraclePredictor,
                                    PrevStepPredictor, TopFreqPredictor)
from repro.runtime.tiers import TieredExpertStore
from repro.runtime.transfers import TransferScheduler, make_ici_links
from repro.serving.engine import ServeEngine
from repro.training.data import MarkovLM


@pytest.fixture(scope="module")
def setup():
    cfg = reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    lm = MarkovLM(cfg.vocab_size, seed=0)
    e = cfg.moe.num_experts
    rng = np.random.default_rng(0)
    q = rng.random((cfg.num_layers, e, e))
    tables = build_buddy_lists(q, alpha=0.95, k_max=e - 1)
    return cfg, params, lm, tables


def _tier(cfg, rate=0.5, **kw):
    return TieredExpertStore(cfg.num_layers, cfg.moe.num_experts, rate,
                             bits=8, d_model=cfg.d_model, d_ff=cfg.moe.d_ff,
                             **kw)


def _cost_engine(cfg, params, tables, *, mode="buddy", prefetch_k=0,
                 predictor=None, upgrade=None, tier_kw=None, seed=0):
    return ServeEngine(
        cfg, params, tables=tables,
        policy=BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8, mode=mode,
                           quant_tier="int8", miss_policy="cost"),
        tier=_tier(cfg, seed=seed, **(tier_kw or {})), predictor=predictor,
        prefetch_k=prefetch_k, seed=seed, upgrade_degraded=upgrade)


# ---------------------------------------------------------------------------
# the unified argmin (in-graph + host mirror)
# ---------------------------------------------------------------------------
def test_high_q_buddy_beats_low_fidelity_replica_and_vice_versa():
    """The tentpole semantics: the SAME policy picks buddy or degraded per
    slot depending on which quality loss is smaller — no fixed precedence."""
    idx = jnp.asarray([[1], [3]], jnp.int32)
    logits = jnp.zeros((2, 1), jnp.float32)
    resident = jnp.asarray([True, False, True, False])
    table = jnp.asarray([[-1], [0], [-1], [2]], jnp.int32)
    q = jnp.asarray([[0.0], [0.99], [0.0], [0.2]], jnp.float32)
    pol = BuddyPolicy(tau=-1.0, beta=1.1, rho=2, H=1, quant_tier="int8",
                      miss_policy="cost", stall_per_quality=0.05)
    fid_cost = jnp.full((4,), 0.05 * 0.1, jnp.float32)   # replica err 0.1
    fetch_cost = jnp.full((4,), 0.01, jnp.float32)
    res = substitute(idx, logits, resident, table, q, pol,
                     fid_cost=fid_cost, fetch_cost=fetch_cost)
    # expert 1: buddy loss 1-0.99=0.01 < 0.1 -> buddy (replica loses)
    assert bool(res.substituted[0, 0]) and int(res.indices[0, 0]) == 0
    # expert 3: buddy loss 1-0.2=0.8 > 0.1 -> degraded (buddy loses)
    assert bool(res.degraded[1, 0]) and not bool(res.substituted[1, 0])
    assert not np.asarray(res.missed).any()


def test_zero_usefulness_replica_never_chosen_over_fetch():
    """A replica with unusable fidelity (inf cost: uncalibrated, uncovered,
    or arbitrarily bad) must lose to a demand fetch at ANY finite ETA."""
    idx = jnp.asarray([[1], [3]], jnp.int32)
    logits = jnp.zeros((2, 1), jnp.float32)
    resident = jnp.asarray([True, False, True, False])
    table = jnp.full((4, 1), -1, jnp.int32)
    q = jnp.zeros((4, 1), jnp.float32)
    pol = BuddyPolicy(mode="none", quant_tier="int8", miss_policy="cost")
    res = substitute(idx, logits, resident, table, q, pol,
                     fid_cost=jnp.full((4,), jnp.inf),
                     fetch_cost=jnp.full((4,), 0.01, jnp.float32))
    assert np.asarray(res.missed).all()
    assert not np.asarray(res.degraded).any()
    # even when fetch is arbitrarily slow the unusable replica stays out —
    # the argmin falls through to drop, never to degraded
    res2 = substitute(idx, logits, resident, table, q, pol,
                      fid_cost=jnp.full((4,), jnp.inf),
                      fetch_cost=jnp.full((4,), 10.0, jnp.float32))
    assert not np.asarray(res2.degraded).any()
    assert np.asarray(res2.dropped).all()
    # host-side mirror agrees
    m = MissCostModel(1, 4, expert_bytes=1000)
    out = m.outcome_argmin(np.full((1, 4), 0.01),
                           fidelity=np.full((1, 4), np.inf), best_q=None)
    assert (out == FETCH).all()


def test_outcome_argmin_tie_break_and_drop():
    m = MissCostModel(1, 2, expert_bytes=1000, stall_per_quality=0.05,
                      drop_loss=1.0)
    # perfect buddy (cost 0) ties nothing else: buddy wins
    out = m.outcome_argmin(np.full((1, 2), 1.0),
                           fidelity=np.zeros((1, 2)),
                           best_q=np.ones((1, 2)))
    assert (out == BUDDY).all(), "equal zero cost breaks to the buddy"
    # nothing usable but a cheap drop
    m2 = MissCostModel(1, 2, expert_bytes=1000, stall_per_quality=0.05,
                       drop_loss=0.1)
    out2 = m2.outcome_argmin(np.full((1, 2), 1.0))
    assert (out2 == DROP).all()
    assert m2.drop_cost() == pytest.approx(0.005)
    # degraded beats a cold fetch when the replica is good
    out3 = m.outcome_argmin(np.full((1, 2), 1.0),
                            fidelity=np.full((1, 2), 0.01))
    assert (out3 == DEGRADED).all()


def test_cold_miss_eta_equals_modeled_full_transfer(setup):
    """Cost-model edge: with nothing in flight, every (layer, expert) fetch
    ETA is exactly the hardware model's full transfer time — and the
    engine's _miss_eta agrees."""
    nbytes = 123_456
    m = MissCostModel(3, 5, expert_bytes=nbytes)
    eta = m.fetch_eta(TransferScheduler(DEFAULT_HW))
    assert eta.shape == (3, 5)
    assert np.allclose(eta, DEFAULT_HW.transfer_time(nbytes))
    cfg, params, _, tables = setup
    eng = _cost_engine(cfg, params, tables)
    assert np.allclose(eng._miss_eta(),
                       eng.hw.transfer_time(eng._expert_bytes))
    # an in-flight PREFETCH discounts its expert; an UPGRADE does not
    # (background quality repair must not lure the scorer into blocking)
    sched = TransferScheduler(DEFAULT_HW)
    m2 = MissCostModel(2, 4, expert_bytes=nbytes)
    t = sched.submit(0, 1, nbytes, "prefetch")
    sched.submit(1, 2, nbytes, "upgrade")
    eta2 = m2.fetch_eta(sched)
    assert eta2[0, 1] == pytest.approx(sched.eta_s(t))
    assert eta2[1, 2] == pytest.approx(DEFAULT_HW.transfer_time(nbytes))


def test_best_resident_q():
    table = np.asarray([[1, 2], [0, -1], [0, 1], [-1, -1]])
    q = np.asarray([[0.9, 0.5], [0.3, 0.0], [0.8, 0.7], [0.0, 0.0]])
    res = np.asarray([False, True, True, False])
    np.testing.assert_allclose(best_resident_q(table, q, res),
                               [0.9, -1.0, 0.7, -1.0])
    # stacked [L, E, R] form slices like the per-layer calls
    res2 = np.stack([res, [True, False, False, True]])
    b3 = best_resident_q(np.stack([table] * 2), np.stack([q] * 2), res2)
    np.testing.assert_allclose(b3[0], [0.9, -1.0, 0.7, -1.0])
    np.testing.assert_allclose(b3[1], [-1.0, 0.3, 0.8, -1.0])


# ---------------------------------------------------------------------------
# the fifth outcome: peer-HBM borrow
# ---------------------------------------------------------------------------
def test_five_way_argmin_tie_breaks():
    """Canonical precedence at EQUAL cost: buddy > degraded > peer > fetch >
    drop (np.argmin takes the first minimal row; reroutes beat transfers,
    the cheaper link beats the host)."""
    # 0.5 is exactly representable, so 1 - bq == fid == drop_cost bit-for-bit
    m = MissCostModel(1, 2, expert_bytes=1000, stall_per_quality=1.0,
                      drop_loss=0.5)
    c = m.drop_cost()           # 0.5 — make every outcome cost exactly this
    fetch = np.full((1, 2), c)
    peer = np.full((1, 2), c)
    fid = np.full((1, 2), 0.5)
    bq = np.full((1, 2), 0.5)
    assert (m.outcome_argmin(fetch, fid, bq, peer) == BUDDY).all()
    assert (m.outcome_argmin(fetch, fid, None, peer) == DEGRADED).all()
    assert (m.outcome_argmin(fetch, None, None, peer) == PEER).all(), \
        "a peer borrow beats an equally-priced host fetch"
    assert (m.outcome_argmin(fetch, None, None, None) == FETCH).all()
    assert (m.outcome_argmin(np.full((1, 2), np.inf)) == DROP).all()
    # peer_eta=None (single-device call sites) prices the peer row at inf:
    # codes never shift, so FETCH is still 3 on a 4-outcome stack
    assert (m.outcome_argmin(np.full((1, 2), 1e-6)) == FETCH).all()


def test_peer_eta_vs_pcie_fetch_crossover():
    """The economics of the fifth outcome: an idle ICI link wins against a
    PCIe fetch, but enough queued demand traffic on the owning link pushes
    the borrow past the host ETA and the argmin falls back to FETCH."""
    nbytes = 4 << 20
    m = MissCostModel(1, 4, expert_bytes=nbytes)
    links = make_ici_links(2, DEFAULT_HW)
    peer_res = np.zeros((2, 1, 4), bool)
    peer_res[1, 0, :] = True            # device 1 owns everything
    fetch = m.fetch_eta(None)           # cold PCIe everywhere
    eta = m.peer_eta(links, peer_res)
    assert (eta[0] < fetch[0]).all(), "idle ICI beats cold PCIe"
    assert (m.outcome_argmin(fetch, peer_eta=eta) == PEER).all()
    # pile demand transfers onto the owning link until the queue backlog
    # alone exceeds the full host transfer: the borrow now loses
    backlog_needed = DEFAULT_HW.transfer_time(nbytes)
    n = int(np.ceil(backlog_needed / links[1].transfer_time(nbytes))) + 1
    for i in range(n):
        links[1].submit(5, i, nbytes, "peer")    # other layer: no discount
    eta2 = m.peer_eta(links, peer_res)
    assert (eta2[0] > fetch[0]).all(), "a saturated ICI queue loses to PCIe"
    assert (m.outcome_argmin(fetch, peer_eta=eta2) == FETCH).all()
    # an expert ALREADY in flight on the link pays only its remaining tail
    t = links[1].submit(0, 2, nbytes, "peer")
    eta3 = m.peer_eta(links, peer_res)
    assert eta3[0, 2] == pytest.approx(links[1].eta_s(t))
    assert eta3[0, 2] < eta2[0, 2]


def test_peer_eta_unheld_expert_is_inf():
    m = MissCostModel(1, 4, expert_bytes=1000)
    links = make_ici_links(3, DEFAULT_HW)
    peer_res = np.zeros((3, 1, 4), bool)
    peer_res[1, 0, 1] = True
    peer_res[2, 0, 2] = True
    eta = m.peer_eta(links, peer_res)
    assert np.isfinite(eta[0, 1]) and np.isfinite(eta[0, 2])
    assert np.isinf(eta[0, 0]) and np.isinf(eta[0, 3])
    # no links at all (single device): everything inf
    assert np.isinf(m.peer_eta({}, peer_res)).all()


# ---------------------------------------------------------------------------
# degraded-then-upgrade
# ---------------------------------------------------------------------------
def test_upgrade_bytes_counted_once_and_tokens_not_flipped(setup):
    """Cost-model edge: an upgrade landing mid-step must not double-count
    bytes (duplicate submissions reuse the in-flight transfer) and must not
    flip tokens already computed from the step's residency snapshot — the
    upgraded expert only changes FUTURE steps."""
    cfg, params, lm, tables = setup
    prompts = lm.sample(2, 4)

    eng = _cost_engine(cfg, params, tables, mode="none")
    assert eng.upgrade_degraded, "cost mode + tier auto-enables upgrades"
    ref = _cost_engine(cfg, params, tables, mode="none", upgrade=False)

    caches = eng.init_caches(2, 8)
    caches_r = ref.init_caches(2, 8)
    tok = jnp.asarray(prompts[:, 0], jnp.int32)
    # step 0: identical snapshots -> identical logits even though eng's
    # upgrades complete DURING the step's timeline replay
    lg, caches = eng.step(tok, caches, 0)
    lg_r, caches_r = ref.step(tok, caches_r, 0)
    assert eng.stats.n_upgrade_issued > 0
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lg, -1)),
                                  np.asarray(jnp.argmax(lg_r, -1)))
    # degraded accounting reflects the snapshot, not the post-upgrade state
    assert eng.ledger.events_by_cause["degraded"] == \
        ref.ledger.events_by_cause["degraded"]

    # run a few more steps: every upgrade's bytes are counted exactly once
    for pos in range(1, 4):
        tok = jnp.asarray(prompts[:, min(pos, prompts.shape[1] - 1)],
                          jnp.int32)
        _, caches = eng.step(tok, caches, pos)
    n_up = eng.ledger.events_by_cause["upgrade"]
    assert n_up == eng.stats.n_upgrade_issued
    assert eng.ledger.bytes_by_cause["upgrade"] == n_up * eng._expert_bytes
    # upgrades are speculative traffic: they never stalled a layer
    assert eng.ledger.demand_stall_s == 0.0
    assert eng.ledger.late_prefetch_stall_s == 0.0


def test_upgrade_lands_and_serves_full_precision(setup):
    """After the background upgrade arrives, the expert is RESIDENT: the
    next step's snapshot serves it as a full-precision cache hit instead of
    another degraded compute."""
    cfg, params, lm, tables = setup
    prompts = lm.sample(2, 4)
    eng = _cost_engine(cfg, params, tables, mode="none")
    caches = eng.init_caches(2, 8)
    _, caches = eng.step(jnp.asarray(prompts[:, 0], jnp.int32), caches, 0)
    ups = [(t.layer, t.expert) for t in eng.scheduler.pending()
           if t.cause == "upgrade"]
    assert eng.stats.n_upgrade_issued > 0
    eng.scheduler.flush()          # land every in-flight upgrade
    # the landed experts were inserted (capacity may have evicted earlier
    # arrivals — at least the most recent upgrade per layer survives)
    landed = {}
    for l, e in ups:
        landed[l] = e
    for l, e in landed.items():
        assert eng.cache.resident[l, e], \
            "a landed upgrade must be resident full-precision"
    # the next snapshot serves those experts from the cache: neither the
    # degraded mask (cost mode: fid_cost) nor the miss path applies to a
    # resident expert (substitute() only scores ~resident slots)
    state = eng._buddy_state()
    for l, e in landed.items():
        assert bool(np.asarray(state.resident)[l, e])


# ---------------------------------------------------------------------------
# P(use) x lateness-risk prefetch ranking
# ---------------------------------------------------------------------------
def test_prefetch_scores_rank_by_expected_stall_saved():
    m = MissCostModel(1, 4, expert_bytes=1000, stall_per_quality=0.05)
    p_use = np.asarray([0.9, 0.5, 0.9, 0.1])
    # expert 0's miss is nearly free (great buddy); expert 2's stalls
    miss_cost = np.asarray([1e-4, 5e-3, 8e-3, 8e-3])
    resident = np.asarray([False, False, False, False])
    s = m.prefetch_scores(p_use, miss_cost, resident)
    order = np.argsort(-s)
    assert order[0] == 2, "high P(use) x high stall risk ranks first"
    assert s[0] < s[1], "a miss a buddy absorbs is worth less than a " \
        "rarer but stalling one"
    # residency / in-flight zero the saving
    s2 = m.prefetch_scores(p_use, miss_cost, np.asarray([0, 0, 1, 0], bool),
                           inflight=np.asarray([0, 1, 0, 0], bool))
    assert s2[2] == 0.0 and s2[1] == 0.0


def test_predict_proba_all_predictors():
    """Contract: per-expert MARGINAL P(use) in [0, 1] (not a distribution
    summing to 1) so the absolute saving threshold treats every predictor
    on the oracle's scale."""
    for cls in (TopFreqPredictor, PrevStepPredictor, CrossLayerPredictor):
        p = cls(2, 4)
        p.observe(1, [0, 0, 2])
        if hasattr(p, "observe_transition"):
            p.observe_transition(1, [1], [0, 2])
        proba = p.predict_proba(1)
        assert proba.shape == (4,)
        assert (proba >= 0).all() and (proba <= 1.0 + 1e-9).all()
        assert proba[0] > proba[3], f"{cls.__name__}: observed expert " \
            "must outrank an unseen one"
    o = NoisyOraclePredictor(2, 4, accuracy=0.75)
    o.set_truth(0, [1])
    po = o.predict_proba(0)
    assert po[1] == pytest.approx(0.75 + 0.25 / 4)
    assert po[0] == pytest.approx(0.25 / 4)
    # marginal scale: a certain-reuse expert under PrevStep scores near its
    # blend weight, the same order of magnitude as the oracle's accuracy —
    # NOT divided by the used-set size
    ps = PrevStepPredictor(1, 8)
    ps.observe(0, [0, 1, 2, 3])
    assert ps.predict_proba(0)[0] >= PrevStepPredictor.PREV_WEIGHT


def test_engine_cost_ranked_prefetch_and_worthwhile(setup):
    """With the cost policy and a proba predictor, prefetches follow the
    expected-stall-saved ranking and the worthwhile count is exposed for
    the budget controller."""
    cfg, params, lm, tables = setup
    l, e = cfg.num_layers, cfg.moe.num_experts
    eng = _cost_engine(cfg, params, tables, prefetch_k=2,
                       predictor=PrevStepPredictor(l, e))
    eng.generate(lm.sample(2, 4), max_new_tokens=6)
    assert eng.stats.n_prefetch_issued > 0
    assert eng.last_prefetch_worthwhile is not None
    # direct ranking call: scores positive only off-residency
    want, worthwhile = eng._rank_prefetch(0, np.asarray([0, 1]))
    assert len(want) <= eng.prefetch_k
    for ex in want:
        assert not eng.cache.resident[0, ex]


def test_cost_ranked_prefetch_keeps_own_inflight(setup):
    """An in-flight prefetch that is still attractive must stay in the
    keep-list fed to cancel_stale_prefetches — otherwise the engine would
    cancel and re-issue its own unfinished prefetches every step
    (issue/cancel ping-pong that never accumulates lead time)."""
    cfg, params, lm, tables = setup
    l, e = cfg.num_layers, cfg.moe.num_experts
    eng = _cost_engine(cfg, params, tables, prefetch_k=2,
                       predictor=PrevStepPredictor(l, e))
    ctx = np.asarray([0, 1])
    eng.predictor.observe(0, ctx)
    eng._issue_prefetches(0, ctx)
    issued = [(t.layer, t.expert) for t in eng.scheduler.pending()
              if t.cause == "prefetch"]
    assert issued, "ranking issued nothing to keep alive"
    # same prediction context again: the in-flight transfers survive
    eng._issue_prefetches(0, ctx)
    assert eng.stats.n_prefetch_cancelled == 0
    still = [(t.layer, t.expert) for t in eng.scheduler.pending()
             if t.cause == "prefetch"]
    assert set(issued) <= set(still)


def test_rank_prefetch_mode_none_ignores_buddies(setup):
    """mode='none' never reroutes, so the ranking must not discount miss
    costs by buddy quality the argmin will never use: its scores are at
    least the buddy-aware engine's on identical state."""
    cfg, params, lm, tables = setup
    l, e = cfg.num_layers, cfg.moe.num_experts
    a = _cost_engine(cfg, params, tables, mode="none", prefetch_k=2,
                     predictor=PrevStepPredictor(l, e))
    b = _cost_engine(cfg, params, tables, mode="buddy", prefetch_k=2,
                     predictor=PrevStepPredictor(l, e))
    for eng in (a, b):
        eng.predictor.observe(0, [0, 1])
    fid = np.full((l, e), 0.01)
    a.tier.attach_fidelity(fid)
    b.tier.attach_fidelity(fid)
    eta = a.costs.fetch_eta(a.scheduler)[0]
    bq_buddy = best_resident_q(a._table[0], a._q[0], a.cache.resident[0])
    risk_none = a.costs.miss_cost(eta, fid[0], None)
    risk_buddy = a.costs.miss_cost(eta, fid[0], bq_buddy)
    assert (risk_none >= risk_buddy - 1e-12).all()
    _, w_none = a._rank_prefetch(0, np.asarray([0, 1]))
    assert w_none >= 0  # runs end-to-end without a buddy term


def test_controller_worthwhile_caps_budget():
    c = AdaptiveBudgetController(prefetch_k=4, lookahead=1, min_k=1,
                                 max_k=8, window=1)
    demand = {"demand_stall_s": 1.0, "late_prefetch_stall_s": 0.0,
              "overlapped_s": 0.0}
    b = c.update(demand, queue_depth=8, worthwhile=2)
    assert b.prefetch_k <= 2
    assert c.trace[-1]["worthwhile"] == 2
    # without the signal the demand-dominant rule grows k as before
    c2 = AdaptiveBudgetController(prefetch_k=4, lookahead=1, min_k=1,
                                  max_k=8, window=1)
    assert c2.update(demand, queue_depth=8).prefetch_k == 5


# ---------------------------------------------------------------------------
# partial-coverage tiers
# ---------------------------------------------------------------------------
def test_partial_coverage_frees_slots_and_limits_degrade(setup):
    cfg, *_ = setup
    e = cfg.moe.num_experts
    full_cov = _tier(cfg, rate=1.0)
    half_cov = _tier(cfg, rate=1.0, coverage=0.5)
    assert half_cov.n_covered == 2
    assert half_cov.cache.capacity >= full_cov.cache.capacity
    assert half_cov.quant_bytes < full_cov.quant_bytes
    sp = half_cov.budget_split()
    assert sp["coverage"] == 0.5 and sp["covered_per_layer"] == 2

    # top-activity experts get the replicas
    act = np.tile(np.asarray([1.0, 9.0, 3.0, 0.1]), (cfg.num_layers, 1))
    half_cov.set_coverage(act)
    assert half_cov.covered[:, 1].all() and half_cov.covered[:, 2].all()
    assert not half_cov.covered[:, 0].any()

    # uncovered experts never degrade (precedence mask AND cost fidelity)
    half_cov.attach_fidelity(np.full((cfg.num_layers, e), 0.01))
    eta = np.full((cfg.num_layers, e), 1.0)
    ok = half_cov.degraded_ok(np.zeros((cfg.num_layers, e), bool), eta)
    assert ok[:, 1].all() and not ok[:, 0].any()
    eff = half_cov.effective_fidelity()
    assert np.isinf(eff[:, 0]).all() and np.isfinite(eff[:, 1]).all()


def test_partial_coverage_engine_runs(setup):
    cfg, params, lm, tables = setup
    eng = _cost_engine(cfg, params, tables, mode="none",
                       tier_kw={"coverage": 0.5})
    eng.generate(lm.sample(1, 3), max_new_tokens=4)
    s = eng.summary()
    assert s["tier"]["tier_budget_split"]["coverage"] == 0.5
    # misses on uncovered experts fell through to fetch, not degrade
    deg = s["tier"]["degraded_tokens"]
    assert np.isfinite(eng.teacher_forced_nll(lm.sample(1, 4)))
    assert deg >= 0  # engine runs end-to-end with a partial tier


# ---------------------------------------------------------------------------
# precedence mode is untouched (regression guard)
# ---------------------------------------------------------------------------
def test_precedence_mode_summary_has_no_cost_section(setup):
    cfg, params, lm, tables = setup
    eng = ServeEngine(cfg, params, tables=tables,
                      policy=BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8),
                      cache=ExpertCache(cfg.num_layers, cfg.moe.num_experts,
                                        0.5, seed=0), seed=0)
    eng.generate(lm.sample(1, 3), max_new_tokens=3)
    s = eng.summary()
    assert "cost_policy" not in s
    assert not eng.upgrade_degraded


def test_cost_policy_rejects_drop_fallback():
    with pytest.raises(AssertionError):
        BuddyPolicy(miss_policy="cost", fallback="drop")
