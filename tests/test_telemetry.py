"""Flight recorder (runtime/telemetry.py + runtime/trace.py): metric merge
semantics, trace export round-trips, request-span completeness under the
continuous scheduler, and the hard invariant — telemetry off is bit-identical
to the pre-telemetry engine."""
import types

import jax
import numpy as np
import pytest

from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.core import BuddyPolicy, build_buddy_lists
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.prefetch import PrevStepPredictor
from repro.runtime.telemetry import (EMA, CalibrationMeter, Counter,
                                     ExpertStats, Gauge, Histogram,
                                     MetricsRegistry, PrefetchMeter,
                                     Telemetry)
from repro.runtime.trace import FlightRecorder, export_trace
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import (ContinuousScheduler, PoissonArrivals,
                                     RequestQueue, SLOConfig, make_requests)
from repro.training.data import MarkovLM


@pytest.fixture(scope="module")
def setup():
    cfg = reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    lm = MarkovLM(cfg.vocab_size, seed=0)
    l, e = cfg.num_layers, cfg.moe.num_experts
    rng = np.random.default_rng(0)
    tables = build_buddy_lists(rng.random((l, e, e)), alpha=0.95, k_max=e - 1)
    return cfg, params, lm, tables


def _engine(cfg, params, tables, *, rate=0.5, seed=0, telemetry=None,
            prefetch_k=2, mode="buddy"):
    l, e = cfg.num_layers, cfg.moe.num_experts
    return ServeEngine(cfg, params, tables=tables,
                       policy=BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8,
                                          mode=mode),
                       cache=ExpertCache(l, e, rate, seed=seed),
                       predictor=PrevStepPredictor(l, e),
                       prefetch_k=prefetch_k, seed=seed, telemetry=telemetry)


# ===========================================================================
# Metric primitives: merge semantics
# ===========================================================================
def test_counter_and_gauge_merge():
    a, b = Counter(), Counter()
    a.inc(3)
    b.inc(4)
    a.merge(b)
    assert a.snapshot() == 7
    g, h = Gauge(), Gauge()
    g.set(2.0)
    h.set(5.0)
    g.merge(h)              # high-water semantics across registries
    assert g.snapshot() == 5.0


def test_histogram_merge_exact():
    a, b = Histogram(), Histogram()
    for v in (1e-5, 3e-4, 0.02):
        a.observe(v)
    for v in (0.02, 1.5):
        b.observe(v, n=2)
    a.merge(b)
    s = a.snapshot()
    assert s["n"] == 7
    assert s["sum"] == pytest.approx(1e-5 + 3e-4 + 0.02 + 2 * 0.02 + 2 * 1.5)
    assert s["min"] == pytest.approx(1e-5)
    assert s["max"] == pytest.approx(1.5)
    # quantile is the bucket upper bound -> never below the true value
    assert a.quantile(0.99) >= 1.5
    with pytest.raises(AssertionError):
        a.merge(Histogram(bounds=(0.1, 1.0)))


def test_histogram_bucket_edges():
    h = Histogram(bounds=(1.0, 10.0))
    h.observe(1.0)          # value AT a bound lands in that bucket
    h.observe(10.0)
    h.observe(100.0)        # overflow bucket
    assert h.counts == [1, 1, 1]


def test_ema_merge_count_weighted():
    a, b = EMA(alpha=0.5), EMA(alpha=0.5)
    a.update(1.0)           # first sample seeds, no pull toward zero
    assert a.value == 1.0
    a.update(3.0)
    assert a.value == pytest.approx(2.0)
    b.update(10.0)
    a.merge(b)
    assert a.n == 3
    assert a.value == pytest.approx((2.0 * 2 + 10.0 * 1) / 3)
    with pytest.raises(AssertionError):
        a.merge(EMA(alpha=0.1))


def test_registry_labels_kinds_and_merge():
    r = MetricsRegistry()
    r.counter("slots", outcome="hit").inc(5)
    r.counter("slots", outcome="fetch").inc(1)
    r.ema("step_time_s", alpha=0.05).update(0.5)
    with pytest.raises(AssertionError):    # one kind per name
        r.gauge("slots")
    other = MetricsRegistry()
    other.counter("slots", outcome="hit").inc(2)
    other.counter("inflight").inc(1)
    r.merge(other)
    snap = r.snapshot()
    assert snap["slots"]["outcome=hit"] == 7
    assert snap["slots"]["outcome=fetch"] == 1
    assert snap["inflight"][""] == 1
    # merging must NOT alias the source registry's metric objects
    other.counter("inflight").inc(10)
    assert r.snapshot()["inflight"][""] == 1


def test_expert_stats_ema_update():
    st = ExpertStats(num_layers=2, num_experts=4, alpha=0.5)
    st.update(0, used=[1, 2], hit=[1], missed=[2])
    assert st.used_ema[0, 1] == pytest.approx(0.5)
    assert st.miss_ema[0, 2] == pytest.approx(0.5)
    assert st.miss_ema[0, 1] == 0.0
    st.update(0, used=[1], hit=[1], missed=[])
    assert st.used_ema[0, 1] == pytest.approx(0.75)     # 0.5*0.5 + 0.5
    assert st.miss_ema[0, 2] == pytest.approx(0.25)     # decayed only
    top = st.summary(top_k=2)["top_miss"]
    assert top and top[0]["expert"] == 2


# ===========================================================================
# Calibration + prefetch meters
# ===========================================================================
def test_calibration_meter_residuals():
    c = CalibrationMeter()
    c.record("fetch", predicted_s=1.0, realized_s=1.5)
    c.record("fetch", predicted_s=2.0, realized_s=1.5)
    c.record("buddy", 0.0, 0.0, quality_cost=0.3)
    s = c.summary()
    f = s["fetch"]
    assert f["n"] == 2
    assert f["residual_mean_s"] == pytest.approx(0.0)    # +0.5 and -0.5
    assert f["residual_abs_mean_s"] == pytest.approx(0.5)
    assert f["residual_rms_s"] == pytest.approx(0.5)
    assert f["residual_max_abs_s"] == pytest.approx(0.5)
    assert s["buddy"]["quality_cost_mean"] == pytest.approx(0.3)
    assert s["degraded"] == {"n": 0}
    other = CalibrationMeter()
    other.record("fetch", 1.0, 1.0)
    c.merge(other)
    assert c.summary()["fetch"]["n"] == 3


def _tev(cause, layer, expert):
    return types.SimpleNamespace(cause=cause, layer=layer, expert=expert)


def test_prefetch_meter_late_is_not_used_in_time():
    """An escalated (late) prefetch that lands and whose expert is then
    routed to must count as LATE, never as a used-in-time true positive —
    the layer already stalled for its tail."""
    m = PrefetchMeter("test")
    t = _tev("prefetch", 0, 1)
    m.on_transfer_event("submit", t)
    m.on_transfer_event("escalate", t)
    m.on_transfer_event("complete", t)
    m.note_used(0, [1])
    assert (m.n_issued, m.n_late, m.n_used) == (1, 1, 0)

    # clean landing -> used-in-time, credited once per landed transfer
    t2 = _tev("prefetch", 0, 2)
    m.on_transfer_event("submit", t2)
    m.on_transfer_event("complete", t2)
    m.note_used(0, [2])
    m.note_used(0, [2])
    assert m.n_used == 1
    m.note_uncovered_miss(0, 3)
    assert m.precision() == pytest.approx(1 / 2)
    assert m.recall() == pytest.approx(1 / 3)   # used + late + uncovered
    # non-prefetch causes are ignored entirely
    m.on_transfer_event("submit", _tev("demand", 0, 0))
    assert m.n_issued == 2


# ===========================================================================
# Trace: ordering, JSONL round-trip, Perfetto export
# ===========================================================================
def test_trace_sequence_ordering_deterministic():
    fr = FlightRecorder()
    fr.instant("engine", 0, "a", "a", 1.0)
    fr.instant("engine", 0, "b", "b", 0.5)
    fr.instant("engine", 0, "c", "c", 0.5)   # same ts -> seq breaks the tie
    evs = fr.sorted_events()
    assert [e["name"] for e in evs] == ["b", "c", "a"]
    assert evs[0]["seq"] < evs[1]["seq"]
    seqs = [e["seq"] for e in fr.sorted_events()]
    assert seqs == [e["seq"] for e in fr.sorted_events()]   # stable


def test_jsonl_roundtrip(tmp_path):
    fr = FlightRecorder()
    fr.instant("requests", 1, "arrive", "req1", 0.0, prompt_len=4)
    fr.span("layers", 0, "compute", "compute", 0.0, 0.5, tokens=3)
    p = str(tmp_path / "trace.jsonl")
    n = fr.export_jsonl(p)
    assert n == 2
    assert FlightRecorder.load_jsonl(p) == fr.sorted_events()
    assert export_trace(fr, str(tmp_path / "t2.jsonl")) == 2
    assert export_trace(None, str(tmp_path / "none.jsonl")) == 0


def test_perfetto_export(tmp_path):
    fr = FlightRecorder()
    fr.span("requests", 7, "decode", "decode", 0.001, 0.003, tokens=2)
    fr.instant("transfers", 0, "submit", "x", 0.002)
    d = fr.to_perfetto()
    meta = {e["args"]["name"]: e["pid"] for e in d["traceEvents"]
            if e["ph"] == "M"}
    assert set(meta) == {"requests", "layers", "transfers", "engine"}
    spans = [e for e in d["traceEvents"] if e["ph"] == "X"]
    assert len(spans) == 1
    assert spans[0]["pid"] == meta["requests"] and spans[0]["tid"] == 7
    assert spans[0]["ts"] == pytest.approx(0.001 * 1e6)     # microseconds
    assert spans[0]["dur"] == pytest.approx(0.002 * 1e6)
    insts = [e for e in d["traceEvents"] if e["ph"] == "i"]
    assert len(insts) == 1 and insts[0]["pid"] == meta["transfers"]
    n = export_trace(fr, str(tmp_path / "trace.json"))
    assert n == len(d["traceEvents"])


# ===========================================================================
# Engine integration
# ===========================================================================
def test_transfer_event_seq_monotonic(setup):
    cfg, params, lm, tables = setup
    tele = Telemetry.with_trace(num_layers=cfg.num_layers,
                                num_experts=cfg.moe.num_experts)
    eng = _engine(cfg, params, tables, mode="none", telemetry=tele)
    seqs = []
    eng.scheduler.add_listener(lambda kind, t: seqs.append(t.event_seq))
    eng.generate(lm.sample(2, 4), max_new_tokens=4)
    assert len(seqs) > 0
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)


def test_bit_identity_telemetry_off_vs_on(setup):
    """The hard invariant: telemetry only OBSERVES. Same tokens, same
    simulated clock, same summary (minus the telemetry section)."""
    cfg, params, lm, tables = setup
    prompts = lm.sample(2, 5)
    eng_off = _engine(cfg, params, tables, seed=3)
    out_off = np.asarray(eng_off.generate(prompts, max_new_tokens=6))
    tele = Telemetry.with_trace(predictor_label="prev_step",
                                num_layers=cfg.num_layers,
                                num_experts=cfg.moe.num_experts)
    eng_on = _engine(cfg, params, tables, seed=3, telemetry=tele)
    out_on = np.asarray(eng_on.generate(prompts, max_new_tokens=6))
    assert np.array_equal(out_off, out_on)
    assert eng_off.stats.sim_time_s == eng_on.stats.sim_time_s
    s_off, s_on = eng_off.summary(), dict(eng_on.summary())
    assert "telemetry" not in s_off
    tele_section = s_on.pop("telemetry")
    assert s_off == s_on
    # and the on-engine actually recorded something
    assert tele_section["metrics"]
    assert len(tele.trace) > 0


def test_fetch_calibration_matches_timeline(setup):
    """Fetch residuals are ~0: the predicted ETA and the realized stall
    come from the same deterministic bandwidth model."""
    cfg, params, lm, tables = setup
    tele = Telemetry(num_layers=cfg.num_layers,
                     num_experts=cfg.moe.num_experts)
    eng = _engine(cfg, params, tables, mode="none", prefetch_k=0,
                  telemetry=tele)
    eng.generate(lm.sample(2, 5), max_new_tokens=6)
    f = tele.calibration.summary()["fetch"]
    assert f["n"] > 0
    assert f["residual_abs_mean_s"] == pytest.approx(0.0, abs=1e-9)
    assert f["predicted_mean_s"] > 0


def _workload(lm, n, rate, max_new, slo, seed=1):
    rng = np.random.default_rng(seed)
    prompts = [lm.sample(1, int(rng.integers(4, 9)))[0] for _ in range(n)]
    return make_requests(prompts, PoissonArrivals(rate, seed=seed + 1),
                         max_new, slo)


def test_request_span_completeness_continuous(setup):
    """Every request that completes under mid-step join/retire gets a full
    lifecycle on the requests track: arrive -> queued -> prefill -> decode
    -> retire, with per-token instants matching its emitted tokens."""
    cfg, params, lm, tables = setup
    tele = Telemetry.with_trace(num_layers=cfg.num_layers,
                                num_experts=cfg.moe.num_experts)
    eng = _engine(cfg, params, tables, telemetry=tele)
    slo = SLOConfig(ttft_s=1.0, tpot_s=1.0, deadline_s=10.0)
    sched = ContinuousScheduler(eng, slots=2, prefill_chunk=2)
    s = sched.run(RequestQueue(_workload(lm, 6, 800.0, 4, slo)))
    assert s["completed"] == 6
    evs = tele.trace.sorted_events()
    by_req = {}
    for ev in evs:
        if ev["track"] == "requests":
            by_req.setdefault(ev["lane"], []).append(ev)
    assert set(by_req) == set(range(6))
    for rid, req_evs in by_req.items():
        kinds = [e["kind"] for e in req_evs]
        for k in ("arrive", "queued", "prefill", "decode", "retire"):
            assert k in kinds, f"req {rid} missing {k}"
        dec = next(e for e in req_evs if e["kind"] == "decode")
        toks = [e for e in req_evs if e["kind"] == "token"]
        assert len(toks) == dec["args"]["tokens"]
        ret = next(e for e in req_evs if e["kind"] == "retire")
        assert ret["args"]["e2e_s"] >= ret["args"]["ttft_s"] >= 0
    # summary() ran inside run(); a second call must not duplicate spans
    sched.summary(RequestQueue([]))
    assert len(tele.trace.sorted_events()) == len(evs)


def test_request_spans_shed_requests(setup):
    """SLO-aware admission sheds doomed requests; they still appear on the
    requests track as arrive -> queued -> shed (no prefill/decode)."""
    cfg, params, lm, tables = setup
    tele = Telemetry.with_trace(num_layers=cfg.num_layers,
                                num_experts=cfg.moe.num_experts)
    eng = _engine(cfg, params, tables, telemetry=tele)
    slo = SLOConfig(deadline_s=1e-9)     # impossible: everything sheds
    queue = RequestQueue(_workload(lm, 4, 800.0, 3, slo), admission="slo")
    s = ContinuousScheduler(eng, slots=2).run(queue)
    assert s["rejected"] == 4
    req_evs = [e for e in tele.trace.sorted_events()
               if e["track"] == "requests"]
    kinds_by_req = {}
    for ev in req_evs:
        kinds_by_req.setdefault(ev["lane"], set()).add(ev["kind"])
    assert len(kinds_by_req) == 4
    for rid, kinds in kinds_by_req.items():
        assert {"arrive", "queued", "shed"} <= kinds
        assert "decode" not in kinds and "retire" not in kinds


def test_engine_summary_contains_telemetry(setup):
    cfg, params, lm, tables = setup
    tele = Telemetry(num_layers=cfg.num_layers,
                     num_experts=cfg.moe.num_experts)
    eng = _engine(cfg, params, tables, telemetry=tele)
    eng.generate(lm.sample(2, 4), max_new_tokens=4)
    s = eng.summary()
    assert "telemetry" in s
    assert "calibration" in s["telemetry"]
    assert "prefetch" in s["telemetry"]
    assert s["telemetry"]["expert_stats"]["steps"] > 0
    assert "slots" in s["telemetry"]["metrics"]


def test_telemetry_survives_reset_runtime(setup):
    """reset_runtime rebuilds the scheduler — the recorder must be re-wired
    so post-reset transfers keep landing in the same bundle."""
    cfg, params, lm, tables = setup
    tele = Telemetry.with_trace(num_layers=cfg.num_layers,
                                num_experts=cfg.moe.num_experts)
    eng = _engine(cfg, params, tables, mode="none", telemetry=tele)
    eng.generate(lm.sample(2, 4), max_new_tokens=3)
    n_before = len(tele.trace)
    assert n_before > 0
    eng.reset_runtime()
    assert eng.scheduler.trace is tele.trace
    eng.generate(lm.sample(2, 4), max_new_tokens=3)
    assert len(tele.trace) > n_before
