"""MoE layer: dispatch correctness vs dense per-expert reference, capacity
behavior, shared experts, and the buddy hook."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core.policy import BuddyPolicy
from repro.models import moe as M


def _dense_ref(params, x, cfg: MoEConfig):
    """Reference: run every expert on every token, combine by top-k weights."""
    xf = np.asarray(x, np.float64).reshape(-1, x.shape[-1])
    logits = xf @ np.asarray(params["router"], np.float64)
    order = np.argsort(-logits, axis=1, kind="stable")[:, :cfg.top_k]
    picked = np.take_along_axis(logits, order, axis=1)
    w = np.exp(picked - picked.max(1, keepdims=True))
    w /= w.sum(1, keepdims=True)
    y = np.zeros_like(xf)
    for e in range(cfg.num_experts):
        w1 = np.asarray(params["w1"][e], np.float64)
        w3 = np.asarray(params["w3"][e], np.float64)
        w2 = np.asarray(params["w2"][e], np.float64)
        h = (xf @ w1) * (1 / (1 + np.exp(-(xf @ w1)))) * (xf @ w3)
        ye = h @ w2
        for k in range(cfg.top_k):
            mask = (order[:, k] == e)
            y[mask] += w[mask, k][:, None] * ye[mask]
    if cfg.num_shared_experts and "shared" in params:
        s = params["shared"]
        hx = xf @ np.asarray(s["w1"], np.float64)
        h = hx * (1 / (1 + np.exp(-hx))) * (xf @ np.asarray(s["w3"], np.float64))
        y += h @ np.asarray(s["w2"], np.float64)
    return y.reshape(x.shape)


@pytest.mark.parametrize("shared", [0, 1])
def test_moe_matches_dense_reference(shared):
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff=32, num_shared_experts=shared)
    key = jax.random.PRNGKey(0)
    params = M.init_moe(key, 24, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 9, 24)) * 0.5
    y, aux = M.moe_forward(params, x, cfg, capacity_factor=4.0)
    ref = _dense_ref(params, np.asarray(x), cfg)
    assert int(aux.n_dropped) == 0
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)


def test_capacity_drops_tokens():
    cfg = MoEConfig(num_experts=2, top_k=1, d_ff=8)
    key = jax.random.PRNGKey(2)
    params = M.init_moe(key, 8, cfg, jnp.float32)
    # force all tokens to one expert by biasing the router
    params["router"] = jnp.zeros_like(params["router"]).at[:, 0].set(10.0)
    x = jax.random.normal(key, (1, 64, 8))
    y, aux = M.moe_forward(params, x, cfg, capacity_factor=0.25)
    assert int(aux.n_dropped) > 0


def test_buddy_substitution_changes_indices():
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff=16)
    key = jax.random.PRNGKey(3)
    params = M.init_moe(key, 16, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 20, 16))
    table = jnp.asarray([[1, 2, 3], [0, 2, 3], [3, 0, 1], [2, 0, 1]],
                        jnp.int32)
    q = jnp.full((4, 3), 0.33, jnp.float32)
    buddy = M.BuddyState(resident=jnp.asarray([True, False, True, False]),
                         table=table, q=q, hop=jnp.zeros((4,), jnp.int32))
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=2, H=3)
    y, aux = M.moe_forward(params, x, cfg, policy=pol, buddy=buddy,
                           capacity_factor=4.0)
    final = np.asarray(aux.indices)
    # all final experts must be resident (every expert has resident buddies)
    assert np.isin(final, [0, 2]).all()
    assert int(aux.n_substituted) > 0
    assert int(aux.n_missed) == 0


def test_original_policy_counts_misses():
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff=16)
    key = jax.random.PRNGKey(4)
    params = M.init_moe(key, 16, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 20, 16))
    buddy = M.BuddyState(resident=jnp.asarray([True, False, True, False]),
                         table=jnp.full((4, 3), -1, jnp.int32),
                         q=jnp.zeros((4, 3)), hop=jnp.zeros((4,), jnp.int32))
    y, aux = M.moe_forward(params, x, cfg, policy=BuddyPolicy(mode="none"),
                           buddy=buddy, capacity_factor=4.0)
    orig = np.asarray(aux.orig_indices)
    expected_misses = np.isin(orig, [1, 3]).sum()
    assert int(aux.n_missed) == expected_misses
    assert int(aux.n_substituted) == 0
    # fetch fallback computes the true experts: output matches full residency
    y_full, _ = M.moe_forward(params, x, cfg, capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_full), rtol=1e-5)


def test_lb_loss_uniform_router_is_one():
    cfg = MoEConfig(num_experts=8, top_k=2, d_ff=8)
    key = jax.random.PRNGKey(5)
    params = M.init_moe(key, 16, cfg, jnp.float32)
    params["router"] = jnp.zeros_like(params["router"])
    x = jax.random.normal(key, (1, 256, 16))
    _, aux = M.moe_forward(params, x, cfg, capacity_factor=4.0)
    # With a uniform router, E * sum(f_e * P_e) = E * E * (k/E) * (1/E) = k
    assert abs(float(aux.lb_loss) - cfg.top_k) < 0.2
