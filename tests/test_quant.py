"""Quant tier numerics + interpret-mode CPU parity for the Pallas kernels.

Covers the new fused dequant SwiGLU kernel (kernels/quant_ffn.py) against
its jnp oracle, the per-channel round-trip error bound of core/quantize.py,
and EXPLICIT interpret=True parity runs of the existing expert_ffn and
buddy_substitute kernels (the ops wrappers pick interpret automatically from
the backend; these pin the CPU-interpret path CI exercises)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize
from repro.kernels import ref
from repro.kernels.buddy_substitute import buddy_substitute_pallas
from repro.kernels.expert_ffn import expert_ffn_pallas
from repro.kernels.quant_ffn import quant_ffn_pallas


def _quant_weights(rng, e, d, f, bits):
    w1 = (rng.normal(size=(e, d, f)) * 0.05).astype(np.float32)
    w3 = (rng.normal(size=(e, d, f)) * 0.05).astype(np.float32)
    w2 = (rng.normal(size=(e, f, d)) * 0.05).astype(np.float32)
    qp = quantize.quantize_expert_ffn(jnp.asarray(w1), jnp.asarray(w3),
                                      jnp.asarray(w2), bits)
    return w1, w3, w2, qp


# ---------------------------------------------------------------------------
# core/quantize.py numerics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [8, 4])
def test_roundtrip_error_bounded_per_channel(bits):
    """Symmetric round-to-nearest: |w - deq(q)| <= scale/2 elementwise, with
    scale = per-channel max / qmax (the issue's int8 round-trip bound)."""
    rng = np.random.default_rng(0)
    w = rng.normal(size=(3, 16, 24)).astype(np.float32)
    q, s = quantize.quantize_per_channel(jnp.asarray(w), bits)
    q, s = np.asarray(q), np.asarray(s)
    qm = quantize.qmax_for_bits(bits)
    assert q.dtype == np.int8 and np.abs(q).max() <= qm
    np.testing.assert_allclose(
        s, np.abs(w).max(axis=-2) / qm, rtol=1e-6)
    err = np.abs(w - np.asarray(quantize.dequantize(jnp.asarray(q),
                                                    jnp.asarray(s))))
    assert (err <= s[:, None, :] / 2 + 1e-7).all()


def test_quantize_zero_channel_safe():
    """All-zero channels must not divide by zero (scale falls back to 1)."""
    w = np.zeros((2, 8, 4), np.float32)
    w[:, :, 0] = 1.0
    q, s = quantize.quantize_per_channel(jnp.asarray(w), 8)
    assert np.isfinite(np.asarray(s)).all()
    np.testing.assert_array_equal(np.asarray(q)[:, :, 1:], 0)


def test_fidelity_orders_precisions():
    """int4 replicas lose strictly more fidelity than int8 (the frontier the
    runtime trades against stall), and exact weights score ~0."""
    rng = np.random.default_rng(1)
    w1, w3, w2, q8 = _quant_weights(rng, 4, 16, 32, 8)
    q4 = quantize.quantize_expert_ffn(jnp.asarray(w1), jnp.asarray(w3),
                                      jnp.asarray(w2), 4)
    f8 = quantize.expert_fidelity(w1, w3, w2, q8)
    f4 = quantize.expert_fidelity(w1, w3, w2, q4)
    assert f8.shape == (4,)
    assert (f8 > 0).all() and (f4 > f8).all()
    assert f8.max() < 0.02 and f4.max() < 0.2


# ---------------------------------------------------------------------------
# quant_ffn kernel parity (explicit interpret=True -> runs on CPU in CI)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("e,c,d,f,bc,bf", [
    (1, 8, 32, 64, 8, 32),
    (4, 96, 128, 384, 32, 128),
    (8, 100, 64, 200, 64, 64),    # non-divisible c/f -> padding path
])
@pytest.mark.parametrize("bits", [8, 4])
def test_quant_ffn_matches_oracle(e, c, d, f, bc, bf, bits):
    rng = np.random.default_rng(e * 100 + c + bits)
    x = (rng.normal(size=(e, c, d)) * 0.1).astype(np.float32)
    _, _, _, qp = _quant_weights(rng, e, d, f, bits)
    args = (jnp.asarray(x), qp["w1_q"], qp["w1_s"], qp["w3_q"], qp["w3_s"],
            qp["w2_q"], qp["w2_s"])
    got = quant_ffn_pallas(*args, block_c=bc, block_f=bf, interpret=True)
    want = ref.ref_quant_ffn(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_quant_ffn_tracks_full_precision():
    """The fused int8 path approximates the full-precision expert FFN within
    the tier's calibrated fidelity budget (the degraded output is a usable
    expert output, not noise)."""
    rng = np.random.default_rng(7)
    e, c, d, f = 2, 16, 32, 64
    x = (rng.normal(size=(e, c, d)) * 0.1).astype(np.float32)
    w1, w3, w2, qp = _quant_weights(rng, e, d, f, 8)
    full = np.asarray(ref.ref_expert_ffn(jnp.asarray(x), jnp.asarray(w1),
                                         jnp.asarray(w3), jnp.asarray(w2)))
    deg = np.asarray(quant_ffn_pallas(
        jnp.asarray(x), qp["w1_q"], qp["w1_s"], qp["w3_q"], qp["w3_s"],
        qp["w2_q"], qp["w2_s"], block_c=8, block_f=32, interpret=True))
    rel = np.linalg.norm(deg - full) / np.linalg.norm(full)
    assert rel < 0.05, f"int8 degraded output {rel:.3f} off full precision"


# ---------------------------------------------------------------------------
# existing kernels: explicit interpret=True parity (satellite)
# ---------------------------------------------------------------------------
def test_expert_ffn_interpret_parity():
    rng = np.random.default_rng(3)
    e, c, d, f = 4, 24, 32, 48
    x = (rng.normal(size=(e, c, d)) * 0.1).astype(np.float32)
    w1 = (rng.normal(size=(e, d, f)) * 0.05).astype(np.float32)
    w3 = (rng.normal(size=(e, d, f)) * 0.05).astype(np.float32)
    w2 = (rng.normal(size=(e, f, d)) * 0.05).astype(np.float32)
    args = [jnp.asarray(a) for a in (x, w1, w3, w2)]
    got = expert_ffn_pallas(*args, block_c=8, block_f=16, interpret=True)
    want = ref.ref_expert_ffn(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_buddy_substitute_interpret_parity():
    rng = np.random.default_rng(4)
    t, e, k, r = 40, 16, 4, 6
    s = np.stack([rng.choice(e, k, replace=False)
                  for _ in range(t)]).astype(np.int32)
    gate = rng.random(t) < 0.7
    resident = rng.random(e) < 0.5
    table = np.full((e, r), -1, np.int32)
    q = np.zeros((e, r), np.float32)
    for i in range(e):
        n = int(rng.integers(1, r + 1))
        peers = rng.choice([x for x in range(e) if x != i], n, replace=False)
        table[i, :n] = peers
        q[i, :n] = np.sort(rng.random(n))[::-1]
    got = buddy_substitute_pallas(jnp.asarray(s), jnp.asarray(gate),
                                  jnp.asarray(resident), jnp.asarray(table),
                                  jnp.asarray(q), h=r, rho=2, interpret=True)
    want = ref.ref_buddy_substitute(s, gate, resident, table, q, h=r, rho=2)
    for g, w, name in zip(got, want, ("indices", "substituted", "missed")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)
