"""End-to-end behaviour: train (upcycled MoE) -> profile -> CFT buddies ->
serve under memory pressure. Reproduces the paper's qualitative claims on a
small model:

  * trained routers show uneven activation + concentrated co-activation
    (Figs. 6/7/9),
  * upcycled experts are functionally redundant (Fig. 4) and buddies are
    measurably better substitutes than random experts (the central claim),
  * BuddyMoE converts prefetch misses into substitutions and moves fewer
    PCIe bytes than on-demand fetching (Table 1 / Fig. 8).

The shared trained model comes from benchmarks.common (cached on disk), so
the suite trains it at most once.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import (BuddyPolicy, build_buddy_lists, make_random_table)
from repro.core.buddies import BuddyTables
from repro.core.similarity import all_layer_similarities, collect_layer_inputs
from repro.models import transformer
from repro.models.moe import BuddyState
from repro.runtime.cache import ExpertCache
from repro.serving.engine import ServeEngine


@pytest.fixture(scope="module")
def setup():
    from benchmarks import common
    from repro.training.data import MarkovLM
    cfg, params, lm = common.get_model(verbose=False)
    rec, q = common.get_profile(cfg, params, lm, verbose=False)
    # dedicated stream: get_profile only consumes lm's rng when its disk
    # cache is cold, so draws taken from `lm` here would depend on cache
    # warmth and make the statistical assertions below flip between runs
    lm = MarkovLM(cfg.vocab_size, num_blocks=8, seed=11)
    sims = all_layer_similarities(cfg, params, jnp.asarray(lm.sample(4, 64)))
    tables = build_buddy_lists(q, alpha=0.95, k_max=16, activity=rec.A,
                               output_sim=sims)
    return cfg, params, lm, rec, q, sims, tables


def test_router_specializes(setup):
    """Figs. 6/7/9 mechanics on the trained model."""
    cfg, params, lm, rec, q, sims, tables = setup
    for l in range(cfg.num_layers):
        assert rec.activation_skew(l)["gini"] > 0.02
        cov = rec.topr_coverage(l, 8).mean()
        assert cov > 8 / (cfg.moe.num_experts - 1) * 1.5, \
            f"co-activation not concentrated: {cov}"


def test_upcycled_experts_are_redundant(setup):
    """Fig. 4: substantial pairwise output similarity (the redundancy)."""
    cfg, params, lm, rec, q, sims, tables = setup
    e = cfg.moe.num_experts
    off = sims[0][~np.eye(e, dtype=bool)]
    assert off.mean() > 0.2, f"no redundancy to exploit: {off.mean():.3f}"


def test_buddies_are_better_substitutes_than_random(setup):
    """Mechanistic core claim: replacing expert i by its top buddy changes
    the layer output less than replacing it by a random expert."""
    cfg, params, lm, rec, q, sims, tables = setup
    e_n = cfg.moe.num_experts
    xs = collect_layer_inputs(cfg, params, jnp.asarray(lm.sample(2, 64)))
    rng = np.random.default_rng(0)
    gp = params["groups"][0]

    def expert_out(lp, e, x):
        m = lp["moe"]
        h = jax.nn.silu(x @ m["w1"][e]) * (x @ m["w3"][e])
        return h @ m["w2"][e]

    errs = {"buddy": [], "random": []}
    for l in range(cfg.num_layers):
        lp = jax.tree.map(lambda a: a[l], gp)
        x = xs[l][:128]
        for i in range(e_n):
            if rec.A[l, i] <= 0 or tables.table[l, i, 0] < 0:
                continue
            yi = expert_out(lp, i, x)
            for name, j in (("buddy", int(tables.table[l, i, 0])),
                            ("random", int(rng.choice(
                                [x_ for x_ in range(e_n) if x_ != i])))):
                yj = expert_out(lp, j, x)
                errs[name].append(float(jnp.linalg.norm(yi - yj)
                                        / (jnp.linalg.norm(yi) + 1e-8)))
    assert np.mean(errs["buddy"]) < np.mean(errs["random"]), \
        f"buddy {np.mean(errs['buddy']):.4f} !< random {np.mean(errs['random']):.4f}"


def _agreement(cfg, params, eval_toks, full_logits, tables_t, tables_q,
               policy, resident):
    l_n, e_n = resident.shape
    buddies = BuddyState(resident=jnp.asarray(resident),
                         table=jnp.asarray(tables_t), q=jnp.asarray(tables_q),
                         hop=jnp.zeros((l_n, e_n), jnp.int32))
    logits, aux = transformer.forward_train(params, cfg, eval_toks,
                                            policy=policy, buddies=buddies)
    agree = float((logits.argmax(-1) == full_logits.argmax(-1)).mean())
    return agree, aux


def test_buddy_beats_random_end_to_end(setup):
    """Tables 2-4 direction: at c=0.5, buddy substitution preserves top-1
    agreement with the full model better than random substitution
    (averaged over residency draws)."""
    cfg, params, lm, rec, q, sims, tables = setup
    l_n, e_n = cfg.num_layers, cfg.moe.num_experts
    eval_toks = jnp.asarray(lm.sample(8, 48))
    full_logits, _ = transformer.forward_train(params, cfg, eval_toks)

    rt, rq = make_random_table(jax.random.PRNGKey(7), e_n, 16)
    rt = np.tile(np.asarray(rt)[None], (l_n, 1, 1))
    rq = np.tile(np.asarray(rq)[None], (l_n, 1, 1))
    pol = BuddyPolicy(tau=0.05, beta=1.1, rho=6, H=16, fallback="drop")

    rng = np.random.default_rng(1)
    diffs = []
    for trial in range(3):
        resident = np.zeros((l_n, e_n), bool)
        for l in range(l_n):
            resident[l, rng.choice(e_n, e_n // 2, replace=False)] = True
        ab, auxb = _agreement(cfg, params, eval_toks, full_logits,
                              tables.table, tables.q, pol, resident)
        ar, _ = _agreement(cfg, params, eval_toks, full_logits, rt, rq, pol,
                           resident)
        assert int(auxb["n_sub"]) > 0
        diffs.append(ab - ar)
    assert np.mean(diffs) > -0.005, f"buddy worse than random: {diffs}"


def test_buddy_reduces_pcie_bytes(setup):
    """Fig. 8 + Table 1: substitutions replace sync fetches -> fewer bytes,
    higher modeled throughput."""
    cfg, params, lm, rec, q, sims, tables = setup

    def run(policy, seed=2):
        eng = ServeEngine(cfg, params, tables=tables, policy=policy,
                          cache=ExpertCache(cfg.num_layers,
                                            cfg.moe.num_experts, 0.5,
                                            seed=seed), seed=seed)
        eng.generate(lm.sample(2, 4), max_new_tokens=6)
        return eng

    eng_b = run(BuddyPolicy(tau=0.0, beta=1.1, rho=6, H=16))
    eng_o = run(BuddyPolicy(mode="none"))
    assert eng_o.stats.n_miss_fetch > 0
    assert eng_b.stats.n_sub > 0
    assert eng_b.stats.n_miss_fetch < eng_o.stats.n_miss_fetch
    assert eng_b.ledger.total_bytes < eng_o.ledger.total_bytes
    assert eng_b.stats.tokens_per_s > eng_o.stats.tokens_per_s


def test_gates_restrict_substitution(setup):
    cfg, params, lm, rec, q, sims, tables = setup
    l_n, e_n = cfg.num_layers, cfg.moe.num_experts
    eval_toks = jnp.asarray(lm.sample(2, 16))
    full_logits, _ = transformer.forward_train(params, cfg, eval_toks)
    rng = np.random.default_rng(3)
    resident = np.zeros((l_n, e_n), bool)
    for l in range(l_n):
        resident[l, rng.choice(e_n, e_n // 2, replace=False)] = True
    # tau=1 forbids everything
    _, aux = _agreement(cfg, params, eval_toks, full_logits, tables.table,
                        tables.q, BuddyPolicy(tau=1.0, beta=1.1, rho=6, H=16),
                        resident)
    assert int(aux["n_sub"]) == 0
    # beta=0 bypasses at batch level
    _, aux = _agreement(cfg, params, eval_toks, full_logits, tables.table,
                        tables.q, BuddyPolicy(tau=0.0, beta=0.0, rho=6, H=16),
                        resident)
    assert int(aux["n_sub"]) == 0
