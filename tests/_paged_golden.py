"""Frozen ring-buffer serving scenario for the paged-KV bit-identity gate.

``golden_summary`` runs a small deterministic continuous-batching scenario
(both serving loops: token-by-token and chunked prefill) and returns the
scheduler summary. ``tests/data/pre_paged_serving.json`` was written by
this module BEFORE the paged-KV refactor landed; ``tests/test_paged.py``
re-runs the identical scenario with ``paged_kv=False`` and requires the
summary to match byte-for-byte — the contract that the ring-buffer path
is the exact pre-refactor engine (same discipline as the mesh gate in
tests/_mesh_golden.py).

Regenerate (only if the scenario itself must change, never to paper over
a diff):  PYTHONPATH=src python -m tests._paged_golden
"""
import json
import os

import jax
import numpy as np

from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.core import BuddyPolicy, build_buddy_lists
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.prefetch import PrevStepPredictor
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import (ContinuousScheduler, PoissonArrivals,
                                     RequestQueue, SLOConfig, make_requests)
from repro.training.data import MarkovLM

from tests._mesh_golden import jsonify

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "pre_paged_serving.json")


def golden_summary(prefill_chunk: int = 4, paged_kv=None,
                   prefix_cache=None) -> dict:
    """The frozen scenario. ``paged_kv=None`` / ``prefix_cache=None`` omit
    the kwargs entirely (how every pre-refactor caller constructed the
    engine); the bit-identity test passes explicit ``False`` instead."""
    cfg = reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    lm = MarkovLM(cfg.vocab_size, seed=0)
    l, e = cfg.num_layers, cfg.moe.num_experts
    q = np.random.default_rng(0).random((l, e, e))
    tables = build_buddy_lists(q, alpha=0.95, k_max=e - 1)
    policy = BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8)
    kw = {}
    if paged_kv is not None:
        kw["paged_kv"] = paged_kv
    if prefix_cache is not None:
        kw["prefix_cache"] = prefix_cache
    eng = ServeEngine(cfg, params, tables=tables, policy=policy,
                      cache=ExpertCache(l, e, 0.5, seed=0),
                      predictor=PrevStepPredictor(l, e),
                      prefetch_k=2, seed=0, **kw)
    rng = np.random.default_rng(7)
    prompts = [lm.sample(1, int(rng.integers(6, 14)))[0] for _ in range(10)]
    new_toks = rng.integers(3, 9, 10)
    slo = SLOConfig(ttft_s=0.5, tpot_s=0.05, deadline_s=2.0)
    reqs = make_requests(prompts, PoissonArrivals(1500.0, seed=3),
                         new_toks, slo)
    cs = ContinuousScheduler(eng, slots=3, prefill_chunk=prefill_chunk)
    return jsonify(cs.run(RequestQueue(reqs)))


def main():
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    golden = {f"chunk{c}": golden_summary(c) for c in (1, 4)}
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
    print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    main()
