"""Training substrate: loss decreases, optimizer math, checkpoint roundtrip,
synthetic data properties."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load_pytree, save_pytree
from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.training import optimizer as O
from repro.training.data import MarkovLM
from repro.training.train_loop import train


def test_loss_decreases():
    cfg = reduced()
    lm = MarkovLM(cfg.vocab_size, seed=0)
    opt = O.AdamWConfig(lr=1e-3, total_steps=30, warmup_steps=5)
    logs = []
    params, hist = train(cfg, opt, lm.batches(4, 32, 30), log_every=1,
                         log_fn=lambda s: logs.append(s))
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first - 0.1, f"no learning: {first} -> {last}"


def test_adamw_step_math():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = {"w": jnp.full((4, 4), 0.5), "b": jnp.ones((4,))}
    st = O.init_opt_state(params)
    cfg = O.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=10,
                        weight_decay=0.0, grad_clip=1e9)
    p2, st2, m = O.apply_updates(params, grads, st, cfg)
    # first Adam step moves every param by ~lr in -sign(grad)
    assert np.allclose(np.asarray(p2["w"]), 1.0 - 0.1, atol=1e-2)
    assert int(st2.step) == 1
    assert float(m["grad_norm"]) > 0


def test_grad_clip():
    params = {"w": jnp.ones((2,))}
    grads = {"w": jnp.full((2,), 100.0)}
    st = O.init_opt_state(params)
    cfg = O.AdamWConfig(lr=1.0, warmup_steps=0, grad_clip=1.0,
                        weight_decay=0.0)
    _, _, m = O.apply_updates(params, grads, st, cfg)
    assert float(m["grad_norm"]) > 100  # reported pre-clip


def test_schedule_shape():
    cfg = O.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                        min_lr_ratio=0.1)
    lrs = [float(O.schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6          # end of warmup
    assert lrs[-1] <= lrs[1]
    assert abs(lrs[-1] - 0.1) < 1e-2         # cosine floor


def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced()
    key = jax.random.PRNGKey(0)
    from repro.models import transformer
    params = transformer.init_params(cfg, key)
    p = str(tmp_path / "ckpt.npz")
    save_pytree(p, params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    restored = load_pytree(p, zeros)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_markov_data_learnable_structure():
    lm = MarkovLM(512, num_blocks=8, seed=0)
    x = lm.sample(4, 256)
    assert x.shape == (4, 256)
    assert x.min() >= 0 and x.max() < 512
    # block persistence: consecutive tokens usually share a block
    blocks = x // lm.block_size
    same = (blocks[:, 1:] == blocks[:, :-1]).mean()
    assert same > 0.8
