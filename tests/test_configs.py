"""Exact assigned-architecture configs (the public-pool table)."""
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config, get_reduced

EXPECTED = {
    "rwkv6-1.6b": dict(family="ssm", num_layers=24, d_model=2048,
                       d_ff=7168, vocab_size=65536),
    "zamba2-7b": dict(family="hybrid", num_layers=81, d_model=3584,
                      num_heads=32, num_kv_heads=32, d_ff=14336,
                      vocab_size=32000),
    "internlm2-1.8b": dict(family="dense", num_layers=24, d_model=2048,
                           num_heads=16, num_kv_heads=8, d_ff=8192,
                           vocab_size=92544),
    "mixtral-8x7b": dict(family="moe", num_layers=32, d_model=4096,
                         num_heads=32, num_kv_heads=8, d_ff=14336,
                         vocab_size=32000),
    "smollm-360m": dict(family="dense", num_layers=32, d_model=960,
                        num_heads=15, num_kv_heads=5, d_ff=2560,
                        vocab_size=49152),
    "musicgen-large": dict(family="audio", num_layers=48, d_model=2048,
                           num_heads=32, num_kv_heads=32, d_ff=8192,
                           vocab_size=2048),
    "mixtral-8x22b": dict(family="moe", num_layers=56, d_model=6144,
                          num_heads=48, num_kv_heads=8, d_ff=16384,
                          vocab_size=32768),
    "llama-3.2-vision-11b": dict(family="vlm", num_layers=40, d_model=4096,
                                 num_heads=32, num_kv_heads=8, d_ff=14336,
                                 vocab_size=128256),
    "internlm2-20b": dict(family="dense", num_layers=48, d_model=6144,
                          num_heads=48, num_kv_heads=8, d_ff=16384,
                          vocab_size=92544),
    "phi3-medium-14b": dict(family="dense", num_layers=40, d_model=5120,
                            num_heads=40, num_kv_heads=10, d_ff=17920,
                            vocab_size=100352),
}


@pytest.mark.parametrize("arch", list(EXPECTED))
def test_exact_config(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


def test_moe_routing_params():
    m7 = get_config("mixtral-8x7b").moe
    assert m7.num_experts == 8 and m7.top_k == 2
    m22 = get_config("mixtral-8x22b").moe
    assert m22.num_experts == 8 and m22.top_k == 2
    ds = get_config("deepseek-v2-lite-buddy").moe
    assert ds.num_experts == 64 and ds.top_k == 6  # the paper's §5.1 setup


def test_ssm_state():
    z = get_config("zamba2-7b")
    assert z.ssm.state_dim == 64
    assert z.attn_every > 0


def test_shapes():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_is_small(arch):
    r = get_reduced(arch)
    assert r.num_layers <= 2
    assert r.d_model <= 512
    if r.is_moe:
        assert r.moe.num_experts <= 4
    assert r.family == get_config(arch).family


def test_param_counts_plausible():
    # sanity: within 2x of the advertised sizes
    approx = {
        "mixtral-8x7b": 46e9, "mixtral-8x22b": 140e9, "phi3-medium-14b": 14e9,
        "internlm2-20b": 20e9, "internlm2-1.8b": 1.8e9, "smollm-360m": 360e6,
        "rwkv6-1.6b": 1.6e9,
    }
    for arch, n in approx.items():
        got = get_config(arch).param_count()
        assert 0.5 * n < got < 2.2 * n, f"{arch}: {got:.2e} vs {n:.2e}"
