"""Event-driven PCIe transfer scheduler: timeline arithmetic, priorities,
cancellation, residency states, and the late-prefetch-as-miss regression
(the scenario buddy substitution exists to absorb)."""
import numpy as np
import pytest

from repro.runtime.cache import ExpertCache
from repro.runtime.memory import HardwareModel, TransferLedger
from repro.runtime.transfers import (DONE, PRIO_DEMAND, TransferScheduler)

# round numbers so completion times are exact: 10 GB/s, 1 ms launch cost
HW = HardwareModel(pcie_bw=10e9, pcie_fixed_s=1e-3)
HW0 = HardwareModel(pcie_bw=10e9, pcie_fixed_s=0.0)

GB = 1_000_000_000


def test_single_transfer_completion_time():
    s = TransferScheduler(HW)
    t = s.submit(0, 1, 10 * GB, "demand")
    done = s.run_until_done(t)
    assert t.state == DONE
    assert abs(done - (1e-3 + 1.0)) < 1e-9
    assert abs(s.busy_s - done) < 1e-9


def test_bandwidth_sharing_completion_order():
    """Two concurrent prefetches fair-share the link: the small one lands
    first, then the big one speeds up to full bandwidth."""
    s = TransferScheduler(HW0)
    small = s.submit(0, 1, 2 * GB, "prefetch")
    big = s.submit(0, 2, 6 * GB, "prefetch")
    s.flush()
    # both stream at 5 GB/s until small is done at 0.4s; big then has 4 GB
    # left at 10 GB/s -> lands at 0.8s
    assert abs(small.done_s - 0.4) < 1e-9
    assert abs(big.done_s - 0.8) < 1e-9
    assert small.done_s < big.done_s


def test_demand_preempts_prefetch():
    """A demand fetch monopolises the link; the prefetch pauses and resumes
    after it, finishing exactly one demand-duration later."""
    s = TransferScheduler(HW0)
    pf = s.submit(0, 1, 10 * GB, "prefetch")
    s.advance(0.5)                       # prefetch has 5 GB left
    dm = s.submit(1, 2, 1 * GB, "demand")
    done = s.run_until_done(dm)
    assert abs(done - 0.6) < 1e-9        # exclusive link from 0.5
    assert pf.in_flight                  # paused, not cancelled
    s.flush()
    assert abs(pf.done_s - 1.1) < 1e-9   # 0.5 remaining after resume


def test_duplicate_demand_escalates_inflight_prefetch():
    s = TransferScheduler(HW0)
    pf = s.submit(0, 1, 10 * GB, "prefetch")
    s.advance(0.2)
    t = s.submit(0, 1, 10 * GB, "demand")
    assert t is pf                        # deduplicated
    assert pf.priority == PRIO_DEMAND     # and escalated
    # only the remaining 8 GB is paid — the early 2 GB overlapped
    assert abs(s.run_until_done(pf) - 1.0) < 1e-9


def test_escalate_queued_prefetch_leaves_counts_unchanged():
    """Regression: escalate() re-pushes a QUEUED transfer at demand priority
    and leaves the stale heap entry behind — n_in_flight and pending() must
    dedup by tid instead of counting the escalated transfer twice."""
    s = TransferScheduler(HW0, max_inflight_prefetch=1)
    a = s.submit(0, 1, GB, "prefetch")
    b = s.submit(0, 2, GB, "prefetch")
    assert s.n_in_flight == 2
    s.escalate(b)                         # still queued -> re-pushed
    assert b.priority == PRIO_DEMAND
    assert s.n_in_flight == 2, "escalation must not double-count"
    tids = sorted(t.tid for t in s.pending())
    assert tids == sorted([a.tid, b.tid])
    s.flush()
    assert s.n_in_flight == 0 and s.pending() == []


def test_cancel_stale_prefetches_refunds_unstarted_bytes():
    s = TransferScheduler(HW0, max_inflight_prefetch=1)
    led = TransferLedger(HW0)
    led.attach(s)
    kept = s.submit(0, 1, GB, "prefetch")
    s.submit(0, 2, GB, "prefetch")
    s.submit(0, 3, GB, "prefetch")
    assert led.bytes_by_cause["prefetch"] == 3 * GB
    n = s.cancel_stale_prefetches(0, keep=[1])
    assert n == 2
    # neither cancelled transfer was ever served -> bytes refunded
    assert led.bytes_by_cause["prefetch"] == GB
    assert led.events_by_cause["cancelled"] == 2
    s.flush()
    assert kept.state == DONE


def test_cancel_refunds_prefetch_paused_behind_demand():
    """A prefetch admitted while a demand monopolises the link has received
    no service: cancelling it must refund its bytes."""
    s = TransferScheduler(HW0)
    led = TransferLedger(HW0)
    led.attach(s)
    s.submit(0, 9, 10 * GB, "demand")
    pf = s.submit(0, 1, GB, "prefetch")
    s.advance(0.5)
    assert not pf.started                 # paused, zero bytes moved
    s.cancel(pf)
    assert led.bytes_by_cause["prefetch"] == 0
    assert led.events_by_cause["cancelled"] == 1


def test_inflight_expert_not_usable_until_arrival():
    cache = ExpertCache(1, 4, 0.5, seed=0)
    s = TransferScheduler(HW0)
    s.add_listener(cache.on_transfer_event)
    e = int(np.flatnonzero(~cache.resident[0])[0])
    t = s.submit(0, e, GB, "prefetch")
    assert cache.inflight[0, e]
    assert not cache.residency_mask()[0, e]      # in flight != usable
    s.run_until_done(t)
    assert cache.residency_mask()[0, e]          # arrived -> resident
    assert not cache.inflight[0, e]


def test_pinned_expert_never_evicted_mid_use():
    cache = ExpertCache(1, 8, 0.5, policy="lru", seed=0)
    pinned = int(np.flatnonzero(cache.resident[0])[0])
    cache.pin(0, [pinned])
    for e in range(8):
        cache.insert(0, e)
    assert cache.resident[0, pinned]
    assert cache.resident[0].sum() == cache.capacity
    cache.unpin(0)


def test_insert_reuses_evicted_partition_slot():
    """Partition topology must not drift as the cache churns (the old code
    re-derived the partition from the resident count)."""
    cache = ExpertCache(1, 8, 0.5, num_partitions=4, seed=0)
    want = sorted(cache.partition[0, cache.resident[0]].tolist())
    assert want == [0, 1, 2, 3]
    rng = np.random.default_rng(0)
    for _ in range(50):
        e = int(rng.integers(0, 8))
        ev = cache.insert(0, e)
        if ev >= 0:
            assert cache.partition[0, e] == cache.partition[0, ev]
        got = sorted(cache.partition[0, cache.resident[0]].tolist())
        assert got == [0, 1, 2, 3], "slot partitions drifted"


def test_buddy_aware_eviction_prefers_absorbable_victim():
    """Among the policy-worst candidates, evict the expert whose buddies are
    resident (its future misses can be substituted, not fetched)."""
    e_n = 8
    table = np.full((1, e_n, 2), -1, np.int32)
    cache = ExpertCache(1, e_n, 0.5, policy="lru", seed=0,
                        buddy_table=table, buddy_candidates=2)
    res = np.flatnonzero(cache.resident[0])
    lru0, lru1 = int(res[0]), int(res[1])     # oldest two (stable order)
    # lru0 has NO buddies; lru1's buddy is resident -> prefer evicting lru1
    table[0, lru1, 0] = int(res[2])
    missing = int(np.flatnonzero(~cache.resident[0])[0])
    assert cache.insert(0, missing) == lru1


def test_scheduler_timeline_vs_analytic():
    """n back-to-back demand fetches cost n * (fixed + bytes/bw)."""
    s = TransferScheduler(HW)
    total = 0.0
    for i in range(3):
        t = s.submit(0, i + 10, 2 * GB, "demand")
        total = s.run_until_done(t)
    assert abs(total - 3 * (1e-3 + 0.2)) < 1e-9


# ---------------------------------------------------------------------------
# Engine-level regression: a prefetch issued too late to arrive before its
# layer is a MISS. Under policy=buddy a resident buddy absorbs it with zero
# sync bytes; under mode=none/fallback=fetch it is sync-fetched, with the
# stall attributed to the late prefetch's remaining tail.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_engine_parts():
    import jax
    from repro.configs.deepseek_v2_lite_buddy import reduced
    from repro.models import transformer
    from repro.training.data import MarkovLM
    cfg = reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    lm = MarkovLM(cfg.vocab_size, seed=0)
    e, l = cfg.moe.num_experts, cfg.num_layers
    # full buddy lists (every peer, q descending) so any miss with >=1
    # resident non-duplicate expert is absorbable — isolates the timeline
    # mechanics from CFT coverage truncation
    from repro.core.buddies import BuddyTables
    table = np.stack([[np.asarray([j for j in range(e) if j != i], np.int32)
                       for i in range(e)] for _ in range(l)])
    q = np.tile(np.linspace(0.9, 0.5, e - 1, dtype=np.float32),
                (l, e, 1))
    tables = BuddyTables(table=table, q=q,
                         sizes=np.full((l, e), e - 1, np.int32))
    return cfg, params, lm, tables


def _slow_hw():
    # one expert takes ~0.4 s over "PCIe" while a decode step is ~us: every
    # prefetch is late (in flight when its layer computes) — the paper's
    # regime, exaggerated
    from repro.runtime.memory import expert_nbytes
    from repro.configs.deepseek_v2_lite_buddy import reduced
    cfg = reduced()
    nb = expert_nbytes(cfg.d_model, cfg.moe.d_ff)
    return HardwareModel(pcie_bw=nb / 0.4, pcie_fixed_s=0.0)


def _late_engine(cfg, params, tables, policy, seed=0):
    from repro.runtime.prefetch import PrevStepPredictor
    from repro.serving.engine import ServeEngine
    l, e = cfg.num_layers, cfg.moe.num_experts
    return ServeEngine(cfg, params, tables=tables, policy=policy,
                       cache=ExpertCache(l, e, 0.5, seed=seed),
                       predictor=PrevStepPredictor(l, e),
                       prefetch_k=2, hw=_slow_hw(), seed=seed)


def test_late_prefetch_absorbed_by_buddy_zero_sync_bytes(small_engine_parts):
    cfg, params, lm, tables = small_engine_parts
    from repro.core import BuddyPolicy
    eng = _late_engine(cfg, params, tables,
                       BuddyPolicy(tau=-1.0, beta=1.1, rho=2, H=3))
    eng.generate(lm.sample(2, 4), max_new_tokens=8)
    # prefetches were issued but are late -> the layers saw misses
    assert eng.stats.n_prefetch_issued > 0
    assert eng.stats.n_sub > 0, "late prefetches should surface as misses"
    # every miss was absorbed by a buddy: no synchronous fetch, no stall
    assert eng.ledger.bytes_by_cause.get("sync_fetch", 0) == 0
    assert eng.stats.n_miss_fetch == 0
    bd = eng.summary()["stall_breakdown"]
    assert set(bd) == {"demand_stall_s", "late_prefetch_stall_s",
                       "overlapped_s"}
    assert bd["demand_stall_s"] == 0.0
    assert bd["late_prefetch_stall_s"] == 0.0


def test_late_prefetch_sync_fetched_without_buddies(small_engine_parts):
    cfg, params, lm, tables = small_engine_parts
    from repro.core import BuddyPolicy
    eng = _late_engine(cfg, params, tables,
                       BuddyPolicy(mode="none", fallback="fetch"))
    eng.generate(lm.sample(2, 4), max_new_tokens=8)
    # misses on in-flight prefetches escalate and stall for the tail
    assert eng.stats.n_late_prefetch > 0
    assert eng.ledger.late_prefetch_stall_s > 0.0
    assert eng.ledger.events_by_cause.get("escalated", 0) > 0
    assert eng.stats.n_miss_fetch > 0
    s = eng.summary()
    assert s["stall_breakdown"]["late_prefetch_stall_s"] > 0.0
    # the aggregate ledger view stays coherent with the breakdown
    led = s["ledger"]["stall_breakdown"]
    assert abs((led["demand_stall_s"] + led["late_prefetch_stall_s"])
               - s["ledger"]["sync_stall_s"]) < 1e-9


def test_batch_size_affects_modeled_compute(small_engine_parts):
    """Regression for the dead batch-amortisation term: per-step compute now
    comes from hw.decode_compute_time(active_params, batch)."""
    cfg, params, lm, tables = small_engine_parts
    from repro.core import BuddyPolicy
    from repro.serving.engine import ServeEngine
    hw = HW
    eng = ServeEngine(cfg, params, tables=tables,
                      policy=BuddyPolicy(mode="none", fallback="drop"),
                      cache=ExpertCache(cfg.num_layers, cfg.moe.num_experts,
                                        1.0, seed=0), hw=hw, seed=0)
    eng.generate(lm.sample(3, 4), max_new_tokens=2)
    expected = hw.decode_compute_time(cfg.active_param_count(), 3)
    assert abs(eng.stats.compute_s / eng.stats.steps - expected) < 1e-12
    # the flops term makes large batches strictly slower per step
    assert hw.decode_compute_time(cfg.active_param_count(), 4096) > \
        hw.decode_compute_time(cfg.active_param_count(), 1)
