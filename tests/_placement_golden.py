"""Frozen placement-off serving scenario for the placement bit-identity gate.

``golden_summary`` runs a small deterministic tiered generate() and returns
the engine summary. ``tests/data/pre_placement_summary.json`` was written by
this module BEFORE the live-placement controller landed;
``tests/test_placement.py`` re-runs the identical scenario with
``placement=None`` and requires the summary to match byte-for-byte — the
contract that an engine without a controller is the exact pre-placement
engine.

Regenerate (only if the scenario itself must change, never to paper over
a diff):  PYTHONPATH=src python -m tests._placement_golden
"""
import json
import os

import jax
import numpy as np

from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.core import BuddyPolicy, build_buddy_lists
from repro.models import transformer
from repro.runtime.prefetch import PrevStepPredictor
from repro.runtime.tiers import TieredExpertStore
from repro.serving.engine import ServeEngine
from repro.training.data import MarkovLM

from tests._mesh_golden import jsonify

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "pre_placement_summary.json")


def golden_summary(miss_policy: str = "precedence",
                   placement="__omit__") -> dict:
    """The frozen scenario: a partial-coverage int8 tier engine — the exact
    configuration the placement controller would re-rank. The default
    ``placement="__omit__"`` omits the kwarg entirely (how every
    pre-placement caller constructed the engine)."""
    cfg = reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    lm = MarkovLM(cfg.vocab_size, seed=0)
    l, e = cfg.num_layers, cfg.moe.num_experts
    q = np.random.default_rng(0).random((l, e, e))
    tables = build_buddy_lists(q, alpha=0.95, k_max=e - 1)
    policy = BuddyPolicy(tau=0.0, beta=1.1, rho=4, H=3, quant_tier="int8",
                         miss_policy=miss_policy)
    tier = TieredExpertStore(l, e, 0.5, bits=8, d_model=cfg.d_model,
                             d_ff=cfg.moe.d_ff, coverage=0.75, seed=0)
    kw = {} if placement == "__omit__" else {"placement": placement}
    eng = ServeEngine(cfg, params, tables=tables, policy=policy,
                      cache=None, tier=tier,
                      predictor=PrevStepPredictor(l, e),
                      prefetch_k=4, seed=0, **kw)
    eng.generate(lm.sample(2, 6), max_new_tokens=8)
    return jsonify(eng.summary())


def main():
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    golden = {mp: golden_summary(mp) for mp in ("precedence", "cost")}
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
    print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    main()
