"""Runtime substrate: expert cache eviction invariants, transfer ledger
arithmetic, prefetch predictors."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.cache import ExpertCache
from repro.runtime.memory import (HardwareModel, TransferLedger,
                                  expert_nbytes)
from repro.runtime.prefetch import (CrossLayerPredictor, NoisyOraclePredictor,
                                    PrevStepPredictor, TopFreqPredictor)

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


@given(st.integers(0, 300), st.integers(2, 16), st.floats(0.2, 1.0),
       st.sampled_from(["lru", "lfu"]), st.integers(1, 60))
def test_cache_capacity_invariant(seed, e, rate, policy, n_ops):
    rng = np.random.default_rng(seed)
    c = ExpertCache(2, e, rate, policy=policy, seed=seed)
    cap = c.capacity
    assert c.resident.sum(axis=1).max() <= cap
    for _ in range(n_ops):
        l = int(rng.integers(0, 2))
        op = rng.random()
        if op < 0.5:
            c.touch(l, rng.integers(0, e, size=3))
        else:
            c.insert(l, int(rng.integers(0, e)))
        assert c.resident[l].sum() <= cap
    # every layer still has exactly cap residents after enough inserts
    for l in range(2):
        for i in range(e):
            c.insert(l, i)
        assert c.resident[l].sum() == cap


def test_lru_evicts_least_recent():
    c = ExpertCache(1, 4, 0.5, policy="lru", seed=0)
    res = np.flatnonzero(c.resident[0])
    c.touch(0, [res[1]])
    c.touch(0, [res[0]])
    missing = np.flatnonzero(~c.resident[0])[0]
    evicted = c.insert(0, int(missing))
    assert evicted == res[1]  # res[1] touched before res[0] -> LRU


def test_lfu_evicts_least_frequent():
    c = ExpertCache(1, 4, 0.5, policy="lfu", seed=0)
    res = np.flatnonzero(c.resident[0])
    c.touch(0, [res[0]])
    c.touch(0, [res[0]])
    c.touch(0, [res[1]])
    missing = np.flatnonzero(~c.resident[0])[0]
    evicted = c.insert(0, int(missing))
    assert evicted == res[1]


def test_hop_vector_zero_single_partition():
    # residents on a single partition are all local (0 hops); non-residents
    # get the -1 sentinel — never 0, which would read as "local and free"
    c = ExpertCache(1, 8, 0.5, num_partitions=1)
    h = c.hop_vector(0)
    assert (h[c.resident[0]] == 0).all()
    assert (h[~c.resident[0]] == -1).all()


def test_hop_vector_multi_partition():
    c = ExpertCache(1, 8, 1.0, num_partitions=4)
    h = c.hop_vector(0, origin_partition=0)
    assert h.min() == 0 and h.max() >= 1


def test_ledger_arithmetic():
    hw = HardwareModel(pcie_bw=10e9, pcie_fixed_s=1e-3)
    led = TransferLedger(hw)
    led.sync_fetch(10e9)            # 1s transfer + 1ms fixed
    assert abs(led.sync_stall_s - 1.001) < 1e-9
    led.prefetch(5e9, 2)
    assert abs(led.overlap_s - (0.5 + 2e-3)) < 1e-9
    led.buddy_hit(3)
    s = led.summary()
    assert s["total_bytes"] == 15e9
    assert s["events"]["buddy_sub"] == 3
    led.reset()
    assert led.total_bytes == 0


def test_expert_nbytes():
    assert expert_nbytes(4096, 14336) == 3 * 4096 * 14336 * 2


def test_topfreq_predictor():
    p = TopFreqPredictor(1, 8)
    for _ in range(5):
        p.observe(0, [3, 5])
    top = p.predict(0, 2)
    assert set(top) == {3, 5}


def test_prevstep_predictor():
    p = PrevStepPredictor(1, 8)
    p.observe(0, [1, 2])
    p.observe(0, [6])
    got = p.predict(0, 3)
    assert 6 in got
    assert len(got) == 3
    assert len(set(got.tolist())) == 3


def test_crosslayer_predictor():
    p = CrossLayerPredictor(2, 8)
    for _ in range(10):
        p.observe_transition(1, [0], [4, 5])
        p.observe(1, [4, 5])
    got = p.predict(1, 2, prev_experts=[0])
    assert set(got) == {4, 5}


def test_cache_rate_above_one_clamps_to_full():
    """Regression: cache_rate > 1 crashed __init__ (rng.choice of capacity >
    E without replacement); it just means the whole expert set fits."""
    c = ExpertCache(2, 8, 1.5, seed=0)
    assert c.capacity == 8
    assert c.resident.all()
    assert c.insert(0, 3) == -1           # already resident, nothing evicted
    assert c.resident[0].sum() == 8
    # boundary: exactly full keeps every expert resident too
    assert ExpertCache(1, 8, 1.0, seed=0).capacity == 8


def test_noisy_oracle_dedups_corrupted_draws():
    """Regression: a corrupted draw colliding with an already-emitted expert
    silently shrank the prediction below k; collisions must be deduped and
    back-filled like the top-up loop."""
    p = NoisyOraclePredictor(1, 8, accuracy=0.5, seed=3)
    p.set_truth(0, [0, 1, 2, 3, 4, 5])
    for _ in range(200):
        got = p.predict(0, 6)
        assert len(got) == 6
        assert len(set(got.tolist())) == 6, "duplicate expert in prediction"


def test_noisy_oracle_accuracy_extremes():
    p = NoisyOraclePredictor(1, 16, accuracy=1.0, seed=0)
    p.set_truth(0, [2, 9, 11])
    assert set(p.predict(0, 3)) == {2, 9, 11}
    p0 = NoisyOraclePredictor(1, 1024, accuracy=0.0, seed=0)
    p0.set_truth(0, [2, 9, 11])
    hits = len(set(p0.predict(0, 3)) & {2, 9, 11})
    assert hits <= 1  # wildly unlikely to match at accuracy 0 with E=1024
