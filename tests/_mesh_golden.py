"""Frozen single-device serving scenario for the mesh bit-identity gate.

``golden_summary`` runs a small deterministic generate() and returns the
engine summary. ``tests/data/pre_mesh_summary.json`` was written by this
module BEFORE the multi-device refactor landed; ``tests/test_mesh.py``
re-runs the identical scenario with ``n_devices=1`` and requires the
summary to match byte-for-byte — the contract that a single-device mesh
is the exact pre-refactor engine.

Regenerate (only if the scenario itself must change, never to paper over
a diff):  PYTHONPATH=src python -m tests._mesh_golden
"""
import json
import os

import jax
import numpy as np

from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.core import BuddyPolicy, build_buddy_lists
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.prefetch import PrevStepPredictor
from repro.serving.engine import ServeEngine
from repro.training.data import MarkovLM

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "pre_mesh_summary.json")


def jsonify(x):
    """Summary -> plain JSON types (exact: ints stay ints, floats floats)."""
    if isinstance(x, dict):
        return {str(k): jsonify(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [jsonify(v) for v in x]
    if isinstance(x, (bool, np.bool_)):
        return bool(x)
    if isinstance(x, (int, np.integer)):
        return int(x)
    if isinstance(x, (float, np.floating)):
        return float(x)
    return x


def golden_summary(miss_policy: str = "precedence", n_devices=None) -> dict:
    """The frozen scenario. ``n_devices=None`` omits the kwarg entirely
    (how every pre-refactor caller constructed the engine)."""
    cfg = reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    lm = MarkovLM(cfg.vocab_size, seed=0)
    l, e = cfg.num_layers, cfg.moe.num_experts
    q = np.random.default_rng(0).random((l, e, e))
    tables = build_buddy_lists(q, alpha=0.95, k_max=e - 1)
    policy = BuddyPolicy(tau=0.0, beta=1.1, rho=4, H=3,
                         miss_policy=miss_policy)
    kw = {} if n_devices is None else {"n_devices": n_devices}
    eng = ServeEngine(cfg, params, tables=tables, policy=policy,
                      cache=ExpertCache(l, e, 0.5, seed=0),
                      predictor=PrevStepPredictor(l, e),
                      prefetch_k=4, seed=0, **kw)
    eng.generate(lm.sample(2, 6), max_new_tokens=8)
    return jsonify(eng.summary())


def main():
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    golden = {mp: golden_summary(mp) for mp in ("precedence", "cost")}
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
    print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    main()
