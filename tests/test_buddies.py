"""Property tests for CFT buddy-list construction (§3.3, Eqs. 5-6)."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import buddies

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


def _rand_q(rng, l, e):
    q = rng.random((l, e, e))
    for i in range(e):
        q[:, i, i] = 0.0
    q /= np.maximum(q.sum(-1, keepdims=True), 1e-30)
    return q


@given(st.integers(0, 1000), st.integers(2, 12), st.integers(1, 3),
       st.floats(0.05, 1.0))
def test_cft_coverage_and_minimality(seed, e, l, alpha):
    rng = np.random.default_rng(seed)
    q = _rand_q(rng, l, e)
    t = buddies.build_buddy_lists(q, alpha=alpha, k_max=e)
    for li in range(l):
        for i in range(e):
            size = t.sizes[li, i]
            assert size >= 1
            ids = t.table[li, i, :size]
            assert (ids >= 0).all()
            assert i not in ids                      # never self
            assert len(set(ids.tolist())) == size    # unique
            cover = q[li, i, ids].sum()
            # coverage >= alpha unless capped by k_max(=e here, no cap)
            if size < e - 1:
                assert cover >= alpha - 1e-9
                # minimality: dropping the last entry breaks coverage
                assert q[li, i, ids[:-1]].sum() < alpha - 1e-12
            # entries are sorted by q descending
            qs = q[li, i, ids]
            assert (np.diff(qs) <= 1e-12).all()
            # padding is -1
            assert (t.table[li, i, size:] == -1).all()


@given(st.integers(0, 100), st.integers(4, 10))
def test_cft_kmax_cap(seed, e):
    rng = np.random.default_rng(seed)
    q = _rand_q(rng, 1, e)
    t = buddies.build_buddy_lists(q, alpha=1.0, k_max=2)
    assert (t.sizes <= 2).all()


def test_cft_prefix_size_exact():
    q = np.asarray([0.5, 0.3, 0.15, 0.05])
    assert buddies.cft_prefix_size(q, 0.5) == 1
    assert buddies.cft_prefix_size(q, 0.51) == 2
    assert buddies.cft_prefix_size(q, 0.8) == 2
    assert buddies.cft_prefix_size(q, 0.81) == 3
    assert buddies.cft_prefix_size(q, 1.0) == 4


def test_alpha_larger_gives_larger_lists():
    rng = np.random.default_rng(7)
    q = _rand_q(rng, 2, 10)
    t_small = buddies.build_buddy_lists(q, alpha=0.3, k_max=10)
    t_big = buddies.build_buddy_lists(q, alpha=0.95, k_max=10)
    assert (t_big.sizes >= t_small.sizes).all()
    assert t_big.sizes.sum() > t_small.sizes.sum()


def test_inactive_pivots_empty():
    rng = np.random.default_rng(8)
    q = _rand_q(rng, 1, 6)
    act = np.ones((1, 6))
    act[0, 2] = 0
    t = buddies.build_buddy_lists(q, alpha=0.9, k_max=6, activity=act)
    assert t.sizes[0, 2] == 0
    assert (t.table[0, 2] == -1).all()


def test_alpha_schedule_monotone():
    s = buddies.alpha_schedule(10, early=0.95, late=0.8)
    assert s[0] == 0.95 and abs(s[-1] - 0.8) < 1e-9
    assert (np.diff(s) <= 0).all()


def test_save_load_roundtrip(tmp_path):
    rng = np.random.default_rng(9)
    q = _rand_q(rng, 2, 6)
    t = buddies.build_buddy_lists(q, alpha=0.9, k_max=4)
    p = str(tmp_path / "tables.npz")
    buddies.save_tables(p, t)
    t2 = buddies.load_tables(p)
    np.testing.assert_array_equal(t.table, t2.table)
    np.testing.assert_array_equal(t.sizes, t2.sizes)
    np.testing.assert_allclose(t.q, t2.q)
