"""Chunked prefill: fused [B, C] prompt ingestion on the transfer timeline.

Covers (1) the tentpole equivalence claim — chunked prefill produces
bit-identical decode caches and logits to token-by-token prefill of the same
prompt (dropless MoE dispatch + per-query slot-validity masks), (2) per-row
chunk positions with mixed prefill/decode batches, and (3) the serving-level
payoff — lower TTFT at the same workload when admission uses chunked
prefill.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.core import BuddyPolicy, build_buddy_lists
from repro.models import transformer
from repro.models.moe import full_residency
from repro.runtime.cache import ExpertCache
from repro.runtime.prefetch import PrevStepPredictor
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import (ContinuousScheduler, PoissonArrivals,
                                     RequestQueue, make_requests)
from repro.training.data import MarkovLM


@pytest.fixture(scope="module")
def setup():
    cfg = reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    lm = MarkovLM(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)
    e = cfg.moe.num_experts
    q = rng.random((cfg.num_layers, e, e))
    tables = build_buddy_lists(q, alpha=0.95, k_max=e - 1)
    return cfg, params, lm, tables


def _full_buddies(cfg):
    return transformer._stack_n(
        lambda: full_residency(cfg.moe.num_experts), cfg.num_layers)


def _flat(tree):
    return np.concatenate([np.asarray(x).ravel()
                           for x in jax.tree.leaves(tree)])


def _tbt_prefill(cfg, params, prompts, ctx_len, buddies, key):
    """Token-by-token prefill through decode_step (the legacy path)."""
    b, p_len = prompts.shape
    caches = transformer.init_caches(cfg, b, ctx_len)
    logits = None
    for p in range(p_len):
        logits, caches, _ = transformer.decode_step(
            params, cfg, jnp.asarray(prompts[:, p], jnp.int32), caches,
            jnp.full((b,), p, jnp.int32), buddies=buddies, rng=key)
    return logits, caches


def _chunked_prefill(cfg, params, prompts, ctx_len, buddies, key, chunk):
    b, p_len = prompts.shape
    caches = transformer.init_caches(cfg, b, ctx_len)
    logits_last = None
    p = 0
    while p < p_len:
        n = min(chunk, p_len - p)
        toks = np.zeros((b, chunk), np.int64)
        toks[:, :n] = prompts[:, p:p + n]
        valid = np.zeros((b, chunk), bool)
        valid[:, :n] = True
        logits, caches, _ = transformer.prefill_chunk(
            params, cfg, jnp.asarray(toks, jnp.int32), caches,
            jnp.full((b,), p, jnp.int32), jnp.asarray(valid),
            buddies=buddies, rng=key)
        logits_last = logits[:, n - 1]
        p += n
    return logits_last, caches


# ===========================================================================
# Equivalence: chunked == token-by-token (the acceptance criterion)
# ===========================================================================
@pytest.mark.parametrize("batch,p_len,chunk", [(2, 9, 4), (1, 9, 4),
                                               (4, 11, 8), (4, 13, 5)])
def test_chunked_prefill_bit_identical_to_token_by_token(setup, batch, p_len,
                                                         chunk):
    """Same prompt, full residency: chunked prefill must produce the SAME
    bits in every KV-cache entry and in the last-token logits as P
    decode_step calls — including partial final chunks (p_len % chunk != 0)
    and the tiny-batch case where decode takes the gather shortcut."""
    cfg, params, _, _ = setup
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, (batch, p_len))
    buddies = _full_buddies(cfg)
    key = jax.random.PRNGKey(3)
    ctx = p_len + 4

    l_tbt, c_tbt = _tbt_prefill(cfg, params, prompts, ctx, buddies, key)
    l_ck, c_ck = _chunked_prefill(cfg, params, prompts, ctx, buddies, key,
                                  chunk)
    np.testing.assert_array_equal(_flat(c_ck), _flat(c_tbt))
    np.testing.assert_array_equal(np.asarray(l_ck), np.asarray(l_tbt))


def test_chunk_size_invariance(setup):
    """Dropless dispatch: per-token outputs must not depend on which other
    tokens share the chunk (C=2 vs C=8 bitwise-equal caches)."""
    cfg, params, _, _ = setup
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg.vocab_size, (3, 12))
    buddies = _full_buddies(cfg)
    key = jax.random.PRNGKey(5)
    l2, c2 = _chunked_prefill(cfg, params, prompts, 16, buddies, key, 2)
    l8, c8 = _chunked_prefill(cfg, params, prompts, 16, buddies, key, 8)
    np.testing.assert_array_equal(_flat(c2), _flat(c8))
    np.testing.assert_array_equal(np.asarray(l2), np.asarray(l8))


# ===========================================================================
# Mixed prefill/decode batches at per-row base positions
# ===========================================================================
def test_mixed_prefill_decode_rows_per_row_positions(setup):
    """Row 0 decodes one token at pos 7 (1-valid chunk) while row 1
    prefills 4 prompt tokens at pos 0 in the SAME fused step. Each row must
    get exactly what it would get stepping alone."""
    cfg, params, _, _ = setup
    rng = np.random.default_rng(3)
    buddies = _full_buddies(cfg)
    key = jax.random.PRNGKey(7)
    ctx = 16
    seq0 = rng.integers(0, cfg.vocab_size, (1, 8))     # row 0: 7 fed + next
    seq1 = rng.integers(0, cfg.vocab_size, (1, 4))     # row 1: fresh prompt

    # reference: each row alone (single-row chunk calls)
    _, c0 = _chunked_prefill(cfg, params, seq0[:, :7], ctx, buddies, key, 7)
    tok0 = np.zeros((1, 4), np.int64)
    tok0[0, 0] = seq0[0, 7]
    v0 = np.zeros((1, 4), bool)
    v0[0, 0] = True
    ref0_logits, ref0_c = transformer.prefill_chunk(
        params, cfg, jnp.asarray(tok0, jnp.int32), c0,
        jnp.full((1,), 7, jnp.int32), jnp.asarray(v0),
        buddies=buddies, rng=key)[:2]
    ref1_logits, ref1_c = _chunked_prefill(cfg, params, seq1, ctx, buddies,
                                           key, 4)

    # fused: both rows in one [2, 4] chunk at base positions [7, 0]
    _, cboth = _chunked_prefill(cfg, params,
                                np.concatenate([seq0[:, :7]] * 2), ctx,
                                buddies, key, 7)
    # overwrite row 1's cache with zeros (fresh slot, like reset_rows)
    cboth = jax.tree.map(lambda a: a.at[:, 1:].set(0), cboth)
    toks = np.stack([tok0[0], seq1[0]]).astype(np.int64)
    valid = np.array([[True, False, False, False], [True] * 4])
    logits, cnew, _ = transformer.prefill_chunk(
        params, cfg, jnp.asarray(toks, jnp.int32), cboth,
        jnp.asarray([7, 0], jnp.int32), jnp.asarray(valid),
        buddies=buddies, rng=key)

    np.testing.assert_allclose(np.asarray(logits[0, 0]),
                               np.asarray(ref0_logits[0, 0]),
                               rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(logits[1, 3]),
                               np.asarray(ref1_logits[0]),
                               rtol=0, atol=1e-5)
    # cache slices per row match the solo runs (row 0: slots 0..7 written;
    # row 1: slots 0..3)
    for got, want, row in ((cnew, ref0_c, 0), (cnew, ref1_c, 1)):
        for g_leaf, w_leaf in zip(jax.tree.leaves(got),
                                  jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(g_leaf[:, row]),
                                       np.asarray(w_leaf[:, 0]),
                                       rtol=0, atol=1e-5)


def test_invalid_tokens_write_nothing(setup):
    """Tokens past a row's validity prefix (and fully-inactive rows) must
    leave the KV cache untouched."""
    cfg, params, _, _ = setup
    buddies = _full_buddies(cfg)
    caches = transformer.init_caches(cfg, 2, 8)
    before = _flat(caches)
    toks = np.full((2, 4), 3, np.int64)
    valid = np.zeros((2, 4), bool)          # nothing valid anywhere
    _, cnew, _ = transformer.prefill_chunk(
        params, cfg, jnp.asarray(toks, jnp.int32), caches,
        jnp.zeros(2, jnp.int32), jnp.asarray(valid),
        buddies=buddies, rng=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(_flat(cnew), before)


# ===========================================================================
# Engine level: prefill_rows accounting + guards
# ===========================================================================
def _engine(cfg, params, tables, rate=1.0, seed=0, prefetch_k=0, hw=None):
    from repro.runtime.memory import DEFAULT_HW
    l, e = cfg.num_layers, cfg.moe.num_experts
    return ServeEngine(cfg, params, tables=tables,
                       policy=BuddyPolicy(mode="none"),
                       cache=ExpertCache(l, e, rate, seed=seed),
                       predictor=PrevStepPredictor(l, e) if prefetch_k else None,
                       prefetch_k=prefetch_k, hw=hw or DEFAULT_HW, seed=seed)


def test_prefill_rows_counts_valid_tokens_only(setup):
    cfg, params, _, tables = setup
    eng = _engine(cfg, params, tables)
    caches = eng.init_caches(2, 16)
    toks = np.zeros((2, 4), np.int64)
    valid = np.array([[True, True, True, False],     # 3 prompt tokens
                      [True, False, False, False]])  # 1 decode token
    logits, _ = eng.prefill_rows(toks, np.array([True, True]), caches,
                                 base_pos=np.array([0, 5]), tok_valid=valid)
    assert logits.shape == (2, 4, cfg.vocab_size)
    assert eng.stats.steps == 1
    assert eng.stats.tokens == 4                     # 3 + 1, not 8
    # one fused step pays ONE weight-streaming pass for all 4 tokens
    assert eng.stats.compute_s == pytest.approx(
        eng.hw.decode_compute_time(cfg.active_param_count(), 4))


def test_prefill_rows_ring_wrap_guard(setup):
    cfg, params, _, tables = setup
    eng = _engine(cfg, params, tables)
    caches = eng.init_caches(1, 6)                   # capacity 6
    toks = np.zeros((1, 4), np.int64)
    with pytest.raises(AssertionError, match="ring"):
        eng.prefill_rows(toks, np.array([True]), caches,
                         base_pos=np.array([4]))     # 4 + 4 > 6


def test_prefill_rows_warms_predictor_for_decode(setup):
    """The chunk's dense expert activations must reach the predictor (the
    prefetch warm-up for the request's first decode steps)."""
    cfg, params, _, tables = setup
    # prefetch_k = E > capacity: the predictor must want something that is
    # not yet resident, so issuance is guaranteed once it has observations
    eng = _engine(cfg, params, tables, rate=0.5,
                  prefetch_k=cfg.moe.num_experts)
    caches = eng.init_caches(1, 16)
    toks = np.arange(8, dtype=np.int64)[None, :]
    eng.prefill_rows(toks, np.array([True]), caches,
                     base_pos=np.array([0]))
    assert all(len(eng.predictor.prev[l]) > 0 for l in range(cfg.num_layers))
    assert eng.stats.n_prefetch_issued > 0


# ===========================================================================
# Serving level: chunked admission lowers TTFT at the same arrival rate
# ===========================================================================
def _serve(cfg, params, tables, chunk, n=8, slots=3, seed=0):
    eng = _engine(cfg, params, tables, rate=1.0, seed=seed)
    # fresh generator per call: chunked and token-by-token runs must see
    # IDENTICAL workloads (the module fixture's MarkovLM is stateful)
    lm = MarkovLM(cfg.vocab_size, seed=1)
    prompts = [lm.sample(1, 24)[0] for _ in range(n)]
    reqs = make_requests(prompts, PoissonArrivals(3000.0, seed=2), 6)
    sched = ContinuousScheduler(eng, slots=slots, prefill_chunk=chunk)
    return sched.run(RequestQueue(reqs)), sched


def test_chunked_prefill_improves_ttft(setup):
    cfg, params, _, tables = setup
    s1, _ = _serve(cfg, params, tables, chunk=1)
    s8, _ = _serve(cfg, params, tables, chunk=8)
    assert s1["completed"] == s8["completed"] == 8
    # ⌈P/C⌉ fused steps instead of P decode steps per prompt
    assert s8["steps"] < s1["steps"]
    assert s8["ttft_s"]["mean"] < s1["ttft_s"]["mean"]
    assert s8["ttft_s"]["p99"] < s1["ttft_s"]["p99"]
    assert s8["e2e_s"]["mean"] < s1["e2e_s"]["mean"]


def test_chunked_serving_same_tokens_as_token_by_token(setup):
    """With a full cache (no transfer timeline divergence) and greedy
    sampling, chunked admission must emit exactly the same tokens per
    request as the token-by-token path — chunking changes WHEN work
    happens, never WHAT is computed."""
    cfg, params, _, tables = setup
    _, sc1 = _serve(cfg, params, tables, chunk=1, n=6, slots=2)
    _, sc8 = _serve(cfg, params, tables, chunk=8, n=6, slots=2)
    by1 = {r.rid: r.tokens for r in sc1.completed}
    by8 = {r.rid: r.tokens for r in sc8.completed}
    assert by1.keys() == by8.keys()
    for rid in by1:
        assert by1[rid] == by8[rid], f"request {rid} diverged"
