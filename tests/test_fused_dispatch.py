"""Single-dispatch hot path (policy.use_fused_dispatch): parity with the
unfused three-dispatch graph across every outcome-mask mix — full / buddy /
degraded / fetch-resolved / dropped — on both the jnp megastep and the
Pallas grouped-kernel arms, including aux-mask equality."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core.policy import BuddyPolicy
from repro.core.quantize import quantize_expert_ffn
from repro.models import moe as M

E, K, D, F = 8, 3, 32, 64


def _setup(seed=0, quant=True):
    cfg = MoEConfig(num_experts=E, top_k=K, d_ff=F)
    key = jax.random.PRNGKey(seed)
    params = M.init_moe(key, D, cfg, jnp.float32)
    if quant:
        params["quant"] = quantize_expert_ffn(params["w1"], params["w3"],
                                              params["w2"], 8)
    return cfg, params, key


def _ring_buddies():
    table = jnp.asarray(np.stack([np.roll(np.arange(E), -i - 1)[:3]
                                  for i in range(E)]), jnp.int32)
    return table, jnp.full((E, 3), 0.4, jnp.float32)


def _state(resident, quant_ok=None, fid_cost=None, fetch_cost=None):
    table, q = _ring_buddies()
    return M.BuddyState(resident=jnp.asarray(resident), table=table, q=q,
                        hop=jnp.zeros((E,), jnp.int32),
                        quant_ok=None if quant_ok is None
                        else jnp.asarray(quant_ok),
                        fid_cost=None if fid_cost is None
                        else jnp.asarray(fid_cost, jnp.float32),
                        fetch_cost=None if fetch_cost is None
                        else jnp.asarray(fetch_cost, jnp.float32))


def _assert_parity(params, x, cfg, pol, buddy, tol=2e-4, **kw):
    """Fused (both arms) must match unfused output AND aux exactly."""
    pol_f = dataclasses.replace(pol, use_fused_dispatch=True)
    y0, a0 = M.moe_forward(params, x, cfg, policy=pol, buddy=buddy, **kw)
    for use_kernel in (False, True):
        y1, a1 = M.moe_forward(params, x, cfg, policy=pol_f, buddy=buddy,
                               use_kernel=use_kernel, **kw)
        tag = f"kernel={use_kernel}"
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                                   rtol=tol, atol=tol, err_msg=tag)
        for name in ("indices", "orig_indices", "sub_slots", "miss_slots",
                     "deg_slots", "drop_slots", "miss_per_expert"):
            np.testing.assert_array_equal(np.asarray(getattr(a1, name)),
                                          np.asarray(getattr(a0, name)),
                                          err_msg=f"{tag}: aux.{name}")
        for name in ("n_substituted", "n_missed", "n_degraded",
                     "n_miss_drop"):
            assert int(getattr(a1, name)) == int(getattr(a0, name)), \
                f"{tag}: aux.{name}"
        np.testing.assert_allclose(float(a1.lb_loss), float(a0.lb_loss),
                                   rtol=1e-5, err_msg=tag)
    return a0


def test_fused_zero_miss_decode():
    cfg, params, key = _setup()
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 1, D)) * 0.5
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=2, H=3, quant_tier="int8")
    aux = _assert_parity(params, x, cfg, pol, _state(np.ones(E, bool),
                                                     np.zeros(E, bool)))
    assert int(aux.n_substituted) + int(aux.n_missed) \
        + int(aux.n_degraded) == 0


def test_fused_mixed_outcomes_decode():
    """Buddy + degraded + fetch-resolved slots in one decode batch."""
    cfg, params, key = _setup(seed=3)
    x = jax.random.normal(jax.random.fold_in(key, 1), (6, 1, D)) * 0.5
    resident = np.ones(E, bool)
    resident[[1, 3, 5]] = False
    quant_ok = ~resident & (np.arange(E) % 2 == 1)
    # rho=1 exhausts the buddy budget so later missed slots fall through to
    # degraded / fetch
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=1, H=3, quant_tier="int8")
    aux = _assert_parity(params, x, cfg, pol, _state(resident, quant_ok))
    assert int(aux.n_substituted) > 0
    assert int(aux.n_degraded) > 0


def test_fused_all_degraded():
    """Every routed slot served from the quant tier (mode='none', nothing
    resident, replicas always eligible)."""
    cfg, params, key = _setup(seed=4)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 1, D)) * 0.5
    pol = BuddyPolicy(mode="none", quant_tier="int8")
    aux = _assert_parity(params, x, cfg, pol,
                         _state(np.zeros(E, bool), np.ones(E, bool)))
    assert int(aux.n_degraded) == 4 * K
    assert int(aux.n_missed) == 0 and int(aux.n_substituted) == 0


def test_fused_all_dropped():
    """fallback='drop' with nothing resident and no tier: every slot is
    skipped, the output must be exactly zero on every arm."""
    cfg, params, key = _setup(quant=False)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 1, D)) * 0.5
    pol = BuddyPolicy(mode="none", fallback="drop")
    aux = _assert_parity(params, x, cfg, pol, _state(np.zeros(E, bool)))
    assert int(aux.n_missed) == 4 * K
    pol_f = dataclasses.replace(pol, use_fused_dispatch=True)
    for use_kernel in (False, True):
        y, _ = M.moe_forward(params, x, cfg, policy=pol_f,
                             buddy=_state(np.zeros(E, bool)),
                             use_kernel=use_kernel)
        np.testing.assert_array_equal(np.asarray(y), 0.0)


def test_fused_cost_mode_with_drops():
    """miss_policy='cost': the per-slot argmin produces buddy, degraded,
    fetch AND drop outcomes; the fused path must honor all four."""
    cfg, params, key = _setup(seed=7)
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, 1, D)) * 0.5
    resident = np.ones(E, bool)
    resident[[0, 2, 4, 6]] = False
    # per-expert costs spread around the buddy (0.03) and drop (0.05)
    # costs: experts 0/4 degrade (0.001), the rest substitute while the
    # rho=1 budget lasts, then drop (fetch is priced out at 1.0)
    fid = np.where(np.arange(E) % 4 == 0, 0.001, np.inf)
    fetch = np.full(E, 1.0)
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=1, H=3, quant_tier="int8",
                      miss_policy="cost", stall_per_quality=0.05,
                      drop_loss=1.0)
    aux = _assert_parity(params, x, cfg, pol,
                         _state(resident, ~resident, fid, fetch))
    outcomes = (int(aux.n_substituted), int(aux.n_degraded),
                int(aux.n_miss_drop))
    assert sum(o > 0 for o in outcomes) >= 2, outcomes


@pytest.mark.parametrize("dropless", [False, True])
def test_fused_prefill_shape(dropless):
    """[B, S, D] prefill exercises the fused capacity computation (parity
    holds when capacity drops nothing; drop ACCOUNTING differs by design:
    fused bins per (expert, class) globally, unfused per batch row)."""
    cfg, params, key = _setup(seed=5)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 12, D)) * 0.5
    resident = np.ones(E, bool)
    resident[[1, 6]] = False
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=2, H=3, quant_tier="int8")
    _assert_parity(params, x, cfg, pol,
                   _state(resident, ~resident),
                   capacity_factor=4.0, dropless=dropless)


def test_fused_capacity_cap_drops_and_counts():
    """Tokens beyond the fused per-(expert, class) capacity are dropped and
    counted in aux.n_dropped."""
    cfg_small = MoEConfig(num_experts=2, top_k=1, d_ff=16)
    key = jax.random.PRNGKey(11)
    params = M.init_moe(key, D, cfg_small, jnp.float32)
    params["router"] = jnp.zeros_like(params["router"]).at[:, 0].set(10.0)
    x = jax.random.normal(key, (1, 64, D))
    pol = BuddyPolicy(mode="none", use_fused_dispatch=True)
    buddy = M.full_residency(2)
    for use_kernel in (False, True):
        _, aux = M.moe_forward(params, x, cfg_small, policy=pol, buddy=buddy,
                               capacity_factor=0.25, use_kernel=use_kernel)
        if use_kernel:      # the jnp megastep is capacity-free by design
            assert int(aux.n_dropped) > 0


def test_fused_off_is_default_graph():
    """use_fused_dispatch=False must be bit-identical to a policy without
    the field ever set (the knob's off state compiles the pre-fused graph)."""
    cfg, params, key = _setup()
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 1, D)) * 0.5
    resident = np.ones(E, bool)
    resident[2] = False
    pol = BuddyPolicy(tau=0.0, beta=1.1, rho=2, H=3)
    y0, _ = M.moe_forward(params, x, cfg, policy=pol, buddy=_state(resident))
    y1, _ = M.moe_forward(params, x, cfg,
                          policy=dataclasses.replace(
                              pol, use_fused_dispatch=False),
                          buddy=_state(resident))
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
