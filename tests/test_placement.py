"""Live placement controller (runtime/placement.py).

Covers the control loop's contracts: hysteresis (no replica/coverage
flapping on a noisy ranking), reclaim-first eviction of cold replicas,
coverage re-picks following the live EMA ranking, peer pushes gated on a
multi-device mesh, the placement-off bit-identity against the frozen
pre-placement capture, and reset_runtime resetting per-run state while
preserving the controller's configuration."""
import functools
import json

import jax
import numpy as np

from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.core import BuddyPolicy, build_buddy_lists
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.placement import PlacementController
from repro.runtime.prefetch import PrevStepPredictor
from repro.runtime.tiers import TieredExpertStore
from repro.serving.engine import ServeEngine
from repro.training.data import MarkovLM

from tests._placement_golden import GOLDEN_PATH, golden_summary


@functools.lru_cache(maxsize=1)
def _base():
    cfg = reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    l, e = cfg.num_layers, cfg.moe.num_experts
    q = np.random.default_rng(0).random((l, e, e))
    tables = build_buddy_lists(q, alpha=0.95, k_max=e - 1)
    return cfg, params, tables


def _tier_engine(ctrl, coverage=0.25, cache_rate=1.0):
    """int8 partial-coverage tier engine — the configuration whose covered
    set and cache placement the controller re-plans."""
    cfg, params, tables = _base()
    l, e = cfg.num_layers, cfg.moe.num_experts
    tier = TieredExpertStore(l, e, cache_rate, bits=8, d_model=cfg.d_model,
                             d_ff=cfg.moe.d_ff, coverage=coverage, seed=0)
    return ServeEngine(cfg, params, tables=tables,
                       policy=BuddyPolicy(mode="none", quant_tier="int8"),
                       cache=None, tier=tier,
                       predictor=PrevStepPredictor(l, e),
                       prefetch_k=0, seed=0, upgrade_degraded=False,
                       placement=ctrl)


def _set_act(ctrl, act):
    ctrl.stats.used_ema[:] = act


def test_replica_hysteresis_no_flapping():
    """An expert whose hotness flaps never earns a replica; one that stays
    hot for hot_windows consecutive ticks earns exactly one transfer."""
    ctrl = PlacementController(hot_windows=3, hot_top_k=1,
                               retune_coverage=False, peer_push=False)
    eng = _tier_engine(ctrl)
    layer = 0
    nr = int(np.flatnonzero(~eng.cache.resident[layer])[0])
    hot = np.full(eng.cache.resident.shape, 1e-3)
    hot[layer, nr] = 1.0
    cold = np.zeros(eng.cache.resident.shape)
    for _ in range(4):                      # hot, cold, hot, cold, ...
        _set_act(ctrl, hot)
        ctrl.tick(eng)
        _set_act(ctrl, cold)
        ctrl.tick(eng)
    assert ctrl.n_replicas_issued == 0
    assert eng.scheduler.in_flight(layer, nr) is None
    _set_act(ctrl, hot)
    for _ in range(3):                      # a genuine sustained hot streak
        ctrl.tick(eng)
    assert ctrl.n_replicas_issued == 1
    assert eng.scheduler.in_flight(layer, nr) is not None
    ctrl.tick(eng)                          # in flight: not re-submitted
    assert ctrl.n_replicas_issued == 1


def test_cold_replica_reclaimed_before_normal_victims():
    """A replica whose expert went cold is marked reclaim-first, and the
    next insertion evicts it even when plain LRU would pick another."""
    ctrl = PlacementController(hot_windows=1, hot_top_k=1,
                               retune_coverage=False, peer_push=False)
    eng = _tier_engine(ctrl)
    layer, cache = 0, eng.cache
    nr = int(np.flatnonzero(~cache.resident[layer])[0])
    hot = np.full(cache.resident.shape, 1e-3)
    hot[layer, nr] = 1.0
    _set_act(ctrl, hot)
    ctrl.tick(eng)
    assert ctrl.n_replicas_issued == 1
    eng.advance_clock(eng.scheduler.now + 1.0)      # land the replica
    assert cache.resident[layer, nr]
    _set_act(ctrl, np.zeros(cache.resident.shape))  # replica goes cold
    ctrl.tick(eng)
    assert cache.reclaimable[layer, nr]
    # make the replica the RECENCY winner, then insert: reclaim-first must
    # override LRU and evict the cold replica anyway
    cache.touch(layer, [nr])
    incoming = int(np.flatnonzero(~cache.resident[layer])[0])
    cache.insert(layer, incoming)
    assert not cache.resident[layer, nr]
    assert cache.resident[layer, incoming]
    ctrl.tick(eng)                                  # notices the eviction
    assert ctrl.n_replicas_reclaimed == 1
    assert ctrl.active_replicas() == 0


def test_coverage_repick_matches_ema_ranking():
    """After hot_windows steady ticks the tier's covered set follows the
    live per-layer activity ranking (and only then — one re-pick)."""
    ctrl = PlacementController(hot_windows=2, replicate=False,
                               peer_push=False)
    eng = _tier_engine(ctrl)
    tier, cache = eng.tier, eng.cache
    assert tier.n_covered == 1
    act = np.full(cache.resident.shape, 1e-3)
    targets = []
    for layer in range(cache.resident.shape[0]):
        if not cache.resident[layer, 0]:
            cache.insert(layer, 0)    # old covered expert resident: the
        # make-before-break pre-stage has nothing to copy and the re-pick
        # applies the moment the hysteresis streak completes
        t = int(np.flatnonzero(cache.resident[layer] &
                               (np.arange(cache.num_experts) != 0))[0])
        act[layer, t] = 1.0
        targets.append(t)
    _set_act(ctrl, act)
    ctrl.tick(eng)
    assert ctrl.n_coverage_repicks == 0             # streak 1 of 2
    ctrl.tick(eng)
    assert ctrl.n_coverage_repicks == 1
    want = np.argsort(-act, axis=1, kind="stable")[:, :1]
    for layer, t in enumerate(targets):
        assert int(want[layer, 0]) == t
        assert tier.covered[layer, t]
        assert not tier.covered[layer, 0]
    ctrl.tick(eng)                                  # stable: no churn
    assert ctrl.n_coverage_repicks == 1


def test_peer_push_only_on_multi_device_mesh():
    """peer_push=True is inert at n_devices=1; on a mesh, a sustained-hot
    device-0 expert is pushed into the least-loaded peer's HBM."""
    cfg, params, tables = _base()
    l, e = cfg.num_layers, cfg.moe.num_experts

    def _mesh_engine(n_devices):
        ctrl = PlacementController(hot_windows=1, hot_top_k=1,
                                   retune_coverage=False)
        eng = ServeEngine(cfg, params, tables=tables,
                          policy=BuddyPolicy(mode="none"),
                          cache=ExpertCache(l, e, 0.5, seed=0),
                          predictor=PrevStepPredictor(l, e),
                          prefetch_k=0, seed=0, n_devices=n_devices,
                          placement=ctrl)
        # expert 0 is device 0's home shard: hot everywhere, resident on
        # device 0 (so replication is a no-op) and absent from every peer
        hot = np.zeros((l, e))
        hot[:, 0] = 1.0
        _set_act(ctrl, hot)
        return eng, ctrl

    eng1, ctrl1 = _mesh_engine(1)
    ctrl1.tick(eng1)
    assert ctrl1.n_peer_pushes == 0

    eng4, ctrl4 = _mesh_engine(4)
    ctrl4.tick(eng4)
    assert ctrl4.n_peer_pushes == l
    assert eng4.cache.peer_resident[1, :, 0].all()  # device 1: least loaded
    ctrl4.tick(eng4)                                # already placed: no churn
    assert ctrl4.n_peer_pushes == l


def test_placement_off_bit_identity():
    """placement=None (and the omitted kwarg) reproduce the frozen
    pre-placement engine summary byte-for-byte, for both miss policies."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    for mp in ("precedence", "cost"):
        fresh = json.loads(json.dumps(golden_summary(mp)))
        assert fresh == golden[mp], f"placement-omitted drift ({mp})"
        explicit = json.loads(json.dumps(golden_summary(mp, placement=None)))
        assert explicit == golden[mp], f"placement=None drift ({mp})"


def test_reset_runtime_preserves_controller_config():
    """reset_runtime re-attaches the controller: per-run state (streaks,
    replicas, counters, pending coverage, EMAs) is fresh, configuration
    knobs are untouched."""
    ctrl = PlacementController(refresh_interval_s=5e-4, hot_windows=4,
                               hot_top_k=3, max_replicas_per_layer=1,
                               replicate_margin=1.5, peer_push=False)
    eng = _tier_engine(ctrl)
    cfg, _, _ = _base()
    lm = MarkovLM(cfg.vocab_size, seed=3)
    eng.generate(lm.sample(2, 6), max_new_tokens=6)
    act = np.ones(eng.cache.resident.shape)
    _set_act(ctrl, act)
    for _ in range(5):
        ctrl.tick(eng)
    assert ctrl.n_ticks >= 5
    eng.reset_runtime()
    assert eng.placement is ctrl
    assert ctrl.n_ticks == 0
    assert ctrl.n_replicas_issued == 0
    assert ctrl.n_coverage_repicks == 0
    assert ctrl.active_replicas() == 0
    assert ctrl.trace == []
    assert ctrl._cov_want is None and ctrl._cov_streak == 0
    assert not ctrl._streak.any()
    assert not ctrl.stats.used_ema.any()
    s = ctrl.summary()
    assert s["refresh_interval_s"] == 5e-4
    assert s["hot_windows"] == 4
    assert s["hot_top_k"] == 3
    assert s["max_replicas_per_layer"] == 1
    assert s["replicate_margin"] == 1.5
    assert s["peer_push"] is False
    assert eng.summary()["placement"]["hot_windows"] == 4
