"""Benchmark-regression gate (benchmarks/check_regression.py): metric
extraction from serving.json, the >15% fail rule with absolute floors, and
the injected-regression self-test."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check_regression import (FLOORS, KIND_PATHS,  # noqa: E402
                                         compare, extract_kernel_metrics,
                                         extract_mesh_metrics,
                                         extract_metrics, inject_regression)


def _results():
    """Minimal bench_serving-shaped results dict."""
    arm = {"token_latency_s": {"p99": 2e-3}, "goodput_rps": 100.0}
    return {
        "seed": 0,
        "c0.5_load1.0": {
            "arrival_rate_rps": 1.0,
            "continuous": dict(arm),
            "tiered": {"summary": {"token_latency_s": {"p99": 1e-3}},
                       "nll": {"tier": 1.25, "full_residency": 1.0}},
            "cost_policy": {
                "cost": {"token_latency_s": {"p99": 1e-3},
                         "goodput_rps": 110.0},
                "nll": {"cost": 1.1, "full_residency": 1.0}},
        },
    }


def test_extract_metrics_shapes():
    m = extract_metrics(_results())
    assert m["c0.5_load1.0.p99_token_latency_ms.continuous"] == \
        pytest.approx(2.0)
    assert m["c0.5_load1.0.goodput_rps.continuous"] == 100.0
    assert m["c0.5_load1.0.nll_absdelta.tier"] == pytest.approx(0.25)
    assert m["c0.5_load1.0.nll_absdelta.cost_policy"] == pytest.approx(0.1)
    assert m["c0.5_load1.0.goodput_rps.cost_policy"] == 110.0
    assert len(m) == 7


def test_identical_metrics_pass():
    m = extract_metrics(_results())
    rows, bad = compare(m, dict(m))
    assert not bad
    assert all(r[4] == "ok" for r in rows)


def test_injected_regression_fails():
    m = extract_metrics(_results())
    rows, bad = compare(m, inject_regression(m, 1.3))
    assert bad
    assert any(r[4] == "REGRESSION" for r in rows)
    # every metric family trips: latency/nll up, goodput down
    tripped = {r[0] for r in rows if r[4] == "REGRESSION"}
    assert any("goodput" in t for t in tripped)
    assert any("latency" in t for t in tripped)


def test_small_regression_passes_and_direction_matters():
    m = extract_metrics(_results())
    cur = dict(m)
    lat = "c0.5_load1.0.p99_token_latency_ms.continuous"
    good = "c0.5_load1.0.goodput_rps.continuous"
    cur[lat] = m[lat] * 1.10           # +10% < 15% threshold
    cur[good] = m[good] * 1.30         # goodput UP is an improvement
    rows, bad = compare(m, cur)
    assert not bad
    assert dict((r[0], r[4]) for r in rows)[good] == "improved"
    # a >15% goodput DROP is a regression
    cur[good] = m[good] * 0.5
    _, bad2 = compare(m, cur)
    assert bad2


def test_absolute_floor_masks_noise():
    """Relative blowups below the absolute floor are noise, not failures —
    an NLL delta of 1e-4 doubling is not a quality regression."""
    base = {"k.nll_absdelta.tier": 1e-4}
    cur = {"k.nll_absdelta.tier": 3e-4}        # 3x, but abs change << floor
    assert FLOORS["nll_absdelta"] > 2e-4
    rows, bad = compare(base, cur)
    assert not bad and rows[0][4] == "ok"


def _kernel_results():
    """Minimal bench_kernels-shaped results dict."""
    return {
        "seed": 0,
        "kernels": {"expert_ffn": {"interp_us": 900.0, "xla_ref_us": 200.0}},
        "decode_step": {
            "shape": {"num_experts": 16},
            "zero_miss": {"unfused_us": 1000.0, "fused_us": 420.0,
                          "step_time_ratio": 0.42, "mix": {}},
            "mixed25": {"unfused_us": 1000.0, "fused_us": 610.0,
                        "step_time_ratio": 0.61, "mix": {}},
        },
    }


def test_extract_kernel_metrics_gates_only_ratios():
    """Raw microsecond timings are host-dependent noise; only the
    fused/unfused step-time ratios are gateable."""
    m = extract_kernel_metrics(_kernel_results())
    assert m == {"decode_step.step_time_ratio.zero_miss": 0.42,
                 "decode_step.step_time_ratio.mixed25": 0.61}
    assert not any("interp" in k or "_us" in k for k in m)


def test_kernel_ratio_regression_trips_above_floor():
    m = extract_kernel_metrics(_kernel_results())
    # +0.05 absolute is under the 0.15 jitter floor -> ok
    rows, bad = compare(m, {k: v + 0.05 for k, v in m.items()})
    assert not bad and all(r[4] == "ok" for r in rows)
    # +0.25 absolute (>15% rel AND > floor) -> regression
    rows, bad = compare(m, {k: v + 0.25 for k, v in m.items()})
    assert bad and all(r[4] == "REGRESSION" for r in rows)
    # the self-test injection must also trip
    _, bad = compare(m, inject_regression(m, 1.3))
    assert bad


def test_kernel_baseline_committed_and_consistent():
    """The committed kernels baseline must exist, parse, and gate the same
    metric names the extractor produces."""
    import json
    baseline_path = KIND_PATHS["kernels"][1]
    assert os.path.exists(baseline_path), baseline_path
    with open(baseline_path) as f:
        baseline = json.load(f)
    extracted = extract_kernel_metrics(_kernel_results())
    assert set(baseline) >= set(extracted)
    for name, val in baseline.items():
        assert name.startswith("decode_step.step_time_ratio.")
        assert 0.0 < val < 2.0, (name, val)


def _mesh_results():
    """Minimal results['mesh'] section as bench_serving --n-devices writes."""
    return {
        "seed": 0,
        "mesh": {"n_devices": 4,
                 "p99_tok_ms": {"peer_on": 0.15, "peer_off": 2.8},
                 "peer_share": 0.014},
    }


def test_extract_mesh_metrics_shapes():
    m = extract_mesh_metrics(_mesh_results())
    assert m == {"mesh_d4.p99_token_latency_ms.peer_on": 0.15,
                 "mesh_d4.p99_token_latency_ms.peer_off": 2.8,
                 "mesh_d4.peer_share": 0.014}
    # a single-device serving.json has no mesh section -> nothing to gate
    assert extract_mesh_metrics({"seed": 0}) == {}


def test_mesh_peer_share_direction_and_floor():
    """peer_share gates HIGHER-is-better: a collapse of the fifth outcome
    fails even when the latency numbers hold; tiny absolute wobbles under
    the 0.002 floor pass."""
    m = extract_mesh_metrics(_mesh_results())
    share = "mesh_d4.peer_share"
    cur = dict(m)
    cur[share] = 0.0                   # borrows stopped firing entirely
    rows, bad = compare(m, cur)
    assert bad
    assert dict((r[0], r[4]) for r in rows)[share] == "REGRESSION"
    cur[share] = m[share] - 0.001      # -7% rel but under the abs floor
    assert FLOORS["peer_share"] > 0.001
    _, bad2 = compare(m, cur)
    assert not bad2
    # and the self-test injection trips every mesh metric
    rows3, bad3 = compare(m, inject_regression(m, 1.3))
    assert bad3 and all(r[4] == "REGRESSION" for r in rows3)


def test_mesh_baseline_committed_and_consistent():
    import json
    baseline_path = KIND_PATHS["mesh"][1]
    assert os.path.exists(baseline_path), baseline_path
    with open(baseline_path) as f:
        baseline = json.load(f)
    assert set(baseline) == set(extract_mesh_metrics(_mesh_results()))
    # the committed A/B must show peer borrowing WINNING on p99 — that is
    # the acceptance contract the gate then protects
    assert baseline["mesh_d4.p99_token_latency_ms.peer_on"] < \
        baseline["mesh_d4.p99_token_latency_ms.peer_off"]
    assert baseline["mesh_d4.peer_share"] > 0.0


def test_missing_metric_fails():
    m = extract_metrics(_results())
    cur = dict(m)
    cur.pop("c0.5_load1.0.nll_absdelta.cost_policy")
    rows, bad = compare(m, cur)
    assert bad
    assert any(r[4] == "MISSING" for r in rows)
    # a NEW metric in the current run is reported but does not fail
    cur2 = dict(m)
    cur2["k.p99_token_latency_ms.new_arm"] = 1.0
    rows2, bad2 = compare(m, cur2)
    assert not bad2
    assert any(r[4] == "new" for r in rows2)
