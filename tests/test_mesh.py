"""Expert-parallel multi-device serving: the n_devices=1 bit-identity
contract, partition-aware cache invariants (enable_mesh / peer residency),
hop-priced ICI links, and the D=4 engine path where peer-HBM borrows fire.

The bit-identity test is the load-bearing one: tests/data/pre_mesh_summary
.json was written by tests/_mesh_golden.py BEFORE the mesh refactor landed,
and an n_devices=1 engine must still reproduce it byte-for-byte."""
import json

import jax
import numpy as np
import pytest

from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.core import BuddyPolicy, build_buddy_lists
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.memory import DEFAULT_HW
from repro.runtime.prefetch import PrevStepPredictor
from repro.runtime.telemetry import Telemetry
from repro.runtime.transfers import device_hops, make_ici_links
from repro.serving.engine import ServeEngine
from repro.training.data import MarkovLM

from tests._mesh_golden import GOLDEN_PATH, golden_summary, jsonify


# ---------------------------------------------------------------------------
# single-device bit-identity (the refactor's hard contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("miss_policy", ["precedence", "cost"])
def test_single_device_bit_identity(miss_policy):
    """An n_devices=1 engine IS the pre-refactor engine: the frozen golden
    scenario's summary must match the committed pre-mesh capture exactly —
    every counter, every float bit. No tolerance: any drift means the mesh
    plumbing leaked into the single-device path."""
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    assert golden_summary(miss_policy, n_devices=1) == golden[miss_policy]


# ---------------------------------------------------------------------------
# satellite: hop_vector non-resident sentinel
# ---------------------------------------------------------------------------
def test_hop_vector_nonresident_sentinel():
    """Regression: hop_vector used to return 0 for NON-RESIDENT experts,
    indistinguishable from 'resident at the origin partition' — consumers
    that forgot to mask with residency priced missing experts as free. It
    must now return -1 exactly on the non-resident set."""
    cache = ExpertCache(2, 8, 0.5, seed=0)
    for l in range(2):
        hv = cache.hop_vector(l)
        np.testing.assert_array_equal(hv < 0, ~cache.resident[l])
        assert (hv[cache.resident[l]] >= 0).all()
        assert (hv[~cache.resident[l]] == -1).all()
    # origin shift never turns the sentinel into a valid hop count
    hv = cache.hop_vector(0, origin_partition=cache.num_partitions - 1)
    assert (hv[~cache.resident[0]] == -1).all()


# ---------------------------------------------------------------------------
# partition-aware cache: enable_mesh / peer residency
# ---------------------------------------------------------------------------
def test_enable_mesh_invariants():
    cache = ExpertCache(3, 8, 0.5, seed=1)
    cache.enable_mesh(4)
    e = np.arange(8)
    np.testing.assert_array_equal(cache.owner, e % 4)
    home0 = cache.owner == 0
    for l in range(3):
        # device 0's home shard is statically placed and eviction-proof
        assert cache.resident[l, home0].all()
        assert cache.locked[l, home0].all()
        assert not cache.locked[l, ~home0].any()
        assert int(cache.resident[l].sum()) <= cache.capacity
    # peers statically hold exactly their home shards; device 0's row in
    # peer_resident stays empty (its residency lives in `resident`)
    assert not cache.peer_resident[0].any()
    for d in range(1, 4):
        np.testing.assert_array_equal(
            cache.peer_resident[d], np.broadcast_to(cache.owner == d, (3, 8)))


def test_enable_mesh_single_device_noop():
    a = ExpertCache(2, 8, 0.5, seed=3)
    b = ExpertCache(2, 8, 0.5, seed=3)
    b.enable_mesh(1)
    assert b.n_devices == 1 and b.owner is None and b.peer_resident is None
    np.testing.assert_array_equal(a.resident, b.resident)
    np.testing.assert_array_equal(a.locked, b.locked)
    np.testing.assert_array_equal(a.partition, b.partition)


def test_peer_insert_evict_holders():
    cache = ExpertCache(1, 8, 0.5, seed=0)      # capacity 4
    cache.enable_mesh(4)                         # home shards of size 2
    # expert 0 lives on device 0; replicate it into device 1's HBM
    assert list(cache.peer_holders(0, 0)) == []
    assert cache.peer_insert(1, 0, 0) == -1      # 3 <= capacity, no victim
    assert list(cache.peer_holders(0, 0)) == [1]
    assert cache.peer_insert(1, 0, 0) == -1      # idempotent re-insert
    assert cache.peer_insert(1, 0, 2) == -1      # 4 == capacity, still fits
    # a fifth insert overflows: the victim must be a non-home replica
    evicted = cache.peer_insert(1, 0, 3)
    assert evicted in (0, 2)
    assert not cache.peer_resident[1, 0, evicted]
    # home-shard experts refuse eviction; live replicas drop
    home_e = int(np.flatnonzero(cache.owner == 1)[0])
    assert not cache.peer_evict(1, 0, home_e)
    assert cache.peer_resident[1, 0, home_e]
    kept = 3 if evicted != 3 else (2 if evicted != 2 else 0)
    assert cache.peer_evict(1, 0, kept)
    assert not cache.peer_resident[1, 0, kept]
    # pinned replicas refuse too
    cache.peer_insert(2, 0, 1)
    cache.peer_pinned[2, 0, 1] = True
    assert not cache.peer_evict(2, 0, 1)


# ---------------------------------------------------------------------------
# per-link transfer schedulers (ICI)
# ---------------------------------------------------------------------------
def test_device_hops_and_ici_links():
    # 4 devices -> 2x2 grid: d1 and d2 are one hop out, d3 is the corner
    assert [device_hops(d, 4) for d in range(4)] == [0, 1, 1, 2]
    links = make_ici_links(4, DEFAULT_HW)
    assert sorted(links) == [1, 2, 3]
    for d, link in links.items():
        assert link.name == f"ici{d}"
        assert link.bw == DEFAULT_HW.ici_bw
        assert link.fixed_s == pytest.approx(
            DEFAULT_HW.ici_fixed_s * device_hops(d, 4))
    # the corner device pays strictly more launch cost than its neighbours
    nb = 4 << 20
    assert links[3].transfer_time(nb) > links[1].transfer_time(nb)
    # bandwidth override rescales the streaming term
    slow = make_ici_links(2, DEFAULT_HW, ici_bw=DEFAULT_HW.ici_bw / 4)
    assert slow[1].transfer_time(nb) > links[1].transfer_time(nb)


def test_peer_link_completion_inserts_into_cache():
    """The borrow lifecycle at the link level: a 'peer' transfer completing
    on an ICI link lands the expert in device 0's cache via the listener —
    a hot borrowed expert converges to a plain hit."""
    cache = ExpertCache(2, 8, 0.5, seed=0)
    cache.enable_mesh(2)
    links = make_ici_links(2, DEFAULT_HW)
    links[1].add_listener(cache.on_transfer_event)
    held = np.flatnonzero((cache.owner == 1) & ~cache.resident[0])
    assert len(held), "seed must leave some peer-owned expert non-resident"
    e = int(held[0])
    t = links[1].submit(0, e, 4 << 20, "peer")
    assert cache.inflight[0, e]
    links[1].run_until_done(t)
    assert cache.resident[0, e] and not cache.inflight[0, e]


# ---------------------------------------------------------------------------
# the D=4 engine path
# ---------------------------------------------------------------------------
def _mesh_engine(n_devices, miss_policy="cost", telemetry=None):
    """The golden scenario's engine, opened up to a device mesh."""
    cfg = reduced()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    l, e = cfg.num_layers, cfg.moe.num_experts
    q = np.random.default_rng(0).random((l, e, e))
    tables = build_buddy_lists(q, alpha=0.95, k_max=e - 1)
    policy = BuddyPolicy(tau=0.0, beta=1.1, rho=4, H=3,
                         miss_policy=miss_policy)
    return ServeEngine(cfg, params, tables=tables, policy=policy,
                       cache=ExpertCache(l, e, 0.5, seed=0),
                       predictor=PrevStepPredictor(l, e),
                       prefetch_k=4, seed=0, n_devices=n_devices,
                       telemetry=telemetry)


@pytest.fixture(scope="module")
def mesh_run():
    eng = _mesh_engine(4, telemetry=Telemetry())
    lm = MarkovLM(eng.cfg.vocab_size, seed=0)
    eng.generate(lm.sample(2, 6), max_new_tokens=8)
    return eng, jsonify(eng.summary())


def test_mesh_engine_peer_borrows_fire(mesh_run):
    eng, s = mesh_run
    m = s["mesh"]
    assert m["n_devices"] == 4 and m["peer_borrow"] is True
    assert m["n_peer_borrow"] > 0, "the fifth outcome never fired at D=4"
    assert 0.0 < m["peer_share"] <= 1.0
    assert m["n_peer_borrow"] == eng._n_peer_borrow
    assert m["peer_stall_s"] > 0.0
    assert s["stall_breakdown"]["peer_stall_s"] == m["peer_stall_s"]
    # one utilization digest per ICI link, and the borrows moved real bytes
    assert [u["name"] for u in m["links"]] == ["ici1", "ici2", "ici3"]
    peer_bytes = sum(u["bytes_by_cause"].get("peer", 0) for u in m["links"])
    assert peer_bytes == m["n_peer_borrow"] * eng._expert_bytes
    # the calibration meter learned a 'peer' outcome class
    assert s["telemetry"]["calibration"]["peer"]["n"] == m["n_peer_borrow"]


def test_mesh_reset_runtime_preserves_mesh(mesh_run):
    eng, _ = mesh_run
    eng.reset_runtime()
    assert eng.cache.n_devices == 4
    assert sorted(eng.peer_links) == [1, 2, 3]
    for link in eng.peer_links.values():
        assert link.busy_s == 0.0
    assert eng._n_peer_borrow == 0
    assert eng.summary()["mesh"]["n_peer_borrow"] == 0


def test_single_device_summary_has_no_mesh_section():
    eng = _mesh_engine(1)
    s = eng.summary()
    assert "mesh" not in s
    assert "peer_stall_s" not in eng.stall_breakdown()
