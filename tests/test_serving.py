"""Serving engine integration: policies change transfers and accuracy in the
directions the paper claims (Tables 1-4 mechanics)."""
import jax
import numpy as np
import pytest

from repro.configs.deepseek_v2_lite_buddy import reduced
from repro.core import BuddyPolicy, build_buddy_lists
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.prefetch import PrevStepPredictor
from repro.serving.engine import ServeEngine
from repro.training.data import MarkovLM


@pytest.fixture(scope="module")
def setup():
    cfg = reduced()
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    lm = MarkovLM(cfg.vocab_size, seed=0)
    e = cfg.moe.num_experts
    l = cfg.num_layers
    rng = np.random.default_rng(0)
    q = rng.random((l, e, e))
    tables = build_buddy_lists(q, alpha=0.95, k_max=e - 1)
    return cfg, params, lm, tables


def _engine(cfg, params, tables, policy, rate=0.5, seed=0):
    l, e = cfg.num_layers, cfg.moe.num_experts
    return ServeEngine(cfg, params, tables=tables, policy=policy,
                       cache=ExpertCache(l, e, rate, seed=seed), seed=seed)


def test_buddy_eliminates_sync_fetches(setup):
    cfg, params, lm, tables = setup
    prompts = lm.sample(2, 4)

    eng_b = _engine(cfg, params, tables,
                    BuddyPolicy(tau=0.0, beta=1.1, rho=4, H=3))
    eng_b.generate(prompts, max_new_tokens=6)
    eng_o = _engine(cfg, params, tables, BuddyPolicy(mode="none"))
    eng_o.generate(prompts, max_new_tokens=6)

    # Original pays sync fetches; buddy converts them to substitutions
    assert eng_o.stats.n_miss_fetch > 0
    assert eng_b.stats.n_sub > 0
    assert eng_b.stats.n_miss_fetch < eng_o.stats.n_miss_fetch
    # and therefore moves fewer PCIe bytes (Fig. 8) and is faster (Tables 2-4)
    assert eng_b.ledger.total_bytes < eng_o.ledger.total_bytes
    assert eng_b.stats.tokens_per_s > eng_o.stats.tokens_per_s


def test_full_cache_no_activity(setup):
    cfg, params, lm, tables = setup
    eng = _engine(cfg, params, tables,
                  BuddyPolicy(tau=0.0, beta=1.1, rho=4, H=3), rate=1.0)
    eng.generate(lm.sample(2, 4), max_new_tokens=4)
    assert eng.stats.n_sub == 0
    assert eng.stats.n_miss_fetch == 0
    assert eng.ledger.total_bytes == 0


def test_drop_fallback_no_transfers(setup):
    cfg, params, lm, tables = setup
    eng = _engine(cfg, params, tables,
                  BuddyPolicy(mode="none", fallback="drop"))
    eng.generate(lm.sample(2, 4), max_new_tokens=4)
    assert eng.ledger.total_bytes == 0
    assert eng.ledger.events_by_cause.get("drop", 0) > 0


def test_teacher_forced_nll_ordering(setup):
    """Original (lossless) NLL <= drop-everything NLL on the same data."""
    cfg, params, lm, tables = setup
    data = lm.sample(2, 8)
    nll_orig = _engine(cfg, params, tables,
                       BuddyPolicy(mode="none")).teacher_forced_nll(data)
    nll_drop = _engine(cfg, params, tables,
                       BuddyPolicy(mode="none",
                                   fallback="drop")).teacher_forced_nll(data)
    assert np.isfinite(nll_orig) and np.isfinite(nll_drop)
    # dropping half the experts must not be better (tiny slack for noise)
    assert nll_drop >= nll_orig - 0.05


def test_prefetch_reduces_misses(setup):
    cfg, params, lm, tables = setup
    l, e = cfg.num_layers, cfg.moe.num_experts
    data = lm.sample(2, 10)
    base = _engine(cfg, params, tables, BuddyPolicy(mode="none"), seed=1)
    base.teacher_forced_nll(data)
    pred = ServeEngine(cfg, params, tables=tables,
                       policy=BuddyPolicy(mode="none"),
                       cache=ExpertCache(l, e, 0.5, seed=1),
                       predictor=PrevStepPredictor(l, e),
                       prefetch_k=2, seed=1)
    pred.teacher_forced_nll(data)
    # prefetching shifts traffic from sync to overlapped
    assert pred.ledger.bytes_by_cause.get("prefetch", 0) > 0


def test_static_batcher_masks_pad_rows(setup):
    """Pad rows (rid=-1 copies) must not count toward throughput or NLL."""
    from repro.serving.requests import Request, StaticBatcher
    cfg, params, lm, tables = setup
    reqs = [Request(rid=i, prompt=lm.sample(1, 4)[0], max_new_tokens=3)
            for i in range(3)]
    chunks = list(StaticBatcher(4).batches(reqs))
    assert len(chunks) == 1
    chunk, mat, mask = chunks[0]
    assert mat.shape[0] == 4 and mask.tolist() == [True] * 3 + [False]

    eng = _engine(cfg, params, tables, BuddyPolicy(mode="none"))
    out = eng.generate(mat, max_new_tokens=3, row_mask=mask)
    assert out.shape[0] == 4
    # 3 real rows x (4 + 3 - 1) steps — the pad row's tokens are excluded
    assert eng.stats.tokens == 3 * (mat.shape[1] + 3 - 1)
    assert eng.stats.steps == mat.shape[1] + 3 - 1

    # NLL: masked mean over a batch with a duplicated pad row equals the
    # mean over the real rows alone (pad rows don't skew accuracy metrics)
    data = lm.sample(2, 6)
    padded = np.concatenate([data, data[:1]], axis=0)      # row 2 = pad copy
    m = np.array([True, True, False])
    eng2 = _engine(cfg, params, tables, BuddyPolicy(mode="none"), rate=1.0)
    nll_masked = eng2.teacher_forced_nll(padded, row_mask=m)
    eng3 = _engine(cfg, params, tables, BuddyPolicy(mode="none"), rate=1.0)
    nll_real = eng3.teacher_forced_nll(data)
    assert nll_masked == pytest.approx(nll_real, rel=1e-4)


def test_generate_sampling_flag(setup):
    """greedy=False draws from the engine's seeded PRNG: reproducible for a
    given seed, and (at high temperature) different from the argmax path."""
    cfg, params, lm, tables = setup
    prompts = lm.sample(2, 4)
    pol = BuddyPolicy(mode="none")

    g1 = _engine(cfg, params, tables, pol, rate=1.0, seed=0).generate(
        prompts, max_new_tokens=6, greedy=True)
    g2 = _engine(cfg, params, tables, pol, rate=1.0, seed=0).generate(
        prompts, max_new_tokens=6, greedy=True)
    np.testing.assert_array_equal(g1, g2)          # greedy is deterministic

    s1 = _engine(cfg, params, tables, pol, rate=1.0, seed=0).generate(
        prompts, max_new_tokens=6, greedy=False, temperature=3.0)
    s2 = _engine(cfg, params, tables, pol, rate=1.0, seed=0).generate(
        prompts, max_new_tokens=6, greedy=False, temperature=3.0)
    np.testing.assert_array_equal(s1, s2)          # same seed -> same draws
    assert (s1 >= 0).all() and (s1 < cfg.vocab_size).all()
    assert not np.array_equal(s1, g1)              # hot sampling != argmax
    s3 = _engine(cfg, params, tables, pol, rate=1.0, seed=7).generate(
        prompts, max_new_tokens=6, greedy=False, temperature=3.0)
    assert not np.array_equal(s1, s3)              # different seed -> differs


def test_reset_runtime_preserves_predictor_config(setup):
    """Regression: reset_runtime rebuilt the predictor as type(...)(L, E),
    silently resetting accuracy/seed/decay to defaults between benchmark
    runs — clone_fresh() must carry the configuration over."""
    from repro.runtime.prefetch import NoisyOraclePredictor, TopFreqPredictor
    cfg, params, lm, tables = setup
    l, e = cfg.num_layers, cfg.moe.num_experts
    eng = ServeEngine(cfg, params, tables=tables,
                      cache=ExpertCache(l, e, 0.5, seed=0),
                      predictor=NoisyOraclePredictor(l, e, accuracy=0.3,
                                                     seed=7),
                      prefetch_k=2, seed=0)
    eng.generate(lm.sample(1, 3), max_new_tokens=2)
    assert any(len(t) for t in eng.predictor.truth)    # learned state
    eng.reset_runtime()
    assert isinstance(eng.predictor, NoisyOraclePredictor)
    assert eng.predictor.accuracy == 0.3, "accuracy must survive a reset"
    assert eng.predictor.seed == 7
    assert all(len(t) == 0 for t in eng.predictor.truth)   # state IS fresh

    eng.predictor = TopFreqPredictor(l, e, decay=0.5)
    eng.reset_runtime()
    assert eng.predictor.decay == 0.5
    # an explicit replacement still wins
    eng.reset_runtime(predictor=TopFreqPredictor(l, e, decay=0.9))
    assert eng.predictor.decay == 0.9


def test_summary_roundtrips(setup):
    cfg, params, lm, tables = setup
    eng = _engine(cfg, params, tables, BuddyPolicy())
    eng.generate(lm.sample(1, 3), max_new_tokens=2)
    s = eng.summary()
    assert 0 < s["cache_rate"] <= 1
    assert s["stats"]["steps"] > 0
    import json
    json.dumps(s, default=str)
