"""Dry-run machinery on a small fake-device mesh (subprocess because the
device count must be fixed before jax initializes)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from jax.sharding import PartitionSpec as P
import dataclasses
from repro.configs.base import get_reduced
from repro.launch.sharding import (ShardingProfile, activation_rules,
                                   param_specs, sanitize_specs)
from repro.launch import roofline as rl
from repro.models import transformer
from repro.models.common import axis_rules
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import make_train_step

arch = "ARCH"
cfg = dataclasses.replace(get_reduced(arch), dtype="bfloat16")
mesh = jax.make_mesh((4, 2), ("data", "model"))
prof = ShardingProfile()
rules = activation_rules(prof, cfg, 2)

key = jax.random.PRNGKey(0)
p_struct = jax.eval_shape(lambda k: transformer.init_params(cfg, k), key)
p_spec = param_specs(cfg, p_struct, prof)

import jax.numpy as jnp
toks = jax.ShapeDtypeStruct((8, 32), jnp.int32)
step = make_train_step(cfg, AdamWConfig(), remat=True)

def fn(params, tokens, targets, rng):
    with axis_rules(rules):
        return step(params, None, tokens, targets, rng)[2]["ce"]

# loss-only lowering (opt state skipped for speed)
def fn2(params, tokens, targets, rng):
    from repro.training.train_loop import lm_loss
    with axis_rules(rules):
        return lm_loss(params, cfg, tokens, targets, rng=rng)[0]

from jax.sharding import NamedSharding
def ns(tree, structs):
    return jax.tree.map(lambda s, x: NamedSharding(mesh, s),
                        sanitize_specs(tree, structs, mesh), structs,
                        is_leaf=lambda x: isinstance(x, P))

with mesh:
    jitted = jax.jit(fn2, in_shardings=(
        ns(p_spec, p_struct),
        NamedSharding(mesh, P("data", None)),
        NamedSharding(mesh, P("data", None)), NamedSharding(mesh, P())))
    lowered = jitted.lower(p_struct, toks, toks, key)
    compiled = lowered.compile()
    hlo = compiled.as_text()
r = rl.analyze_hlo(hlo, 8)
print(json.dumps({"flops": r["flops"], "coll": r["coll_bytes"],
                  "loops": r["loops"]}))
"""


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mixtral-8x7b",
                                  "rwkv6-1.6b"])
def test_small_mesh_lowering(arch):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT.replace("ARCH", arch)],
                         capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["flops"] > 0
    assert r["loops"], "expected a scan-over-layers while loop"


def test_roofline_parsers():
    from repro.launch import roofline as rl
    hlo = """
HloModule m

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %d = f32[8,8] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%d), replica_groups=[4,2]<=[8]
}

ENTRY %main () -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %w = (s32[], f32[8,8]) while(%t), condition=%c, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
"""
    r = rl.analyze_hlo(hlo, 8)
    assert r["loops"] == [{"comp": "main", "trip": 5}]
    # all-reduce: 2 * (1/2) * 256 bytes * 5 trips = 1280
    assert abs(r["coll_bytes"] - 2 * 0.5 * 256 * 5) < 1e-6
    # dot: 2*64*8 * 5 = 5120 flops (contract dim read from %a's shape)
    assert abs(r["flops"] - 2 * 64 * 8 * 5) < 1e-6


def test_shape_bytes():
    from repro.launch.roofline import _shape_bytes
    assert _shape_bytes("f32[2,3]") == 24
    assert _shape_bytes("bf16[10]{0}") == 20
    assert _shape_bytes("(f32[2], s32[4])") == 24
    assert _shape_bytes("pred[]") == 1


def test_sanitize_spec():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import sanitize_spec
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        shape = {"model": 16, "data": 4}
    m = FakeMesh()
    assert sanitize_spec(P("model"), (32,), m) == P("model")
    assert sanitize_spec(P("model"), (5,), m) == P(None)
    assert sanitize_spec(P(("data", "model")), (64,), m) == P(("data", "model"))
    assert sanitize_spec(P(("data", "model")), (8,), m) == P("data")
