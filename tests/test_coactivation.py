"""Co-activation recorder (§3.2) vs brute-force counting."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.coactivation import CoactivationRecorder

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


@given(st.integers(0, 500), st.integers(2, 10), st.integers(1, 3),
       st.integers(1, 20))
def test_counts_match_bruteforce(seed, e, k, t):
    k = min(k, e)
    rng = np.random.default_rng(seed)
    idx = np.stack([rng.choice(e, k, replace=False) for _ in range(t)])
    rec = CoactivationRecorder(1, e)
    rec.update(0, idx)

    a_ref = np.zeros(e)
    m_ref = np.zeros((e, e))
    for row in idx:
        for i in row:
            a_ref[i] += 1
        for i in row:
            for j in row:
                if i != j:
                    m_ref[i, j] += 1
    np.testing.assert_allclose(rec.A[0], a_ref)
    np.testing.assert_allclose(rec.M[0], m_ref)


def test_conditional_rows_normalized():
    rng = np.random.default_rng(1)
    rec = CoactivationRecorder(2, 8)
    for _ in range(5):
        idx = np.stack([rng.choice(8, 3, replace=False) for _ in range(16)])
        rec.update(0, idx)
        rec.update(1, idx)
    for l in range(2):
        q = rec.conditional(l)
        np.testing.assert_allclose(q.sum(1), 1.0, rtol=1e-9)
        assert (np.diag(q) == 0).all()


def test_prob_weighted_coactivation():
    rec = CoactivationRecorder(1, 4)
    idx = np.asarray([[0, 1]])
    probs = np.asarray([[0.7, 0.3]])
    rec.update(0, idx, probs)
    assert abs(rec.W[0][0, 1] - 0.3) < 1e-9   # min(p_i, p_j)
    assert abs(rec.W[0][1, 0] - 0.3) < 1e-9
    assert rec.W[0][0, 0] == 0                # diag zero


def test_warmup_downweight():
    rec = CoactivationRecorder(1, 4, warmup_steps=1, warmup_weight=0.5)
    rec.update(0, np.asarray([[0, 1]]))
    rec.step_done()
    assert abs(rec.M[0][0, 1] - 0.5) < 1e-9
    rec.update(0, np.asarray([[0, 1]]))
    assert abs(rec.M[0][0, 1] - 1.5) < 1e-9


def test_skew_and_coverage_stats():
    rng = np.random.default_rng(2)
    rec = CoactivationRecorder(1, 16)
    # heavy-tailed usage: expert 0 dominates
    for _ in range(20):
        idx = np.concatenate([np.zeros((12, 1), np.int64),
                              rng.integers(1, 16, (12, 1))], axis=1)
        rec.update(0, idx)
    skew = rec.activation_skew(0)
    assert skew["top1_share"] > 0.3
    assert 0 < skew["gini"] <= 1
    cov = rec.topr_coverage(0, r=3)
    assert cov.shape == (16,)
    assert (cov <= 1 + 1e-9).all()


def test_save_load_roundtrip(tmp_path):
    rec = CoactivationRecorder(1, 4)
    rec.update(0, np.asarray([[0, 1], [2, 3]]))
    p = str(tmp_path / "coact.npz")
    rec.save(p)
    rec2 = CoactivationRecorder.load(p)
    np.testing.assert_allclose(rec.M, rec2.M)
    np.testing.assert_allclose(rec.A, rec2.A)
