"""Attention unit tests: flash chunked vs naive oracle, SWA, GQA,
ring-buffer decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A

KW = dict(num_heads=4, num_kv_heads=2, head_dim=16, rope_theta=10000.0)


def _naive_attention(q, k, v, causal=True, window=0):
    """Reference: full-matrix softmax attention with GQA."""
    b, s, h, hd = q.shape
    kv_h = k.shape[2]
    g = h // kv_h
    qg = q.reshape(b, s, kv_h, g, hd).astype(np.float64)
    kk = np.asarray(k, np.float64)
    vv = np.asarray(v, np.float64)
    scores = np.einsum("bikgh,bjkh->bkgij", qg, kk) / np.sqrt(hd)
    i = np.arange(s)[:, None]
    j = np.arange(s)[None, :]
    mask = np.ones((s, s), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= j > i - window
    scores = np.where(mask, scores, -1e30)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    out = np.einsum("bkgij,bjkh->bikgh", p, vv)
    return out.reshape(b, s, h, hd)


@pytest.mark.parametrize("s,window", [(32, 0), (64, 0), (64, 16), (33, 7)])
def test_flash_matches_naive(s, window):
    key = jax.random.PRNGKey(0)
    b, h, kvh, hd = 2, 4, 2, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kvh, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kvh, hd))
    out = A._flash_attend(q, k, v, 0, causal=True, window=window,
                          q_chunk=16, kv_chunk=8)
    ref = _naive_attention(np.asarray(q), np.asarray(k), np.asarray(v),
                           causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-5)


def test_decode_ring_buffer_matches_full():
    """Decoding with a ring buffer smaller than the sequence must equal
    windowed attention over the same positions."""
    key = jax.random.PRNGKey(3)
    b, s, window = 1, 24, 8
    d_model = KW["num_heads"] * KW["head_dim"]
    params = A.init_attn(key, d_model, KW["num_heads"], KW["num_kv_heads"],
                         KW["head_dim"], jnp.float32)
    xs = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d_model)) * 0.3

    # reference: full forward with window
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    ref = A.attn_forward(params, xs, positions, window=window, **KW)

    # decode with ring buffer capacity == window
    cache = A.init_kv_cache(b, window, KW["num_kv_heads"], KW["head_dim"],
                            jnp.float32)
    outs = []
    for t in range(s):
        y, cache = A.attn_decode(params, xs[:, t:t + 1], cache,
                                 jnp.asarray(t, jnp.int32), window=window, **KW)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_cross_attention_no_mask():
    key = jax.random.PRNGKey(4)
    b, s, nc = 2, 6, 5
    d_model = KW["num_heads"] * KW["head_dim"]
    params = A.init_attn(key, d_model, KW["num_heads"], KW["num_kv_heads"],
                         KW["head_dim"], jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d_model))
    cond = jax.random.normal(jax.random.fold_in(key, 2), (b, nc, d_model))
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    y = A.attn_forward(params, x, positions, cross_embeds=cond, **KW)
    assert y.shape == x.shape
    # every query attends to the SAME cond set -> permuting queries permutes
    # outputs identically
    y2 = A.attn_forward(params, x[:, ::-1], positions, cross_embeds=cond, **KW)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y[:, ::-1]),
                               rtol=1e-4, atol=1e-5)


def test_rope_shift_invariance():
    """RoPE attention scores depend only on relative positions."""
    from repro.models.common import apply_rope
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (1, 4, 2, 16))
    pos1 = jnp.arange(4)[None, :]
    pos2 = pos1 + 100
    s1 = np.einsum("bqhd,bkhd->bhqk", np.asarray(apply_rope(q, pos1, 1e4)),
                   np.asarray(apply_rope(q, pos1, 1e4)))
    s2 = np.einsum("bqhd,bkhd->bhqk", np.asarray(apply_rope(q, pos2, 1e4)),
                   np.asarray(apply_rope(q, pos2, 1e4)))
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-5)
