"""Per-architecture smoke tests: REDUCED same-family variant, one forward /
train step + one decode step on CPU; assert shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_reduced
from repro.models import transformer
from repro.training.optimizer import AdamWConfig
from repro.training import optimizer as opt_mod
from repro.training.train_loop import make_train_step


def _cond(cfg, b, key):
    if cfg.num_cond_tokens:
        return jax.random.normal(key, (b, cfg.num_cond_tokens, cfg.cond_dim),
                                 jnp.float32)
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_decode(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0,
                                cfg.vocab_size)
    cond = _cond(cfg, b, jax.random.fold_in(key, 2))
    logits, aux = transformer.forward_train(params, cfg, tokens,
                                            cond_embeds=cond,
                                            rng=jax.random.fold_in(key, 3))
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()

    caches = transformer.init_caches(cfg, b, 32)
    tok = jnp.zeros((b,), jnp.int32)
    lg, caches, _ = transformer.decode_step(params, cfg, tok, caches,
                                            jnp.asarray(0, jnp.int32),
                                            cond_embeds=cond)
    assert lg.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg)).all()


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-buddy", "mixtral-8x7b",
                                  "rwkv6-1.6b", "zamba2-7b", "smollm-360m"])
def test_one_train_step(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    opt_state = opt_mod.init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=10)))
    toks = jax.random.randint(key, (2, 17), 0, cfg.vocab_size)
    params, opt_state, m = step(params, opt_state, toks[:, :-1], toks[:, 1:],
                                jax.random.fold_in(key, 1))
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0
    for leaf in jax.tree.leaves(params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_remat_matches_no_remat():
    cfg = get_reduced("internlm2-1.8b")
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    l1, _ = transformer.forward_train(params, cfg, toks, remat=False)
    l2, _ = transformer.forward_train(params, cfg, toks, remat=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5)


def test_decode_matches_forward_dense():
    """Teacher-forced decode logits must match full-sequence forward."""
    cfg = get_reduced("internlm2-1.8b")
    key = jax.random.PRNGKey(1)
    params = transformer.init_params(cfg, key)
    b, s = 2, 10
    tokens = np.asarray(jax.random.randint(key, (b, s), 0, cfg.vocab_size))
    full_logits, _ = transformer.forward_train(params, cfg, jnp.asarray(tokens))
    caches = transformer.init_caches(cfg, b, s)
    for pos in range(s - 1):
        lg, caches, _ = transformer.decode_step(
            params, cfg, jnp.asarray(tokens[:, pos]), caches,
            jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, pos]),
                                   rtol=2e-4, atol=2e-4)


def test_decode_matches_forward_ssm():
    cfg = get_reduced("rwkv6-1.6b")
    key = jax.random.PRNGKey(2)
    params = transformer.init_params(cfg, key)
    b, s = 2, 8
    tokens = np.asarray(jax.random.randint(key, (b, s), 0, cfg.vocab_size))
    full_logits, _ = transformer.forward_train(params, cfg, jnp.asarray(tokens))
    caches = transformer.init_caches(cfg, b, s)
    for pos in range(s - 1):
        lg, caches, _ = transformer.decode_step(
            params, cfg, jnp.asarray(tokens[:, pos]), caches,
            jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, pos]),
                                   rtol=2e-4, atol=2e-4)


def test_decode_matches_forward_hybrid():
    cfg = get_reduced("zamba2-7b")
    key = jax.random.PRNGKey(3)
    params = transformer.init_params(cfg, key)
    b, s = 2, 8
    tokens = np.asarray(jax.random.randint(key, (b, s), 0, cfg.vocab_size))
    full_logits, _ = transformer.forward_train(params, cfg, jnp.asarray(tokens))
    caches = transformer.init_caches(cfg, b, s)
    for pos in range(s - 1):
        lg, caches, _ = transformer.decode_step(
            params, cfg, jnp.asarray(tokens[:, pos]), caches,
            jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, pos]),
                                   rtol=2e-4, atol=2e-4)
