"""Fallback stand-in for ``hypothesis`` when it is not installed.

Six test modules use property-based tests. On CI images without
``hypothesis`` we install a tiny deterministic replacement into
``sys.modules`` (see conftest.py) so the suite still collects and the
properties are exercised on a fixed, seeded set of examples. When the real
``hypothesis`` is importable this module is never used.

Supported surface (only what the suite needs):
  given, settings (register_profile/load_profile), strategies.{integers,
  floats, booleans, sampled_from, data}, hypothesis.extra.numpy.{arrays,
  array_shapes}.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types

import numpy as np

# Examples per @given test in fallback mode. Kept modest: several suites jit
# per drawn shape, and the point here is collection + smoke coverage, not
# shrinking.
_FALLBACK_MAX_EXAMPLES = 12


class _Strategy:
    def __init__(self, sample):
        self.sample = sample  # sample(rng) -> value

    def map(self, fn):
        return _Strategy(lambda rng: fn(self.sample(rng)))

    def filter(self, pred):
        def sample(rng):
            for _ in range(1000):
                v = self.sample(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return _Strategy(sample)


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value, width=None, **_kw):
    def sample(rng):
        v = float(rng.uniform(min_value, max_value))
        return float(np.float32(v)) if width == 32 else v
    return _Strategy(sample)


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def lists(elements, min_size=0, max_size=10):
    def sample(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.sample(rng) for _ in range(n)]
    return _Strategy(sample)


class _DataObject:
    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.sample(self._rng)


def data():
    return _Strategy(lambda rng: _DataObject(rng))


def _np_arrays(dtype, shape, elements=None, **_kw):
    """hypothesis.extra.numpy.arrays lookalike."""
    def sample(rng):
        shp = shape.sample(rng) if isinstance(shape, _Strategy) else shape
        if isinstance(shp, (int, np.integer)):
            shp = (int(shp),)
        n = int(np.prod(shp)) if len(shp) else 1
        if elements is not None:
            flat = np.asarray([elements.sample(rng) for _ in range(n)])
        else:
            flat = rng.random(n)
        return flat.astype(dtype).reshape(shp)
    return _Strategy(sample)


def _np_array_shapes(min_dims=1, max_dims=3, min_side=1, max_side=8):
    def sample(rng):
        d = int(rng.integers(min_dims, max_dims + 1))
        return tuple(int(rng.integers(min_side, max_side + 1))
                     for _ in range(d))
    return _Strategy(sample)


class settings:  # noqa: N801 — mirrors hypothesis' API
    _profiles: dict = {}
    _current: dict = {}

    def __init__(self, **kw):
        self._kw = kw

    def __call__(self, fn):   # used as a decorator
        return fn

    @classmethod
    def register_profile(cls, name, **kw):
        cls._profiles[name] = kw

    @classmethod
    def load_profile(cls, name):
        cls._current = cls._profiles.get(name, {})


def given(*strategies, **kw_strategies):
    def deco(fn):
        n = min(int(settings._current.get("max_examples", 25)),
                _FALLBACK_MAX_EXAMPLES)
        # stable per-test seed so failures reproduce across runs
        seed = abs(hash(fn.__qualname__)) % (2 ** 31)

        @functools.wraps(fn)
        def wrapper():
            rng = np.random.default_rng(seed)
            for _ in range(n):
                args = [s.sample(rng) for s in strategies]
                kwargs = {k: s.sample(rng) for k, s in kw_strategies.items()}
                fn(*args, **kwargs)

        # pytest resolves fixtures from the (followed) signature; the drawn
        # parameters must not look like fixtures.
        wrapper.__signature__ = inspect.Signature()
        del wrapper.__wrapped__
        return wrapper
    return deco


def install():
    """Register fake hypothesis modules in sys.modules."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = lambda cond: True
    hyp.__version__ = "0.0-fallback"

    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "data"):
        setattr(st_mod, name, globals()[name])
    hyp.strategies = st_mod

    extra = types.ModuleType("hypothesis.extra")
    hnp = types.ModuleType("hypothesis.extra.numpy")
    hnp.arrays = _np_arrays
    hnp.array_shapes = _np_array_shapes
    extra.numpy = hnp
    hyp.extra = extra

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = hnp
