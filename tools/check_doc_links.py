"""Doc link checker (CI): every RELATIVE markdown link must resolve.

Scans README.md and docs/**/*.md for inline links/images
(``[text](target)``) and verifies that each relative target exists on
disk, resolved against the file containing the link. What counts:

  * relative file links (``docs/architecture.md``, ``../README.md``) —
    must exist, including an optional ``#anchor`` suffix (the anchor
    itself is checked against the target's headings);
  * intra-file anchors (``#section-name``) — checked against the
    current file's headings, GitHub-slugged (lowercase, punctuation
    stripped, spaces to dashes);
  * absolute URLs (``http://``, ``https://``, ``mailto:``) — skipped,
    CI must not depend on the network;
  * code spans and fenced code blocks — stripped before scanning, so
    ``[i](j)``-looking indexing in examples never false-positives.

Stdlib only. Exit 0 when every link resolves, 1 with a per-link report
otherwise.

  python tools/check_doc_links.py            # repo root inferred
  python tools/check_doc_links.py --root .   # explicit root
"""
from __future__ import annotations

import argparse
import os
import re
import sys

# [text](target) — non-greedy text, target up to the first unescaped ')';
# a leading '!' (image) is consumed so alt text is treated the same
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_FENCE = re.compile(r"^(```|~~~)", re.M)
_CODE_SPAN = re.compile(r"`[^`\n]*`")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def _slug(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop everything but word
    characters / spaces / dashes, spaces to dashes."""
    h = re.sub(r"[`*_]", "", heading.strip()).lower()
    h = re.sub(r"[^\w\s-]", "", h)
    return re.sub(r"\s+", "-", h)


def _strip_code(text: str) -> str:
    """Remove fenced blocks and inline code spans (link-shaped source code
    inside them is not a link)."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(_CODE_SPAN.sub("", line))
    return "\n".join(out)


def _anchors(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        return {_slug(m.group(1)) for m in _HEADING.finditer(f.read())}


def check_file(path: str) -> list:
    """[(target, reason)] for every broken link in one markdown file."""
    with open(path, encoding="utf-8") as f:
        text = _strip_code(f.read())
    bad = []
    base = os.path.dirname(path)
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP_SCHEMES):
            continue
        if target.startswith("#"):
            if _slug(target[1:]) not in _anchors(path):
                bad.append((target, "missing heading in this file"))
            continue
        file_part, _, anchor = target.partition("#")
        dest = os.path.normpath(os.path.join(base, file_part))
        if not os.path.exists(dest):
            bad.append((target, f"no such file: {dest}"))
            continue
        if anchor and dest.endswith(".md") and \
                _slug(anchor) not in _anchors(dest):
            bad.append((target, f"missing heading in {dest}"))
    return bad


def iter_docs(root: str):
    readme = os.path.join(root, "README.md")
    if os.path.exists(readme):
        yield readme
    docs = os.path.join(root, "docs")
    for dirpath, _, names in sorted(os.walk(docs)):
        for name in sorted(names):
            if name.endswith(".md"):
                yield os.path.join(dirpath, name)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), ".."),
        help="repo root (default: parent of tools/)")
    args = ap.parse_args(argv)
    root = os.path.normpath(args.root)
    n_files, n_bad = 0, 0
    for path in iter_docs(root):
        n_files += 1
        for target, reason in check_file(path):
            rel = os.path.relpath(path, root)
            print(f"BROKEN {rel}: ({target}) — {reason}")
            n_bad += 1
    if n_bad:
        print(f"\nFAIL: {n_bad} broken link(s) across {n_files} file(s)")
        return 1
    print(f"OK: all relative links resolve across {n_files} markdown "
          f"file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
