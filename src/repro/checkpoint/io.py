"""Checkpoint I/O: params/opt-state pytrees -> npz, configs -> json.

Buddy tables (core.buddies.BuddyTables) serialize alongside the model
checkpoint, as the paper prescribes (§3.4 'serialized and distributed
alongside model checkpoints')."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_pytree(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez_compressed(path, **_flatten_with_paths(tree))


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (same keys)."""
    data = np.load(path)
    flat = _flatten_with_paths(like)
    assert set(flat) == set(data.files), \
        f"checkpoint keys mismatch: {set(flat) ^ set(data.files)}"
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(_path_str(p) for p in path)
        arr = data[key]
        assert arr.shape == leaf.shape, f"{key}: {arr.shape} != {leaf.shape}"
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_config(path: str, cfg_dict: dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(cfg_dict, f, indent=2, default=str)


def load_config(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
