"""Expert prefetch predictors (§2.3 — the systems BuddyMoE complements).

All predictors answer: which experts should layer l's cache hold for the next
step? Their misses are exactly what BuddyMoE absorbs.

  TopFreqPredictor   — historical activation frequency (MoE-Infinity-style).
  PrevStepPredictor  — temporal locality: last step's experts per layer.
  CrossLayerPredictor— gate-signal chaining (Pre-gated/Fate-style): score
                       experts at layer l by P(e | experts used at l-1) from
                       a profiled cross-layer co-usage matrix.
  NoisyOraclePredictor — ground truth corrupted at rate (1-accuracy): the
                       controllable-miss-rate harness for Table 1/2-4 sweeps.

Lookahead API: ``predict_ahead(layer, k, lookahead)`` answers "while layer l
computes, which experts should be in flight for layer l+lookahead?" so the
transfer scheduler can overlap layer l+k prefetches with layer l compute.
The default is the same-layer temporal prediction; CrossLayerPredictor
chains its co-usage matrices ``lookahead`` hops so deeper lookahead has a
real signal (Pre-gated/Fate-style pipelining).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


class LookaheadMixin:
    """Default lookahead: reuse the per-layer temporal prediction."""

    def predict_ahead(self, layer: int, k: int, lookahead: int = 1,
                      context=None, rng=None) -> np.ndarray:
        return self.predict(layer, k, rng=rng)


class TopFreqPredictor(LookaheadMixin):
    def __init__(self, num_layers: int, num_experts: int, decay: float = 0.99):
        self.freq = np.ones((num_layers, num_experts), np.float64)
        self.decay = decay

    def clone_fresh(self) -> "TopFreqPredictor":
        """Same configuration, no learned state (benchmark-run resets)."""
        return TopFreqPredictor(*self.freq.shape, decay=self.decay)

    def observe(self, layer: int, experts) -> None:
        self.freq[layer] *= self.decay
        np.add.at(self.freq[layer], np.asarray(experts, np.int64).reshape(-1), 1.0)

    def predict(self, layer: int, k: int, rng=None) -> np.ndarray:
        return np.argsort(-self.freq[layer])[:k]


class PrevStepPredictor(LookaheadMixin):
    def __init__(self, num_layers: int, num_experts: int):
        self.prev = [np.array([], np.int64) for _ in range(num_layers)]
        self.freq = TopFreqPredictor(num_layers, num_experts)

    def clone_fresh(self) -> "PrevStepPredictor":
        return PrevStepPredictor(*self.freq.freq.shape)

    def observe(self, layer: int, experts) -> None:
        self.prev[layer] = np.unique(np.asarray(experts, np.int64).reshape(-1))
        self.freq.observe(layer, experts)

    def predict(self, layer: int, k: int, rng=None) -> np.ndarray:
        p = self.prev[layer][:k]
        if len(p) < k:   # back-fill with frequency prior
            rest = [e for e in self.freq.predict(layer, k) if e not in p]
            p = np.concatenate([p, np.asarray(rest[:k - len(p)], np.int64)])
        return p


class CrossLayerPredictor(LookaheadMixin):
    """P(expert j at layer l | expert i at layer l-1), profiled offline."""

    def __init__(self, num_layers: int, num_experts: int, eps: float = 1e-3):
        self.eps = eps
        self.C = np.full((num_layers, num_experts, num_experts), eps, np.float64)
        self.prev_set: Optional[np.ndarray] = None
        self.freq = TopFreqPredictor(num_layers, num_experts)

    def clone_fresh(self) -> "CrossLayerPredictor":
        return CrossLayerPredictor(self.C.shape[0], self.C.shape[1],
                                   eps=self.eps)

    def observe_transition(self, layer: int, prev_experts, cur_experts) -> None:
        prev_experts = np.unique(np.asarray(prev_experts, np.int64).reshape(-1))
        cur_experts = np.unique(np.asarray(cur_experts, np.int64).reshape(-1))
        for i in prev_experts:
            self.C[layer, i, cur_experts] += 1.0

    def observe(self, layer: int, experts) -> None:
        self.freq.observe(layer, experts)

    def predict(self, layer: int, k: int, prev_experts=None, rng=None) -> np.ndarray:
        if prev_experts is None or len(np.atleast_1d(prev_experts)) == 0 or layer == 0:
            return self.freq.predict(layer, k)
        prev_experts = np.unique(np.asarray(prev_experts, np.int64).reshape(-1))
        score = self.C[layer, prev_experts].sum(axis=0)
        return np.argsort(-score)[:k]

    def predict_ahead(self, layer: int, k: int, lookahead: int = 1,
                      context=None, rng=None) -> np.ndarray:
        """Chain co-usage matrices ``lookahead`` hops forward: while layer
        ``layer - lookahead`` computes with experts ``context``, score layer
        ``layer``'s experts by propagating the activation indicator through
        C[layer-lookahead+1] .. C[layer] (row-normalised)."""
        if context is None or len(np.atleast_1d(context)) == 0 or lookahead < 1:
            return self.predict(layer, k)
        src = layer - lookahead
        if src < 0:
            return self.freq.predict(layer, k)
        e_n = self.C.shape[1]
        s = np.zeros(e_n, np.float64)
        s[np.unique(np.asarray(context, np.int64).reshape(-1))] = 1.0
        for m in range(src + 1, layer + 1):
            cm = self.C[m]
            cm = cm / np.maximum(cm.sum(axis=1, keepdims=True), 1e-30)
            s = s @ cm
        return np.argsort(-s)[:k]


@dataclasses.dataclass
class PrefetchBudget:
    """How much speculative PCIe traffic the runtime may spend per layer."""
    prefetch_k: int           # experts predicted/issued per target layer
    lookahead: int            # layers ahead the prediction targets
    max_inflight: int         # link-level concurrent-prefetch cap


class AdaptiveBudgetController:
    """Closes the loop between the serving scheduler and the transfer
    runtime: instead of a fixed ``--prefetch-k`` CLI constant, the budget is
    resized every ``window`` steps from two signals —

      * the ledger's stall-attribution DELTAS over the window
        (``stall_breakdown``: demand vs late-prefetch vs overlapped), and
      * the request-queue depth (continuous batching: deeper backlog means
        fuller batches, longer compute slices, more overlap headroom).

    Policy (each adjustment is one step on an integer ladder, so the budget
    cannot oscillate wildly):

      late-prefetch stalls dominate  -> the link cannot land speculation in
          time: SHRINK prefetch_k (fewer, better bets) and DEEPEN lookahead
          (issue earlier so the tail lands before the layer needs it);
      demand stalls dominate         -> speculation is too timid: GROW
          prefetch_k (and let the link cap follow);
      mostly overlapped / idle       -> hold.

    Queue depth sets the ceiling: an empty queue halves the allowed k (the
    speculative bytes would evict still-useful experts for no latency win);
    a deep queue restores the full configured range.
    """

    def __init__(self, prefetch_k: int, lookahead: int = 1, *,
                 min_k: int = 1, max_k: int = 16,
                 min_lookahead: int = 1, max_lookahead: int = 4,
                 max_inflight: int = 4, window: int = 8,
                 dominance: float = 1.5, deep_queue: int = 4):
        assert min_k <= prefetch_k <= max_k
        assert min_lookahead <= lookahead <= max_lookahead
        self.budget = PrefetchBudget(prefetch_k, lookahead,
                                     max(1, min(max_inflight, prefetch_k)))
        self.max_inflight_cap = max_inflight
        self.min_k, self.max_k = min_k, max_k
        self.min_lookahead, self.max_lookahead = min_lookahead, max_lookahead
        self.window = max(1, window)
        self.dominance = dominance
        self.deep_queue = deep_queue
        self._steps = 0
        self._last = {"demand_stall_s": 0.0, "late_prefetch_stall_s": 0.0,
                      "overlapped_s": 0.0}
        self.trace: list = []

    # -- observation ----------------------------------------------------
    def observe_step(self, stall_breakdown: dict, queue_depth: int):
        """Call once per engine step. Returns the (possibly updated) budget."""
        self._steps += 1
        if self._steps % self.window == 0:
            self.update(stall_breakdown, queue_depth)
        return self.budget

    def update(self, stall_breakdown: dict, queue_depth: int) -> PrefetchBudget:
        """Apply one feedback adjustment from cumulative stall attribution
        (deltas are taken against the previous update)."""
        d_demand = stall_breakdown["demand_stall_s"] - \
            self._last["demand_stall_s"]
        d_late = stall_breakdown["late_prefetch_stall_s"] - \
            self._last["late_prefetch_stall_s"]
        self._last = {k: stall_breakdown[k] for k in self._last}

        b = self.budget
        k, la = b.prefetch_k, b.lookahead
        if d_late > self.dominance * max(d_demand, 1e-12):
            # speculation arrives too late: spend less, issue earlier
            k = max(self.min_k, k - 1)
            la = min(self.max_lookahead, la + 1)
        elif d_demand > self.dominance * max(d_late, 1e-12):
            k = min(self._queue_cap(queue_depth), k + 1)
            # lateness is no longer the problem: walk lookahead back toward
            # shallow (prediction accuracy decays with depth)
            la = max(self.min_lookahead, la - 1)
        k = min(k, self._queue_cap(queue_depth))
        b.prefetch_k, b.lookahead = k, la
        b.max_inflight = max(1, min(self.max_inflight_cap, k))
        self.trace.append({"step": self._steps, "prefetch_k": k,
                           "lookahead": la,
                           "demand_delta_s": d_demand,
                           "late_delta_s": d_late,
                           "queue_depth": queue_depth})
        return b

    def _queue_cap(self, queue_depth: int) -> int:
        if queue_depth >= self.deep_queue:
            return self.max_k
        return max(self.min_k, self.max_k // 2)

    # -- actuation ------------------------------------------------------
    def apply(self, engine) -> PrefetchBudget:
        """Push the current budget into a ServeEngine and its transfer
        scheduler (the runtime knobs the budget governs)."""
        engine.prefetch_k = self.budget.prefetch_k
        engine.lookahead = self.budget.lookahead
        engine.scheduler.set_prefetch_cap(self.budget.max_inflight)
        return self.budget


class NoisyOraclePredictor(LookaheadMixin):
    """Knows the true next-step experts; corrupts each slot with prob
    (1 - accuracy). Gives direct control of the prefetch-miss rate."""

    def __init__(self, num_layers: int, num_experts: int, accuracy: float = 0.8,
                 seed: int = 0):
        self.num_experts = num_experts
        self.accuracy = accuracy
        self.seed = seed
        self.truth = [np.array([], np.int64) for _ in range(num_layers)]
        self.rng = np.random.default_rng(seed)

    def clone_fresh(self) -> "NoisyOraclePredictor":
        return NoisyOraclePredictor(len(self.truth), self.num_experts,
                                    accuracy=self.accuracy, seed=self.seed)

    def set_truth(self, layer: int, experts) -> None:
        self.truth[layer] = np.unique(np.asarray(experts, np.int64).reshape(-1))

    def observe(self, layer: int, experts) -> None:
        self.set_truth(layer, experts)

    def predict(self, layer: int, k: int, rng=None) -> np.ndarray:
        rng = rng or self.rng
        t = self.truth[layer][:k]
        out, seen, corrupted = [], set(), []
        # corrupted draws can collide with an already-emitted expert; dedup
        # them like the back-fill loop so the prediction stays a k-set
        # (duplicates silently shrank the effective prefetch set below k).
        # Accurate truth draws land first — a colliding corrupted draw must
        # displace ITSELF, not a truth expert, or the realised accuracy
        # drifts below the configured knob.
        for e in t:
            if rng.random() < self.accuracy:
                out.append(int(e))
                seen.add(int(e))          # truth is unique: never collides
            else:
                corrupted.append(int(rng.integers(0, self.num_experts)))
        for e in corrupted:
            if e not in seen:
                out.append(e)
                seen.add(e)
        while len(out) < min(k, self.num_experts):
            e = int(rng.integers(0, self.num_experts))
            if e not in seen:
                out.append(e)
                seen.add(e)
        return np.asarray(out[:k], np.int64)
