"""Expert prefetch predictors (§2.3 — the systems BuddyMoE complements).

All predictors answer: which experts should layer l's cache hold for the next
step? Their misses are exactly what BuddyMoE absorbs.

  TopFreqPredictor   — historical activation frequency (MoE-Infinity-style).
  PrevStepPredictor  — temporal locality: last step's experts per layer.
  CrossLayerPredictor— gate-signal chaining (Pre-gated/Fate-style): score
                       experts at layer l by P(e | experts used at l-1) from
                       a profiled cross-layer co-usage matrix.
  NoisyOraclePredictor — ground truth corrupted at rate (1-accuracy): the
                       controllable-miss-rate harness for Table 1/2-4 sweeps.

Lookahead API: ``predict_ahead(layer, k, lookahead)`` answers "while layer l
computes, which experts should be in flight for layer l+lookahead?" so the
transfer scheduler can overlap layer l+k prefetches with layer l compute.
The default is the same-layer temporal prediction; CrossLayerPredictor
chains its co-usage matrices ``lookahead`` hops so deeper lookahead has a
real signal (Pre-gated/Fate-style pipelining).

Probability API: ``predict_proba(layer, lookahead, context)`` returns [E]
per-expert MARGINAL P(expert used at the target step) — each entry in
[0, 1], NOT a distribution summing to 1 (a decode step uses many experts,
so the marginals sum to roughly the used-set size). The scale matters:
the serving engine multiplies by the unified miss cost (runtime/costs.py)
to rank prefetch candidates by EXPECTED STALL SAVED, compares the product
against an absolute seconds threshold, and counts worthwhile candidates
for the budget controller — predictors must agree on units or the
threshold filters them inconsistently (NoisyOraclePredictor's ``accuracy``
is the reference: a certain-reuse expert scores ~1, not ~1/k).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


class LookaheadMixin:
    """Default lookahead: reuse the per-layer temporal prediction. Default
    probability: flat 0.5 marginal on the top-quarter prediction (any
    predictor gets a usable — if crude — P(use) signal in marginal units;
    concrete classes override with their real posterior)."""

    def predict_ahead(self, layer: int, k: int, lookahead: int = 1,
                      context=None, rng=None) -> np.ndarray:
        return self.predict(layer, k, rng=rng)

    def predict_proba(self, layer: int, lookahead: int = 1,
                      context=None) -> np.ndarray:
        e_n = self.num_experts
        k = max(1, e_n // 4)
        top = np.asarray(self.predict_ahead(layer, k, lookahead=lookahead,
                                            context=context), np.int64)
        p = np.zeros(e_n, np.float64)
        if len(top):
            p[top] = 0.5
        return p


class TopFreqPredictor(LookaheadMixin):
    def __init__(self, num_layers: int, num_experts: int, decay: float = 0.99):
        self.num_experts = num_experts
        self.freq = np.ones((num_layers, num_experts), np.float64)
        self.decay = decay
        # EWMA of the per-step used-set size: converts the frequency SHARE
        # into a per-expert marginal (share x experts-per-step), keeping
        # predict_proba on the same [0, 1]-marginal scale as the oracle
        self._avg_used = 1.0

    def clone_fresh(self) -> "TopFreqPredictor":
        """Same configuration, no learned state (benchmark-run resets)."""
        return TopFreqPredictor(*self.freq.shape, decay=self.decay)

    def observe(self, layer: int, experts) -> None:
        experts = np.asarray(experts, np.int64).reshape(-1)
        self.freq[layer] *= self.decay
        np.add.at(self.freq[layer], experts, 1.0)
        self._avg_used = 0.9 * self._avg_used + 0.1 * len(np.unique(experts))

    def predict(self, layer: int, k: int, rng=None) -> np.ndarray:
        return np.argsort(-self.freq[layer])[:k]

    def predict_proba(self, layer: int, lookahead: int = 1,
                      context=None) -> np.ndarray:
        f = self.freq[layer]
        share = f / max(f.sum(), 1e-30)
        return np.clip(share * self._avg_used, 0.0, 1.0)


class PrevStepPredictor(LookaheadMixin):
    """Temporal-locality blend: most of the mass on last step's experts,
    the rest on the decayed frequency prior."""

    PREV_WEIGHT = 0.7

    def __init__(self, num_layers: int, num_experts: int):
        self.num_experts = num_experts
        self.prev = [np.array([], np.int64) for _ in range(num_layers)]
        self.freq = TopFreqPredictor(num_layers, num_experts)

    def clone_fresh(self) -> "PrevStepPredictor":
        return PrevStepPredictor(*self.freq.freq.shape)

    def observe(self, layer: int, experts) -> None:
        self.prev[layer] = np.unique(np.asarray(experts, np.int64).reshape(-1))
        self.freq.observe(layer, experts)

    def predict(self, layer: int, k: int, rng=None) -> np.ndarray:
        p = self.prev[layer][:k]
        if len(p) < k:   # back-fill with frequency prior
            rest = [e for e in self.freq.predict(layer, k) if e not in p]
            p = np.concatenate([p, np.asarray(rest[:k - len(p)], np.int64)])
        return p

    def predict_proba(self, layer: int, lookahead: int = 1,
                      context=None) -> np.ndarray:
        """Marginal blend: an expert seen last step has ~PREV_WEIGHT chance
        of immediate reuse (temporal locality, NOT divided across the set —
        each prev expert independently carries the marginal); everything
        else falls back to the frequency marginal."""
        p = np.zeros(self.num_experts, np.float64)
        prev = self.prev[layer]
        w = self.PREV_WEIGHT if len(prev) else 0.0
        if len(prev):
            p[prev] = w
        return np.clip(p + (1.0 - w) * self.freq.predict_proba(layer),
                       0.0, 1.0)


class CrossLayerPredictor(LookaheadMixin):
    """P(expert j at layer l | expert i at layer l-1), profiled offline."""

    def __init__(self, num_layers: int, num_experts: int, eps: float = 1e-3):
        self.num_experts = num_experts
        self.eps = eps
        self.C = np.full((num_layers, num_experts, num_experts), eps, np.float64)
        self.prev_set: Optional[np.ndarray] = None
        self.freq = TopFreqPredictor(num_layers, num_experts)

    def clone_fresh(self) -> "CrossLayerPredictor":
        return CrossLayerPredictor(self.C.shape[0], self.C.shape[1],
                                   eps=self.eps)

    def observe_transition(self, layer: int, prev_experts, cur_experts) -> None:
        prev_experts = np.unique(np.asarray(prev_experts, np.int64).reshape(-1))
        cur_experts = np.unique(np.asarray(cur_experts, np.int64).reshape(-1))
        for i in prev_experts:
            self.C[layer, i, cur_experts] += 1.0

    def observe(self, layer: int, experts) -> None:
        self.freq.observe(layer, experts)

    def predict(self, layer: int, k: int, prev_experts=None, rng=None) -> np.ndarray:
        if prev_experts is None or len(np.atleast_1d(prev_experts)) == 0 or layer == 0:
            return self.freq.predict(layer, k)
        prev_experts = np.unique(np.asarray(prev_experts, np.int64).reshape(-1))
        score = self.C[layer, prev_experts].sum(axis=0)
        return np.argsort(-score)[:k]

    def predict_ahead(self, layer: int, k: int, lookahead: int = 1,
                      context=None, rng=None) -> np.ndarray:
        """Chain co-usage matrices ``lookahead`` hops forward: while layer
        ``layer - lookahead`` computes with experts ``context``, score layer
        ``layer``'s experts by propagating the activation indicator through
        C[layer-lookahead+1] .. C[layer] (row-normalised)."""
        s = self._chained_scores(layer, lookahead, context)
        if s is None:
            return self.predict(layer, k)
        return np.argsort(-s)[:k]

    def _chained_scores(self, layer: int, lookahead: int,
                        context) -> Optional[np.ndarray]:
        """Indicator-propagation scores, or None when there is no usable
        context / the chain would start before layer 0 (callers fall back
        to the frequency prior)."""
        if context is None or len(np.atleast_1d(context)) == 0 \
                or lookahead < 1 or layer - lookahead < 0:
            return None
        e_n = self.C.shape[1]
        s = np.zeros(e_n, np.float64)
        s[np.unique(np.asarray(context, np.int64).reshape(-1))] = 1.0
        for m in range(layer - lookahead + 1, layer + 1):
            cm = self.C[m]
            cm = cm / np.maximum(cm.sum(axis=1, keepdims=True), 1e-30)
            s = s @ cm
        return s

    def predict_proba(self, layer: int, lookahead: int = 1,
                      context=None) -> np.ndarray:
        # the propagated indicator is already marginal-like: entry j sums
        # P(j | i) over active sources i, so clip rather than renormalize
        # (renormalizing would shrink every marginal by the used-set size)
        s = self._chained_scores(layer, lookahead, context)
        if s is None:
            return self.freq.predict_proba(layer)
        return np.clip(s, 0.0, 1.0)


@dataclasses.dataclass
class PrefetchBudget:
    """How much speculative PCIe traffic the runtime may spend per layer."""
    prefetch_k: int           # experts predicted/issued per target layer
    lookahead: int            # layers ahead the prediction targets
    max_inflight: int         # link-level concurrent-prefetch cap


class AdaptiveBudgetController:
    """Closes the loop between the serving scheduler and the transfer
    runtime: instead of a fixed ``--prefetch-k`` CLI constant, the budget is
    resized every ``window`` steps from two signals —

      * the ledger's stall-attribution DELTAS over the window
        (``stall_breakdown``: demand vs late-prefetch vs overlapped), and
      * the request-queue depth (continuous batching: deeper backlog means
        fuller batches, longer compute slices, more overlap headroom).

    Policy (each adjustment is one step on an integer ladder, so the budget
    cannot oscillate wildly):

      late-prefetch stalls dominate  -> the link cannot land speculation in
          time: SHRINK prefetch_k (fewer, better bets) and DEEPEN lookahead
          (issue earlier so the tail lands before the layer needs it);
      demand stalls dominate         -> speculation is too timid: GROW
          prefetch_k (and let the link cap follow);
      mostly overlapped / idle       -> hold.

    Queue depth sets the ceiling: an empty queue halves the allowed k (the
    speculative bytes would evict still-useful experts for no latency win);
    a deep queue restores the full configured range.

    When the engine ranks prefetches by expected stall saved (the cost
    model's P(use) x miss-cost scores), it reports how many candidates were
    actually WORTHWHILE (positive expected saving); the budget is capped at
    that count so k never pays for transfers whose misses a buddy or
    replica would absorb for free anyway.
    """

    def __init__(self, prefetch_k: int, lookahead: int = 1, *,
                 min_k: int = 1, max_k: int = 16,
                 min_lookahead: int = 1, max_lookahead: int = 4,
                 max_inflight: int = 4, window: int = 8,
                 dominance: float = 1.5, deep_queue: int = 4):
        assert min_k <= prefetch_k <= max_k
        assert min_lookahead <= lookahead <= max_lookahead
        self.budget = PrefetchBudget(prefetch_k, lookahead,
                                     max(1, min(max_inflight, prefetch_k)))
        self.max_inflight_cap = max_inflight
        self.min_k, self.max_k = min_k, max_k
        self.min_lookahead, self.max_lookahead = min_lookahead, max_lookahead
        self.window = max(1, window)
        self.dominance = dominance
        self.deep_queue = deep_queue
        self._steps = 0
        self._last = {"demand_stall_s": 0.0, "late_prefetch_stall_s": 0.0,
                      "overlapped_s": 0.0}
        self.trace: list = []

    # -- observation ----------------------------------------------------
    def observe_step(self, stall_breakdown: dict, queue_depth: int,
                     worthwhile: Optional[int] = None):
        """Call once per engine step. Returns the (possibly updated) budget.
        ``worthwhile``: number of prefetch candidates with positive expected
        stall saved at the last issue (cost-ranked prefetch only)."""
        self._steps += 1
        if self._steps % self.window == 0:
            self.update(stall_breakdown, queue_depth, worthwhile=worthwhile)
        return self.budget

    def update(self, stall_breakdown: dict, queue_depth: int,
               worthwhile: Optional[int] = None) -> PrefetchBudget:
        """Apply one feedback adjustment from cumulative stall attribution
        (deltas are taken against the previous update)."""
        d_demand = stall_breakdown["demand_stall_s"] - \
            self._last["demand_stall_s"]
        d_late = stall_breakdown["late_prefetch_stall_s"] - \
            self._last["late_prefetch_stall_s"]
        self._last = {k: stall_breakdown[k] for k in self._last}

        b = self.budget
        k, la = b.prefetch_k, b.lookahead
        if d_late > self.dominance * max(d_demand, 1e-12):
            # speculation arrives too late: spend less, issue earlier
            k = max(self.min_k, k - 1)
            la = min(self.max_lookahead, la + 1)
        elif d_demand > self.dominance * max(d_late, 1e-12):
            k = min(self._queue_cap(queue_depth), k + 1)
            # lateness is no longer the problem: walk lookahead back toward
            # shallow (prediction accuracy decays with depth)
            la = max(self.min_lookahead, la - 1)
        k = min(k, self._queue_cap(queue_depth))
        if worthwhile is not None:
            # expected-stall-saved ranking found only this many candidates
            # worth the bytes — spending k beyond it buys nothing
            k = min(k, max(self.min_k, int(worthwhile)))
        b.prefetch_k, b.lookahead = k, la
        b.max_inflight = max(1, min(self.max_inflight_cap, k))
        self.trace.append({"step": self._steps, "prefetch_k": k,
                           "lookahead": la,
                           "demand_delta_s": d_demand,
                           "late_delta_s": d_late,
                           "queue_depth": queue_depth,
                           "worthwhile": worthwhile})
        return b

    def _queue_cap(self, queue_depth: int) -> int:
        if queue_depth >= self.deep_queue:
            return self.max_k
        return max(self.min_k, self.max_k // 2)

    # -- actuation ------------------------------------------------------
    def apply(self, engine) -> PrefetchBudget:
        """Push the current budget into a ServeEngine and its transfer
        scheduler (the runtime knobs the budget governs). With a telemetry
        bundle attached to the engine, the applied budget is mirrored to
        gauges and — when it CHANGED — stamped as a trace instant on the
        "engine" track (read-only observation; telemetry=None engines run
        the identical path)."""
        changed = (engine.prefetch_k != self.budget.prefetch_k
                   or engine.lookahead != self.budget.lookahead)
        engine.prefetch_k = self.budget.prefetch_k
        engine.lookahead = self.budget.lookahead
        engine.scheduler.set_prefetch_cap(self.budget.max_inflight)
        tele = getattr(engine, "telemetry", None)
        if tele is not None:
            tele.metrics.gauge("budget_prefetch_k").set(
                self.budget.prefetch_k)
            tele.metrics.gauge("budget_lookahead").set(self.budget.lookahead)
            tele.metrics.gauge("budget_max_inflight").set(
                self.budget.max_inflight)
            if changed and tele.trace is not None:
                tele.trace.instant(
                    "engine", 0, "budget", "budget", engine.scheduler.now,
                    prefetch_k=self.budget.prefetch_k,
                    lookahead=self.budget.lookahead,
                    max_inflight=self.budget.max_inflight)
        return self.budget


class NoisyOraclePredictor(LookaheadMixin):
    """Knows the true next-step experts; corrupts each slot with prob
    (1 - accuracy). Gives direct control of the prefetch-miss rate."""

    def __init__(self, num_layers: int, num_experts: int, accuracy: float = 0.8,
                 seed: int = 0):
        self.num_experts = num_experts
        self.accuracy = accuracy
        self.seed = seed
        self.truth = [np.array([], np.int64) for _ in range(num_layers)]
        self.rng = np.random.default_rng(seed)

    def clone_fresh(self) -> "NoisyOraclePredictor":
        return NoisyOraclePredictor(len(self.truth), self.num_experts,
                                    accuracy=self.accuracy, seed=self.seed)

    def set_truth(self, layer: int, experts) -> None:
        self.truth[layer] = np.unique(np.asarray(experts, np.int64).reshape(-1))

    def observe(self, layer: int, experts) -> None:
        self.set_truth(layer, experts)

    def predict_proba(self, layer: int, lookahead: int = 1,
                      context=None) -> np.ndarray:
        """Each truth expert survives with P = accuracy; the corruption mass
        is spread uniformly (the predictor's actual noise model)."""
        p = np.full(self.num_experts, 0.0, np.float64)
        t = self.truth[layer]
        if len(t):
            p[t] = self.accuracy
            p += (1.0 - self.accuracy) * len(t) / self.num_experts
        return p

    def predict(self, layer: int, k: int, rng=None) -> np.ndarray:
        rng = rng or self.rng
        t = self.truth[layer][:k]
        out, seen, corrupted = [], set(), []
        # corrupted draws can collide with an already-emitted expert; dedup
        # them like the back-fill loop so the prediction stays a k-set
        # (duplicates silently shrank the effective prefetch set below k).
        # Accurate truth draws land first — a colliding corrupted draw must
        # displace ITSELF, not a truth expert, or the realised accuracy
        # drifts below the configured knob.
        for e in t:
            if rng.random() < self.accuracy:
                out.append(int(e))
                seen.add(int(e))          # truth is unique: never collides
            else:
                corrupted.append(int(rng.integers(0, self.num_experts)))
        for e in corrupted:
            if e not in seen:
                out.append(e)
                seen.add(e)
        while len(out) < min(k, self.num_experts):
            e = int(rng.integers(0, self.num_experts))
            if e not in seen:
                out.append(e)
                seen.add(e)
        return np.asarray(out[:k], np.int64)
