"""Flight recorder — structured trace spans/events on the simulated clock.

The runtime's whole premise is temporal: a prefetch that lands 50 us after
the layer needed it is a miss, one that lands 50 us before is free. End-of-
run ``summary()`` dicts cannot show WHEN a stall happened or WHICH transfer
caused it. This module records the timeline itself:

  track "requests"   per-request lifecycle — arrive (instant), queued span
                     (arrival -> admit), prefill span (admit -> first token),
                     decode span (first token -> retire), per-token instants,
                     retire/shed instants. Lane = request id.
  track "layers"     the per-layer step timeline ServeEngine._account
                     replays — compute slices, stall spans (with cause and
                     the transfer that caused them), and one instant per
                     layer-step carrying the miss-outcome breakdown
                     {hit, buddy, degraded, fetch, drop}. Lane = MoE layer.
  track "transfers"  per-transfer spans (submit -> land/cancel) with cause,
                     bytes, and priority, plus start/escalate instants —
                     emitted by TransferScheduler. Lane = transfer id.
                     Named links (the ICI links of a multi-device mesh) get
                     their own lanes: track "transfers:<link>" per link, so
                     host-PCIe and each peer's borrow traffic render as
                     separate rows. The unnamed host link keeps the bare
                     "transfers" track — single-device traces are unchanged.
  track "engine"     whole-step spans and controller/budget events.

Every record carries a monotonic sequence id assigned at record time, so
simultaneous events (common on a discrete-event clock) have a total order
and exports are byte-stable across runs at a fixed seed.

Exports:
  * JSONL — one record per line, lossless round-trip (``load_jsonl``);
  * Chrome/Perfetto ``trace_event`` JSON — open in https://ui.perfetto.dev
    (or chrome://tracing): a stall span on the "layers" track sits directly
    under the transfer span that caused it on the "transfers" track, making
    a stall visually attributable.

Zero-overhead-when-off contract: the recorder is opt-in. Call sites hold
``None`` instead of a recorder and guard every emission with an ``is not
None`` check, so a telemetry-off run executes the exact pre-telemetry code
path (bit-identical outputs and summaries).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

# Canonical track names -> Chrome trace pids (stable, documented in
# docs/trace_schema.md). Unknown tracks get pids past the known ones.
TRACKS = ("requests", "layers", "transfers", "engine")

# Span/event kinds (the ``kind`` field; one vocabulary for both exports)
REQUEST_KINDS = ("arrive", "queued", "prefill", "decode", "token",
                 "retire", "shed", "prefix_hit")
LAYER_KINDS = ("compute", "stall", "outcomes")
TRANSFER_KINDS = ("transfer", "start", "escalate")
ENGINE_KINDS = ("step", "budget", "prefix_hit")


class FlightRecorder:
    """Append-only event log on the simulated clock.

    Records are plain dicts:
      seq    monotonic int — assigned at record time; the deterministic
             tie-break for simultaneous events
      track  one of TRACKS (trace process / Perfetto track group)
      lane   int lane within the track (request id / layer / transfer id)
      kind   event kind (vocabulary above)
      name   display name
      ts     simulated-clock seconds (span start for spans)
      dur    span duration in seconds; absent (None) for instants
      args   labels dict (JSON-safe scalars)
    """

    def __init__(self) -> None:
        self.events: List[dict] = []
        self._seq = 0
        # open transfer spans keyed by (link, transfer id) — tids restart
        # per scheduler, so the link name disambiguates mesh traffic
        self._open_transfers: Dict[tuple, dict] = {}

    def __len__(self) -> int:
        return len(self.events)

    # -- primitives -----------------------------------------------------
    def _record(self, track: str, lane: int, kind: str, name: str,
                ts: float, dur: Optional[float], args: dict) -> dict:
        self._seq += 1
        ev = {"seq": self._seq, "track": track, "lane": int(lane),
              "kind": kind, "name": name, "ts": float(ts)}
        if dur is not None:
            ev["dur"] = float(max(0.0, dur))
        if args:
            ev["args"] = args
        self.events.append(ev)
        return ev

    def instant(self, track: str, lane: int, kind: str, name: str,
                ts: float, **args) -> dict:
        return self._record(track, lane, kind, name, ts, None, args)

    def span(self, track: str, lane: int, kind: str, name: str,
             t0: float, t1: float, **args) -> dict:
        return self._record(track, lane, kind, name, t0, t1 - t0, args)

    # -- transfer listener (driven by TransferScheduler._emit) ----------
    def transfer_event(self, kind: str, t, now: float,
                       link: Optional[str] = None) -> None:
        """Map scheduler events onto per-transfer spans + instants. The
        scheduler stamps ``t.event_seq`` before calling (satellite:
        deterministic ordering), recorded as a label for cross-checking.
        ``link`` names the emitting scheduler's lane: ``None`` (the host
        PCIe link) records on the bare "transfers" track exactly as the
        single-link recorder always did; a named ICI link records on its
        own "transfers:<link>" track."""
        track = "transfers" if link is None else f"transfers:{link}"
        base = {"cause": t.cause, "bytes": int(t.nbytes), "layer": t.layer,
                "expert": t.expert, "event_seq": getattr(t, "event_seq", 0)}
        if kind == "submit":
            self._open_transfers[(link, t.tid)] = dict(base,
                                                       issue_s=t.issue_s)
            self.instant(track, t.tid, "start", "submit", now, **base)
        elif kind == "start":
            self.instant(track, t.tid, "start", "link_start", now, **base)
        elif kind == "escalate":
            self.instant(track, t.tid, "escalate", "escalate", now, **base)
        elif kind in ("complete", "cancel"):
            opened = self._open_transfers.pop((link, t.tid), None)
            t0 = opened["issue_s"] if opened else t.issue_s
            self.span(track, t.tid, "transfer",
                      f"{t.cause}:{t.layer}.{t.expert}", t0, now,
                      outcome=("land" if kind == "complete" else "cancel"),
                      **base)

    # -- exports --------------------------------------------------------
    def sorted_events(self) -> List[dict]:
        """Events in (ts, seq) order — seq breaks simultaneous-event ties,
        so the export byte-stream is stable across runs at a fixed seed."""
        return sorted(self.events, key=lambda e: (e["ts"], e["seq"]))

    def export_jsonl(self, path: str) -> int:
        evs = self.sorted_events()
        with open(path, "w") as f:
            for ev in evs:
                f.write(json.dumps(ev, sort_keys=True) + "\n")
        return len(evs)

    @staticmethod
    def load_jsonl(path: str) -> List[dict]:
        out = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out

    def to_perfetto(self) -> dict:
        """Chrome ``trace_event`` format dict (json.dump it; Perfetto and
        chrome://tracing both load it). ts/dur are microseconds. Spans are
        complete ("X") events; instants are "i" with thread scope."""
        pids = {name: i + 1 for i, name in enumerate(TRACKS)}
        out: List[dict] = []
        for name, pid in pids.items():
            out.append({"ph": "M", "name": "process_name", "pid": pid,
                        "tid": 0, "args": {"name": name}})
        for ev in self.sorted_events():
            pid = pids.setdefault(ev["track"], len(pids) + 1)
            row = {"name": ev["name"], "cat": ev["kind"], "pid": pid,
                   "tid": ev["lane"], "ts": ev["ts"] * 1e6,
                   "args": dict(ev.get("args", {}), seq=ev["seq"])}
            if "dur" in ev:
                row["ph"] = "X"
                row["dur"] = ev["dur"] * 1e6
            else:
                row["ph"] = "i"
                row["s"] = "t"
            out.append(row)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export_perfetto(self, path: str) -> int:
        trace = self.to_perfetto()
        with open(path, "w") as f:
            json.dump(trace, f)
        return len(trace["traceEvents"])


def export_trace(recorder: Optional[FlightRecorder], path: str) -> int:
    """Shared --trace-out handler: ``*.jsonl`` exports the lossless JSONL
    log, anything else the Chrome/Perfetto trace_event JSON. Returns the
    number of events written (0 when no recorder is attached)."""
    if recorder is None:
        return 0
    if path.endswith(".jsonl"):
        return recorder.export_jsonl(path)
    return recorder.export_perfetto(path)
