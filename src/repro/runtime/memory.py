"""Two-tier memory model + transfer ledger.

The paper's regime: experts offloaded to host memory, fetched over PCIe
(~10 ms / expert on Mixtral-8x7B; transfers are 85-94% of latency on edge
deployments, §2.4). The container is CPU-only, so transfer latency and device
compute are MODELED (constants below, documented for the TPU v5e target);
bytes and event counts are exact. Accuracy effects of substitution are real.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """TPU v5e-adjacent single-chip constants (roofline + transfer model)."""
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s
    ici_bw: float = 50e9                # bytes/s per link
    pcie_bw: float = 24e9               # bytes/s host<->device (16-32 GB/s, §2.4)
    pcie_fixed_s: float = 0.5e-3        # per-transfer fixed cost (launch+pin)

    def transfer_time(self, nbytes: int) -> float:
        return self.pcie_fixed_s + nbytes / self.pcie_bw

    def decode_compute_time(self, active_params: int, batch: int,
                            dtype_bytes: int = 2) -> float:
        """Per-decode-step compute estimate: weight-streaming bound
        (memory term dominates at decode) vs FLOPs term."""
        flops = 2.0 * active_params * batch
        mem = active_params * dtype_bytes
        return max(flops / self.peak_flops, mem / self.hbm_bw)


DEFAULT_HW = HardwareModel()


class TransferLedger:
    """Counts host<->device traffic by cause; the measurement substrate for
    Fig. 8 (PCIe bytes) and the Tables 2-4 throughput model."""

    def __init__(self, hw: HardwareModel = DEFAULT_HW):
        self.hw = hw
        self.reset()

    def reset(self) -> None:
        self.bytes_by_cause = defaultdict(int)
        self.events_by_cause = defaultdict(int)
        self.sync_stall_s = 0.0
        self.overlap_s = 0.0

    # -- recording ------------------------------------------------------
    def prefetch(self, nbytes: int, n_events: int = 1) -> None:
        """Asynchronous, overlappable transfer (issued ahead of use)."""
        self.bytes_by_cause["prefetch"] += nbytes
        self.events_by_cause["prefetch"] += n_events
        self.overlap_s += n_events * self.hw.pcie_fixed_s + nbytes / self.hw.pcie_bw

    def sync_fetch(self, nbytes: int, n_events: int = 1) -> None:
        """Synchronous on-demand fetch — stalls the pipeline (prefetch miss
        with no buddy, or the Original baseline)."""
        self.bytes_by_cause["sync_fetch"] += nbytes
        self.events_by_cause["sync_fetch"] += n_events
        self.sync_stall_s += n_events * self.hw.pcie_fixed_s + nbytes / self.hw.pcie_bw

    def buddy_hit(self, n_events: int = 1) -> None:
        """Substitution — zero transfer (the whole point)."""
        self.events_by_cause["buddy_sub"] += n_events

    def drop(self, n_events: int = 1) -> None:
        self.events_by_cause["drop"] += n_events

    # -- reporting ------------------------------------------------------
    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_cause.values())

    def summary(self) -> dict:
        return {
            "bytes": dict(self.bytes_by_cause),
            "events": dict(self.events_by_cause),
            "total_bytes": self.total_bytes,
            "sync_stall_s": self.sync_stall_s,
            "overlap_s": self.overlap_s,
        }


def expert_nbytes(d_model: int, d_ff: int, dtype_bytes: int = 2) -> int:
    """SwiGLU expert: w1 + w3 + w2."""
    return 3 * d_model * d_ff * dtype_bytes
