"""Two-tier memory model + transfer ledger.

The paper's regime: experts offloaded to host memory, fetched over PCIe
(~10 ms / expert on Mixtral-8x7B; transfers are 85-94% of latency on edge
deployments, §2.4). The container is CPU-only, so transfer latency and device
compute are MODELED (constants below, documented for the TPU v5e target);
bytes and event counts are exact. Accuracy effects of substitution are real.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """TPU v5e-adjacent single-chip constants (roofline + transfer model)."""
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s
    ici_bw: float = 50e9                # bytes/s per device<->device link
    ici_fixed_s: float = 25e-6          # per-hop launch cost on the ICI mesh
    pcie_bw: float = 24e9               # bytes/s host<->device (16-32 GB/s, §2.4)
    pcie_fixed_s: float = 0.5e-3        # per-transfer fixed cost (launch+pin)

    def transfer_time(self, nbytes: int) -> float:
        return self.pcie_fixed_s + nbytes / self.pcie_bw

    def ici_transfer_time(self, nbytes: int, hops: int = 1) -> float:
        """One expert over the device mesh: per-hop launch cost, then the
        payload streams at link bandwidth (wormhole routing — bytes pay the
        link once, not per hop)."""
        return self.ici_fixed_s * max(1, hops) + nbytes / self.ici_bw

    def decode_compute_time(self, active_params: int, batch: int,
                            dtype_bytes: int = 2) -> float:
        """Per-decode-step compute estimate: weight-streaming bound
        (memory term dominates at decode) vs FLOPs term."""
        flops = 2.0 * active_params * batch
        mem = active_params * dtype_bytes
        return max(flops / self.peak_flops, mem / self.hbm_bw)


DEFAULT_HW = HardwareModel()


class TransferLedger:
    """Counts host<->device traffic by cause; the measurement substrate for
    Fig. 8 (PCIe bytes) and the Tables 2-4 throughput model.

    Two recording paths coexist:
      * the legacy direct calls (``prefetch``/``sync_fetch``) used by unit
        tests and simple scripts, and
      * the event path — attach the ledger to a
        ``runtime.transfers.TransferScheduler`` and every submit/cancel
        updates byte counts, while the engine attributes stalls via
        ``stall()``/``overlapped()`` with a cause breakdown:
          demand_stall_s        cold miss, nothing in flight (full fetch wait)
          late_prefetch_stall_s predicted but not yet ARRIVED — the paper's
                                late-prefetch case; stall is only the tail
          peer_stall_s          miss served by borrowing the expert from a
                                peer device's HBM over ICI (multi-device
                                meshes only; absent from the breakdown when
                                zero so single-device summaries are
                                unchanged)
          overlapped_s          transfer time hidden under earlier layers'
                                compute (costs bytes, not latency)

    The ledger is link-agnostic: attach it to every per-link scheduler of a
    device mesh and the cause keys (``peer_borrow`` for ICI borrows) keep
    host-PCIe and peer traffic separable in one byte count.
    """

    def __init__(self, hw: HardwareModel = DEFAULT_HW):
        self.hw = hw
        self.reset()

    def reset(self) -> None:
        self.bytes_by_cause = defaultdict(int)
        self.events_by_cause = defaultdict(int)
        self.sync_stall_s = 0.0
        self.overlap_s = 0.0
        self.demand_stall_s = 0.0
        self.late_prefetch_stall_s = 0.0
        self.peer_stall_s = 0.0
        self.overlapped_s = 0.0

    # -- scheduler event path -------------------------------------------
    _CAUSE_KEY = {"prefetch": "prefetch", "demand": "sync_fetch",
                  "upgrade": "upgrade", "peer": "peer_borrow",
                  "replicate": "replicate"}

    def attach(self, scheduler) -> None:
        scheduler.add_listener(self.on_transfer_event)

    def on_transfer_event(self, kind: str, t) -> None:
        key = self._CAUSE_KEY.get(t.cause, t.cause)
        if kind == "submit":
            self.bytes_by_cause[key] += t.nbytes
            self.events_by_cause[key] += 1
        elif kind == "cancel":
            self.events_by_cause["cancelled"] += 1
            if not t.started:
                # never touched the link: refund the bytes
                self.bytes_by_cause[key] -= t.nbytes
                self.events_by_cause[key] -= 1
        elif kind == "escalate":
            self.events_by_cause["escalated"] += 1

    def stall(self, kind: str, seconds: float) -> None:
        """Engine-attributed pipeline stall.
        kind: 'demand'|'late_prefetch'|'peer'."""
        assert kind in ("demand", "late_prefetch", "peer")
        seconds = max(0.0, seconds)
        if kind == "demand":
            self.demand_stall_s += seconds
        elif kind == "peer":
            self.peer_stall_s += seconds
        else:
            self.late_prefetch_stall_s += seconds
        self.sync_stall_s += seconds     # aggregate view stays coherent

    def overlapped(self, seconds: float) -> None:
        """Transfer service time hidden under compute (no latency cost)."""
        self.overlapped_s += max(0.0, seconds)
        self.overlap_s += max(0.0, seconds)

    # -- recording ------------------------------------------------------
    def prefetch(self, nbytes: int, n_events: int = 1) -> None:
        """Asynchronous, overlappable transfer (issued ahead of use)."""
        self.bytes_by_cause["prefetch"] += nbytes
        self.events_by_cause["prefetch"] += n_events
        self.overlap_s += n_events * self.hw.pcie_fixed_s + nbytes / self.hw.pcie_bw

    def sync_fetch(self, nbytes: int, n_events: int = 1) -> None:
        """Synchronous on-demand fetch — stalls the pipeline (prefetch miss
        with no buddy, or the Original baseline)."""
        self.bytes_by_cause["sync_fetch"] += nbytes
        self.events_by_cause["sync_fetch"] += n_events
        self.sync_stall_s += n_events * self.hw.pcie_fixed_s + nbytes / self.hw.pcie_bw

    def buddy_hit(self, n_events: int = 1) -> None:
        """Substitution — zero transfer (the whole point)."""
        self.events_by_cause["buddy_sub"] += n_events

    def drop(self, n_events: int = 1) -> None:
        self.events_by_cause["drop"] += n_events

    def degraded(self, n_events: int = 1) -> None:
        """Miss served from the resident quant-replica tier — zero transfer,
        zero stall, bounded fidelity loss (runtime/tiers.py)."""
        self.events_by_cause["degraded"] += n_events

    def tier_upload(self, nbytes: int) -> None:
        """One-time host->device upload of the compressed replica tier (paid
        at engine init / runtime reset, amortized over the whole run)."""
        self.bytes_by_cause["tier_upload"] += int(nbytes)
        self.events_by_cause["tier_upload"] += 1

    # -- reporting ------------------------------------------------------
    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_cause.values())

    def summary(self) -> dict:
        breakdown = {
            "demand_stall_s": self.demand_stall_s,
            "late_prefetch_stall_s": self.late_prefetch_stall_s,
            "overlapped_s": self.overlapped_s,
        }
        if self.peer_stall_s:       # multi-device only: D=1 dict unchanged
            breakdown["peer_stall_s"] = self.peer_stall_s
        return {
            "bytes": dict(self.bytes_by_cause),
            "events": dict(self.events_by_cause),
            "total_bytes": self.total_bytes,
            "sync_stall_s": self.sync_stall_s,
            "overlap_s": self.overlap_s,
            "stall_breakdown": breakdown,
        }


def expert_nbytes(d_model: int, d_ff: int, dtype_bytes: int = 2) -> int:
    """SwiGLU expert: w1 + w3 + w2."""
    return 3 * d_model * d_ff * dtype_bytes


def quant_expert_nbytes(d_model: int, d_ff: int, bits: int,
                        scale_bytes: int = 4) -> int:
    """HBM footprint of one compressed expert replica (runtime/tiers.py):
    the int8/int4 payload of w1+w3+w2 plus f32 per-output-channel scales
    (F each for w1/w3, D for w2). int4 is accounted at its true 4-bit
    payload even though core/quantize.py stores values unpacked."""
    assert bits in (4, 8)
    weights = 3 * d_model * d_ff * bits // 8
    scales = (2 * d_ff + d_model) * scale_bytes
    return weights + scales
