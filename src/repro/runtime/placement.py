"""Online expert replication & live placement — the traffic→placement loop.

Buddy groups, tier coverage, and the initial cache seed are all frozen at
profiling time, but real traffic drifts (tenant mix, language, time of day).
Related systems close exactly this gap: predictive-prefetch replication
copies persistently-hot experts so misses on them disappear, and ExpertFlow
re-plans expert placement from live routing statistics. This module is the
repo's version of that loop: a ``PlacementController`` that runs ON THE
ENGINE'S SIMULATED CLOCK and, every ``refresh_interval_s`` of simulated
time, turns the per-expert activity EMAs into three placement actions:

  (a) coverage re-pick — re-rank per-layer activity and point
      ``TieredExpertStore.set_coverage`` at the live ranking, so a
      partial-coverage quant tier replicates the experts traffic actually
      hits instead of the profiling draw. The re-pick is hysteresis-guarded
      like replication — the desired covered set must persist for
      ``hot_windows`` CONSECUTIVE ticks, because a near-tied EMA ranking
      must not flap the tier — and applied make-before-break: experts
      about to LOSE coverage are pre-staged into the cache by a background
      'replicate' copy first, so the uncovering never converts their next
      miss into a fetch stall;
  (b) replication — persistently-hot experts that are NOT resident earn a
      full-precision replica: a background ``'replicate'``-cause transfer
      on the host link (prefetch priority, exempt from stale-prediction
      cancellation, its own ledger bucket). Hysteresis guards both edges:
      an expert must stay hot for ``hot_windows`` CONSECUTIVE windows
      before it earns a replica, and a replica whose expert has gone cold
      is marked reclaim-first so the cache evicts it before any normal
      victim. Admission control guards the slot itself: the copy is only
      issued when the victim it would displace (``preview_victim``) is
      clearly colder than the candidate (``replicate_margin``), because
      evicting a warm resident to install a replica just moves the miss;
  (c) peer push — on a mesh (``n_devices > 1``), hot experts are pushed to
      the least-loaded peer's HBM via ``ExpertCache.peer_insert``, with the
      bytes riding the owning device's ICI link, so future peer borrows
      come off a shorter queue.

The controller is a pure add-on: ``placement=None`` engines never construct
one and stay bit-identical to the pre-placement build (frozen-capture test
in tests/test_placement.py). All of its time arithmetic is in simulated
seconds (the transfer timeline's clock), never wall time.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.runtime.telemetry import ExpertStats


class PlacementController:
    """Closes the loop from live routing statistics to expert placement.

    Lifecycle: construct with the knobs below, pass as ``ServeEngine(...,
    placement=ctrl)``. The engine calls ``attach`` (from ``__init__`` and
    again from ``reset_runtime`` — configuration survives, per-run state
    does not), feeds ``observe_layer`` every (layer, step), and calls
    ``maybe_tick`` after each step; the continuous scheduler's feedback
    hook ticks it as well, so the controller fires at most once per
    ``refresh_interval_s`` of SIMULATED time regardless of who drives it.

    Knobs (all constructor-only; ``attach`` never changes them):

      refresh_interval_s   simulated seconds between placement ticks
      hot_windows          hysteresis K: consecutive hot windows an expert
                           needs before it earns a replica, and consecutive
                           ticks a changed coverage ranking must persist
                           before the tier is re-pointed at it
      hot_top_k            experts per layer counted as hot each window
                           (None: half the cache capacity, set at attach)
      max_replicas_per_layer  new 'replicate' transfers (and peer pushes)
                           issued per layer per tick
      replicate_margin     admission control: a replica is only issued when
                           candidate EMA > victim EMA x this margin (the
                           would-be eviction victim from ``preview_victim``)
      retune_coverage / replicate / peer_push   gate each action
      alpha                EMA decay of the controller's own ExpertStats
    """

    def __init__(self, *, refresh_interval_s: float = 1e-3,
                 hot_windows: int = 3, hot_top_k: Optional[int] = None,
                 max_replicas_per_layer: int = 2,
                 replicate_margin: float = 2.0,
                 retune_coverage: bool = True, replicate: bool = True,
                 peer_push: bool = True, alpha: float = 0.05):
        assert refresh_interval_s > 0.0, "refresh interval: simulated seconds"
        assert hot_windows >= 1, "hysteresis needs at least one hot window"
        assert max_replicas_per_layer >= 1
        assert replicate_margin >= 1.0, \
            "margin < 1 would admit replicas HOTTER victims must yield to"
        self.refresh_interval_s = float(refresh_interval_s)
        self.hot_windows = int(hot_windows)
        self._hot_top_k_cfg = hot_top_k
        self.hot_top_k = hot_top_k if hot_top_k is None else int(hot_top_k)
        self.max_replicas_per_layer = int(max_replicas_per_layer)
        self.replicate_margin = float(replicate_margin)
        self.retune_coverage = bool(retune_coverage)
        self.replicate = bool(replicate)
        self.peer_push = bool(peer_push)
        self.alpha = float(alpha)
        self.stats: Optional[ExpertStats] = None
        self.trace: list = []       # one dict per tick that changed placement
        self._reset_run_state(0, 0)

    # -- lifecycle ------------------------------------------------------
    def _reset_run_state(self, num_layers: int, num_experts: int) -> None:
        self._streak = np.zeros((num_layers, num_experts), np.int32)
        self._replicas = [set() for _ in range(num_layers)]
        self._cov_want: Optional[np.ndarray] = None   # pending covered set
        self._cov_streak = 0       # ticks the pending set has persisted
        self._next_tick = self.refresh_interval_s
        self.trace = []
        self.n_ticks = 0
        self.n_coverage_repicks = 0
        self.n_replicas_issued = 0
        self.n_replicas_reclaimed = 0
        self.n_peer_pushes = 0

    def attach(self, engine) -> None:
        """Bind to an engine: fresh per-run state (streaks, replica sets,
        counters, next-tick time) on the SAME configuration — exactly what
        ``reset_runtime`` needs between benchmark arms."""
        l_n = engine.num_moe_layers
        e_n = engine.cfg.moe.num_experts
        self.stats = ExpertStats(l_n, e_n, alpha=self.alpha)
        if self._hot_top_k_cfg is None:
            self.hot_top_k = max(1, engine.cache.capacity // 2)
        self.hot_top_k = min(self.hot_top_k, e_n)
        self._reset_run_state(l_n, e_n)

    # -- signal ---------------------------------------------------------
    def observe_layer(self, layer: int, used, hit, missed,
                      degraded=None) -> None:
        """Per-(layer, step) activity feed — same indicator-EMA semantics as
        ``telemetry.ExpertStats.update``. The controller owns its stats so
        live placement works with or without a Telemetry bundle attached."""
        self.stats.update(layer, used, hit, missed, degraded)

    # -- tick -----------------------------------------------------------
    def maybe_tick(self, engine) -> bool:
        """Fire ``tick`` when the engine's simulated clock has crossed the
        next refresh boundary. Idempotent within a window, so both the
        engine's step loop and the continuous scheduler's feedback hook may
        call it. Returns True when a tick ran."""
        now = engine.scheduler.now
        if now + 1e-12 < self._next_tick:
            return False
        self._next_tick = now + self.refresh_interval_s
        self.tick(engine)
        return True

    def tick(self, engine) -> None:
        """One placement window: re-rank activity, advance hot streaks, and
        apply the three actions. All transfers issued here are background
        ('replicate' cause — prefetch priority, cancel-exempt); the tick
        never advances the clock or stalls a layer."""
        self.n_ticks += 1
        act = self.stats.used_ema
        changed: dict = {}

        hot = self._hot_mask(act)
        self._streak = np.where(hot, self._streak + 1, 0).astype(np.int32)
        earned = self._streak >= self.hot_windows

        n_cov = self._retune_coverage(engine, act)
        n_rep = (self._replicate_hot(engine, earned, act)
                 if self.replicate else 0)
        n_rec = self._reclaim_cold(engine)
        n_push = (self._push_to_peers(engine, earned)
                  if self.peer_push and engine.n_devices > 1 else 0)

        if n_cov:
            changed["coverage_repicks"] = n_cov
        if n_rep:
            changed["replicas_issued"] = n_rep
        if n_rec:
            changed["replicas_reclaimed"] = n_rec
        if n_push:
            changed["peer_pushes"] = n_push
        if changed:
            entry = {"t": engine.scheduler.now, **changed}
            self.trace.append(entry)
            self._emit(engine, changed)

    def _hot_mask(self, act: np.ndarray) -> np.ndarray:
        """Per-layer top-``hot_top_k`` by activity EMA; experts with zero
        recorded activity are never hot (argsort would otherwise fill the
        top-k with arbitrary unused ids on a fresh run)."""
        hot = np.zeros(act.shape, bool)
        if act.size == 0:
            return hot
        top = np.argsort(-act, axis=1, kind="stable")[:, :self.hot_top_k]
        np.put_along_axis(hot, top, True, axis=1)
        return hot & (act > 0.0)

    # -- action (a): live tier coverage ---------------------------------
    def _retune_coverage(self, engine, act: np.ndarray) -> int:
        """Re-pick the quant tier's covered set from live activity.

        The target set is the live per-layer top-``n_covered`` by EMA —
        the same ranking ``set_coverage`` was designed for, fed from
        traffic instead of the profiling draw. Four guards keep the
        re-pick from costing what it saves:

          * margin — the activity mass under the desired set must beat the
            mass under the current covered set by ``replicate_margin``;
            swapping coverage between near-tied experts buys nothing and
            risks a stall on the uncovering edge;
          * persistence — the desired set must differ from the current
            covered mask AND persist unchanged for ``hot_windows``
            consecutive ticks, so a near-tied ranking that flips order
            between windows never churns the tier;
          * make-before-break — experts about to LOSE coverage are
            pre-staged into the cache by a background 'replicate' copy,
            and the re-pick is DEFERRED until every one of them is
            resident, so uncovering never turns their next miss into a
            fetch stall;
          * a stable workload (want == covered) resets the pending state
            and never touches the tier."""
        tier = engine.tier
        if (not self.retune_coverage or tier is None
                or tier.n_covered >= tier.num_experts or not act.any()):
            return 0
        want = np.zeros(act.shape, bool)
        top = np.argsort(-act, axis=1, kind="stable")[:, :tier.n_covered]
        np.put_along_axis(want, top, True, axis=1)
        if np.array_equal(want, tier.covered):
            self._cov_want, self._cov_streak = None, 0
            return 0
        if float((act * want).sum()) <= \
                float((act * tier.covered).sum()) * self.replicate_margin:
            self._cov_want, self._cov_streak = None, 0
            return 0
        if self._cov_want is None or not np.array_equal(want, self._cov_want):
            self._cov_want, self._cov_streak = want, 1
        else:
            self._cov_streak += 1
        if self._cov_streak < self.hot_windows:
            return 0
        if self._prestage_uncovered(engine, tier.covered & ~want, act):
            return 0            # copies in flight: apply on a later tick
        tier.set_coverage(act)
        self._cov_want, self._cov_streak = None, 0
        self.n_coverage_repicks += 1
        return 1

    def _prestage_uncovered(self, engine, losing: np.ndarray,
                            act: np.ndarray) -> int:
        """Make-before-break: background-copy every non-resident expert in
        ``losing`` (covered now, uncovered after the pending re-pick) into
        the cache. Returns the number still NOT resident — the re-pick is
        deferred while that is nonzero. The copy obeys the same
        ``replicate_margin`` admission control as replication: installing
        a cold about-to-be-uncovered expert must not evict a warm resident
        (the re-pick simply stays deferred until a slot opens up or the
        victim cools). Duplicate submits are absorbed by the scheduler (an
        in-flight (layer, expert) is returned, not re-queued), so calling
        this every tick until landing is safe."""
        cache = engine.cache
        pending = 0
        for layer, e in zip(*np.nonzero(losing)):
            layer, e = int(layer), int(e)
            if cache.resident[layer, e]:
                continue
            pending += 1
            if (cache.inflight[layer, e]
                    or engine.scheduler.in_flight(layer, e) is not None):
                continue
            victim = cache.preview_victim(layer, e)
            if victim >= 0 and act[layer, e] <= \
                    act[layer, victim] * self.replicate_margin:
                continue        # victim still warm: keep the re-pick deferred
            engine.scheduler.submit(layer, e, engine._expert_bytes,
                                    "replicate")
            self._replicas[layer].add(e)
            self.n_replicas_issued += 1
        return pending

    # -- action (b): replicate persistently-hot experts -----------------
    def _replicate_hot(self, engine, earned: np.ndarray,
                       act: np.ndarray) -> int:
        """Issue background 'replicate' fetches for hot-streak experts that
        are not resident or already in flight, bounded per layer per tick.
        The host link's cache listener commits each one into a full-
        precision slot when it lands. Admission control: when the cache is
        full, the copy only goes out if the would-be eviction victim is
        colder than the candidate by ``replicate_margin`` — displacing a
        warm resident doesn't remove a miss, it relocates it (and on a
        small cache the resulting ping-pong turns background replication
        into foreground fetch stalls)."""
        cache = engine.cache
        issued = 0
        for layer in range(earned.shape[0]):
            n_layer = 0
            for e in np.flatnonzero(earned[layer]):
                if n_layer >= self.max_replicas_per_layer:
                    break
                e = int(e)
                if (cache.resident[layer, e] or cache.inflight[layer, e]
                        or engine.scheduler.in_flight(layer, e) is not None):
                    # already placed (or arriving): just track hot residents
                    # we previously installed via their replica set
                    continue
                victim = cache.preview_victim(layer, e)
                if victim >= 0 and act[layer, e] <= \
                        act[layer, victim] * self.replicate_margin:
                    continue
                engine.scheduler.submit(layer, e, engine._expert_bytes,
                                        "replicate")
                self._replicas[layer].add(e)
                self.n_replicas_issued += 1
                n_layer += 1
                issued += 1
        return issued

    def _reclaim_cold(self, engine) -> int:
        """Hysteresis down-edge: replicas whose expert broke its hot streak
        are marked reclaim-first (``ExpertCache.mark_reclaimable``), so the
        next insertion evicts them before any normal victim. Replicas that
        heated back up are unmarked; replicas already evicted are counted
        reclaimed and forgotten."""
        cache = engine.cache
        reclaimed = 0
        for layer in range(self._streak.shape[0]):
            for e in list(self._replicas[layer]):
                if not cache.resident[layer, e]:
                    if not cache.inflight[layer, e] and \
                            engine.scheduler.in_flight(layer, e) is None:
                        # eviction already cleared the reclaimable flag
                        # (cache.insert does), so the eviction itself is
                        # the signal: count it and forget the replica
                        self._replicas[layer].discard(e)
                        self.n_replicas_reclaimed += 1
                        reclaimed += 1
                        cache.clear_reclaimable(layer, [e])
                    continue
                if self._streak[layer, e] == 0:
                    cache.mark_reclaimable(layer, [e])
                else:
                    cache.clear_reclaimable(layer, [e])
        return reclaimed

    # -- action (c): dynamic peer placement -----------------------------
    def _push_to_peers(self, engine, earned: np.ndarray) -> int:
        """Push hot experts into the least-loaded peer's HBM: the replica
        mask flips at submit time (``peer_insert``), while the bytes ride
        the owning device's ICI link as a background 'replicate' transfer —
        an optimistic flip, the same discipline peer seeding uses. Load is
        the link's cumulative busy time plus its current demand backlog."""
        cache = engine.cache
        links = engine.peer_links
        if not links:
            return 0
        load = {d: lk.busy_s + lk.backlog_s() for d, lk in links.items()}
        target = min(sorted(load), key=lambda d: load[d])
        pushed = 0
        for layer in range(earned.shape[0]):
            n_layer = 0
            for e in np.flatnonzero(earned[layer]):
                if n_layer >= self.max_replicas_per_layer:
                    break
                e = int(e)
                if cache.peer_resident[target, layer, e]:
                    continue
                owner = int(cache.owner[e])
                link = links.get(owner, links[target])
                if link.in_flight(layer, e) is not None:
                    continue
                link.submit(layer, e, engine._expert_bytes, "replicate")
                cache.peer_insert(target, layer, e)
                self.n_peer_pushes += 1
                n_layer += 1
                pushed += 1
        return pushed

    # -- observability --------------------------------------------------
    def _emit(self, engine, changed: dict) -> None:
        """Telemetry counters + an engine-track trace instant per changing
        tick — both behind the engine's ``telemetry is None`` guard, so a
        telemetry-off run pays nothing."""
        tele = engine.telemetry
        if tele is None:
            return
        for action, n in changed.items():
            tele.metrics.counter("placement", action=action).inc(n)
        if tele.trace is not None:
            tele.trace.instant("engine", 0, "placement", "placement",
                               engine.scheduler.now, **changed)

    def active_replicas(self) -> int:
        return sum(len(s) for s in self._replicas)

    def summary(self) -> dict:
        """Config + counter digest — ``ServeEngine.summary()['placement']``.
        Counters are per-run (reset by attach); times are simulated
        seconds."""
        return {
            "refresh_interval_s": self.refresh_interval_s,
            "hot_windows": self.hot_windows,
            "hot_top_k": self.hot_top_k,
            "max_replicas_per_layer": self.max_replicas_per_layer,
            "replicate_margin": self.replicate_margin,
            "retune_coverage": self.retune_coverage,
            "replicate": self.replicate,
            "peer_push": self.peer_push,
            "n_ticks": self.n_ticks,
            "coverage_repicks": self.n_coverage_repicks,
            "replicas_issued": self.n_replicas_issued,
            "replicas_reclaimed": self.n_replicas_reclaimed,
            "peer_pushes": self.n_peer_pushes,
            "active_replicas": self.active_replicas(),
            "trace": list(self.trace),
        }
