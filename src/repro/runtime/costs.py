"""Unified expected-cost model for the five-way miss decision.

Every prefetch miss has five possible outcomes — buddy substitution
(core/substitute.py), degraded compute from the resident quant-replica tier
(runtime/tiers.py), borrowing the full-precision expert from a peer
device's HBM over ICI (multi-device meshes), a demand fetch over PCIe
(runtime/transfers.py), or dropping the slot and renormalizing. Before this
module the runtime resolved them with a FIXED precedence (buddy strictly
before degraded before fetch/drop) and a per-mechanism threshold
(``stall_per_fidelity``). Related systems (MELINOE's compressed experts,
predictive-prefetch replication, ExpertFlow's hierarchy-wide memory
coordination) show the choices only compose when they are scored on ONE
scale, so this module puts all five outcomes in stall-second units via a
single exchange rate:

  ``stall_per_quality``  seconds of pipeline stall the deployment is willing
                         to pay to avoid one unit of quality loss.

  cost(buddy)    = stall_per_quality * (1 - Psi_best)    zero stall; quality
                   loss shrinks with the buddy's co-activation score
  cost(degraded) = stall_per_quality * fidelity[l, e]    zero stall; quality
                   loss is the replica's calibrated round-trip error
  cost(peer)     = peer_eta_s[l, e]                      pure stall: the
                   owning device's ICI link queue plus the hop-priced
                   transfer — usually ~100x cheaper than PCIe, and zero
                   quality loss (it is the full-precision expert)
  cost(fetch)    = eta_s[l, e]                           pure stall: the
                   in-flight tail (TransferScheduler.eta_s) or the modeled
                   full cold transfer; zero quality loss
  cost(drop)     = stall_per_quality * drop_loss         zero stall; the slot's
                   contribution to the token's expert mixture is lost

The argmin over these replaces the precedence chain (policy.miss_policy =
'cost'): a high-q buddy beats a low-fidelity int4 replica, a nearly-landed
prefetch beats both, and a cold fetch loses to anything cheap. The same
scores drive the prefetcher: the expected stall SAVED by prefetching expert
e is P(use) x the miss cost the runtime would otherwise pay (the lateness
risk on the current timeline), which is what ``prefetch_scores`` ranks.

Host-side numpy only — the in-graph argmin lives in core/substitute.py and
consumes the per-expert cost vectors this module prepares (BuddyState
fid_cost / fetch_cost).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.runtime.memory import DEFAULT_HW, HardwareModel

# outcome codes (argmin tie-break order: quality-free fetch never beats an
# equally-priced reroute — ties go to the earlier, transfer-free outcome;
# a peer-HBM borrow beats an equally-priced host fetch, the cheaper link)
BUDDY, DEGRADED, PEER, FETCH, DROP = 0, 1, 2, 3, 4
OUTCOMES = ("buddy", "degraded", "peer", "fetch", "drop")


class MissCostModel:
    """Scores the five miss outcomes of every (layer, expert) on one
    stall-seconds scale and ranks prefetch candidates by expected stall
    saved. Stateless apart from its constants — call sites pass the current
    timeline (scheduler + per-link ICI schedulers), residency, and
    calibration each step. Single-device call sites simply never pass
    ``peer_eta`` and the model is the pre-mesh four-way scorer."""

    def __init__(self, num_layers: int, num_experts: int, *,
                 expert_bytes: int, hw: HardwareModel = DEFAULT_HW,
                 stall_per_quality: float = 0.05, drop_loss: float = 1.0):
        assert stall_per_quality > 0.0, "the exchange rate must be positive"
        assert drop_loss >= 0.0
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.expert_bytes = int(expert_bytes)
        self.hw = hw
        self.stall_per_quality = float(stall_per_quality)
        self.drop_loss = float(drop_loss)

    # -- per-outcome costs ----------------------------------------------
    def fetch_eta(self, scheduler=None) -> np.ndarray:
        """[L, E] expected stall of fetching each expert on a miss THIS
        step. A cold miss pays the full modeled transfer; an expert with a
        transfer already in flight pays only its optimistic remaining tail
        (TransferScheduler.eta_s).

        'upgrade'-cause transfers keep the COLD estimate: an upgrade is
        background quality-repair for a slot the policy already chose to
        serve degraded, so blocking a layer on its tail would invert the
        decision that spawned it — and because eta_s is an optimistic lower
        bound (link sharing ignored), waiting on self-generated upgrade
        traffic systematically overpays. The replica serves until the
        upgrade lands; only genuine prefetches discount the fetch cost.
        'replicate'-cause transfers (the placement controller's background
        hot-expert copies) are self-generated repair traffic of the same
        kind and keep the COLD estimate too."""
        eta = np.full((self.num_layers, self.num_experts),
                      self.hw.transfer_time(self.expert_bytes))
        if scheduler is not None:
            for t in scheduler.pending():
                if t.layer < self.num_layers and \
                        t.cause not in ("upgrade", "replicate"):
                    eta[t.layer, t.expert] = scheduler.eta_s(t)
        return eta

    def degraded_cost(self, fidelity: Optional[np.ndarray],
                      shape=None) -> np.ndarray:
        """Stall-equivalent of serving from the quant tier. fidelity is the
        calibrated relative round-trip error (inf = no replica / uncovered /
        uncalibrated -> never degrade); None means no tier at all."""
        if fidelity is None:
            return np.full(shape or (self.num_layers, self.num_experts),
                           np.inf)
        fidelity = np.asarray(fidelity, np.float64)
        return self.stall_per_quality * fidelity

    def buddy_cost(self, best_q: Optional[np.ndarray],
                   shape=None) -> np.ndarray:
        """Stall-equivalent of rerouting to the best currently ELIGIBLE
        buddy (best_q < 0 or NaN = no resident buddy -> inf). This is the
        host-side approximation used for prefetch ranking; the in-graph
        argmin recomputes Psi per token."""
        if best_q is None:
            return np.full(shape or (self.num_layers, self.num_experts),
                           np.inf)
        q = np.asarray(best_q, np.float64)
        cost = self.stall_per_quality * (1.0 - np.clip(q, 0.0, 1.0))
        return np.where(np.isfinite(q) & (q >= 0.0), cost, np.inf)

    def drop_cost(self) -> float:
        return self.stall_per_quality * self.drop_loss

    def peer_eta(self, links, peer_resident) -> np.ndarray:
        """[L, E] expected stall of borrowing each expert from a peer
        device's HBM over ICI — priced FROM THE OWNING LINK'S QUEUE, not a
        free-link idealization. For every peer link d:

            eta_d[e] = backlog_s(d) + fixed_s(d) + bytes / bw(d)

        where backlog is the remaining service of demand-class transfers
        already on that link (a borrow queues behind them). An expert the
        link is already carrying pays only its optimistic in-flight tail.
        Experts no peer holds are inf — the argmin falls through to
        host-PCIe fetch. ``links``: {device: TransferScheduler},
        ``peer_resident``: [D, L, E] bool (ExpertCache.peer_resident)."""
        eta = np.full((self.num_layers, self.num_experts), np.inf)
        if not links:
            return eta
        peer_resident = np.asarray(peer_resident, bool)
        for d, link in links.items():
            cold = link.backlog_s() + link.transfer_time(self.expert_bytes)
            eta = np.where(peer_resident[d], np.minimum(eta, cold), eta)
            for t in link.pending():
                if t.layer < self.num_layers:
                    eta[t.layer, t.expert] = min(eta[t.layer, t.expert],
                                                 link.eta_s(t))
        return eta

    # -- the unified score ----------------------------------------------
    def _outcome_stack(self, fetch_eta, fidelity, best_q,
                       peer_eta=None) -> np.ndarray:
        """Rows are indexed by the outcome codes: peer_eta=None (any
        single-device call site) prices the peer row at inf, so the stack
        is always 5-deep and codes never shift."""
        fetch_eta = np.asarray(fetch_eta, np.float64)
        if peer_eta is None:
            peer = np.full(fetch_eta.shape, np.inf)
        else:
            peer = np.asarray(peer_eta, np.float64)
        return np.stack([
            self.buddy_cost(best_q, shape=fetch_eta.shape),
            self.degraded_cost(fidelity, shape=fetch_eta.shape),
            peer,
            fetch_eta,
            np.full(fetch_eta.shape, self.drop_cost()),
        ])

    def miss_cost(self, fetch_eta: np.ndarray,
                  fidelity: Optional[np.ndarray] = None,
                  best_q: Optional[np.ndarray] = None,
                  peer_eta: Optional[np.ndarray] = None) -> np.ndarray:
        """The stall-equivalent cost the runtime would actually pay if this
        expert missed right now — the min over all five outcomes. This is
        the 'lateness risk' a prefetch removes. Shapes follow ``fetch_eta``
        ([L, E] or a single layer's [E])."""
        return self._outcome_stack(fetch_eta, fidelity, best_q,
                                   peer_eta).min(axis=0)

    def outcome_argmin(self, fetch_eta: np.ndarray,
                       fidelity: Optional[np.ndarray] = None,
                       best_q: Optional[np.ndarray] = None,
                       peer_eta: Optional[np.ndarray] = None) -> np.ndarray:
        """Int outcome codes (BUDDY/DEGRADED/PEER/FETCH/DROP) — the
        host-side mirror of the in-graph argmin, for introspection/tests.
        np.argmin takes the first minimal row, which encodes the tie-break
        order: reroutes beat transfers at equal cost, and a peer borrow
        beats an equally-priced host fetch (cheaper link, full fidelity)."""
        return self._outcome_stack(fetch_eta, fidelity, best_q,
                                   peer_eta).argmin(axis=0)

    # -- prefetch ranking -----------------------------------------------
    def prefetch_scores(self, p_use: np.ndarray, miss_cost: np.ndarray,
                        resident: np.ndarray,
                        inflight: Optional[np.ndarray] = None) -> np.ndarray:
        """Expected stall SAVED by prefetching each expert of one layer:

            score[e] = P(use e next step) x miss_cost[e]

        Residents save nothing; in-flight transfers are already paid for.
        The prefetcher ranks by this instead of raw predicted frequency, so
        an expert whose miss a cheap fallback absorbs (good buddy, high-
        fidelity replica) stops crowding out one whose miss would stall."""
        p_use = np.asarray(p_use, np.float64)
        score = p_use * np.asarray(miss_cost, np.float64)
        score = np.where(np.asarray(resident, bool), 0.0, score)
        if inflight is not None:
            score = np.where(np.asarray(inflight, bool), 0.0, score)
        return score


def best_resident_q(table: np.ndarray, q: np.ndarray,
                    resident: np.ndarray) -> np.ndarray:
    """[L, E] (or [E] given per-layer slices) best buddy q among each
    expert's currently-resident candidates; -1 where none is eligible.
    Vectorized over the buddy rank axis (last)."""
    table = np.asarray(table)
    q = np.asarray(q, np.float64)
    resident = np.asarray(resident, bool)
    valid = table >= 0
    safe = np.where(valid, table, 0)
    if table.ndim == 3:                       # [L, E, R]
        res = resident[np.arange(safe.shape[0])[:, None, None], safe]
    else:                                     # [E, R] single layer
        res = resident[safe]
    elig = valid & res
    qv = np.where(elig, q, -1.0)
    return qv.max(axis=-1)
