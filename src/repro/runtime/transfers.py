"""Event-driven transfer scheduler — one instance per link.

The paper's regime is transfer-bound: one expert over PCIe is ~10 ms while a
decode layer is ~100 us, so WHEN a transfer lands — not just how many bytes
moved — decides whether a prefetched expert is usable or is a miss that buddy
substitution must absorb. This module models that timeline explicitly:

  * a simulated clock shared with the serving engine (``now``),
  * one link per scheduler whose bandwidth is FAIR-SHARED among the
    transfers it is currently serving — by default the host→device PCIe
    link, but ``bw``/``fixed_s``/``name`` parameterize any link: an
    expert-parallel mesh instantiates one scheduler per device↔device ICI
    link (``make_ici_links``) next to the host link, all on one clock,
  * two priority classes — DEMAND fetches preempt PREFETCHES entirely (a
    stalled layer must not queue behind speculative traffic),
  * per-transfer fixed launch cost (host pinning + descriptor setup) paid
    before that transfer's bytes stream (launch costs of concurrent
    transfers overlap; bandwidth is what they contend for),
  * cancellation of stale prefetches (predictions superseded before service),
  * escalation: an in-flight prefetch that a layer suddenly needs is promoted
    to demand priority and the caller stalls only for its *remaining* time —
    the "late prefetch" case, which is accounted separately from a cold
    demand fetch.

Listeners (the ledger, the cache) receive ``(kind, transfer)`` events with
kind in {"submit", "start", "complete", "cancel", "escalate"} so byte
accounting and residency commits are driven by the same timeline the latency
model uses.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.runtime.memory import DEFAULT_HW, HardwareModel

# Transfer states
QUEUED = "queued"
ACTIVE = "active"
DONE = "done"
CANCELLED = "cancelled"

# Priority classes (lower value serves first)
PRIO_DEMAND = 0
PRIO_PREFETCH = 10

# float-residue tolerances: a transfer with less than half a byte (or a
# femtosecond of launch cost) left is complete — without these, event steps
# can underflow (now + dt == now) and the loop stops making progress
_EPS_B = 0.5
_EPS_S = 1e-12


@dataclasses.dataclass
class Transfer:
    tid: int
    layer: int
    expert: int
    nbytes: int
    cause: str          # "prefetch"|"demand"|"upgrade"|"peer"|"replicate"
    priority: int
    issue_s: float                  # submission time
    remaining_fixed_s: float        # launch cost left (serial, per transfer)
    remaining_bytes: float          # payload left to stream
    start_s: float = -1.0           # first time the link served it
    done_s: float = -1.0
    state: str = QUEUED
    event_seq: int = 0              # last event's scheduler sequence number

    @property
    def started(self) -> bool:
        return self.start_s >= 0.0

    @property
    def in_flight(self) -> bool:
        return self.state in (QUEUED, ACTIVE)


class TransferScheduler:
    """Single-link timeline with priorities and fair bandwidth sharing.

    ``advance(t)`` plays the link forward to simulated time ``t``; transfers
    that complete in that window fire "complete" events at their exact finish
    times. ``run_until_done(tr)`` is the stall primitive: it advances time
    until ``tr`` lands and returns the completion timestamp.

    The default link is the host→device PCIe lane (``hw.pcie_bw`` /
    ``hw.pcie_fixed_s``). Pass ``bw``/``fixed_s`` to model any other link —
    a device↔device ICI hop for peer-HBM borrows — and ``name`` to label its
    trace lane (``transfers:<name>``) and per-link accounting; ``name=None``
    keeps the pre-mesh single-link trace output byte-identical.
    """

    def __init__(self, hw: HardwareModel = DEFAULT_HW,
                 max_inflight_prefetch: int = 4, *,
                 bw: Optional[float] = None,
                 fixed_s: Optional[float] = None,
                 name: Optional[str] = None):
        self.hw = hw
        self.bw = hw.pcie_bw if bw is None else float(bw)
        self.fixed_s = hw.pcie_fixed_s if fixed_s is None else float(fixed_s)
        self.name = name
        self.now = 0.0
        self.busy_s = 0.0           # cumulative time the link was serving
        self.max_inflight_prefetch = max_inflight_prefetch
        self._queued: List[Tuple[int, int, Transfer]] = []   # heap
        self._active: List[Transfer] = []
        self._by_key: Dict[Tuple[int, int], Transfer] = {}
        self._listeners: List[Callable[[str, Transfer], None]] = []
        self._next_tid = 0
        self._event_seq = 0
        self.bytes_by_cause: Dict[str, int] = {}    # per-link utilization
        self.trace = None           # optional FlightRecorder (runtime/trace)

    # -- wiring ---------------------------------------------------------
    def add_listener(self, fn: Callable[[str, Transfer], None]) -> None:
        self._listeners.append(fn)

    def _emit(self, kind: str, t: Transfer) -> None:
        # monotonic per-scheduler sequence id: simultaneous events (common on
        # a discrete-event clock) get a total order, so listeners and trace
        # exports are byte-stable across runs at a fixed seed
        self._event_seq += 1
        t.event_seq = self._event_seq
        for fn in self._listeners:
            fn(kind, t)
        if self.trace is not None:
            self.trace.transfer_event(kind, t, self.now, link=self.name)

    # -- submission / lookup -------------------------------------------
    def in_flight(self, layer: int, expert: int) -> Optional[Transfer]:
        t = self._by_key.get((layer, expert))
        return t if t is not None and t.in_flight else None

    def submit(self, layer: int, expert: int, nbytes: int, cause: str,
               priority: Optional[int] = None) -> Transfer:
        """Queue a transfer at the current clock. Duplicate (layer, expert)
        submissions return the in-flight transfer (escalated if the new
        request is more urgent). ``cause`` 'upgrade' is the degraded-then-
        upgrade background fetch (runtime/costs.py): speculative priority —
        it shares the prefetch class and cap — but exempt from stale-
        prediction cancellation, and its bytes are ledgered separately.
        ``cause`` 'peer' is a peer-HBM borrow over an ICI link: a stalled
        slot is waiting on it, so it rides at demand priority. ``cause``
        'replicate' is the placement controller's background copy of a
        persistently-hot expert (runtime/placement.py): prefetch priority
        like 'upgrade', exempt from stale-prediction cancellation, bytes
        ledgered under its own key."""
        assert cause in ("prefetch", "demand", "upgrade", "peer",
                         "replicate")
        existing = self.in_flight(layer, expert)
        if existing is not None:
            if cause in ("demand", "peer") and \
                    existing.priority > PRIO_DEMAND:
                self.escalate(existing)
            return existing
        prio = priority if priority is not None else (
            PRIO_DEMAND if cause in ("demand", "peer") else PRIO_PREFETCH)
        t = Transfer(tid=self._next_tid, layer=layer, expert=expert,
                     nbytes=int(nbytes), cause=cause, priority=prio,
                     issue_s=self.now,
                     remaining_fixed_s=self.fixed_s,
                     remaining_bytes=float(nbytes))
        self._next_tid += 1
        self.bytes_by_cause[cause] = \
            self.bytes_by_cause.get(cause, 0) + int(nbytes)
        self._by_key[(layer, expert)] = t
        heapq.heappush(self._queued, (t.priority, t.tid, t))
        self._emit("submit", t)
        return t

    def escalate(self, t: Transfer) -> None:
        """Promote a prefetch to demand priority (a layer needs it NOW)."""
        if not t.in_flight or t.priority <= PRIO_DEMAND:
            return
        t.priority = PRIO_DEMAND
        if t.state == QUEUED:
            # re-push; stale heap entries are skipped on pop by state check
            heapq.heappush(self._queued, (t.priority, t.tid, t))
        self._emit("escalate", t)

    def cancel(self, t: Transfer) -> bool:
        """Drop a queued/active transfer. Returns True if it was in flight."""
        if not t.in_flight:
            return False
        t.state = CANCELLED
        if t in self._active:
            self._active.remove(t)
        self._by_key.pop((t.layer, t.expert), None)
        self._emit("cancel", t)
        return True

    def cancel_stale_prefetches(self, layer: int, keep) -> int:
        """Cancel in-flight prefetches for ``layer`` not in ``keep``."""
        keep = set(int(e) for e in keep)
        n = 0
        for (l, e), t in list(self._by_key.items()):
            if (l == layer and t.cause == "prefetch" and t.in_flight
                    and e not in keep):
                n += int(self.cancel(t))
        return n

    def set_prefetch_cap(self, n: int) -> None:
        """Resize the concurrent-prefetch cap (the serving layer's adaptive
        budget controller shrinks this when late-prefetch stalls dominate).
        Already-admitted prefetches keep streaming; the new cap gates
        admission from the queue."""
        self.max_inflight_prefetch = max(1, int(n))

    # -- timeline -------------------------------------------------------
    def _admit(self) -> None:
        """Move queued transfers onto the link: every demand immediately;
        prefetches up to the concurrency cap."""
        requeue = []
        n_prefetch = sum(1 for t in self._active if t.priority > PRIO_DEMAND)
        while self._queued:
            prio, _, t = heapq.heappop(self._queued)
            if t.state != QUEUED or prio != t.priority:
                continue    # cancelled, already admitted, or stale heap entry
            if t.priority > PRIO_DEMAND and \
                    n_prefetch >= self.max_inflight_prefetch:
                requeue.append(t)
                continue
            t.state = ACTIVE
            self._active.append(t)
            if t.priority > PRIO_DEMAND:
                n_prefetch += 1
        for t in requeue:
            heapq.heappush(self._queued, (t.priority, t.tid, t))

    def _serving(self) -> List[Transfer]:
        """Demand transfers monopolise the link; prefetches only progress
        when no demand is in flight."""
        if not self._active:
            return []
        best = min(t.priority for t in self._active)
        return [t for t in self._active if t.priority == best]

    def _next_event_dt(self) -> float:
        """Time until the next state change on the link (inf if idle)."""
        serving = self._serving()
        if not serving:
            return float("inf")
        streaming = [t for t in serving if t.remaining_fixed_s <= _EPS_S]
        share = self.bw / max(1, len(streaming))
        dts = []
        for t in serving:
            if t.remaining_fixed_s > _EPS_S:
                dts.append(t.remaining_fixed_s)
            else:
                dts.append(t.remaining_bytes / share)
        return max(0.0, min(dts))

    def advance(self, to_time: float) -> None:
        """Play the link forward to ``to_time`` (no-op if in the past)."""
        while True:
            self._admit()
            if to_time <= self.now:
                return
            dt = self._next_event_dt()
            step = min(dt, to_time - self.now)
            serving = self._serving()
            streaming = [t for t in serving if t.remaining_fixed_s <= _EPS_S]
            share = self.bw / max(1, len(streaming))
            if serving:
                self.busy_s += step
            for t in serving:
                # "started" = actually received link service; a paused
                # prefetch admitted behind a demand has NOT started and a
                # later cancel refunds its bytes in the ledger
                if not t.started and step > 0.0:
                    t.start_s = self.now
                    self._emit("start", t)
                if t.remaining_fixed_s > _EPS_S:
                    t.remaining_fixed_s = max(0.0, t.remaining_fixed_s - step)
                else:
                    t.remaining_bytes = max(0.0, t.remaining_bytes
                                            - share * step)
            self.now += step
            for t in list(serving):
                if t.remaining_fixed_s <= _EPS_S and t.remaining_bytes <= _EPS_B:
                    t.state = DONE
                    t.done_s = self.now
                    self._active.remove(t)
                    self._by_key.pop((t.layer, t.expert), None)
                    self._emit("complete", t)
            if dt == float("inf") and not self._queued:
                self.now = to_time
                return

    def eta_s(self, t: Transfer) -> float:
        """Optimistic remaining-service estimate for an in-flight transfer:
        the fixed-cost tail plus its bytes at FULL link bandwidth (queued
        demands ahead of it and bandwidth sharing are ignored). The tiered
        store's degrade-vs-wait decision wants a cheap lower bound — if even
        the optimistic ETA exceeds the fidelity-justified stall, computing
        from the resident replica wins for sure."""
        if t.state == DONE:
            return 0.0
        return max(0.0, t.remaining_fixed_s) \
            + t.remaining_bytes / self.bw

    def transfer_time(self, nbytes: int) -> float:
        """Cold (unqueued, unshared) service time of one transfer on THIS
        link — the per-link analogue of ``HardwareModel.transfer_time``."""
        return self.fixed_s + nbytes / self.bw

    def backlog_s(self) -> float:
        """Pessimistic wait before a NEW demand-class transfer would get
        bandwidth: total remaining service of demand-priority transfers
        already on the link (prefetches don't count — a new demand preempts
        them). Used to price peer-borrow ETAs off a busy ICI link."""
        s = 0.0
        for t in self.pending():
            if t.priority <= PRIO_DEMAND:
                s += max(0.0, t.remaining_fixed_s) \
                    + t.remaining_bytes / self.bw
        return s

    def run_until_done(self, t: Transfer) -> float:
        """Advance the clock until ``t`` completes; returns its finish time.
        This is the synchronous-stall primitive: the caller's layer is
        blocked for ``t.done_s - now``."""
        if t.state == DONE:
            return t.done_s
        assert t.in_flight, f"cannot wait on a {t.state} transfer"
        guard = 0
        while t.in_flight:
            self._admit()
            dt = self._next_event_dt()
            assert dt != float("inf"), "waiting on a transfer the link lost"
            self.advance(self.now + dt)
            guard += 1
            assert guard < 1_000_000, "scheduler failed to converge"
        return t.done_s

    def flush(self) -> float:
        """Run every in-flight transfer to completion; returns the clock."""
        while self._active or self._queued:
            self._admit()
            dt = self._next_event_dt()
            if dt == float("inf"):
                break
            self.advance(self.now + dt)
        return self.now

    # -- introspection --------------------------------------------------
    # escalate() re-pushes a QUEUED transfer at its new priority and leaves
    # the stale heap entry behind (skipped on pop by the state/priority
    # check), so heap walks must dedup by tid or escalated transfers are
    # counted twice.
    @property
    def n_in_flight(self) -> int:
        return len(self._active) + len(
            {t.tid for _, _, t in self._queued if t.state == QUEUED})

    def pending(self) -> List[Transfer]:
        out = list(self._active)
        seen = {t.tid for t in out}
        for _, _, t in sorted(self._queued):
            if t.state == QUEUED and t.tid not in seen:
                seen.add(t.tid)
                out.append(t)
        return out

    def utilization(self) -> dict:
        """Per-link digest: cumulative busy time, queue depth right now, and
        the bytes submitted per cause (demand / prefetch / upgrade / peer /
        replicate)."""
        return {
            "name": self.name or "pcie",
            "busy_s": self.busy_s,
            "queue_depth": self.n_in_flight,
            "bytes_by_cause": dict(sorted(self.bytes_by_cause.items())),
            "total_bytes": sum(self.bytes_by_cause.values()),
        }


def device_hops(d: int, n_devices: int) -> int:
    """Manhattan distance from device 0 to device ``d`` on the smallest
    square grid holding ``n_devices`` chips — the same shape
    ``launch/mesh.py`` builds and ``ExpertCache.hop_vector`` models."""
    side = max(1, int(math.ceil(math.sqrt(n_devices))))
    return abs(d % side - 0) + abs(d // side - 0)


def make_ici_links(n_devices: int, hw: HardwareModel = DEFAULT_HW, *,
                   ici_bw: Optional[float] = None
                   ) -> Dict[int, "TransferScheduler"]:
    """One ICI scheduler per peer device (1..D-1), each pricing the
    Manhattan hop count from device 0 into its fixed launch cost. Returns
    ``{device: scheduler}``; the caller advances them alongside the host
    PCIe link so every link shares one simulated clock."""
    bw = hw.ici_bw if ici_bw is None else float(ici_bw)
    return {
        d: TransferScheduler(
            hw, bw=bw,
            fixed_s=hw.ici_fixed_s * device_hops(d, n_devices),
            name=f"ici{d}")
        for d in range(1, n_devices)
    }
