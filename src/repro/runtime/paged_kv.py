"""Paged KV allocator — fixed-size blocks, refcounts, copy-on-write.

Replaces the per-slot ``[B, Cap, KV, hd]`` KV rings with one shared pool of
``n_blocks`` physical blocks of ``block_size`` tokens each.  Device storage
(owned by the model code, see ``transformer.init_paged_caches``) is a stack
of ``[P, bs, KV, hd]`` leaves; this class owns only the *host-side* mapping
state:

  * ``tables``   — ``[batch, max_blocks]`` int32: row r's logical block j
                   (token positions ``[j*bs, (j+1)*bs)``) lives in physical
                   block ``tables[r, j]``; ``-1`` means unmapped.  The engine
                   ships this array to the device as the block table every
                   paged step.
  * ``refcount`` — per-physical-block holder count.  A block's holders are
                   the row tables that map it plus the radix-tree nodes that
                   list it (``serving/prefix.py``); it returns to the free
                   list exactly when the count hits zero.
  * free list    — a LIFO stack popped deterministically, so allocation
                   order (and therefore every downstream device gather) is
                   reproducible at a fixed seed.

Copy-on-write: a row may only *write* into a block it owns exclusively
(refcount 1).  ``ensure_range`` remaps any shared block in the write range
to a fresh block and queues a ``(src, dst)`` physical copy; the engine
drains the queue (``drain_copies``) into one batched device copy before the
next scatter.  This is what makes prefix sharing safe — adopted blocks are
never mutated in place, so the radix tree's contents stay frozen.

Pool pressure: when the free list is empty, ``alloc`` invokes ``evict_cb``
(wired to the prefix tree's LRU leaf eviction) until a block frees up or
the callback gives up, then raises if the pool is genuinely exhausted.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


class PagedKVPool:
    """Host-side block allocator + per-row block tables for paged KV."""

    def __init__(self, n_blocks: int, block_size: int, batch: int,
                 max_blocks: int) -> None:
        assert n_blocks >= 1 and block_size >= 1
        assert batch >= 1 and max_blocks >= 1
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self.batch = int(batch)
        self.max_blocks = int(max_blocks)
        self.refcount = np.zeros(self.n_blocks, np.int32)
        # LIFO: block 0 pops first (reversed range), keeping allocation
        # order stable run-to-run
        self._free: List[int] = list(range(self.n_blocks - 1, -1, -1))
        self.tables = np.full((self.batch, self.max_blocks), -1, np.int32)
        self._copies: List[Tuple[int, int]] = []
        # called under pool pressure; returns True if it released something
        self.evict_cb: Optional[Callable[[], bool]] = None
        self.cow_copies = 0
        self.evictions = 0

    # -- raw block ops ---------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def alloc(self) -> int:
        """Pop a free block (refcount 1), evicting cold prefix-tree leaves
        under pressure."""
        while not self._free and self.evict_cb is not None:
            if not self.evict_cb():
                break
            self.evictions += 1
        if not self._free:
            raise RuntimeError(
                f"PagedKVPool exhausted: all {self.n_blocks} blocks of "
                f"{self.block_size} tokens are held")
        b = self._free.pop()
        assert self.refcount[b] == 0
        self.refcount[b] = 1
        return b

    def ref(self, blk: int) -> None:
        assert self.refcount[blk] > 0, "ref of a free block"
        self.refcount[blk] += 1

    def release(self, blk: int) -> None:
        assert self.refcount[blk] > 0, "release of a free block"
        self.refcount[blk] -= 1
        if self.refcount[blk] == 0:
            self._free.append(blk)

    # -- per-row table ops ----------------------------------------------
    def free_row(self, row: int) -> None:
        """Drop every block the row maps (idempotent)."""
        t = self.tables[row]
        for j in np.flatnonzero(t >= 0):
            self.release(int(t[j]))
        self.tables[row] = -1

    def n_mapped(self, row: int) -> int:
        return int((self.tables[row] >= 0).sum())

    def row_blocks(self, row: int, n_tokens: int) -> List[int]:
        """Physical blocks covering positions ``[0, n_tokens)`` of a row —
        all must be mapped (the row has written them)."""
        need = -(-n_tokens // self.block_size)
        out = [int(self.tables[row, j]) for j in range(need)]
        assert all(b >= 0 for b in out), "row_blocks over unmapped range"
        return out

    def adopt(self, row: int, blocks: Sequence[int]) -> None:
        """Map a shared prefix chain (block j = positions ``[j*bs,
        (j+1)*bs)``) into an empty row table, bumping refcounts.  The row
        must CoW (via ``ensure_range``) before writing any of them."""
        for j, b in enumerate(blocks):
            assert self.tables[row, j] == -1, "adopt into a mapped slot"
            self.ref(int(b))
            self.tables[row, j] = int(b)

    def ensure_range(self, row: int, start: int, end: int) -> None:
        """Make positions ``[start, end)`` writable by this row: allocate
        unmapped blocks in the range and copy-on-write shared ones (the
        adopted tail block a prefix hit will append into).  Queued device
        copies are picked up by ``drain_copies``."""
        if end <= start:
            return
        bs = self.block_size
        lo, hi = start // bs, (end - 1) // bs
        assert hi < self.max_blocks, (
            f"row {row} needs block {hi} but tables are "
            f"{self.max_blocks} wide (context overflow)")
        for j in range(lo, hi + 1):
            b = int(self.tables[row, j])
            if b < 0:
                self.tables[row, j] = self.alloc()
            elif self.refcount[b] > 1:
                nb = self.alloc()
                self._copies.append((b, nb))
                self.cow_copies += 1
                self.release(b)
                self.tables[row, j] = nb

    def drain_copies(self) -> List[Tuple[int, int]]:
        """Pending ``(src, dst)`` physical block copies, cleared on read.
        The engine applies them to the device pool before the next write."""
        out, self._copies = self._copies, []
        return out

    # -- digests ---------------------------------------------------------
    def occupancy(self) -> dict:
        return {"n_blocks": self.n_blocks, "block_size": self.block_size,
                "used_blocks": self.used_blocks,
                "free_blocks": self.free_blocks,
                "cow_copies": self.cow_copies,
                "evictions": self.evictions}

    def check(self, extra_holders: Optional[dict] = None) -> None:
        """Invariant audit (tests): every block's refcount equals the number
        of row-table slots mapping it plus ``extra_holders`` (e.g. the radix
        tree's per-block listing counts), and the free list holds exactly
        the zero-refcount blocks, each once."""
        holders = np.zeros(self.n_blocks, np.int64)
        for b in self.tables[self.tables >= 0]:
            holders[int(b)] += 1
        for b, n in (extra_holders or {}).items():
            holders[int(b)] += int(n)
        assert (holders == self.refcount).all(), (
            "refcount drift: "
            f"{np.flatnonzero(holders != self.refcount).tolist()}")
        free = sorted(self._free)
        assert free == sorted(set(free)), "duplicate free-list entry"
        assert free == np.flatnonzero(self.refcount == 0).tolist(), (
            "free list out of sync with refcounts")
