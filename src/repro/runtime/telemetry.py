"""Metrics registry + cost-model calibration telemetry.

Three layers, all opt-in (a ``None`` telemetry leaves the runtime's code
path, outputs, and summaries bit-identical to pre-telemetry behavior):

  MetricsRegistry  counters / gauges / histograms / windowed EMAs with
                   labels — replaces ad-hoc summary accumulation. Metrics
                   are keyed by (name, sorted label items) and mergeable
                   (multi-engine benchmark aggregation).
  ExpertStats      per-(layer, expert) hit / miss / degraded EMAs — the
                   ledger-to-signal layer ROADMAP direction 3 ("online
                   expert replication + router shaping") trains on.
  CalibrationMeter for every miss, the cost model's PREDICTED stall-seconds
                   for the chosen outcome next to the REALIZED stall from
                   the transfer timeline, bucketed by outcome class — turns
                   "calibrate HardwareModel / stall_per_quality" into a
                   measured residual instead of a guess.
  PrefetchMeter    per-predictor prefetch precision / recall / expected
                   stall saved, driven by TransferScheduler events plus two
                   engine hooks (used-in-time, uncovered demand miss).

``Telemetry`` bundles the four with an optional FlightRecorder
(runtime/trace.py) and renders ``summary()`` — surfaced by the serving
engine as ``summary()["telemetry"]`` and reported by
benchmarks/bench_telemetry.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.trace import FlightRecorder

# ---------------------------------------------------------------------------
# Metric primitives
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic accumulator; ``merge`` sums across registries."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def merge(self, other: "Counter") -> None:
        self.value += other.value

    def snapshot(self):
        return self.value


class Gauge:
    """Last-written value (``merge`` keeps the max — high-water
    semantics, the only aggregation meaningful across registries)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def merge(self, other: "Gauge") -> None:
        # last-write-wins has no meaning across registries; keep the max so
        # merged high-water gauges (queue depth, inflight) stay useful
        self.value = max(self.value, other.value)

    def snapshot(self):
        return self.value


class Histogram:
    """Log-spaced-bucket histogram for latency-like positive values.

    Default bounds span 1 us .. 100 s in quarter-decade steps — wide enough
    for both simulated stall seconds and modeled step times. Two histograms
    merge iff their bounds match (bucket-wise count addition; sum/count/min/
    max combine exactly)."""

    DEFAULT_BOUNDS: Tuple[float, ...] = tuple(
        10.0 ** (-6 + 0.25 * i) for i in range(33))   # 1e-6 .. 1e2

    __slots__ = ("bounds", "counts", "sum", "n", "min", "max")

    def __init__(self, bounds: Optional[Tuple[float, ...]] = None) -> None:
        self.bounds = tuple(bounds) if bounds is not None \
            else self.DEFAULT_BOUNDS
        assert all(a < b for a, b in zip(self.bounds, self.bounds[1:])), \
            "histogram bounds must be strictly increasing"
        self.counts = [0] * (len(self.bounds) + 1)   # last = overflow
        self.sum = 0.0
        self.n = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float, n: int = 1) -> None:
        # bisect over a short tuple; values at a bound land in that bucket
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += n
        self.sum += v * n
        self.n += n
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def merge(self, other: "Histogram") -> None:
        assert self.bounds == other.bounds, \
            "cannot merge histograms with different bucket bounds"
        self.counts = [a + b for a, b in zip(self.counts, other.counts)]
        self.sum += other.sum
        self.n += other.n
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def quantile(self, q: float) -> float:
        """Bucket-upper-bound quantile estimate (conservative)."""
        assert 0.0 <= q <= 1.0
        if self.n == 0:
            return 0.0
        rank = q * self.n
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank and c > 0:
                return self.bounds[i] if i < len(self.bounds) else self.max
        return self.max

    def snapshot(self) -> dict:
        return {"n": self.n, "sum": self.sum,
                "mean": self.sum / self.n if self.n else 0.0,
                "min": self.min if self.n else 0.0,
                "max": self.max if self.n else 0.0,
                "p50": self.quantile(0.50), "p99": self.quantile(0.99)}


class EMA:
    """Exponential moving average with a half-life expressed in updates.

    ``merge`` combines two EMAs as a count-weighted average — exact for
    equal-rate streams and the standard approximation otherwise (tested in
    tests/test_telemetry.py)."""

    __slots__ = ("alpha", "value", "n")

    def __init__(self, alpha: float = 0.1) -> None:
        assert 0.0 < alpha <= 1.0
        self.alpha = alpha
        self.value = 0.0
        self.n = 0

    def update(self, v: float) -> float:
        if self.n == 0:
            self.value = float(v)       # seed at the first sample, no pull
        else:                           # toward the arbitrary zero init
            self.value += self.alpha * (float(v) - self.value)
        self.n += 1
        return self.value

    def merge(self, other: "EMA") -> None:
        assert self.alpha == other.alpha, \
            "cannot merge EMAs with different decay rates"
        tot = self.n + other.n
        if tot == 0:
            return
        self.value = (self.value * self.n + other.value * other.n) / tot
        self.n = tot

    def snapshot(self) -> dict:
        return {"value": self.value, "n": self.n}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram,
          "ema": EMA}


class MetricsRegistry:
    """Labelled metric store. Metrics are created on first touch:

        reg.counter("stall_events", cause="demand").inc()
        reg.histogram("stall_s", cause="demand").observe(0.01)
        reg.ema("step_time_s", alpha=0.05).update(t)

    Keys are (name, sorted label items); a name is bound to ONE metric kind
    (mixing kinds under a name is a bug and asserts). ``snapshot`` renders
    {name: {label_repr: value}}; ``merge`` folds another registry in
    (kind-wise merge semantics above)."""

    def __init__(self) -> None:
        self._metrics: Dict[Tuple[str, Tuple], object] = {}
        self._kind_of: Dict[str, str] = {}

    def _get(self, kind: str, name: str, labels: dict, **ctor):
        assert self._kind_of.setdefault(name, kind) == kind, \
            f"metric {name!r} already registered as {self._kind_of[name]}"
        key = (name, tuple(sorted(labels.items())))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = _KINDS[kind](**ctor)
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, bounds=None, **labels) -> Histogram:
        if bounds is None:
            return self._get("histogram", name, labels)
        return self._get("histogram", name, labels, bounds=bounds)

    def ema(self, name: str, alpha: float = 0.1, **labels) -> EMA:
        return self._get("ema", name, labels, alpha=alpha)

    def merge(self, other: "MetricsRegistry") -> None:
        for (name, lab), m in other._metrics.items():
            kind = other._kind_of[name]
            assert self._kind_of.setdefault(name, kind) == kind, \
                f"merge kind clash on metric {name!r}"
            mine = self._metrics.get((name, lab))
            if mine is None:
                # fresh copies so the merged registry owns its state
                if kind == "histogram":
                    mine = Histogram(m.bounds)
                elif kind == "ema":
                    mine = EMA(m.alpha)
                else:
                    mine = _KINDS[kind]()
                self._metrics[(name, lab)] = mine
            mine.merge(m)

    @staticmethod
    def _label_repr(lab: Tuple) -> str:
        return ",".join(f"{k}={v}" for k, v in lab) if lab else ""

    def snapshot(self) -> dict:
        out: Dict[str, dict] = {}
        for (name, lab), m in sorted(self._metrics.items(),
                                     key=lambda kv: (kv[0][0],
                                                     str(kv[0][1]))):
            out.setdefault(name, {})[self._label_repr(lab)] = m.snapshot()
        return out


# ---------------------------------------------------------------------------
# Per-expert hit/miss/degraded EMAs (ROADMAP direction 3's training signal)
# ---------------------------------------------------------------------------
class ExpertStats:
    """[L, E] EMAs of per-step usage, hit, miss, and degraded indicators.

    Updated once per (layer, step) with the step's expert sets; rows decay
    every step so the EMAs track the traffic's CURRENT hot set — exactly
    the signal online replication / set_coverage re-picking needs."""

    def __init__(self, num_layers: int, num_experts: int,
                 alpha: float = 0.05) -> None:
        assert 0.0 < alpha <= 1.0
        self.alpha = alpha
        shape = (num_layers, num_experts)
        self.used_ema = np.zeros(shape)
        self.hit_ema = np.zeros(shape)
        self.miss_ema = np.zeros(shape)
        self.degraded_ema = np.zeros(shape)
        self.steps = np.zeros(num_layers, np.int64)

    def update(self, layer: int, used, hit, missed, degraded=None) -> None:
        a = self.alpha
        for arr, experts in ((self.used_ema, used), (self.hit_ema, hit),
                             (self.miss_ema, missed),
                             (self.degraded_ema, degraded)):
            row = arr[layer]
            row *= (1.0 - a)
            if experts is not None and len(experts):
                # indicator EMA: each listed expert moves toward 1 this step
                row[np.unique(np.asarray(experts, np.int64))] += a
        self.steps[layer] += 1

    def summary(self, top_k: int = 5) -> dict:
        """Aggregates only — the full [L, E] arrays stay on the object for
        programmatic consumers (replication policies, set_coverage)."""
        flat_miss = self.miss_ema.ravel()
        order = np.argsort(-flat_miss)[:top_k]
        l_n = self.miss_ema.shape[1]
        return {
            "alpha": self.alpha,
            "steps": int(self.steps.max(initial=0)),
            "mean_used_ema": float(self.used_ema.mean()),
            "mean_miss_ema": float(self.miss_ema.mean()),
            "mean_degraded_ema": float(self.degraded_ema.mean()),
            "top_miss": [
                {"layer": int(i // l_n), "expert": int(i % l_n),
                 "miss_ema": float(flat_miss[i])}
                for i in order if flat_miss[i] > 0.0],
        }


# ---------------------------------------------------------------------------
# Miss-cost calibration
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _OutcomeCal:
    n: int = 0
    predicted_sum_s: float = 0.0
    realized_sum_s: float = 0.0
    abs_residual_sum_s: float = 0.0
    sq_residual_sum: float = 0.0
    max_abs_residual_s: float = 0.0
    quality_cost_sum: float = 0.0


class CalibrationMeter:
    """Predicted-vs-realized stall per miss-outcome class.

    The engine records, at the instant a miss outcome is chosen, the cost
    model's predicted stall-seconds for that outcome (the fetch ETA for
    fetch; the ICI-link ETA for peer-HBM borrows; 0 for the transfer-free
    buddy/degraded/drop outcomes) and the realized stall the timeline then
    actually charged. The per-class residual (realized - predicted) is the
    direct calibration signal for ``HardwareModel`` (fetch and peer
    classes) and — via the recorded quality-cost column — for the
    ``stall_per_quality`` exchange rate."""

    OUTCOMES = ("buddy", "degraded", "peer", "fetch", "drop")

    def __init__(self) -> None:
        self.by_outcome: Dict[str, _OutcomeCal] = {
            o: _OutcomeCal() for o in self.OUTCOMES}

    def record(self, outcome: str, predicted_s: float, realized_s: float,
               n: int = 1, quality_cost: float = 0.0) -> None:
        c = self.by_outcome[outcome]
        r = realized_s - predicted_s
        c.n += n
        c.predicted_sum_s += predicted_s * n
        c.realized_sum_s += realized_s * n
        c.abs_residual_sum_s += abs(r) * n
        c.sq_residual_sum += r * r * n
        c.max_abs_residual_s = max(c.max_abs_residual_s, abs(r))
        c.quality_cost_sum += quality_cost * n

    def merge(self, other: "CalibrationMeter") -> None:
        for o, c in other.by_outcome.items():
            mine = self.by_outcome[o]
            mine.n += c.n
            mine.predicted_sum_s += c.predicted_sum_s
            mine.realized_sum_s += c.realized_sum_s
            mine.abs_residual_sum_s += c.abs_residual_sum_s
            mine.sq_residual_sum += c.sq_residual_sum
            mine.max_abs_residual_s = max(mine.max_abs_residual_s,
                                          c.max_abs_residual_s)
            mine.quality_cost_sum += c.quality_cost_sum

    def summary(self) -> dict:
        out = {}
        for o, c in self.by_outcome.items():
            if c.n == 0:
                out[o] = {"n": 0}
                continue
            out[o] = {
                "n": c.n,
                "predicted_mean_s": c.predicted_sum_s / c.n,
                "realized_mean_s": c.realized_sum_s / c.n,
                "residual_mean_s": (c.realized_sum_s - c.predicted_sum_s)
                / c.n,
                "residual_abs_mean_s": c.abs_residual_sum_s / c.n,
                "residual_rms_s": math.sqrt(c.sq_residual_sum / c.n),
                "residual_max_abs_s": c.max_abs_residual_s,
                "quality_cost_mean": c.quality_cost_sum / c.n,
            }
        return out


# ---------------------------------------------------------------------------
# Prefetch precision / recall
# ---------------------------------------------------------------------------
class PrefetchMeter:
    """Per-predictor prefetch quality. Attached to the TransferScheduler as
    an event listener (prefetch-cause events only) plus two engine hooks:

      note_used(layer, experts)   — a landed prefetch's expert was actually
                                    routed to at its layer (true positive;
                                    counted once per landed transfer)
      note_uncovered_miss(l, e)   — a demand miss with nothing in flight
                                    (the predictor never covered it)

    precision = used / issued          (issued bytes that paid off)
    recall    = used / (used + late + uncovered)
                                       (needed experts delivered IN TIME —
                                        a late prefetch is a recall miss:
                                        the layer still stalled)
    ``expected_stall_saved_s`` accumulates the cost model's P(use) x
    miss-cost score of every issued prefetch (cost-ranked mode), directly
    comparable to the realized stall the ledger charges."""

    def __init__(self, label: str = "") -> None:
        self.label = label
        self.n_issued = 0
        self.n_cancelled = 0
        self.n_landed = 0
        self.n_used = 0
        self.n_late = 0
        self.n_uncovered_miss = 0
        self.expected_stall_saved_s = 0.0
        self._landed: set = set()
        self._late: set = set()

    # -- scheduler event path -------------------------------------------
    def on_transfer_event(self, kind: str, t) -> None:
        if t.cause != "prefetch":
            return
        key = (t.layer, t.expert)
        if kind == "submit":
            self.n_issued += 1
        elif kind == "cancel":
            self.n_cancelled += 1
        elif kind == "escalate":
            self.n_late += 1
            self._late.add(key)
        elif kind == "complete":
            self.n_landed += 1
            # an escalated prefetch that now lands was LATE — the layer
            # already stalled for its tail, so it must not also be credited
            # as a used-in-time true positive when its expert is routed to
            if key in self._late:
                self._late.discard(key)
            else:
                self._landed.add(key)

    # -- engine hooks ---------------------------------------------------
    def add_expected_saving(self, seconds: float) -> None:
        self.expected_stall_saved_s += float(seconds)

    def note_used(self, layer: int, experts) -> None:
        for e in experts:
            key = (layer, int(e))
            if key in self._landed:
                self._landed.discard(key)
                self.n_used += 1

    def note_uncovered_miss(self, layer: int, expert: int) -> None:
        self.n_uncovered_miss += 1

    # -- reporting ------------------------------------------------------
    def precision(self) -> float:
        return self.n_used / self.n_issued if self.n_issued else 0.0

    def recall(self) -> float:
        needed = self.n_used + self.n_late + self.n_uncovered_miss
        return self.n_used / needed if needed else 0.0

    def merge(self, other: "PrefetchMeter") -> None:
        for f in ("n_issued", "n_cancelled", "n_landed", "n_used", "n_late",
                  "n_uncovered_miss"):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        self.expected_stall_saved_s += other.expected_stall_saved_s

    def summary(self) -> dict:
        return {
            "predictor": self.label,
            "issued": self.n_issued, "cancelled": self.n_cancelled,
            "landed": self.n_landed, "used_in_time": self.n_used,
            "late": self.n_late, "uncovered_miss": self.n_uncovered_miss,
            "precision": self.precision(), "recall": self.recall(),
            "expected_stall_saved_s": self.expected_stall_saved_s,
        }


# ---------------------------------------------------------------------------
# The bundle the engine threads through
# ---------------------------------------------------------------------------
class Telemetry:
    """Everything the serving stack records when telemetry is ON.

    ``trace=None`` keeps the metrics/calibration layers without the event
    log (cheapest on-mode); ``Telemetry.with_trace()`` builds the full
    flight-recorder configuration. An engine holding ``telemetry=None``
    (the default) runs the exact pre-telemetry code path."""

    def __init__(self, *, trace: Optional[FlightRecorder] = None,
                 predictor_label: str = "",
                 num_layers: int = 0, num_experts: int = 0,
                 ema_alpha: float = 0.05) -> None:
        self.trace = trace
        self.metrics = MetricsRegistry()
        self.calibration = CalibrationMeter()
        self.prefetch = PrefetchMeter(predictor_label)
        self.expert_stats = (ExpertStats(num_layers, num_experts, ema_alpha)
                             if num_layers and num_experts else None)

    @classmethod
    def with_trace(cls, **kw) -> "Telemetry":
        return cls(trace=FlightRecorder(), **kw)

    def summary(self) -> dict:
        out = {
            "metrics": self.metrics.snapshot(),
            "calibration": self.calibration.summary(),
            "prefetch": self.prefetch.summary(),
        }
        if self.expert_stats is not None:
            out["expert_stats"] = self.expert_stats.summary()
        if self.trace is not None:
            out["trace_events"] = len(self.trace)
        return out


__all__: List[str] = [
    "Counter", "Gauge", "Histogram", "EMA", "MetricsRegistry",
    "ExpertStats", "CalibrationMeter", "PrefetchMeter", "Telemetry",
]
