"""Per-layer expert cache (the GPU-resident expert set).

Host-side structure: for each MoE layer a fixed number of slots
(capacity = cache_rate * E). Eviction policies: LRU, LFU, or a frequency
prior (EdgeMoE-style). Slots are assigned round-robin to mesh partitions so
the topology term hop(j) in Psi (Eq. 3) has real structure.

Residency states (driven by the transfer scheduler's timeline):

  resident   weights are on device and USABLE this step
  in-flight  a transfer was issued but has not arrived — the expert is NOT
             usable (the paper's late-prefetch case) and NOT evictable
  pinned     resident and in use by the layer currently computing — never
             chosen as an eviction victim mid-use
  locked     statically placed (an expert-parallel home shard) — resident
             forever, never an eviction victim. All-False until
             ``enable_mesh`` runs, so single-device behavior is unchanged.

Eviction is buddy-aware when a buddy table is attached: among the
policy-worst candidates, prefer evicting an expert that still has resident
buddies, so a future miss on it can be absorbed by substitution instead of a
synchronous PCIe fetch.

Multi-device (``enable_mesh(n_devices)``): experts are sharded
round-robin — ``owner[e] = e % D`` — and the cache becomes the view FROM
device 0, the simulated compute device. Device 0 seeds its own home shard
first (locked), spending leftover capacity on dynamic slots; each peer
device d >= 1 keeps its home shard statically resident in its own HBM
(``peer_resident[d]``), which is what the peer-borrow miss outcome reads.
Peer residency has the same per-device capacity/pin/evict discipline via
``peer_insert``/``peer_evict``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class ExpertCache:
    """Device-resident expert set: per-layer boolean residency over
    ``capacity = cache_rate * E`` slots, with LRU/LFU eviction, pin/lock
    protection, an in-flight mask driven by the transfer timeline, and
    (on a mesh) per-peer-device residency views. All masks are [L, E]
    bool arrays; sizes are slot counts, not bytes."""

    def __init__(self, num_layers: int, num_experts: int, cache_rate: float,
                 policy: str = "lru", num_partitions: int = 1, seed: int = 0,
                 buddy_table: Optional[np.ndarray] = None,
                 buddy_candidates: int = 4):
        assert policy in ("lru", "lfu")
        self.num_layers = num_layers
        self.num_experts = num_experts
        # clamp to [1, E]: cache_rate > 1 just means "everything fits" (the
        # unclamped capacity made rng.choice(E, capacity, replace=False) throw)
        self.capacity = min(num_experts,
                            max(1, int(round(cache_rate * num_experts))))
        self.policy = policy
        self.num_partitions = num_partitions
        self.resident = np.zeros((num_layers, num_experts), bool)
        self.inflight = np.zeros((num_layers, num_experts), bool)
        self.pinned = np.zeros((num_layers, num_experts), bool)
        self.locked = np.zeros((num_layers, num_experts), bool)
        # reclaim-first slots: replicas the placement controller installed
        # whose expert has gone cold — evicted BEFORE any normal victim
        # (all-False unless a PlacementController marks them, so the
        # pre-placement eviction order is unchanged)
        self.reclaimable = np.zeros((num_layers, num_experts), bool)
        self.n_devices = 1
        self.owner = None               # [E] home device, set by enable_mesh
        self.peer_resident = None       # [D, L, E] bool, set by enable_mesh
        self.peer_pinned = None         # [D, L, E] bool, set by enable_mesh
        self.last_used = np.zeros((num_layers, num_experts), np.int64)
        self.freq = np.zeros((num_layers, num_experts), np.float64)
        self.partition = np.zeros((num_layers, num_experts), np.int32)
        self.clock = 0
        # [L, E, R] buddy lists (-1 padded) for buddy-aware victim choice
        self.buddy_table = buddy_table
        self.buddy_candidates = buddy_candidates
        rng = np.random.default_rng(seed)
        for l in range(num_layers):
            init = rng.choice(num_experts, self.capacity, replace=False)
            self.resident[l, init] = True
            self._assign_partitions(l)

    def _assign_partitions(self, layer: int) -> None:
        slots = np.flatnonzero(self.resident[layer])
        for s_i, e in enumerate(slots):
            self.partition[layer, e] = s_i % self.num_partitions

    # -- queries --------------------------------------------------------
    def residency_mask(self) -> np.ndarray:
        """Usable experts only — in-flight transfers have NOT arrived."""
        return self.resident.copy()

    def hop_vector(self, layer: int, origin_partition: int = 0) -> np.ndarray:
        """ICI hops from origin to each expert's slot partition (0 if
        local). Non-resident experts are -1 — a sentinel, NOT zero hops:
        the old 0 made "absent" indistinguishable from "local", so any
        consumer that forgot to mask with ``resident`` silently priced
        missing experts as free. Eligible buddy candidates are always
        resident, so substitution masks the sentinel away; direct callers
        must treat negatives as "not on device"."""
        p = self.partition[layer]
        side = max(1, int(np.sqrt(self.num_partitions)))
        dx = np.abs(p % side - origin_partition % side)
        dy = np.abs(p // side - origin_partition // side)
        hops = (dx + dy).astype(np.int32)
        return np.where(self.resident[layer], hops, np.int32(-1))

    # -- expert-parallel mesh (view from device 0) ----------------------
    def enable_mesh(self, n_devices: int) -> None:
        """Shard experts round-robin across ``n_devices`` and re-seed this
        cache as device 0's HBM: its home shard first (locked — statically
        placed experts are never eviction victims), then as many of the
        previously-seeded dynamic slots as capacity still allows. Peers
        hold their own home shards (``peer_resident``). ``n_devices <= 1``
        is a no-op, keeping the single-device build bit-identical."""
        if n_devices <= 1:
            return
        d_n = int(n_devices)
        l_n, e_n = self.num_layers, self.num_experts
        self.n_devices = d_n
        self.owner = (np.arange(e_n) % d_n).astype(np.int32)
        home0 = self.owner == 0
        for l in range(l_n):
            prev = np.flatnonzero(self.resident[l] & ~home0)
            seeded = np.flatnonzero(home0)[:self.capacity]
            self.resident[l] = False
            self.resident[l, seeded] = True
            self.locked[l, seeded] = True
            room = self.capacity - len(seeded)
            if room > 0:
                self.resident[l, prev[:room]] = True
            self._assign_partitions(l)
        self.peer_resident = np.zeros((d_n, l_n, e_n), bool)
        self.peer_pinned = np.zeros((d_n, l_n, e_n), bool)
        for d in range(1, d_n):
            self.peer_resident[d] = (self.owner == d)[None, :]

    def peer_holders(self, layer: int, expert: int) -> np.ndarray:
        """Peer device ids whose HBM holds ``expert`` right now."""
        if self.peer_resident is None:
            return np.empty(0, np.int64)
        return np.flatnonzero(self.peer_resident[:, layer, expert])

    def peer_insert(self, device: int, layer: int, expert: int) -> int:
        """Replicate an expert into peer ``device``'s HBM, evicting its
        policy-worst unpinned non-home slot when over capacity. Returns the
        evicted expert id or -1."""
        assert self.peer_resident is not None and device >= 1
        row = self.peer_resident[device, layer]
        if row[expert]:
            return -1
        row[expert] = True
        evicted = -1
        if int(row.sum()) > self.capacity:
            home = self.owner == device
            cand = np.flatnonzero(row & ~home
                                  & ~self.peer_pinned[device, layer])
            cand = cand[cand != expert]
            if len(cand):
                evicted = int(self._policy_order(layer, cand)[0])
                row[evicted] = False
        return evicted

    def peer_evict(self, device: int, layer: int, expert: int) -> bool:
        """Drop a replica from a peer's HBM; home-shard experts (the
        mesh's statically-placed copies) and pinned replicas refuse."""
        assert self.peer_resident is not None and device >= 1
        if (self.owner[expert] == device
                or self.peer_pinned[device, layer, expert]
                or not self.peer_resident[device, layer, expert]):
            return False
        self.peer_resident[device, layer, expert] = False
        return True

    # -- updates --------------------------------------------------------
    def touch(self, layer: int, experts, weight: float = 1.0) -> None:
        """Record usage (for LRU clocks / LFU frequencies)."""
        experts = np.atleast_1d(np.asarray(experts, np.int64))
        self.clock += 1
        self.last_used[layer, experts] = self.clock
        self.freq[layer, experts] += weight

    # -- pinning (mid-use protection) -----------------------------------
    def pin(self, layer: int, experts) -> None:
        """Protect residents in use by the currently-computing layer from
        eviction; released by ``unpin`` after the layer's prefetches."""
        experts = np.atleast_1d(np.asarray(experts, np.int64))
        self.pinned[layer, experts] = True

    def unpin(self, layer: int, experts=None) -> None:
        """Release pins (``experts=None``: the whole layer)."""
        if experts is None:
            self.pinned[layer] = False
        else:
            experts = np.atleast_1d(np.asarray(experts, np.int64))
            self.pinned[layer, experts] = False

    # -- reclaim-first replicas (placement controller) -------------------
    def mark_reclaimable(self, layer: int, experts) -> None:
        """Flag cold placement replicas as preferred eviction victims:
        ``_pick_victim`` evicts any flagged candidate before consulting the
        normal LRU/LFU order (runtime/placement.py's hysteresis down-edge)."""
        experts = np.atleast_1d(np.asarray(experts, np.int64))
        self.reclaimable[layer, experts] = True

    def clear_reclaimable(self, layer: int, experts) -> None:
        """Unflag replicas (the expert heated back up, or was evicted)."""
        experts = np.atleast_1d(np.asarray(experts, np.int64))
        self.reclaimable[layer, experts] = False

    # -- in-flight lifecycle (scheduler-driven) -------------------------
    def begin_inflight(self, layer: int, expert: int) -> None:
        """A transfer was submitted: the expert is arriving but NOT usable
        (and not evictable) until ``commit_inflight``."""
        if not self.resident[layer, expert]:
            self.inflight[layer, expert] = True

    def cancel_inflight(self, layer: int, expert: int) -> None:
        """The transfer was cancelled before landing: clear the mark."""
        self.inflight[layer, expert] = False

    def commit_inflight(self, layer: int, expert: int) -> int:
        """Transfer arrived: the expert becomes resident (evicting per
        policy if needed). Returns the evicted expert id or -1."""
        self.inflight[layer, expert] = False
        return self.insert(layer, expert)

    def on_transfer_event(self, kind: str, t) -> None:
        """Listener hook for runtime.transfers.TransferScheduler."""
        if kind == "submit":
            self.begin_inflight(t.layer, t.expert)
        elif kind == "complete":
            self.commit_inflight(t.layer, t.expert)
        elif kind == "cancel":
            self.cancel_inflight(t.layer, t.expert)

    # -- eviction -------------------------------------------------------
    def _policy_order(self, layer: int, cand: np.ndarray) -> np.ndarray:
        """Candidates sorted worst-first under the eviction policy."""
        score = (self.last_used if self.policy == "lru" else self.freq)
        return cand[np.argsort(score[layer, cand], kind="stable")]

    def _pick_victim(self, layer: int, exclude: int) -> int:
        """Choose an eviction victim: never pinned, never the incoming
        expert; among the policy-worst few, prefer one whose buddies are
        resident (its future misses are absorbable). Returns -1 if every
        candidate is pinned (caller tolerates transient over-capacity).
        Locked slots — an expert-parallel home shard — are never victims.
        Cold placement replicas (``reclaimable``) go first: a replica whose
        expert stopped being hot is by construction the least valuable
        slot, so it is reclaimed before any normal victim."""
        cand = np.flatnonzero(self.resident[layer] & ~self.pinned[layer]
                              & ~self.locked[layer])
        cand = cand[cand != exclude]
        if len(cand) == 0:
            return -1
        recl = cand[self.reclaimable[layer, cand]]
        if len(recl):
            return int(self._policy_order(layer, recl)[0])
        ordered = self._policy_order(layer, cand)
        pool = ordered[:max(1, self.buddy_candidates)]
        if self.buddy_table is not None and len(pool) > 1:
            for e in pool:
                buddies = self.buddy_table[layer, e]
                buddies = buddies[buddies >= 0]
                if len(buddies) and self.resident[layer, buddies].any():
                    return int(e)
        return int(pool[0])

    def preview_victim(self, layer: int, incoming: int) -> int:
        """The expert ``insert(layer, incoming)`` would evict right now, or
        -1 when a free slot (or no evictable candidate) means nothing is
        displaced. Read-only — the placement controller uses it for
        replication admission control: copying a hot expert in is only
        worth it when what it pushes out is colder."""
        if int(self.resident[layer].sum()) < self.capacity:
            return -1
        return self._pick_victim(layer, exclude=incoming)

    def insert(self, layer: int, expert: int) -> int:
        """Insert an expert (post-fetch); evicts per policy if full.
        Returns the evicted expert id or -1."""
        if self.resident[layer, expert]:
            return -1
        evicted = -1
        n_res = int(self.resident[layer].sum())
        if n_res >= self.capacity:
            evicted = self._pick_victim(layer, exclude=expert)
            if evicted >= 0:
                self.resident[layer, evicted] = False
                self.reclaimable[layer, evicted] = False
        self.resident[layer, expert] = True
        self.reclaimable[layer, expert] = False
        if evicted >= 0:
            # reuse the vacated slot so partition topology stays stable
            self.partition[layer, expert] = self.partition[layer, evicted]
        else:
            self.partition[layer, expert] = n_res % self.num_partitions
        # trim any transient over-capacity left by fully-pinned layers
        while (int(self.resident[layer].sum()) > self.capacity):
            extra = self._pick_victim(layer, exclude=expert)
            if extra < 0:
                break
            self.resident[layer, extra] = False
            self.reclaimable[layer, extra] = False
        return evicted

    def prefetch_to(self, layer: int, experts) -> list:
        """Ensure ``experts`` resident; returns list of (inserted, evicted).

        Legacy instant-arrival path (no timeline). The serving engine now
        issues prefetches through the TransferScheduler instead, so arrival
        happens at the modeled PCIe completion time."""
        out = []
        for e in experts:
            e = int(e)
            if not self.resident[layer, e]:
                ev = self.insert(layer, e)
                out.append((e, ev))
        return out
