"""Per-layer expert cache (the GPU-resident expert set).

Host-side structure: for each MoE layer a fixed number of slots
(capacity = cache_rate * E). Eviction policies: LRU, LFU, or a frequency
prior (EdgeMoE-style). Slots are assigned round-robin to mesh partitions so
the topology term hop(j) in Psi (Eq. 3) has real structure.
"""
from __future__ import annotations

import numpy as np


class ExpertCache:
    def __init__(self, num_layers: int, num_experts: int, cache_rate: float,
                 policy: str = "lru", num_partitions: int = 1, seed: int = 0):
        assert policy in ("lru", "lfu")
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.capacity = max(1, int(round(cache_rate * num_experts)))
        self.policy = policy
        self.num_partitions = num_partitions
        self.resident = np.zeros((num_layers, num_experts), bool)
        self.last_used = np.zeros((num_layers, num_experts), np.int64)
        self.freq = np.zeros((num_layers, num_experts), np.float64)
        self.partition = np.zeros((num_layers, num_experts), np.int32)
        self.clock = 0
        rng = np.random.default_rng(seed)
        for l in range(num_layers):
            init = rng.choice(num_experts, self.capacity, replace=False)
            self.resident[l, init] = True
            self._assign_partitions(l)

    def _assign_partitions(self, layer: int) -> None:
        slots = np.flatnonzero(self.resident[layer])
        for s_i, e in enumerate(slots):
            self.partition[layer, e] = s_i % self.num_partitions

    # -- queries --------------------------------------------------------
    def residency_mask(self) -> np.ndarray:
        return self.resident.copy()

    def hop_vector(self, layer: int, origin_partition: int = 0) -> np.ndarray:
        """ICI hops from origin to each expert's slot partition (0 if local;
        non-resident experts get 0 — they are never eligible buddies)."""
        p = self.partition[layer]
        side = max(1, int(np.sqrt(self.num_partitions)))
        dx = np.abs(p % side - origin_partition % side)
        dy = np.abs(p // side - origin_partition // side)
        return ((dx + dy) * self.resident[layer]).astype(np.int32)

    # -- updates --------------------------------------------------------
    def touch(self, layer: int, experts, weight: float = 1.0) -> None:
        """Record usage (for LRU clocks / LFU frequencies)."""
        experts = np.atleast_1d(np.asarray(experts, np.int64))
        self.clock += 1
        self.last_used[layer, experts] = self.clock
        self.freq[layer, experts] += weight

    def insert(self, layer: int, expert: int) -> int:
        """Insert an expert (post-fetch); evicts per policy if full.
        Returns the evicted expert id or -1."""
        if self.resident[layer, expert]:
            return -1
        evicted = -1
        if self.resident[layer].sum() >= self.capacity:
            cand = np.flatnonzero(self.resident[layer])
            if self.policy == "lru":
                evicted = int(cand[np.argmin(self.last_used[layer, cand])])
            else:
                evicted = int(cand[np.argmin(self.freq[layer, cand])])
            self.resident[layer, evicted] = False
        self.resident[layer, expert] = True
        self.partition[layer, expert] = (
            int(self.resident[layer].sum()) % self.num_partitions)
        return evicted

    def prefetch_to(self, layer: int, experts) -> list:
        """Ensure ``experts`` resident; returns list of (inserted, evicted)."""
        out = []
        for e in experts:
            e = int(e)
            if not self.resident[layer, e]:
                ev = self.insert(layer, e)
                out.append((e, ev))
        return out
