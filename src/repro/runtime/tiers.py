"""Tiered expert store — compressed resident replicas as a third
prefetch-failure fallback.

On a prefetch miss the runtime previously had exactly two outcomes: buddy
substitution (accuracy cost, core/substitute.py) or demand fetch / drop
(latency or accuracy cliff, runtime/transfers.py). Following MoBiLE
(big-little experts) and MELINOE (compressed memory-efficient experts), this
module adds a third regime: split the per-layer HBM expert budget between

  full tier   cache slots holding full-precision experts (runtime/cache.py,
              the existing ExpertCache — fetch/evict over PCIe), and
  quant tier  an ALWAYS-RESIDENT int8/int4 per-channel-quantized replica of
              every one of the L x E experts (core/quantize.py numerics),

so a miss whose buddy search fails can be computed immediately at degraded
fidelity instead of stalling the layer or dropping the expert. The miss
decision tree becomes four-way: buddy / degraded / fetch / drop.

Degrade-vs-wait is scored per (layer, expert) each step: the expected stall
(the transfer timeline's in-flight ETA for a late prefetch, the full modeled
transfer time for a cold miss) is traded against the replica's calibrated
fidelity loss via ``stall_per_fidelity`` — the seconds of stall that justify
one unit of relative round-trip weight error. A nearly-landed prefetch is
waited for (tail < threshold); a cold miss degrades.

Budget semantics: at EQUAL total HBM budget (``cache_rate`` x E full-precision
experts per layer), the quant tier displaces full cache slots —
slots = floor((budget - n_covered * replica_bytes) / expert_bytes). When the
tier alone exceeds the budget (int8 at cache_rate 0.5 with scale overhead),
one mandatory full slot is kept and the split is reported as clamped.

Partial coverage (``coverage`` < 1.0): replicate only the top-P(use)
``ceil(coverage * E)`` experts per layer and spend the freed bytes on full
cache slots — the knee of the accuracy-vs-stall frontier sits where the
replicas cover the hot tail that the cache misses, not the cold experts the
router never picks. Which experts are covered defaults to the lowest ids
(deterministic) until ``set_coverage`` re-picks the top experts per layer
from activity statistics (profiling recorder counts or predictor
frequencies). Uncovered experts report infinite fidelity — the cost model
(runtime/costs.py) and the precedence quant_ok mask both then exclude them.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.quantize import TIER_BITS  # noqa: F401  (re-export: the
#   tier-name -> bits mapping has ONE source of truth in core/quantize.py)
from repro.runtime.cache import ExpertCache
from repro.runtime.memory import expert_nbytes, quant_expert_nbytes


class TieredExpertStore:
    """Two-tier expert storage at one fixed HBM budget.

    The budget ``cache_rate * E * full_bytes`` per layer is split between
    a FULL tier — an ``ExpertCache`` of ``cache_slots`` full-precision
    experts, fetched/evicted over the transfer timeline — and a QUANT
    tier: always-resident int8/int4 per-channel-quantized replicas of the
    ``n_covered`` covered experts per layer (``slots = ⌊(budget −
    n_covered·replica_bytes)/full_bytes⌋``, clamped to ≥ 1 slot). A miss
    on a covered expert can be computed immediately against its replica —
    zero transfer, zero stall — at a calibrated per-expert fidelity cost
    (``fidelity``; uncovered experts report ``inf`` so no policy ever
    degrades them).

    ``covered`` starts as the lowest expert ids; ``set_coverage(activity)``
    re-points it at the per-layer top-``n_covered`` by any activity
    ranking — the profiling draw at startup, or live traffic EMAs when a
    ``PlacementController`` drives it. Note the self-inhibition this
    store creates: a covered miss is absorbed by the replica, so nothing
    ever promotes that expert into a full-precision slot — repairing that
    (replication, degraded-then-upgrade) is the caller's job.

    ``quant_ok(...)`` is the per-step degrade decision (expected stall
    saved vs ``stall_per_fidelity`` × fidelity lost); ``degraded_tokens``
    counts slots actually served degraded; ``summary()`` reports the
    budget split, coverage, and counters for
    ``ServeEngine.summary()["tier"]``."""

    def __init__(self, num_layers: int, num_experts: int, cache_rate: float,
                 *, bits: int = 8, d_model: int, d_ff: int,
                 dtype_bytes: int = 2, stall_per_fidelity: float = 0.05,
                 coverage: float = 1.0,
                 policy: str = "lru", num_partitions: int = 1, seed: int = 0,
                 buddy_table: Optional[np.ndarray] = None,
                 buddy_candidates: int = 4):
        assert bits in (4, 8)
        assert 0.0 < coverage <= 1.0, "coverage: fraction of experts " \
            "replicated per layer (top-P(use) once set_coverage is called)"
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.bits = bits
        self.stall_per_fidelity = float(stall_per_fidelity)
        self.coverage = float(coverage)
        self.n_covered = max(1, min(num_experts,
                                    int(np.ceil(coverage * num_experts))))
        self.full_bytes = expert_nbytes(d_model, d_ff, dtype_bytes)
        self.replica_bytes = quant_expert_nbytes(d_model, d_ff, bits)

        # -- budget split (per layer, equal total HBM budget) ------------
        # partial coverage replicates only n_covered experts; the freed
        # replica bytes become additional full-precision cache slots
        budget = cache_rate * num_experts * self.full_bytes
        slots = int((budget - self.n_covered * self.replica_bytes)
                    // self.full_bytes)
        self.clamped = slots < 1
        slots = max(1, min(num_experts, slots))
        self.cache_slots = slots
        self.budget_bytes = int(round(budget))
        self.quant_bytes = num_layers * self.n_covered * self.replica_bytes
        # which experts hold a replica: lowest ids until set_coverage picks
        # the top-activity set per layer (budget depends only on the COUNT)
        self.covered = np.zeros((num_layers, num_experts), bool)
        self.covered[:, :self.n_covered] = True

        self.cache = ExpertCache(num_layers, num_experts,
                                 slots / num_experts, policy=policy,
                                 num_partitions=num_partitions, seed=seed,
                                 buddy_table=buddy_table,
                                 buddy_candidates=buddy_candidates)
        # calibrated per-expert relative round-trip error; inf until the
        # engine attaches real scores = "never degrade" (conservative)
        self.fidelity = np.full((num_layers, num_experts), np.inf)
        self.degraded_tokens = 0
        # optional runtime.telemetry.Telemetry bundle: note_degraded ticks
        # a counter and set_coverage stamps a trace instant when attached;
        # None (the default) leaves every path bit-identical
        self.telemetry = None

    # -- calibration ----------------------------------------------------
    def attach_fidelity(self, fidelity: np.ndarray) -> None:
        fidelity = np.asarray(fidelity, np.float64)
        assert fidelity.shape == (self.num_layers, self.num_experts), \
            f"fidelity shape {fidelity.shape} != (L, E)"
        self.fidelity = fidelity

    def set_coverage(self, activity: np.ndarray) -> None:
        """Re-pick the covered set: the top-``n_covered`` experts per layer
        by ``activity`` [L, E] (P(use) proxy — profiling counts, predictor
        frequencies). The budget split is unchanged (it depends only on the
        count); only WHICH experts may serve degraded moves."""
        activity = np.asarray(activity, np.float64)
        assert activity.shape == (self.num_layers, self.num_experts)
        self.covered[:] = False
        top = np.argsort(-activity, axis=1)[:, :self.n_covered]
        np.put_along_axis(self.covered, top, True, axis=1)
        if self.telemetry is not None:
            self.telemetry.metrics.counter("tier_coverage_repicks").inc()

    def effective_fidelity(self, layer: Optional[int] = None) -> np.ndarray:
        """Fidelity with uncovered experts masked to inf — the form the
        cost model consumes (inf = no usable replica). [L, E], or one
        layer's [E] row when ``layer`` is given (the per-layer prefetch
        ranking must not rebuild the full matrix each call). This method is
        the single owner of the uncovered-masking rule."""
        if layer is None:
            return np.where(self.covered, self.fidelity, np.inf)
        return np.where(self.covered[layer], self.fidelity[layer], np.inf)

    # -- the degrade-vs-wait decision -----------------------------------
    def degraded_ok(self, resident: np.ndarray,
                    eta_s: np.ndarray) -> np.ndarray:
        """[L, E] bool: misses worth serving from the quant tier this step.

        resident [L, E]: the cache's usable mask (residents never degrade);
        eta_s [L, E]: expected stall of fetching each expert instead — the
        in-flight transfer's optimistic ETA (TransferScheduler.eta_s) or the
        full modeled transfer time for a cold miss. Degrade iff the stall
        saved buys the fidelity loss: eta >= fidelity * stall_per_fidelity."""
        resident = np.asarray(resident, bool)
        eta_s = np.asarray(eta_s, np.float64)
        assert eta_s.shape == resident.shape == self.fidelity.shape
        fid = self.effective_fidelity()
        worth = np.isfinite(fid) & (eta_s >= fid * self.stall_per_fidelity)
        return ~resident & worth

    # -- accounting ------------------------------------------------------
    def note_degraded(self, n_slots: int) -> None:
        self.degraded_tokens += int(n_slots)
        if self.telemetry is not None:
            self.telemetry.metrics.counter("tier_degraded_slots").inc(
                int(n_slots))

    def reset_counters(self) -> None:
        self.degraded_tokens = 0

    def budget_split(self) -> dict:
        """Where the per-layer HBM expert budget went."""
        cache_bytes = self.cache_slots * self.full_bytes
        tier_bytes = self.n_covered * self.replica_bytes
        return {
            "budget_bytes_per_layer": self.budget_bytes,
            "quant_bytes_per_layer": tier_bytes,
            "cache_bytes_per_layer": cache_bytes,
            "cache_slots_per_layer": self.cache_slots,
            "quant_frac": tier_bytes / max(1, self.budget_bytes),
            "coverage": self.coverage,
            "covered_per_layer": self.n_covered,
            "clamped": bool(self.clamped),
        }

    def summary(self) -> dict:
        return {
            "bits": self.bits,
            "stall_per_fidelity": self.stall_per_fidelity,
            "degraded_tokens": self.degraded_tokens,
            "quant_bytes": self.quant_bytes,
            "tier_budget_split": self.budget_split(),
            "mean_fidelity_loss": float(np.mean(
                self.fidelity[np.isfinite(self.fidelity)]))
            if np.isfinite(self.fidelity).any() else None,
        }
