"""Zamba2-7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    source="Zamba2 [arXiv:2411.15242]",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,       # GQA kv=32 (MHA-style shared blocks)
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, num_heads=56, head_dim=128, expand=2, conv_dim=4),
    attn_every=6,          # one shared attention block per 6 mamba2 layers
)


def reduced() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2-reduced",
        family="hybrid",
        source=CONFIG.source,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        ssm=SSMConfig(state_dim=32, num_heads=8, head_dim=64, expand=2, conv_dim=4),
        attn_every=2,
    )
