"""Model / shape configuration system.

Every assigned architecture provides a module in ``repro.configs`` exposing
``CONFIG`` (the exact full-scale config from its source paper/model card) and
``reduced()`` (a tiny same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds used by the layer-stack compiler in models/transformer.py.
# A stack is a list of "groups"; each group is (block_kind, repeat) and is
# executed with one lax.scan over stacked params.
# ---------------------------------------------------------------------------
ATTN_DENSE = "attn_dense"      # self-attn + dense SwiGLU FFN
ATTN_MOE = "attn_moe"          # self-attn + MoE FFN
CROSS_DENSE = "cross_dense"    # cross-attn + dense FFN (VLM image layers)
RWKV = "rwkv6"                 # RWKV6 time-mix + channel-mix
MAMBA2 = "mamba2"              # Mamba2 (SSD) block
SHARED_ATTN = "shared_attn"    # zamba2 shared attention block (tied params)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden size
    num_shared_experts: int = 0     # always-on shared experts (DeepSeek-style)
    router_jitter: float = 0.0
    # Sparse-upcycling init (Komatsuzaki et al.; the provenance of most
    # production MoEs the paper targets): every expert starts as a shared
    # base FFN + upcycle_noise * perturbation. This is what creates the
    # functional redundancy BuddyMoE exploits (paper Fig. 4) — experts
    # trained from independent inits are near-orthogonal and substitution
    # (buddy OR random) cannot work. 0.0 = independent init.
    upcycle_noise: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    # Shared by rwkv6/mamba2 families; interpretation depends on block kind.
    state_dim: int = 64             # per-head state size N
    num_heads: int = 32
    head_dim: int = 64
    conv_dim: int = 4               # mamba2 depthwise-conv width
    expand: int = 2                 # mamba2 inner expansion


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    source: str                     # citation
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # layer-pattern knobs
    attn_every: int = 0             # hybrid: 1 shared-attn block per N ssm blocks
    cross_attn_every: int = 0       # vlm: 1 cross-attn block per N self-attn blocks
    sliding_window: int = 0         # 0 = full attention (native arch value)
    # frontend stubs (audio/vlm): number of conditioning embeddings
    num_cond_tokens: int = 0
    cond_dim: int = 0
    # misc
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "float32"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def supports_long_context(self) -> bool:
        """True if decode at 500k is sub-quadratic (SSM state or SWA cache)."""
        return self.family in ("ssm", "hybrid") or True  # all archs get SWA fallback

    def param_count(self) -> int:
        """Approximate total parameter count (embeddings included)."""
        d, v = self.d_model, self.vocab_size
        n = v * d  # embed
        if not self.tie_embeddings:
            n += v * d
        hd = self.head_dim

        def attn_block():
            return d * (self.num_heads * hd) \
                + 2 * d * (self.num_kv_heads * hd) \
                + (self.num_heads * hd) * d + 3 * d * self.d_ff + 2 * d

        def mamba_block():
            s = self.ssm or SSMConfig()
            inner = s.expand * d
            return d * 2 * inner + inner * s.state_dim * 2 + inner * d + 2 * d

        for kind, repeat in self.stack():
            if kind in (ATTN_DENSE, CROSS_DENSE, SHARED_ATTN):
                n += repeat * attn_block()
            elif kind == ATTN_MOE:
                assert self.moe is not None
                e = self.moe
                attn = d * (self.num_heads * hd) \
                    + 2 * d * (self.num_kv_heads * hd) \
                    + (self.num_heads * hd) * d
                ffn = e.num_experts * 3 * d * e.d_ff + d * e.num_experts
                ffn += e.num_shared_experts * 3 * d * e.d_ff
                n += repeat * (attn + ffn + 2 * d)
            elif kind == RWKV:
                s = self.ssm or SSMConfig()
                dh = s.num_heads * s.head_dim
                n += repeat * (5 * d * dh + dh * d + 3 * d * self.d_ff + 2 * d)
            elif kind == MAMBA2:
                n += repeat * mamba_block()
            elif kind == "hybrid_super":
                # attn_every mamba blocks per super; ONE shared attn block
                # overall (tied params — added once below)
                n += repeat * self.attn_every * mamba_block()
            elif kind == "vlm_super":
                n += repeat * self.cross_attn_every * attn_block()
        if self.family == "hybrid":
            n += attn_block()
        return n

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        e = self.moe
        full_moe = e.num_experts * 3 * self.d_model * e.d_ff
        act_moe = (e.top_k + e.num_shared_experts) * 3 * self.d_model * e.d_ff
        n_moe_layers = sum(r for k, r in self.stack() if k == ATTN_MOE)
        return self.param_count() - n_moe_layers * (full_moe - act_moe) \
            + n_moe_layers * e.num_shared_experts * 0

    def stack(self) -> Tuple[Tuple[str, int], ...]:
        """Layer-group structure: ((block_kind, repeat), ...)."""
        if self.family == "ssm":
            return ((RWKV, self.num_layers),)
        if self.family == "hybrid":
            # zamba2: mamba2 backbone with a shared attention block applied
            # every `attn_every` layers. Modeled as scan over super-blocks.
            assert self.attn_every > 0
            n_super = self.num_layers // self.attn_every
            rem = self.num_layers - n_super * self.attn_every
            groups = [("hybrid_super", n_super)]
            if rem:
                groups.append((MAMBA2, rem))
            return tuple(groups)
        if self.family == "vlm":
            assert self.cross_attn_every > 0
            n_super = self.num_layers // self.cross_attn_every
            rem = self.num_layers - n_super * self.cross_attn_every
            groups = [("vlm_super", n_super)]
            if rem:
                groups.append((ATTN_DENSE, rem))
            return tuple(groups)
        if self.is_moe:
            return ((ATTN_MOE, self.num_layers),)
        return ((ATTN_DENSE, self.num_layers),)


# ---------------------------------------------------------------------------
# Input shapes (assigned).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "rwkv6-1.6b",
    "zamba2-7b",
    "internlm2-1.8b",
    "mixtral-8x7b",
    "smollm-360m",
    "musicgen-large",
    "mixtral-8x22b",
    "llama-3.2-vision-11b",
    "internlm2-20b",
    "phi3-medium-14b",
    # the paper's own evaluation model family
    "deepseek-v2-lite-buddy",
]

_MODULES = {
    "rwkv6-1.6b": "rwkv6_1p6b",
    "zamba2-7b": "zamba2_7b",
    "internlm2-1.8b": "internlm2_1p8b",
    "mixtral-8x7b": "mixtral_8x7b",
    "smollm-360m": "smollm_360m",
    "musicgen-large": "musicgen_large",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "internlm2-20b": "internlm2_20b",
    "phi3-medium-14b": "phi3_medium_14b",
    "deepseek-v2-lite-buddy": "deepseek_v2_lite_buddy",
}


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_reduced(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.reduced()
