"""Mixtral-8x22B — MoE 8 experts top-2, GQA, SWA [arXiv:2401.04088]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x22b",
    family="moe",
    source="Mixtral of Experts [arXiv:2401.04088]",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=16384),
    sliding_window=4096,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        arch_id="mixtral22-reduced",
        family="moe",
        source=CONFIG.source,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=512),
        sliding_window=128,
    )
