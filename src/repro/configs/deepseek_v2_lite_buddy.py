"""DeepSeek-V2-Lite family — the paper's own evaluation model.

BuddyMoE (§5.1) evaluates DeepSeek-V2-Lite configured with 64 experts per MoE
layer and top-6 gating. We reproduce that routing regime: 64 experts, top-6,
with DeepSeek-style shared experts. Full config mirrors DeepSeek-V2-Lite
(27 layers, d_model 2048); reduced() is the CPU-trainable variant used by the
accuracy benchmarks (Tables 2-4).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-lite-buddy",
    family="moe",
    source="DeepSeek-V2-Lite (BuddyMoE eval model, top-6/64)",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,
    vocab_size=102400,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff=1408, num_shared_experts=2),
)


def reduced() -> ModelConfig:
    """~20M-param trainable variant keeping the 64-expert/top-6 routing."""
    return ModelConfig(
        arch_id="deepseek-lite-reduced",
        family="moe",
        source=CONFIG.source,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=128, num_shared_experts=1),
    )


def profiling() -> ModelConfig:
    """Mid-size variant with the paper's full 64-expert top-6 routing, small
    enough to train briefly on CPU for co-activation profiling experiments.

    Upcycled expert init (MoEConfig.upcycle_noise): production MoEs are
    sparse-upcycled from dense checkpoints, which is what gives them the
    functional redundancy BuddyMoE exploits (Fig. 4). Trained-from-scratch
    experts at this scale are near-orthogonal and provide NO redundancy to
    exploit — see EXPERIMENTS.md §Redundancy-ablation."""
    return ModelConfig(
        arch_id="deepseek-lite-prof",
        family="moe",
        source=CONFIG.source,
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        moe=MoEConfig(num_experts=64, top_k=6, d_ff=64, num_shared_experts=2,
                      upcycle_noise=0.25),
    )
