"""RWKV6 "Finch" 1.6B — attn-free, data-dependent decay [arXiv:2404.05892]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-1.6b",
    family="ssm",
    source="Finch: RWKV-6 [arXiv:2404.05892]",
    num_layers=24,
    d_model=2048,
    num_heads=32,          # wkv heads (d_model / 64)
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    ssm=SSMConfig(state_dim=64, num_heads=32, head_dim=64),
)


def reduced() -> ModelConfig:
    return ModelConfig(
        arch_id="rwkv6-reduced",
        family="ssm",
        source=CONFIG.source,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        ssm=SSMConfig(state_dim=64, num_heads=4, head_dim=64),
    )
