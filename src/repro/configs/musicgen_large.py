"""MusicGen-Large — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec conv/codec frontend is STUBBED: input_specs provides precomputed
frame embeddings (num_cond_tokens x cond_dim) consumed via additive prefix.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-large",
    family="audio",
    source="MusicGen [arXiv:2306.05284]",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    num_cond_tokens=256,   # stubbed text/melody conditioning prefix
    cond_dim=2048,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        arch_id="musicgen-reduced",
        family="audio",
        source=CONFIG.source,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        num_cond_tokens=16,
        cond_dim=256,
    )
