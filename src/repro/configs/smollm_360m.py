"""SmolLM-360M — llama-arch small [hf:HuggingFaceTB/SmolLM-135M family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-360m",
    family="dense",
    source="SmolLM [hf:HuggingFaceTB/SmolLM-135M]",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        arch_id="smollm-reduced",
        family="dense",
        source=CONFIG.source,
        num_layers=2,
        d_model=192,
        num_heads=3,
        num_kv_heads=1,
        d_ff=512,
        vocab_size=512,
        tie_embeddings=True,
    )
