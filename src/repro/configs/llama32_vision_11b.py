"""Llama-3.2-Vision-11B backbone — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

The ViT vision encoder + projector are STUBBED: input_specs provides
precomputed patch embeddings (num_cond_tokens x cond_dim) consumed by the
cross-attention layers.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-11b",
    family="vlm",
    source="Llama-3.2-Vision [hf:meta-llama/Llama-3.2-11B-Vision]",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_every=5,    # 8 cross-attn layers interleaved in 40
    num_cond_tokens=1601,  # 1 image: (448/14)^2 + cls
    cond_dim=4096,
    rope_theta=500000.0,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        arch_id="llama-vision-reduced",
        family="vlm",
        source=CONFIG.source,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        cross_attn_every=2,
        num_cond_tokens=16,
        cond_dim=256,
    )
