"""InternLM2-20B — dense, GQA [arXiv:2403.17297]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internlm2-20b",
    family="dense",
    source="InternLM2 [arXiv:2403.17297]",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        arch_id="internlm2-20b-reduced",
        family="dense",
        source=CONFIG.source,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
    )
