"""InternLM2-1.8B — dense, GQA [arXiv:2403.17297]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internlm2-1.8b",
    family="dense",
    source="InternLM2 [arXiv:2403.17297]",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        arch_id="internlm2-reduced",
        family="dense",
        source=CONFIG.source,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
    )
