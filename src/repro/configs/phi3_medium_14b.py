"""Phi-3-Medium-14B — dense, RoPE SwiGLU GQA [arXiv:2404.14219]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3-medium-14b",
    family="dense",
    source="Phi-3 [arXiv:2404.14219]",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        arch_id="phi3-reduced",
        family="dense",
        source=CONFIG.source,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
    )
