"""repro — BuddyMoE (expert-redundancy substitution for memory-constrained
MoE inference) reproduced as a multi-pod JAX/Pallas framework."""
__version__ = "1.0.0"
