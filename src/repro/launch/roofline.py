"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), per the assignment spec:

  compute term    = HLO_FLOPs   / (chips * peak_FLOP/s)
  memory term     = HLO_bytes   / (chips * HBM_bw)
  collective term = coll_bytes  / (chips * link_bw)

Sources: ``compiled.cost_analysis()`` (NOTE: after SPMD partitioning this is
the PER-DEVICE module, so flops/bytes are per-chip; we multiply by `chips`
to get the global HLO_FLOPs the formulas expect) and the post-partitioning
HLO text for collective bytes.

Collective byte conventions (ring algorithms, n = group size):
  all-gather        (n-1)/n * result_bytes      (received bytes)
  reduce-scatter    (n-1)/n * operand_bytes
  all-reduce        2(n-1)/n * operand_bytes    (RS + AG)
  all-to-all        (n-1)/n * operand_bytes
  collective-permute  operand_bytes
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

# TPU v5e-class hardware constants (assignment-specified)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = (.*?) (all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute)(?:-start)?\(", re.M)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> dict:
    """Per-device collective bytes by op type (ring conventions above)."""
    out = defaultdict(float)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        result_bytes = _shape_bytes(result_type)
        n = max(_group_size(line, n_devices), 1)
        ring = (n - 1) / n
        if op == "all-gather":
            moved = ring * result_bytes
        elif op == "reduce-scatter":
            moved = ring * result_bytes * n          # operand = result * n
        elif op == "all-reduce":
            moved = 2 * ring * result_bytes          # operand == result
        elif op == "all-to-all":
            moved = ring * result_bytes              # operand == result
        else:  # collective-permute
            moved = result_bytes
        out[op] += moved
        counts[op] += 1
    return {"bytes_by_op": dict(out), "counts": dict(counts),
            "total_bytes_per_device": sum(out.values())}


# ---------------------------------------------------------------------------
# Loop-aware HLO analyzer
#
# ``compiled.cost_analysis()`` on the CPU backend counts each while-loop body
# ONCE, so scan-over-layers programs under-count FLOPs/bytes by ~num_layers.
# XLA annotates optimized while ops with backend_config known_trip_count; we
# parse the HLO text, propagate trip-count multipliers through the call graph
# (while bodies, fusions, calls), and count dot FLOPs / collective bytes /
# HBM traffic per computation x multiplier.
# ---------------------------------------------------------------------------
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+) = (\(.*?\)|\S+)\s+"
                    r"([\w\-]+)\((.*?)\)", )
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLEE_RES = [re.compile(p) for p in
               (r"body=%?([\w.\-]+)", r"condition=%?([\w.\-]+)",
                r"calls=%?([\w.\-]+)", r"to_apply=%?([\w.\-]+)",
                r"branch_computations=\{([^}]*)\}")]
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "iota", "after-all", "partition-id", "replica-id",
                 # control flow: carried buffers alias through the loop
                 "while", "conditional", "call", "optimization-barrier"}


def _first_shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, 1
    dt, dims = m.group(1), m.group(2)
    d = [int(x) for x in dims.split(",")] if dims else []
    n = 1
    for x in d:
        n *= x
    return d, n


def parse_hlo_module(text: str) -> dict:
    """Split into computations; return {comp: [line, ...]} plus ENTRY name."""
    comps, cur, entry = {}, None, None
    for line in text.splitlines():
        if line.startswith("ENTRY") or (line and not line[0].isspace()
                                        and "{" in line and " = " not in
                                        line.split("{")[0]):
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)", line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None and line.strip():
            comps[cur].append(line)
    return {"comps": comps, "entry": entry}


def analyze_hlo(text: str, n_devices: int) -> dict:
    """Loop-aware per-device FLOPs, HBM traffic and collective bytes."""
    mod = parse_hlo_module(text)
    comps, entry = mod["comps"], mod["entry"]
    if entry is None:
        return {"flops": 0.0, "traffic_bytes": 0.0,
                "coll_bytes_by_op": {}, "coll_counts": {},
                "coll_bytes": 0.0, "loops": []}

    # op name -> (result type, opcode, first operand) for byte lookup and
    # convert/copy chain resolution. XLA-CPU has no native bf16: it inserts
    # convert-to-f32 around every dot, doubling apparent bytes. On the TPU
    # target those converts do not exist, so we resolve operands through
    # convert/copy chains to the source tensor's true width.
    shapes = {}
    op_info = {}
    for lines in comps.values():
        for line in lines:
            m = _OP_RE.match(line)
            if m:
                name, rtype, opcode, operands = m.groups()
                shapes[name] = rtype
                first = _OPERAND_RE.match(operands.strip())
                op_info[name] = (opcode, first.group(1) if first else None)

    def _resolve_bytes(name: str) -> int:
        """Bytes of `name`, looking through convert/copy/bitcast chains."""
        for _ in range(4):
            info = op_info.get(name)
            if info is None or info[0] not in ("convert", "copy", "bitcast"):
                break
            if info[1] is None:
                break
            name = info[1]
        return _shape_bytes(shapes.get(name, ""))

    # Per-fused-computation: parameters consumed ONLY via dynamic-slice
    # (possibly through bitcast/convert/copy/reshape chains) read a slice
    # per call, not the full tensor — e.g. a layer scan slicing this layer's
    # weights from the stacked [L, ...] buffer. param_access[comp][i] =
    # sliced bytes per call.
    _PASSTHROUGH = ("bitcast", "convert", "copy", "reshape")
    param_access = {}
    pnum_re = re.compile(r"parameter\((\d+)\)")
    for comp, lines in comps.items():
        local = {}         # op name -> (opcode, [operand names], rtype)
        param_of = {}      # op name -> parameter index
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            name, rtype, opcode, operands = m.groups()
            local[name] = (opcode, _OPERAND_RE.findall(operands), rtype)
            if opcode == "parameter":
                pm = pnum_re.search(line)
                if pm:
                    param_of[name] = int(pm.group(1))
        consumers = defaultdict(list)
        for name, (opcode, refs, rtype) in local.items():
            for i, r in enumerate(refs):
                consumers[r].append((name, i))

        def access_bytes(op_name, depth=0):
            """(sliced_bytes, is_full) walking consumer chains."""
            total, full = 0, False
            for cname, pos in consumers.get(op_name, []):
                copcode, _, crtype = local[cname]
                if copcode == "dynamic-slice" and pos == 0:
                    total += _shape_bytes(crtype)
                elif copcode == "dynamic-update-slice" and pos == 0:
                    pass   # buffer aliases in place
                elif copcode in _PASSTHROUGH and depth < 6:
                    t, f = access_bytes(cname, depth + 1)
                    total += t
                    full = full or f
                else:
                    full = True
            return total, full

        acc = {}
        for pname, pi in param_of.items():
            t, f = access_bytes(pname)
            if not f and t > 0:
                acc[pi] = t
        param_access[comp] = acc

    _FUSION_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")

    # fused computations that only change dtype/layout (parameter + convert/
    # bitcast/copy/reshape) — CPU-backend artifacts, skipped like converts
    pure_convert_comps = set()
    for comp, lines in comps.items():
        ok, n_ops = True, 0
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            if m.group(3) == "parameter":
                continue
            n_ops += 1
            if m.group(3) not in ("convert", "bitcast", "copy", "reshape",
                                  "transpose"):
                ok = False
                break
        if ok and n_ops:
            pure_convert_comps.add(comp)

    # multiplier propagation (iterative DFS over call edges)
    mult = defaultdict(float)
    traffic_comp = set()
    loops = []

    def visit(comp: str, m: float, count_traffic: bool):
        mult[comp] += m
        if count_traffic:
            traffic_comp.add(comp)
        for line in comps.get(comp, []):
            om = _OP_RE.match(line)
            trip = 1.0
            if om and om.group(3) == "while":
                t = _TRIP_RE.search(line)
                if t:
                    trip = float(t.group(1))
                    loops.append({"comp": comp, "trip": int(trip)})
            for cre in _CALLEE_RES:
                cm = cre.search(line)
                if not cm:
                    continue
                names = [n.strip().lstrip("%") for n in
                         cm.group(1).split(",")]
                for name in names:
                    if name in comps:
                        child_m = m * (trip if "body=" in cre.pattern or
                                       "condition=" in cre.pattern else 1.0)
                        # fusion interiors don't touch HBM
                        child_traffic = count_traffic and "calls=" not in \
                            cre.pattern and "to_apply=" not in cre.pattern
                        visit(name, child_m, child_traffic)

    visit(entry, 1.0, True)

    flops = 0.0
    traffic = 0.0
    coll = defaultdict(float)
    counts = defaultdict(int)
    for comp, lines in comps.items():
        m = mult.get(comp, 0.0)
        if m <= 0:
            continue
        in_traffic = comp in traffic_comp
        for line in lines:
            om = _OP_RE.match(line)
            if not om:
                continue
            name, rtype, opcode, operands = om.groups()
            # --- dot FLOPs ---
            if opcode == "dot":
                rdims, rn = _first_shape_dims(rtype)
                cdim_m = _CONTRACT_RE.search(line)
                csize = 1
                ops = _OPERAND_RE.findall(operands)
                if cdim_m and ops:
                    lhs_dims, _ = _first_shape_dims(shapes.get(ops[0], ""))
                    if lhs_dims is not None and cdim_m.group(1):
                        for ci in cdim_m.group(1).split(","):
                            ci = int(ci)
                            if ci < len(lhs_dims):
                                csize *= lhs_dims[ci]
                flops += m * 2.0 * rn * csize
            if opcode in ("convolution",):
                rdims, rn = _first_shape_dims(rtype)
                flops += m * 2.0 * rn  # coarse lower bound
            # --- collective bytes ---
            base_op = opcode.replace("-start", "")
            if base_op in ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all", "collective-permute"):
                rb = _shape_bytes(rtype)
                ops_ = _OPERAND_RE.findall(operands)
                if ops_:
                    src = _resolve_bytes(ops_[0])
                    rb = min(rb, max(src, rb // 2) if src else rb)
                n = max(_group_size(line, n_devices), 1)
                ring = (n - 1) / n
                if base_op == "all-gather":
                    moved = ring * rb
                elif base_op == "reduce-scatter":
                    moved = ring * rb * n
                elif base_op == "all-reduce":
                    moved = 2 * ring * rb
                elif base_op == "all-to-all":
                    moved = ring * rb
                else:
                    moved = rb
                coll[base_op] += m * moved
                counts[base_op] += 1
            # --- HBM traffic (fusion-boundary convention; converts/copies
            # are CPU-backend artifacts and excluded) ---
            if in_traffic and opcode not in _SKIP_TRAFFIC \
                    and opcode not in ("convert", "copy"):
                ops_list = _OPERAND_RE.findall(operands)
                # In-place slice updates (dynamic-update-slice / scatter,
                # either standalone or as a fusion root — XLA names fusions
                # after their root op): the carried buffer aliases in place,
                # so traffic is the small update + written slice, NOT the
                # whole buffer per loop iteration.
                is_slice_update = (opcode in ("dynamic-update-slice",
                                              "scatter")
                                   or "dynamic-update-slice" in name
                                   or "scatter" in name)
                if is_slice_update and ops_list:
                    op_bytes = [_resolve_bytes(o) for o in ops_list]
                    b = 2 * max(0, sum(op_bytes) - max(op_bytes))
                elif opcode == "dynamic-slice" or "dynamic-slice" in name:
                    b = 2 * _shape_bytes(rtype)   # read + write the slice
                else:
                    b = _shape_bytes(rtype)
                    access = {}
                    if opcode == "fusion":
                        cm = _FUSION_CALLS_RE.search(line)
                        if cm:
                            if cm.group(1) in pure_convert_comps:
                                continue
                            access = param_access.get(cm.group(1), {})
                    for i, op_name in enumerate(ops_list):
                        b += access[i] if i in access \
                            else _resolve_bytes(op_name)
                traffic += m * b

    return {"flops": flops, "traffic_bytes": traffic,
            "coll_bytes_by_op": dict(coll), "coll_counts": dict(counts),
            "coll_bytes": sum(coll.values()), "loops": loops}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_flop_ratio: float = 0.0

    def finalize(self) -> "RooflineReport":
        self.compute_s = self.flops_per_device / PEAK_FLOPS
        self.memory_s = self.bytes_per_device / HBM_BW
        self.collective_s = self.coll_bytes_per_device / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        global_flops = self.flops_per_device * self.chips
        self.useful_flop_ratio = (self.model_flops / global_flops
                                  if global_flops else 0.0)
        return self

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg, shape_kind: str, seq_len: int, global_batch: int) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D for forward-only (N = active
    params for MoE), D = total tokens processed (1/step for decode)."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * global_batch


def summarize(report: RooflineReport) -> str:
    r = report
    return (f"{r.arch:24s} {r.shape:12s} {r.mesh:10s} "
            f"compute {r.compute_s * 1e3:9.3f} ms | "
            f"memory {r.memory_s * 1e3:9.3f} ms | "
            f"collective {r.collective_s * 1e3:9.3f} ms | "
            f"dominant {r.dominant:10s} | useful {r.useful_flop_ratio:6.1%}")
