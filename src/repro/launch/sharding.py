"""Sharding rules: logical-axis rules for activations + per-parameter
PartitionSpecs derived from tree paths.

Baseline layout (see DESIGN.md §3):
  * batch        -> data (x pod)
  * TP features  -> model: attention heads / d_ff / vocab; experts are
    tensor-parallel over d_ff (divides 16 for every assigned arch)
  * expert FSDP  -> optionally shard expert d_model over data (the two
    Mixtrals: 2D-sharded expert weights so params fit 16 GB/chip HBM)

Head counts that do not divide the model axis (smollm: 15 q-heads) rely on
GSPMD padding — lowering succeeds; noted in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import axis_rules


@dataclasses.dataclass(frozen=True)
class ShardingProfile:
    multi_pod: bool = False
    fsdp_experts: bool = False      # shard expert d_model over data axis
    fsdp_dense: bool = False        # shard dense ffn / attn over data too
    shard_vocab: bool = True
    cache_layout: str = "auto"      # 'auto' (heads/hd) | 'seq' (§Perf it. 2)

    @property
    def batch(self):
        return ("pod", "data") if self.multi_pod else "data"

    @property
    def fsdp_axis(self):
        return "data"


def profile_for(cfg: ModelConfig, multi_pod: bool = False,
                train: bool = False) -> ShardingProfile:
    # The two Mixtrals need 2D (FSDP x TP) weight sharding to fit optimizer
    # state + params in HBM when TRAINING. At inference FSDP conflicts with
    # batch data-parallelism (the contraction dim and the batch want the same
    # mesh axis -> giant all-reduces), so serve paths use pure TP; the
    # resulting >HBM footprint for mixtral-8x22b decode is exactly the
    # memory-constrained regime BuddyMoE's expert offloading targets
    # (DESIGN.md (TP) / EXPERIMENTS.md notes).
    fsdp = train and cfg.arch_id.startswith("mixtral")
    # Decode KV-cache layout, chosen per-arch by A/B dry-runs (§Perf
    # iteration 2): sequence-sharding wins when kv_heads don't divide the
    # model axis AND the step is collective-bound under head/hd sharding
    # (internlm2-*: 507->1.9ms, phi3: 513->1.3ms collective). It REGRESSES
    # smollm (small cache), mixtral (SWA window cache) and the nested
    # zamba2/vlm caches — those keep 'auto'.
    seq_cache_archs = ("internlm2-1.8b", "internlm2-20b", "phi3-medium-14b")
    layout = "seq" if cfg.arch_id in seq_cache_archs else "auto"
    return ShardingProfile(multi_pod=multi_pod, fsdp_experts=fsdp,
                           fsdp_dense=fsdp, cache_layout=layout)


def activation_rules(prof: ShardingProfile, cfg: Optional[ModelConfig] = None,
                     model_size: int = 16) -> dict:
    """cache layout (prof.cache_layout):
      'auto' — shard kv-head axis when it divides `model`, else head_dim.
      'seq'  — shard the cache SEQUENCE axis over `model` (decode context
               parallelism): attention scores/outputs reduce over the
               sharded axis with tiny softmax-stat collectives instead of
               gathering the cache. §Perf iteration 2.
    The cache update in attn_decode is constrained to the SAME layout so the
    dynamic-update-slice stays collective-free."""
    rules = {
        "batch": prof.batch,
        "heads": "model",
        "kv_heads": "model",
        "dff": "model",
        "vocab": "model" if prof.shard_vocab else None,
        "expert": None,
        "cache_heads": None,
        "cache_hd": None,
        "cache_seq": None,
    }
    if cfg is not None:
        if prof.cache_layout == "seq":
            rules["cache_seq"] = "model"
        elif cfg.num_kv_heads % model_size == 0:
            rules["cache_heads"] = "model"
        elif cfg.head_dim % model_size == 0:
            rules["cache_hd"] = "model"
    return rules


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------
_OUT_FEATURE = ("wq", "wk", "wv", "wg", "ww", "wr", "w1", "w3", "ck",
                "in_proj", "conv_w")
_IN_FEATURE = ("wo", "w2", "cv", "out_proj")


def _base_spec(path: str, name: str, ndim: int, prof: ShardingProfile):
    fsdp = prof.fsdp_axis if prof.fsdp_dense else None
    if name == "embed":
        # d_model-sharded: the token gather stays local (a row-sharded table
        # makes GSPMD emit one-hot matmul gathers + giant all-reduces)
        return (None, "model")
    if name == "lm_head":
        return (None, "model" if prof.shard_vocab else None)
    if "/moe/" in path:
        efsdp = prof.fsdp_axis if prof.fsdp_experts else None
        if name in ("w1", "w3"):
            return (None, efsdp, "model")        # [E, D, F]
        if name == "w2":
            return (None, "model", efsdp)        # [E, F, D]
        if name == "router":
            return (None, None)
    if "/shared/" in path:
        if name in ("w1", "w3"):
            return (fsdp, "model")
        if name == "w2":
            return ("model", fsdp)
    if name == "u":
        return ("model", None)                   # rwkv bonus [H, hd]
    if name in _OUT_FEATURE:
        return (fsdp, "model")
    if name in _IN_FEATURE:
        return ("model", fsdp)
    if name == "cr":
        return (None, None)
    return None                                   # replicate


def param_specs(cfg: ModelConfig, params_shape, prof: ShardingProfile):
    """PartitionSpec pytree matching params (works on ShapeDtypeStructs)."""
    def spec(path_elems, leaf):
        path = "/" + "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                              for p in path_elems) + "/"
        name = str(getattr(path_elems[-1], "key", path_elems[-1]))
        base = _base_spec(path, name, leaf.ndim, prof)
        if base is None:
            return P()
        base = [b for b in base]
        pad = leaf.ndim - len(base)
        if pad < 0:   # 1-D leaf matched a 2-D rule — replicate
            return P()
        return P(*([None] * pad + base))

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def to_shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, (tuple, list)):
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axes]


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop mesh axes from dims they do not evenly divide (jit in_shardings
    require exact divisibility — unlike internal sharding constraints)."""
    out = []
    for i, axes in enumerate(spec):
        if axes is None or i >= len(shape):
            out.append(axes)
            continue
        if isinstance(axes, (tuple, list)):
            kept, size = [], 1
            for a in axes:
                if shape[i] % (size * mesh.shape[a]) == 0:
                    kept.append(a)
                    size *= mesh.shape[a]
            out.append(tuple(kept) if len(kept) > 1 else
                       (kept[0] if kept else None))
        else:
            out.append(axes if shape[i] % mesh.shape[axes] == 0 else None)
    return P(*out)


def sanitize_specs(spec_tree, struct_tree, mesh):
    """Pairwise sanitize a spec pytree against a ShapeDtypeStruct pytree."""
    return jax.tree.map(
        lambda s, x: sanitize_spec(s, x.shape, mesh), spec_tree, struct_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------
def token_spec(prof: ShardingProfile) -> P:
    return P(prof.batch, None)


def cache_specs(cache_shapes, prof: ShardingProfile):
    """Decode caches: shard batch dim. Cache leaves all have the batch at
    axis 1 (after the stacked-layer axis); ssm 'conv'/'ssm'/'wkv'/'x_*' too.
    Leaves under hybrid supers have an extra leading axis — detected by ndim
    heuristics is brittle, so we shard the axis whose size equals the batch
    via a marker: we instead rebuild specs structurally in dryrun (knowing
    batch), here we just map: first axis None, batch axis = 1 or 2."""
    raise NotImplementedError("use dryrun._cache_specs")


def apply_rules(prof: ShardingProfile):
    return axis_rules(activation_rules(prof))
