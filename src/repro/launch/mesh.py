"""Production mesh construction (TPU v5e target).

Single-pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model).

A FUNCTION (not a module constant) so importing this module never touches
jax device state — smoke tests must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int = 8):
    """Small mesh over however many (fake) devices tests set up."""
    return jax.make_mesh((n_devices // 2, 2), ("data", "model"))


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)
