"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production mesh; report memory/cost analysis and roofline terms.

MUST be the very first lines — before any other import — since jax locks the
device count on first init:
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse            # noqa: E402
import dataclasses         # noqa: E402
import functools           # noqa: E402
import json                # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402

import jax                 # noqa: E402
import jax.numpy as jnp    # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig,  # noqa: E402
                                get_config)
from repro.core.policy import BuddyPolicy                       # noqa: E402
from repro.launch import roofline as rl                         # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.launch.sharding import (ShardingProfile, activation_rules,  # noqa: E402
                                   param_specs, profile_for, sanitize_specs)
from repro.models import transformer                            # noqa: E402
from repro.models.common import axis_rules                      # noqa: E402
from repro.models.moe import BuddyState                         # noqa: E402
from repro.training.optimizer import AdamWConfig, init_opt_state  # noqa: E402
from repro.training.train_loop import make_train_step           # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")
R_MAX = 8


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins (weak-type-correct, no allocation)
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def params_struct(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: transformer.init_params(cfg, k), key)


def cond_struct(cfg: ModelConfig, batch: int):
    if cfg.num_cond_tokens:
        return _sds((batch, cfg.num_cond_tokens, cfg.cond_dim), jnp.bfloat16)
    return None


def buddy_struct(cfg: ModelConfig):
    if not cfg.is_moe:
        return None
    l = sum(r for k, r in cfg.stack() if k == "attn_moe")
    e = cfg.moe.num_experts
    return BuddyState(resident=_sds((l, e), jnp.bool_),
                      table=_sds((l, e, R_MAX), jnp.int32),
                      q=_sds((l, e, R_MAX), jnp.float32),
                      hop=_sds((l, e), jnp.int32))


def input_specs(cfg: ModelConfig, shape_name: str):
    """All model inputs for the given shape, as ShapeDtypeStructs."""
    shp = SHAPES[shape_name]
    b, s = shp.global_batch, shp.seq_len
    out = {"tokens": _sds((b, s), jnp.int32)}
    if shp.kind == "train":
        out["targets"] = _sds((b, s), jnp.int32)
    if shp.kind == "decode":
        out["token"] = _sds((b,), jnp.int32)
        out["pos"] = _sds((), jnp.int32)
    c = cond_struct(cfg, b)
    if c is not None:
        out["cond"] = c
    return out


# ---------------------------------------------------------------------------
# Sharding spec trees
# ---------------------------------------------------------------------------
def _cache_spec_tree(cfg: ModelConfig, prof: ShardingProfile, rules: dict):
    """PartitionSpecs mirroring transformer.init_caches structure."""
    bt = prof.batch

    kvh, hd = rules.get("cache_heads"), rules.get("cache_hd")
    cseq = rules.get("cache_seq")
    kv = {"k": P(None, bt, cseq, kvh, hd),
          "v": P(None, bt, cseq, kvh, hd)}
    specs = []
    for kind, repeat in cfg.stack():
        if kind in ("attn_dense", "attn_moe"):
            specs.append({"kv": kv})
        elif kind == "rwkv6":
            specs.append({"wkv": P(None, bt, "model", None, None),
                          "x_tm": P(None, bt, None, None),
                          "x_cm": P(None, bt, None, None)})
        elif kind == "mamba2":
            specs.append({"conv": P(None, bt, None, "model"),
                          "ssm": P(None, bt, "model", None, None)})
        elif kind == "hybrid_super":
            specs.append({
                "mamba": {"conv": P(None, None, bt, None, "model"),
                          "ssm": P(None, None, bt, "model", None, None)},
                "kv": kv})
        elif kind == "vlm_super":
            specs.append({
                "self_kv": {"k": P(None, None, bt, cseq, kvh, hd),
                            "v": P(None, None, bt, cseq, kvh, hd)},
                "cross_kv": (P(None, bt, None, kvh, hd),
                             P(None, bt, None, kvh, hd))})
        else:
            raise ValueError(kind)
    return tuple(specs)


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _arg_shardings(mesh, shardings, args):
    """Sanitize specs against arg shapes (divisibility), then to shardings."""
    return tuple(_ns(mesh, sanitize_specs(s, a, mesh))
                 for s, a in zip(shardings, args))


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------
def _bf16(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, dtype="bfloat16")


def lower_case(cfg: ModelConfig, shape_name: str, mesh, prof: ShardingProfile,
               policy: BuddyPolicy = BuddyPolicy()):
    """Build + lower the step function for one (arch x shape). Returns
    (lowered, meta)."""
    shp = SHAPES[shape_name]
    cfg = _bf16(cfg)
    b, s = shp.global_batch, shp.seq_len
    ins = input_specs(cfg, shape_name)
    p_struct = params_struct(cfg)
    p_spec = param_specs(cfg, p_struct, prof)
    bt = prof.batch
    has_cond = "cond" in ins
    model_size = mesh.shape["model"]
    rules = activation_rules(prof, cfg, model_size)

    if shp.kind == "train":
        opt_struct = jax.eval_shape(init_opt_state, p_struct)
        # optimizer state shards like params (FSDP-consistent)
        opt_spec = type(opt_struct)(P(), p_spec, p_spec)
        step = make_train_step(cfg, AdamWConfig(), remat=True)

        def fn(params, opt_state, tokens, targets, rng, cond=None):
            with axis_rules(rules):
                return step(params, opt_state, tokens, targets, rng,
                            cond_embeds=cond)

        args = [p_struct, opt_struct, ins["tokens"], ins["targets"],
                jax.random.PRNGKey(0)]
        shardings = [p_spec, opt_spec, P(bt, None), P(bt, None), P()]
        if has_cond:
            args.append(ins["cond"])
            shardings.append(P(bt, None, None))
        out_shardings = (_ns(mesh, sanitize_specs(p_spec, p_struct, mesh)),
                         _ns(mesh, sanitize_specs(opt_spec, opt_struct, mesh)),
                         NamedSharding(mesh, P()))
        jitted = jax.jit(fn, in_shardings=_arg_shardings(mesh, shardings, args),
                         out_shardings=out_shardings, donate_argnums=(0, 1))
        return jitted.lower(*args), {"kind": "train"}

    if shp.kind == "prefill":
        def fn(params, tokens, cond=None):
            with axis_rules(rules):
                logits, _ = transformer.forward_train(params, cfg, tokens,
                                                      cond_embeds=cond)
                return logits

        args = [p_struct, ins["tokens"]]
        shardings = [p_spec, P(bt, None)]
        if has_cond:
            args.append(ins["cond"])
            shardings.append(P(bt, None, None))
        logits_spec = sanitize_specs(
            P(bt, None, None), _sds((b, s, cfg.vocab_size), jnp.float32), mesh)
        jitted = jax.jit(fn, in_shardings=_arg_shardings(mesh, shardings, args),
                         out_shardings=_ns(mesh, logits_spec))
        return jitted.lower(*args), {"kind": "prefill"}

    # decode
    long_ctx = s >= 262144
    window = transformer.effective_window(cfg, s, long_context=long_ctx)
    cache_struct = jax.eval_shape(
        lambda: transformer.init_caches(cfg, b, s, window=window,
                                        dtype=jnp.bfloat16))
    cache_spec = _cache_spec_tree(cfg, prof, rules)
    bd_struct = buddy_struct(cfg)

    def decode(params, caches, token, pos, cond, buddies):
        with axis_rules(rules):
            logits, new_caches, _ = transformer.decode_step(
                params, cfg, token, caches, pos, cond_embeds=cond,
                policy=policy if cfg.is_moe else None,
                buddies=buddies, window=window)
            return logits, new_caches

    args = [p_struct, cache_struct, ins["token"], ins["pos"]]
    shardings = [p_spec, cache_spec, P(bt), P()]
    if has_cond:
        fn = lambda p, c, t, ps, cond: decode(p, c, t, ps, cond, None)  # noqa: E731
        args.append(ins["cond"])
        shardings.append(P(bt, None, None))
    elif bd_struct is not None:
        fn = lambda p, c, t, ps, bd: decode(p, c, t, ps, None, bd)  # noqa: E731
        args.append(bd_struct)
        shardings.append(jax.tree.map(lambda _: P(), bd_struct))
    else:
        fn = lambda p, c, t, ps: decode(p, c, t, ps, None, None)  # noqa: E731
    logits_spec = sanitize_specs(
        P(bt, None), _sds((b, cfg.vocab_size), jnp.float32), mesh)
    out_shardings = (_ns(mesh, logits_spec),
                     _ns(mesh, sanitize_specs(cache_spec, cache_struct, mesh)))
    jitted = jax.jit(fn, in_shardings=_arg_shardings(mesh, shardings, args),
                     out_shardings=out_shardings, donate_argnums=(1,))
    return jitted.lower(*args), {"kind": "decode", "window": window}


def _is_spec(x):
    return isinstance(x, P)


# ---------------------------------------------------------------------------
# Run + report
# ---------------------------------------------------------------------------
def run_one(arch: str, shape_name: str, multi_pod: bool,
            save: bool = True, verbose: bool = True,
            cache_layout: str = "auto") -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    prof = profile_for(cfg, multi_pod=multi_pod,
                       train=SHAPES[shape_name].kind == "train")
    if cache_layout != "auto":
        prof = dataclasses.replace(prof, cache_layout=cache_layout)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    t0 = time.time()
    with mesh:
        lowered, meta = lower_case(cfg, shape_name, mesh, prof)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    # loop-aware analyzer (cost_analysis counts while bodies once)
    hla = rl.analyze_hlo(hlo, chips)

    shp = SHAPES[shape_name]
    report = rl.RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=hla["flops"], bytes_per_device=hla["traffic_bytes"],
        coll_bytes_per_device=hla["coll_bytes"],
        model_flops=rl.model_flops(cfg, shp.kind, shp.seq_len,
                                   shp.global_batch),
    ).finalize()

    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "meta": meta,
        "lower_s": t_lower, "compile_s": t_compile,
        "memory_analysis": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "cost_analysis": {"flops": flops, "bytes_accessed": byts},
        "hlo_analysis": {k: hla[k] for k in
                         ("flops", "traffic_bytes", "coll_bytes",
                          "coll_bytes_by_op", "coll_counts", "loops")},
        "roofline": report.as_dict(),
    }
    if verbose:
        ma = out["memory_analysis"]
        print(f"[dryrun] {arch} {shape_name} {mesh_name}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s | "
              f"args {_gb(ma['argument_size_bytes'])} "
              f"temp {_gb(ma['temp_size_bytes'])} | "
              f"flops/dev {hla['flops']:.3e} bytes/dev "
              f"{hla['traffic_bytes']:.3e} coll/dev {hla['coll_bytes']:.3e}")
        print("         " + rl.summarize(report))
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        fn = os.path.join(RESULTS_DIR, f"{arch}_{shape_name}_{mesh_name}.json")
        with open(fn, "w") as f:
            json.dump(out, f, indent=1)
        import gzip
        hlo_dir = os.path.join(RESULTS_DIR, "hlo")
        os.makedirs(hlo_dir, exist_ok=True)
        with gzip.open(os.path.join(
                hlo_dir, f"{arch}_{shape_name}_{mesh_name}.hlo.gz"),
                "wt") as f:
            f.write(hlo)
    return out


def reanalyze_all() -> None:
    """Recompute rooflines from stored HLO (analyzer iterations are free)."""
    import glob
    import gzip
    for fn in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(fn) as f:
            out = json.load(f)
        tag = f"{out['arch']}_{out['shape']}_{out['mesh']}"
        hfn = os.path.join(RESULTS_DIR, "hlo", tag + ".hlo.gz")
        if not os.path.exists(hfn):
            print(f"[reanalyze] no HLO for {tag}")
            continue
        with gzip.open(hfn, "rt") as f:
            hlo = f.read()
        chips = out["chips"]
        hla = rl.analyze_hlo(hlo, chips)
        cfg = get_config(out["arch"])
        shp = SHAPES[out["shape"]]
        report = rl.RooflineReport(
            arch=out["arch"], shape=out["shape"], mesh=out["mesh"],
            chips=chips, flops_per_device=hla["flops"],
            bytes_per_device=hla["traffic_bytes"],
            coll_bytes_per_device=hla["coll_bytes"],
            model_flops=rl.model_flops(cfg, shp.kind, shp.seq_len,
                                       shp.global_batch)).finalize()
        out["hlo_analysis"] = {k: hla[k] for k in
                               ("flops", "traffic_bytes", "coll_bytes",
                                "coll_bytes_by_op", "coll_counts", "loops")}
        out["roofline"] = report.as_dict()
        with open(fn, "w") as f:
            json.dump(out, f, indent=1)
        print("[reanalyze] " + rl.summarize(report))


def _gb(x):
    return f"{x / 1e9:.2f}GB" if x is not None else "?"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute rooflines from stored HLO")
    ap.add_argument("--cache-layout", default="auto",
                    choices=["auto", "seq"])
    args = ap.parse_args()
    if args.reanalyze:
        reanalyze_all()
        return

    archs = ARCH_IDS[:10] if args.arch in (None, "all") else [args.arch]
    shapes = list(SHAPES) if args.shape in (None, "all") else [args.shape]
    failures = []
    for arch in archs:
        for shape in shapes:
            mesh_name = "2x16x16" if args.multi_pod else "16x16"
            fn = os.path.join(RESULTS_DIR, f"{arch}_{shape}_{mesh_name}.json")
            if args.skip_existing and os.path.exists(fn):
                print(f"[dryrun] skip {arch} {shape} {mesh_name} (exists)")
                continue
            try:
                run_one(arch, shape, args.multi_pod,
                        cache_layout=args.cache_layout)
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, repr(e)))
                print(f"[dryrun] FAIL {arch} {shape}: {e}")
                traceback.print_exc(limit=4)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
