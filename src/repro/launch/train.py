"""Training launcher.

CPU-scale run (this container):
    PYTHONPATH=src python -m repro.launch.train --arch deepseek-v2-lite-buddy \
        --reduced --steps 200 --batch 8 --seq 64

On a real pod the same module launches with --mesh 16x16 and the full config;
the dry-run (launch/dryrun.py) proves that path lowers and compiles.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import get_config, get_reduced
from repro.training.data import MarkovLM
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-buddy")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    lm = MarkovLM(cfg.vocab_size, seed=0)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(10, args.steps // 10))
    params, hist = train(cfg, opt, lm.batches(args.batch, args.seq, args.steps))
    if args.save:
        from repro.checkpoint.io import save_pytree
        save_pytree(args.save, params)
        print(f"saved params to {args.save}")
    print(f"final loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
