"""Serving launcher — BuddyMoE engine over a trained (or random) checkpoint.

    # static one-shot batch (the paper's harness)
    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v2-lite-buddy \
        --reduced --cache-rate 0.5 --policy buddy --steps 64

    # continuous batching under Poisson load with SLOs + adaptive prefetch
    PYTHONPATH=src python -m repro.launch.serve --reduced --mode continuous \
        --num-requests 16 --arrival-rate 500 --slots 4 \
        --slo-ttft-ms 5 --slo-tpot-ms 1 --adaptive-prefetch

    # chunked prefill: joining prompts ingested 8 tokens per fused step
    PYTHONPATH=src python -m repro.launch.serve --reduced --mode continuous \
        --num-requests 16 --arrival-rate 500 --slots 4 --prefill-chunk 8

    # tiered expert store: int8 replicas of every expert stay resident, so a
    # buddy-less miss computes degraded instead of stalling on PCIe
    PYTHONPATH=src python -m repro.launch.serve --reduced --cache-rate 0.5 \
        --quant-tier int8 --steps 64

    # workload replay: arrivals + per-request token budgets from a JSONL
    # trace of {t_arrival, prompt_len, max_new_tokens} rows
    PYTHONPATH=src python -m repro.launch.serve --reduced --mode continuous \
        --trace trace.jsonl --slots 4

    # expert-parallel mesh: experts sharded across 4 devices; misses on
    # peer-owned experts borrow over ICI instead of waiting on host PCIe
    PYTHONPATH=src python -m repro.launch.serve --reduced --cache-rate 0.5 \
        --n-devices 4 --steps 64

    # flight recorder: metrics + calibration in the summary, and a Perfetto
    # trace of the run (load serve_trace.json at https://ui.perfetto.dev)
    PYTHONPATH=src python -m repro.launch.serve --reduced --mode continuous \
        --num-requests 16 --slots 4 --telemetry on --trace-out serve_trace.json

    # paged KV + radix prefix cache: shared-prefix requests adopt the
    # donated block chain and prefill only their novel suffix
    PYTHONPATH=src python -m repro.launch.serve --reduced --mode continuous \
        --num-requests 16 --slots 4 --paged-kv --kv-block 16 \
        --prefix-cache --prefill-chunk 8

    # live placement: tier coverage + replication track live traffic
    # instead of the profiling draw (runtime/placement.py)
    PYTHONPATH=src python -m repro.launch.serve --reduced --mode continuous \
        --num-requests 16 --slots 4 --quant-tier int8 --tier-coverage 0.5 \
        --placement live --placement-interval-ms 1
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs.base import get_config, get_reduced
from repro.core import BuddyPolicy, CoactivationRecorder, build_buddy_lists
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.placement import PlacementController
from repro.runtime.prefetch import (AdaptiveBudgetController,
                                    CrossLayerPredictor, PrevStepPredictor,
                                    TopFreqPredictor)
from repro.runtime.telemetry import Telemetry
from repro.runtime.tiers import TIER_BITS, TieredExpertStore
from repro.runtime.trace import export_trace
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import (BurstyArrivals, ContinuousScheduler,
                                     PoissonArrivals, RequestQueue, SLOConfig,
                                     make_requests, requests_from_trace)
from repro.training.data import MarkovLM

PREDICTORS = {
    "prev-step": PrevStepPredictor,
    "top-freq": TopFreqPredictor,
    "cross-layer": CrossLayerPredictor,
}


def profile_buddies(cfg, params, lm, *, steps: int = 4, batch: int = 4,
                    seq: int = 64, alpha: float = 0.9, k_max: int = 8):
    """Offline phase: router traces -> co-activation -> CFT buddy lists."""
    import jax.numpy as jnp
    n_moe = sum(r for k, r in cfg.stack() if k == "attn_moe")
    rec = CoactivationRecorder(n_moe, cfg.moe.num_experts)
    fwd = jax.jit(lambda p, t: transformer.forward_train(p, cfg, t, record=True))
    for _ in range(steps):
        toks = jnp.asarray(lm.sample(batch, seq))
        _, aux = fwd(params, toks)
        per = aux["recorded"][0]
        for l in range(n_moe):
            rec.update(l, np.asarray(per["indices"][l]),
                       np.asarray(per["probs"][l]))
        rec.step_done()
    q = np.stack([rec.conditional(l) for l in range(n_moe)])
    return build_buddy_lists(q, alpha=alpha, k_max=k_max, activity=rec.A), rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-buddy")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--cache-rate", type=float, default=0.5)
    ap.add_argument("--policy", choices=["buddy", "random", "none"],
                    default="buddy")
    ap.add_argument("--tau", type=float, default=0.2)
    ap.add_argument("--beta", type=float, default=0.8)
    ap.add_argument("--rho", type=int, default=3)
    ap.add_argument("--alpha", type=float, default=0.9)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--predictor", choices=sorted(PREDICTORS),
                    default="prev-step")
    ap.add_argument("--prefetch-k", type=int, default=-1,
                    help="-1: half the cache capacity")
    ap.add_argument("--lookahead", type=int, default=1,
                    help="issue layer l+k prefetches while layer l computes")
    # -- continuous serving under load ---------------------------------
    ap.add_argument("--mode", choices=["batch", "continuous"],
                    default="batch")
    ap.add_argument("--num-requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (continuous batch width)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="requests per SIMULATED second (0: sized to ~70%% "
                         "of MEASURED decode capacity, stalls included)")
    ap.add_argument("--arrivals", choices=["poisson", "bursty"],
                    default="poisson")
    ap.add_argument("--slo-ttft-ms", type=float, default=0.0,
                    help="TTFT objective in modeled ms (0: disabled)")
    ap.add_argument("--slo-tpot-ms", type=float, default=0.0,
                    help="per-token objective in modeled ms (0: disabled)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="end-to-end deadline; with --admission slo, doomed "
                         "requests are shed instead of admitted")
    ap.add_argument("--admission", choices=["fcfs", "slo"], default="fcfs")
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help="prompt tokens ingested per fused step when a "
                         "request joins (1: token-by-token through decode)")
    ap.add_argument("--adaptive-prefetch", action="store_true",
                    help="resize prefetch budget from queue depth + stall "
                         "attribution instead of the fixed --prefetch-k")
    ap.add_argument("--trace", default=None,
                    help="JSONL serving trace ({t_arrival, prompt_len, "
                         "max_new_tokens} rows) replayed with per-request "
                         "token budgets (--mode continuous)")
    # -- tiered expert store (compressed resident replicas) -------------
    ap.add_argument("--quant-tier", choices=["off", "int8", "int4"],
                    default="off",
                    help="keep a low-precision replica of EVERY expert "
                         "resident so a buddy-less miss computes degraded "
                         "instead of stalling; the tier displaces full-"
                         "precision cache slots from the --cache-rate budget")
    ap.add_argument("--tier-stall-per-fidelity", type=float, default=0.05,
                    help="seconds of expected stall that justify one unit "
                         "of relative quantization error when deciding "
                         "degrade-vs-wait on a miss (precedence mode)")
    ap.add_argument("--tier-coverage", type=float, default=1.0,
                    help="fraction of experts per layer holding a resident "
                         "replica (top-P(use) from the profiling activity "
                         "stats); the freed bytes become full cache slots")
    # -- unified expected-cost miss policy (runtime/costs.py) -----------
    ap.add_argument("--miss-policy", choices=["precedence", "cost"],
                    default="precedence",
                    help="'precedence': fixed buddy->degraded->fetch/drop "
                         "chain; 'cost': per-slot argmin of the unified "
                         "expected-cost model — buddy Psi loss, replica "
                         "fidelity, fetch ETA, and drop loss scored on one "
                         "stall-seconds scale")
    ap.add_argument("--stall-per-quality", type=float, default=0.05,
                    help="the single exchange rate: seconds of stall worth "
                         "one unit of quality loss (generalizes "
                         "--tier-stall-per-fidelity across all outcomes)")
    ap.add_argument("--drop-loss", type=float, default=1.0,
                    help="quality units lost by dropping a routed slot "
                         "(cost mode's drop outcome)")
    ap.add_argument("--upgrade-degraded", choices=["auto", "on", "off"],
                    default="auto",
                    help="degraded-then-upgrade: background-fetch the true "
                         "expert after serving its slot from the quant tier "
                         "(auto: on exactly when --miss-policy cost and a "
                         "tier is attached)")
    ap.add_argument("--fused-dispatch", action="store_true",
                    help="single-dispatch hot path: compute full-precision,"
                         " buddy, and degraded slots in ONE grouped step "
                         "(kernels/grouped_ffn.py) instead of three "
                         "dispatches; off = bit-identical pre-fused graph")
    # -- paged KV + radix-tree prefix cache (runtime/paged_kv.py) --------
    ap.add_argument("--paged-kv", action="store_true",
                    help="block-pooled KV cache: rows map fixed-size blocks "
                         "through per-row tables (ref-counted, copy-on-"
                         "write); off = the ring layout, bit-identical")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="tokens per KV block (--paged-kv)")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="pool size in blocks (0: exact ring-cache "
                         "footprint, so paged vs ring runs at equal HBM)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prefix cache over the paged pool: "
                         "retiring requests donate their block chains; "
                         "admitted requests adopt the longest cached prefix "
                         "and prefill only the novel suffix (requires "
                         "--paged-kv)")
    ap.add_argument("--adaptive-chunk", action="store_true",
                    help="per-request prefill chunk policy: halve the "
                         "chunk while the estimated chunk-step time would "
                         "blow co-resident decode rows' TPOT budget")
    # -- expert-parallel mesh (peer-HBM borrowing over ICI) --------------
    ap.add_argument("--n-devices", type=int, default=1,
                    help="expert-parallel mesh size (1-8): experts shard "
                         "round-robin across devices; a miss on an expert a "
                         "peer holds borrows it over that device's ICI link "
                         "— the fifth miss outcome (1: single-device, "
                         "bit-identical to the pre-mesh engine)")
    ap.add_argument("--ici-gbps", type=float, default=0.0,
                    help="per-ICI-link bandwidth in GB/s (0: hardware "
                         "model default)")
    ap.add_argument("--no-peer-borrow", action="store_true",
                    help="mesh ablation: shard experts but resolve misses "
                         "with the four single-device outcomes only")
    # -- live placement (runtime/placement.py) ---------------------------
    ap.add_argument("--placement", choices=["off", "live"], default="off",
                    help="live traffic->placement loop: every refresh "
                         "window of SIMULATED time, re-pick the quant "
                         "tier's covered experts from live activity EMAs, "
                         "background-replicate persistently-hot experts "
                         "('replicate' cause, prefetch priority), and on a "
                         "mesh push hot experts to underloaded peers "
                         "('off' is the exact pre-placement code path — "
                         "bit-identical)")
    ap.add_argument("--placement-interval-ms", type=float, default=1.0,
                    help="simulated ms between placement ticks")
    ap.add_argument("--placement-hot-windows", type=int, default=3,
                    help="hysteresis: consecutive hot windows an expert "
                         "needs before it earns a replica")
    ap.add_argument("--placement-top-k", type=int, default=0,
                    help="experts per layer counted as hot each window "
                         "(0: half the cache capacity)")
    # -- observability (runtime/telemetry.py + runtime/trace.py) ---------
    ap.add_argument("--telemetry", choices=["off", "on"], default="off",
                    help="attach the flight recorder: metrics registry, "
                         "miss-cost calibration, and prefetch meters in the "
                         "final summary ('off' runs the exact pre-telemetry "
                         "code path — bit-identical outputs and timeline)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the event log here after the run (implies "
                         "--telemetry on): '*.jsonl' = lossless JSONL, "
                         "anything else = Chrome/Perfetto trace_event JSON "
                         "— load it at https://ui.perfetto.dev or "
                         "chrome://tracing")
    ap.add_argument("--prefetch-min-saving", type=float, default=-1.0,
                    help="cost-ranked prefetch: skip candidates whose "
                         "expected stall saved (P(use) x miss cost) is at "
                         "or below this many seconds (<0: auto = 1%% of a "
                         "full expert transfer)")
    args = ap.parse_args()
    if args.lookahead < 1:
        ap.error("--lookahead must be >= 1 (layers ahead to prefetch)")
    if args.prefill_chunk < 1:
        ap.error("--prefill-chunk must be >= 1 (prompt tokens per fused step)")
    if args.trace and args.mode != "continuous":
        ap.error("--trace replays a request stream: use --mode continuous")
    if not 1 <= args.n_devices <= 8:
        ap.error("--n-devices must be in 1..8")
    if args.prefix_cache and not args.paged_kv:
        ap.error("--prefix-cache shares KV at block granularity: it "
                 "requires --paged-kv")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    assert cfg.is_moe, "serving engine targets MoE archs"
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    if args.checkpoint:
        from repro.checkpoint.io import load_pytree
        params = load_pytree(args.checkpoint, params)

    lm = MarkovLM(cfg.vocab_size, seed=0)
    tables, rec = profile_buddies(cfg, params, lm, alpha=args.alpha)
    n_moe = sum(r for k, r in cfg.stack() if k == "attn_moe")
    policy = BuddyPolicy(tau=args.tau, beta=args.beta, rho=args.rho,
                         mode=args.policy, quant_tier=args.quant_tier,
                         miss_policy=args.miss_policy,
                         stall_per_quality=args.stall_per_quality,
                         drop_loss=args.drop_loss,
                         use_fused_dispatch=args.fused_dispatch)
    tier = None
    if args.quant_tier != "off":
        tier = TieredExpertStore(
            n_moe, cfg.moe.num_experts, args.cache_rate,
            bits=TIER_BITS[args.quant_tier], d_model=cfg.d_model,
            d_ff=cfg.moe.d_ff,
            stall_per_fidelity=args.tier_stall_per_fidelity,
            coverage=args.tier_coverage)
        if args.tier_coverage < 1.0:
            # partial coverage: replicate the top-P(use) experts per layer,
            # ranked by the profiling run's activation counts
            tier.set_coverage(rec.A)
        cache = tier.cache
        print(f"[serve] quant tier {args.quant_tier}: "
              f"{tier.budget_split()}")
    else:
        cache = ExpertCache(n_moe, cfg.moe.num_experts, args.cache_rate)
    prefetch_k = (max(1, cache.capacity // 2) if args.prefetch_k < 0
                  else args.prefetch_k)
    predictor = PREDICTORS[args.predictor](n_moe, cfg.moe.num_experts)
    upgrade = {"auto": None, "on": True, "off": False}[args.upgrade_degraded]
    tele = None
    if args.telemetry == "on" or args.trace_out:
        make = Telemetry.with_trace if args.trace_out else Telemetry
        tele = make(predictor_label=args.predictor, num_layers=n_moe,
                    num_experts=cfg.moe.num_experts)
    placement = None
    if args.placement == "live":
        placement = PlacementController(
            refresh_interval_s=args.placement_interval_ms * 1e-3,
            hot_windows=args.placement_hot_windows,
            hot_top_k=args.placement_top_k or None)
    eng = ServeEngine(cfg, params, tables=tables, policy=policy,
                      cache=None if tier is not None else cache, tier=tier,
                      predictor=predictor, prefetch_k=prefetch_k,
                      lookahead=args.lookahead, upgrade_degraded=upgrade,
                      prefetch_min_saving=(None if args.prefetch_min_saving
                                           < 0 else args.prefetch_min_saving),
                      telemetry=tele,
                      n_devices=args.n_devices,
                      ici_gbps=args.ici_gbps if args.ici_gbps > 0 else None,
                      peer_borrow=not args.no_peer_borrow,
                      paged_kv=args.paged_kv, kv_block=args.kv_block,
                      kv_blocks=args.kv_blocks if args.kv_blocks > 0 else None,
                      prefix_cache=args.prefix_cache, placement=placement)

    if args.mode == "continuous":
        _serve_continuous(args, cfg, eng, lm, prefetch_k)
        return

    prompts = lm.sample(args.batch, 8)
    out = eng.generate(prompts, max_new_tokens=args.steps)
    s = eng.summary()
    print(json.dumps(s, indent=1, default=str))
    bd = s["stall_breakdown"]
    print(f"stalls: demand {bd['demand_stall_s']*1e3:.2f}ms  "
          f"late-prefetch {bd['late_prefetch_stall_s']*1e3:.2f}ms  "
          f"overlapped {bd['overlapped_s']*1e3:.2f}ms")
    if "tier" in s:
        t = s["tier"]
        print(f"tier: {t['degraded_tokens']} degraded slots at "
              f"{t['bits']}-bit, {t['quant_bytes']/1e6:.1f}MB resident, "
              f"{t['tier_budget_split']['cache_slots_per_layer']} full "
              f"slots/layer left")
    _report_mesh(s)
    _report_placement(s)
    print("sample output tokens:", out[0, -16:].tolist())
    _report_telemetry(eng.telemetry, args.trace_out)


def _report_mesh(s):
    """Per-link utilization digest for mesh runs (no-op at n_devices=1)."""
    if "mesh" not in s:
        return
    m = s["mesh"]
    print(f"[mesh] {m['n_devices']} devices, peer-borrow "
          f"{'on' if m['peer_borrow'] else 'off'}: "
          f"{m['n_peer_borrow']} borrows ({m['peer_share']*100:.1f}% of "
          f"served slots), peer stall {m['peer_stall_s']*1e3:.2f}ms")
    for link in m["links"]:
        by = ", ".join(f"{k} {v/1e6:.2f}MB"
                       for k, v in link["bytes_by_cause"].items())
        print(f"[mesh]   {link['name']}: busy {link['busy_s']*1e3:.2f}ms, "
              f"queue {link['queue_depth']}, {by or 'idle'}")


def _report_placement(s):
    """Live-placement digest (absent on placement=off engines)."""
    if "placement" not in s:
        return
    p = s["placement"]
    print(f"[placement] {p['n_ticks']} ticks every "
          f"{p['refresh_interval_s']*1e3:.2f}ms: "
          f"{p['coverage_repicks']} coverage re-picks, "
          f"{p['replicas_issued']} replicas issued "
          f"({p['active_replicas']} live, "
          f"{p['replicas_reclaimed']} reclaimed), "
          f"{p['peer_pushes']} peer pushes")


def _report_telemetry(tele, trace_out):
    """One-line calibration + prefetch digest, then the --trace-out export
    (the full nested summary is already inside the engine summary JSON)."""
    if tele is None:
        return
    cal = tele.calibration.summary()
    parts = []
    for o, c in cal.items():
        p = f"{o} n={c['n']}"
        if c["n"]:
            p += f" |resid| {c['residual_abs_mean_s']*1e3:.3f}ms"
        parts.append(p)
    print("[telemetry] calibration: " + "; ".join(parts))
    pf = tele.prefetch.summary()
    print(f"[telemetry] prefetch[{pf['predictor']}]: precision "
          f"{pf['precision']:.3f} recall {pf['recall']:.3f} issued "
          f"{pf['issued']} used-in-time {pf['used_in_time']} late "
          f"{pf['late']} expected-saved "
          f"{pf['expected_stall_saved_s']*1e3:.2f}ms")
    if trace_out:
        n = export_trace(tele.trace, trace_out)
        kind = "JSONL" if trace_out.endswith(".jsonl") else "Perfetto"
        print(f"[telemetry] wrote {n} {kind} trace events to {trace_out}")


def _serve_continuous(args, cfg, eng, lm, prefetch_k):
    """Drive the engine with continuously arriving requests + SLOs."""
    slo = SLOConfig(
        ttft_s=args.slo_ttft_ms * 1e-3 if args.slo_ttft_ms > 0 else None,
        tpot_s=args.slo_tpot_ms * 1e-3 if args.slo_tpot_ms > 0 else None,
        deadline_s=args.deadline_ms * 1e-3 if args.deadline_ms > 0 else None)
    if args.trace:
        # workload replay: recorded arrivals + per-request token budgets
        reqs = requests_from_trace(args.trace,
                                   lambda n: lm.sample(1, max(1, n))[0], slo)
        print(f"[serve] replaying {len(reqs)} requests from {args.trace}")
    else:
        rng = np.random.default_rng(1)
        prompts = [lm.sample(1, int(rng.integers(4, 9)))[0]
                   for _ in range(args.num_requests)]
        rate = args.arrival_rate
        if rate <= 0:
            # ~70% of MEASURED capacity: probe an unloaded generate so the
            # step time includes transfer stalls (the compute-only estimate
            # is far too optimistic in the transfer-bound regime), then
            # reset the engine's runtime state for the real run
            eng.generate(lm.sample(args.slots, 4), max_new_tokens=8)
            step_s = eng.stats.sim_time_s / max(1, eng.stats.steps)
            eng.reset_runtime()
            per_req = (8 + args.steps) * step_s
            rate = 0.7 * args.slots / per_req
            print(f"[serve] auto arrival rate: {rate:.1f} req/s "
                  f"(measured step {step_s*1e3:.3f}ms)")
        proc = (PoissonArrivals(rate, seed=2) if args.arrivals == "poisson"
                else BurstyArrivals(rate, seed=2))
        reqs = make_requests(prompts, proc, args.steps, slo)
    queue = RequestQueue(reqs, admission=args.admission)
    ctrl = None
    if args.adaptive_prefetch and prefetch_k > 0:
        ctrl = AdaptiveBudgetController(
            prefetch_k=prefetch_k, lookahead=args.lookahead,
            max_k=max(2 * prefetch_k, 4),
            max_lookahead=max(4, args.lookahead))
    sched = ContinuousScheduler(eng, slots=args.slots, controller=ctrl,
                                prefill_chunk=args.prefill_chunk,
                                adaptive_chunk=args.adaptive_chunk)
    s = sched.run(queue)
    print(json.dumps(s, indent=1, default=str))
    print(f"completed {s['completed']}/{s['num_requests']} "
          f"(rejected {s['rejected']})  "
          f"TTFT p50/p99 {s['ttft_s']['p50']*1e3:.2f}/"
          f"{s['ttft_s']['p99']*1e3:.2f}ms  "
          f"goodput {s['goodput_rps']:.1f} req/s  "
          f"SLO-met {s['slo_met_frac']*100:.0f}%")
    _report_mesh(s.get("engine", eng.summary()))
    _report_prefix(s.get("engine", {}))
    _report_placement(s.get("engine", {}))
    _report_telemetry(eng.telemetry, args.trace_out)


def _report_prefix(s):
    """Pool/CoW/tree digest for paged-KV runs (absent on ring engines)."""
    if "prefix" not in s:
        return
    px = s["prefix"]
    occ = px["pool"]
    line = (f"[paged-kv] block {px['kv_block']}: "
            f"{occ['used_blocks']}/{occ['n_blocks']} blocks used, "
            f"{occ['cow_copies']} CoW copies, {occ['evictions']} evictions")
    if px.get("tree") is not None:
        line += (f"; prefix cache: {px['hits']} hits, "
                 f"{px['hit_tokens']} tokens adopted / "
                 f"{px['novel_tokens']} novel, tree "
                 f"{px['tree']['nodes']} nodes")
    print(line)


if __name__ == "__main__":
    main()
