"""Serving launcher — BuddyMoE engine over a trained (or random) checkpoint.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v2-lite-buddy \
        --reduced --cache-rate 0.5 --policy buddy --steps 64
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs.base import get_config, get_reduced
from repro.core import BuddyPolicy, CoactivationRecorder, build_buddy_lists
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.runtime.prefetch import (CrossLayerPredictor, PrevStepPredictor,
                                    TopFreqPredictor)
from repro.serving.engine import ServeEngine
from repro.training.data import MarkovLM

PREDICTORS = {
    "prev-step": PrevStepPredictor,
    "top-freq": TopFreqPredictor,
    "cross-layer": CrossLayerPredictor,
}


def profile_buddies(cfg, params, lm, *, steps: int = 4, batch: int = 4,
                    seq: int = 64, alpha: float = 0.9, k_max: int = 8):
    """Offline phase: router traces -> co-activation -> CFT buddy lists."""
    import jax.numpy as jnp
    n_moe = sum(r for k, r in cfg.stack() if k == "attn_moe")
    rec = CoactivationRecorder(n_moe, cfg.moe.num_experts)
    fwd = jax.jit(lambda p, t: transformer.forward_train(p, cfg, t, record=True))
    for _ in range(steps):
        toks = jnp.asarray(lm.sample(batch, seq))
        _, aux = fwd(params, toks)
        per = aux["recorded"][0]
        for l in range(n_moe):
            rec.update(l, np.asarray(per["indices"][l]),
                       np.asarray(per["probs"][l]))
        rec.step_done()
    q = np.stack([rec.conditional(l) for l in range(n_moe)])
    return build_buddy_lists(q, alpha=alpha, k_max=k_max, activity=rec.A), rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-buddy")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--cache-rate", type=float, default=0.5)
    ap.add_argument("--policy", choices=["buddy", "random", "none"],
                    default="buddy")
    ap.add_argument("--tau", type=float, default=0.2)
    ap.add_argument("--beta", type=float, default=0.8)
    ap.add_argument("--rho", type=int, default=3)
    ap.add_argument("--alpha", type=float, default=0.9)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--predictor", choices=sorted(PREDICTORS),
                    default="prev-step")
    ap.add_argument("--prefetch-k", type=int, default=-1,
                    help="-1: half the cache capacity")
    ap.add_argument("--lookahead", type=int, default=1,
                    help="issue layer l+k prefetches while layer l computes")
    args = ap.parse_args()
    if args.lookahead < 1:
        ap.error("--lookahead must be >= 1 (layers ahead to prefetch)")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    assert cfg.is_moe, "serving engine targets MoE archs"
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    if args.checkpoint:
        from repro.checkpoint.io import load_pytree
        params = load_pytree(args.checkpoint, params)

    lm = MarkovLM(cfg.vocab_size, seed=0)
    tables, _ = profile_buddies(cfg, params, lm, alpha=args.alpha)
    n_moe = sum(r for k, r in cfg.stack() if k == "attn_moe")
    cache = ExpertCache(n_moe, cfg.moe.num_experts, args.cache_rate)
    policy = BuddyPolicy(tau=args.tau, beta=args.beta, rho=args.rho,
                         mode=args.policy)
    prefetch_k = (max(1, cache.capacity // 2) if args.prefetch_k < 0
                  else args.prefetch_k)
    predictor = PREDICTORS[args.predictor](n_moe, cfg.moe.num_experts)
    eng = ServeEngine(cfg, params, tables=tables, policy=policy, cache=cache,
                      predictor=predictor, prefetch_k=prefetch_k,
                      lookahead=args.lookahead)
    prompts = lm.sample(args.batch, 8)
    out = eng.generate(prompts, max_new_tokens=args.steps)
    s = eng.summary()
    print(json.dumps(s, indent=1, default=str))
    bd = s["stall_breakdown"]
    print(f"stalls: demand {bd['demand_stall_s']*1e3:.2f}ms  "
          f"late-prefetch {bd['late_prefetch_stall_s']*1e3:.2f}ms  "
          f"overlapped {bd['overlapped_s']*1e3:.2f}ms")
    print("sample output tokens:", out[0, -16:].tolist())


if __name__ == "__main__":
    main()
