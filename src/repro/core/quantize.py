"""Per-channel symmetric quantization for the resident expert replica tier.

The tiered expert store (runtime/tiers.py) keeps a low-precision replica of
EVERY expert resident in HBM so a prefetch miss can be computed immediately
at degraded fidelity (MELINOE-style compressed experts / MoBiLE-style
big-little experts) instead of stalling on PCIe or rerouting to a buddy.
This module owns the numerics:

  * per-output-channel symmetric quantization (int8 or int4 value range) of
    the SwiGLU expert matrices — scale s_c = max|W[:, c]| / qmax, stored f32,
  * dequantization (the jnp fallback path; the fused Pallas kernel in
    kernels/quant_ffn.py applies scales post-matmul instead), and
  * calibrated per-expert fidelity scores — the relative round-trip weight
    error that the runtime trades against expected transfer stall when it
    decides buddy vs degraded-replica vs demand-fetch.

int4 values are STORED as int8 in [-7, 7] (no bit-packing — packing needs no
new dependency but adds nothing to the simulation); byte ACCOUNTING uses the
true 4-bit payload via runtime.memory.quant_expert_nbytes.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

TIER_BITS = {"int8": 8, "int4": 4}


def qmax_for_bits(bits: int) -> int:
    """Symmetric signed range: int8 -> 127, int4 -> 7."""
    assert bits in (4, 8), f"supported tier precisions: int4/int8, got {bits}"
    return 2 ** (bits - 1) - 1


def quantize_per_channel(w, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """w [..., D, C]: symmetric per-channel quant over the contraction axis.

    Returns (q int8 [..., D, C], scale f32 [..., C]) with
    dequant = q * scale[..., None, :]. Scales are per OUTPUT channel so the
    fused kernel can apply them after the matmul: (x @ q) * scale."""
    qm = qmax_for_bits(bits)
    w32 = jnp.asarray(w, jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=-2)                       # [..., C]
    scale = jnp.where(amax > 0, amax / qm, 1.0)
    q = jnp.clip(jnp.round(w32 / scale[..., None, :]), -qm, qm)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize(q, scale) -> jnp.ndarray:
    """Inverse of quantize_per_channel: [..., D, C] f32."""
    return q.astype(jnp.float32) * scale[..., None, :]


def quantize_expert_ffn(w1, w3, w2, bits: int) -> dict:
    """Quantize a (stacked) SwiGLU expert FFN: w1/w3 [..., D, F], w2 [..., F, D].

    Returns the quant-tier parameter dict consumed by models.moe (keys
    ``w1_q``/``w1_s``/... mirroring the full-precision names)."""
    w1_q, w1_s = quantize_per_channel(w1, bits)
    w3_q, w3_s = quantize_per_channel(w3, bits)
    w2_q, w2_s = quantize_per_channel(w2, bits)
    return {"w1_q": w1_q, "w1_s": w1_s, "w3_q": w3_q, "w3_s": w3_s,
            "w2_q": w2_q, "w2_s": w2_s}


def expert_fidelity(w1, w3, w2, quant: dict) -> np.ndarray:
    """Per-expert relative round-trip error (the calibrated fidelity score).

    fid[e] = ||W_e - deq(Q_e)||_F / ||W_e||_F pooled over {w1, w3, w2}.
    Lower is better; the runtime degrades a miss only when the expected
    transfer stall outweighs this loss (TieredExpertStore.degraded_ok)."""
    err2 = 0.0
    norm2 = 0.0
    for w, q, s in ((w1, quant["w1_q"], quant["w1_s"]),
                    (w3, quant["w3_q"], quant["w3_s"]),
                    (w2, quant["w2_q"], quant["w2_s"])):
        w32 = jnp.asarray(w, jnp.float32)
        d = w32 - dequantize(q, s)
        err2 = err2 + jnp.sum(d * d, axis=(-1, -2))
        norm2 = norm2 + jnp.sum(w32 * w32, axis=(-1, -2))
    fid = jnp.sqrt(err2 / jnp.maximum(norm2, 1e-30))
    return np.asarray(fid)                                       # [..., E]


def attach_quant_tier(cfg, params: dict, bits: int) -> Tuple[dict, np.ndarray]:
    """Build the resident replica tier for every MoE layer of ``params``.

    Returns (params', fidelity [L_moe, E]) where params' is a shallow copy
    whose attn_moe groups carry a ``quant`` sub-dict (stacked [R, E, ...]
    int8 weights + f32 scales) next to the full-precision weights — the
    models.moe degraded path reads it in the same fused step. Shared experts
    are always device-resident and are NOT quantized."""
    groups = list(params["groups"])
    fids = []
    for gi, (kind, _repeat) in enumerate(cfg.stack()):
        if kind != "attn_moe":
            continue
        moe_p = dict(groups[gi]["moe"])
        quant = quantize_expert_ffn(moe_p["w1"], moe_p["w3"], moe_p["w2"],
                                    bits)
        fids.append(expert_fidelity(moe_p["w1"], moe_p["w3"], moe_p["w2"],
                                    quant))
        moe_p["quant"] = quant
        g = dict(groups[gi])
        g["moe"] = moe_p
        groups[gi] = g
    assert fids, "attach_quant_tier: config has no attn_moe groups"
    out = dict(params)
    out["groups"] = tuple(groups)
    return out, np.concatenate(fids, axis=0)
