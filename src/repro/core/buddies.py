"""Buddy list construction via the Cumulative Frequency Threshold (§3.3).

Given q_{j|i} (Eq. 4), sort peers descending and keep the minimal prefix
whose cumulative mass >= alpha (Eqs. 5-6), capped at K_max, with
t_i(alpha) >= 1 for any active pivot. Supports per-layer alpha schedules
(layer-wise heterogeneity, §3.2).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Union

import numpy as np


class BuddyTables(NamedTuple):
    """Padded per-layer buddy profiles (the runtime lookup structure).

    table: [L, E, R_max] int32, rank-ordered buddy ids, -1 padding.
    q:     [L, E, R_max] float32, q_{j|i} for each entry (0 on padding).
    sizes: [L, E] int32, t_i(alpha) per pivot.
    """
    table: np.ndarray
    q: np.ndarray
    sizes: np.ndarray


def cft_prefix_size(q_row: np.ndarray, alpha: float) -> int:
    """t_i(alpha) = min{t | sum_{r<=t} q_{pi_i(r)|i} >= alpha} (Eq. 5)."""
    order = np.argsort(-q_row, kind="stable")
    cum = np.cumsum(q_row[order])
    t = int(np.searchsorted(cum, alpha - 1e-12) + 1)
    return max(1, min(t, len(q_row)))


def build_buddy_lists(q: np.ndarray, alpha: Union[float, Sequence[float]],
                      k_max: int = 16,
                      activity: Optional[np.ndarray] = None,
                      output_sim: Optional[np.ndarray] = None,
                      sim_gamma: float = 2.0) -> BuddyTables:
    """q: [L, E, E] conditional co-activation (rows ~sum to 1, diag 0).

    alpha: scalar or per-layer schedule. activity: [L, E] activation counts —
    pivots with zero activity get an empty (all -1) list.

    output_sim: optional [L, E, E] expert output-similarity matrices
    (core/similarity.py). The paper identifies buddies by co-activation AND
    output similarity (§1); when given, the ranking score becomes
    q_{j|i} * ((1+sim_ij)/2)^sim_gamma, renormalized per pivot before CFT.
    Returns padded BuddyTables with R_max = k_max.
    """
    l_n, e_n, _ = q.shape
    if output_sim is not None:
        w = ((1.0 + np.clip(output_sim, -1.0, 1.0)) / 2.0) ** sim_gamma
        q = q * w
        np.einsum("lii->li", q)[:] = 0.0
        q = q / np.maximum(q.sum(axis=2, keepdims=True), 1e-30)
    alphas = np.full(l_n, alpha, np.float64) if np.isscalar(alpha) \
        else np.asarray(alpha, np.float64)
    assert alphas.shape == (l_n,)

    table = np.full((l_n, e_n, k_max), -1, np.int32)
    qv = np.zeros((l_n, e_n, k_max), np.float32)
    sizes = np.zeros((l_n, e_n), np.int32)
    for l in range(l_n):
        for i in range(e_n):
            row = q[l, i].copy()
            row[i] = 0.0
            if activity is not None and activity[l, i] <= 0:
                continue
            if row.sum() <= 0:
                continue
            t = min(cft_prefix_size(row, alphas[l]), k_max)
            order = np.argsort(-row, kind="stable")[:t]
            table[l, i, :t] = order
            qv[l, i, :t] = row[order]
            sizes[l, i] = t
    return BuddyTables(table, qv, sizes)


def alpha_schedule(num_layers: int, early: float = 0.95,
                   late: float = 0.80) -> np.ndarray:
    """Monotone per-layer alpha: early layers tolerate broader substitution
    (higher coverage alpha), later specialized layers get tighter lists."""
    return np.linspace(early, late, num_layers)


def list_size_stats(tables: BuddyTables) -> dict:
    s = tables.sizes.astype(np.float64)
    return {"mean": float(s.mean()), "p50": float(np.percentile(s, 50)),
            "p90": float(np.percentile(s, 90)), "max": int(s.max())}


def save_tables(path: str, tables: BuddyTables) -> None:
    np.savez_compressed(path, table=tables.table, q=tables.q,
                        sizes=tables.sizes)


def load_tables(path: str) -> BuddyTables:
    d = np.load(path)
    return BuddyTables(d["table"], d["q"], d["sizes"])
