"""BuddyMoE core — the paper's primary contribution.

Offline: coactivation (profiling, Eq. 4) -> buddies (CFT lists, Eqs. 5-6).
Online:  gates (TAE Eq. 1, distribution Eq. 2) -> substitute (Alg. 1 + Psi Eq. 3),
parameterized by policy.BuddyPolicy.
"""
from repro.core.buddies import (BuddyTables, alpha_schedule, build_buddy_lists,
                                cft_prefix_size, list_size_stats, load_tables,
                                save_tables)
from repro.core.coactivation import CoactivationRecorder
from repro.core.gates import (calibrate_tau, distribution_delta,
                              distribution_gate, prob_margin, tae_from_logits,
                              tae_from_probs, token_gate)
from repro.core.policy import DROP, ORIGINAL, BuddyPolicy
from repro.core.quantize import (TIER_BITS, attach_quant_tier, dequantize,
                                 expert_fidelity, quantize_expert_ffn,
                                 quantize_per_channel)
from repro.core.substitute import (SubstituteResult, make_random_table,
                                   substitute)
