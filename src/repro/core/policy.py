"""BuddyMoE runtime policy (hashable; used as a jit static argument)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BuddyPolicy:
    """Deployment-time knobs (paper §3.1/§3.4, §5.1).

    tau:    TAE gate threshold — forbid substitution when TAE <= tau.
    beta:   distribution gate — bypass substitution when delta >= beta.
    rho:    max substitutions per token (paper §5's replacement budget).
    H:      max buddy search rank (Algorithm 1).
    eta:    local router-logit compatibility weight in Psi (Eq. 3).
    kappa:  cross-partition hop penalty weight in Psi (Eq. 3).
    temperature: optional TAE smoothing temperature (§3.1, T in [0.8, 1.2]).
    margin_gamma: optional probability-margin co-gate (>=1.0 disables).
    fallback: what to do on a miss with no eligible buddy:
              'fetch' — synchronous transfer of the true expert (lossless,
              slow; the paper's Original behavior), or 'drop' — skip the
              expert and renormalize (baseline MoE drop policy).
    mode:   'buddy' (the paper), 'random' (random-resident baseline),
            'none' (no substitution — Original baseline).
    quant_tier: precision of the always-resident compressed replica tier
            ('off' | 'int8' | 'int4', runtime/tiers.py). When on, a missed
            slot whose per-step quant_ok mask allows it is computed from the
            low-precision replica ('degraded') INSTEAD of falling back — the
            four-way miss decision becomes buddy / degraded / fetch / drop.
            Static under jit: 'off' compiles the exact pre-tier graph.
    miss_policy: how the four miss outcomes are resolved.
            'precedence' — the fixed chain (buddy, then degraded, then the
            global ``fallback``), the pre-cost-model behavior.
            'cost' — per-slot argmin of the unified expected-cost model
            (runtime/costs.py): every outcome is scored in stall-seconds via
            ``stall_per_quality`` and the cheapest wins, so a high-q buddy
            can beat a low-fidelity replica and vice versa. The per-slot
            scorer owns the fetch/drop choice, so ``fallback`` must stay at
            its 'fetch' default (it is unused).
    stall_per_quality: the single exchange rate (seconds of stall worth one
            unit of quality loss) that puts buddy Psi loss, replica
            fidelity error, and drop renormalization loss on the same scale
            as fetch stall. Generalizes the tier's ``stall_per_fidelity``.
    drop_loss: quality units lost by dropping a routed slot and
            renormalizing (the whole slot's mixture contribution; 1.0 makes
            drop the outcome of last resort).
    use_fused_dispatch: collapse the decode step's outcome-diverse dispatch
            (full-precision expert FFN + buddy-replica einsum + separate
            degraded dequant pass) into ONE dispatch that computes every
            outcome class with the right weights exactly once — the jnp
            megastep selects per-slot operands by outcome class, the Pallas
            path (kernels/grouped_ffn.py) bins slots by (resolved expert,
            class) into a single grouped launch. Static under jit: False
            (default) compiles the exact pre-fused graph, bit-identical to
            before the knob existed.
    """
    tau: float = 0.2
    beta: float = 0.6
    rho: int = 3
    H: int = 8
    eta: float = 0.0
    kappa: float = 0.0
    temperature: float = 1.0
    margin_gamma: float = 1.0
    fallback: str = "fetch"
    mode: str = "buddy"
    quant_tier: str = "off"
    miss_policy: str = "precedence"
    stall_per_quality: float = 0.05
    drop_loss: float = 1.0
    use_fused_dispatch: bool = False

    def __post_init__(self):
        assert self.fallback in ("fetch", "drop")
        assert self.mode in ("buddy", "random", "none")
        assert self.quant_tier in ("off", "int8", "int4")
        assert self.miss_policy in ("precedence", "cost")
        assert self.miss_policy == "precedence" or self.fallback == "fetch", \
            "miss_policy='cost' scores fetch vs drop per slot — the global " \
            "fallback knob is subsumed; leave it at 'fetch'"
        assert self.stall_per_quality > 0.0
        assert self.drop_loss >= 0.0
        assert self.rho >= 0 and self.H >= 1


ORIGINAL = BuddyPolicy(mode="none", fallback="fetch")
DROP = BuddyPolicy(mode="none", fallback="drop")
