"""The paper's three sequenced decision metrics (§3.1).

1. Token Activating Entropy (TAE, Eq. 1) — per-token substitution tolerance.
2. Expert Distribution gate (Eq. 2)      — batch-level CPU-residency fraction.
3. Buddy Selection Priority Psi (Eq. 3)  — computed in core/substitute.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tae_from_logits(topk_logits: jax.Array, temperature: float = 1.0) -> jax.Array:
    """TAE over the renormalized top-k distribution (Eq. 1).

    topk_logits: [..., K] router logits restricted to the selected set
    (optionally temperature-smoothed). Returns [...] in [0, 1].
    """
    k = topk_logits.shape[-1]
    if k <= 1:
        return jnp.zeros(topk_logits.shape[:-1], jnp.float32)
    p = jax.nn.softmax(topk_logits.astype(jnp.float32) / temperature, axis=-1)
    ent = -jnp.sum(p * jnp.log(jnp.maximum(p, 1e-20)), axis=-1)
    return ent / np.log(k)


def tae_from_probs(topk_probs: jax.Array) -> jax.Array:
    """TAE from already-renormalized top-k probabilities."""
    k = topk_probs.shape[-1]
    if k <= 1:
        return jnp.zeros(topk_probs.shape[:-1], jnp.float32)
    p = topk_probs.astype(jnp.float32)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
    ent = -jnp.sum(p * jnp.log(jnp.maximum(p, 1e-20)), axis=-1)
    return ent / np.log(k)


def prob_margin(topk_probs: jax.Array) -> jax.Array:
    """m = p_max - p_2nd over the renormalized top-k distribution."""
    if topk_probs.shape[-1] < 2:
        return jnp.ones(topk_probs.shape[:-1], jnp.float32)
    p = jnp.sort(topk_probs.astype(jnp.float32), axis=-1)
    return p[..., -1] - p[..., -2]


def token_gate(topk_logits: jax.Array, tau: float, temperature: float = 1.0,
               margin_gamma: float = 1.0) -> jax.Array:
    """True where substitution is ALLOWED (TAE > tau and margin < gamma)."""
    t = tae_from_logits(topk_logits, temperature)
    allow = t > tau
    if margin_gamma < 1.0:
        p = jax.nn.softmax(topk_logits.astype(jnp.float32) / temperature, -1)
        allow = allow & (prob_margin(p) < margin_gamma)
    return allow


def distribution_delta(indices: jax.Array, resident: jax.Array) -> jax.Array:
    """delta_l(B) (Eq. 2): fraction of *requested* experts that are CPU-resident.

    indices: [T, K] selected expert ids; resident: [E] bool. The requested set
    R_l(B) is the set of unique experts requested by the micro-batch.
    """
    e = resident.shape[0]
    onehot = jax.nn.one_hot(indices.reshape(-1), e, dtype=jnp.float32)
    requested = onehot.max(axis=0) > 0                     # [E]
    n_req = jnp.maximum(requested.sum(), 1.0)
    n_cpu = (requested & ~resident).sum()
    return n_cpu.astype(jnp.float32) / n_req


def distribution_gate(indices: jax.Array, resident: jax.Array,
                      beta: float) -> jax.Array:
    """True (scalar) when substitution is ALLOWED (delta < beta)."""
    return distribution_delta(indices, resident) < beta


def calibrate_tau(tae_samples: np.ndarray, percentile: float = 15.0) -> float:
    """Percentile calibration of tau from a profiling TAE distribution
    (§3.1: p in [10, 20])."""
    return float(np.percentile(np.asarray(tae_samples), percentile))
