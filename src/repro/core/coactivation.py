"""Offline profiling — expert activation and co-activation statistics (§3.2).

Per layer l we accumulate over a profiling corpus:
  A[i]    — activations: #tokens with i in S_l(x)                (Fig. 6)
  M[i,j]  — binary co-activations: #tokens with i,j in S_l(x)    (Figs. 7/9)
  W[i,j]  — probability-weighted co-activations:
            sum_x 1{i,j in S_l(x)} * min(p_i|x, p_j|x)           (§3.3 (i))

and derive the conditional co-activation distribution (Eq. 4):
  q_{j|i} = M[i,j] / sum_j' M[i,j'],  q_{i|i} = 0
with Laplace smoothing M <- M + eps (§3.3 (ii)) and optional warm-up
down-weighting (§3.3 (iii)).
"""
from __future__ import annotations

import numpy as np


class CoactivationRecorder:
    """Host-side accumulator (numpy). One instance per model; indexed by layer."""

    def __init__(self, num_layers: int, num_experts: int,
                 warmup_steps: int = 0, warmup_weight: float = 0.25):
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.A = np.zeros((num_layers, num_experts), np.float64)
        self.M = np.zeros((num_layers, num_experts, num_experts), np.float64)
        self.W = np.zeros((num_layers, num_experts, num_experts), np.float64)
        self.steps = 0
        self.warmup_steps = warmup_steps
        self.warmup_weight = warmup_weight

    def update(self, layer: int, indices, probs=None) -> None:
        """indices: [T, K] int expert ids; probs: [T, K] renormalized top-k."""
        indices = np.asarray(indices).reshape(-1, np.asarray(indices).shape[-1])
        t_n, k_n = indices.shape
        w = self.warmup_weight if self.steps < self.warmup_steps else 1.0
        onehot = np.zeros((t_n, self.num_experts), np.float64)
        rows = np.repeat(np.arange(t_n), k_n)
        onehot[rows, indices.reshape(-1)] = 1.0
        self.A[layer] += w * onehot.sum(0)
        m = onehot.T @ onehot                     # [E, E]; diag = A increment
        np.fill_diagonal(m, 0.0)
        self.M[layer] += w * m
        if probs is not None:
            probs = np.asarray(probs, np.float64).reshape(t_n, k_n)
            pmat = np.zeros((t_n, self.num_experts), np.float64)
            pmat[rows, indices.reshape(-1)] = probs.reshape(-1)
            # min(p_i, p_j) outer for co-activated pairs, chunked over tokens
            for s in range(0, t_n, 2048):
                chunk = pmat[s:s + 2048]
                act = chunk > 0
                pm = np.minimum(chunk[:, :, None], chunk[:, None, :])
                pm *= (act[:, :, None] & act[:, None, :])
                self.W[layer] += w * pm.sum(0)
        np.fill_diagonal(self.W[layer], 0.0)

    def step_done(self) -> None:
        self.steps += 1

    def conditional(self, layer: int, eps: float = 1e-3,
                    weighted: bool = False) -> np.ndarray:
        """q_{j|i} (Eq. 4) with Laplace smoothing. Rows sum to 1, diag 0."""
        m = (self.W if weighted else self.M)[layer] + eps
        np.fill_diagonal(m, 0.0)
        denom = m.sum(axis=1, keepdims=True)
        return m / np.maximum(denom, 1e-30)

    # ------------------------------------------------------------------
    # Analysis helpers (Figs. 6/7/9 reproduction)
    # ------------------------------------------------------------------
    def activation_skew(self, layer: int) -> dict:
        a = np.sort(self.A[layer])[::-1]
        total = max(a.sum(), 1e-30)
        cum = np.cumsum(a) / total
        lorenz = np.cumsum(np.sort(self.A[layer])) / total
        gini = 1.0 - 2.0 * np.trapezoid(lorenz, dx=1.0 / len(a))
        return {
            "counts": self.A[layer].copy(),
            "top1_share": float(a[0] / total),
            "top8_share": float(cum[min(7, len(a) - 1)]),
            "gini": float(gini),
        }

    def topr_coverage(self, layer: int, r: int) -> np.ndarray:
        """Per-pivot fraction of co-activation mass covered by top-r peers
        (the §3.2 'top-r peers cover a large majority' claim)."""
        q = self.conditional(layer)
        qs = np.sort(q, axis=1)[:, ::-1]
        return qs[:, :r].sum(axis=1)

    def save(self, path: str) -> None:
        np.savez_compressed(path, A=self.A, M=self.M, W=self.W,
                            steps=self.steps)

    @classmethod
    def load(cls, path: str) -> "CoactivationRecorder":
        d = np.load(path)
        rec = cls(d["A"].shape[0], d["A"].shape[1])
        rec.A, rec.M, rec.W = d["A"], d["M"], d["W"]
        rec.steps = int(d["steps"])
        return rec
