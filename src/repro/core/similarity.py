"""Expert output similarity on a calibration set (§1: buddies are
"identified via an offline analysis of co-activation patterns and output
similarity on a calibration dataset").

For each MoE layer we run EVERY expert on a sample of that layer's input
activations and compute the pairwise cosine similarity of their outputs.
This complements the co-activation signal q_{j|i} (Eq. 4): co-activation
says "these experts serve the same tokens", output similarity says "they
compute similar functions on those tokens".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def expert_output_similarity(moe_params: dict, xs: jax.Array) -> np.ndarray:
    """moe_params: one layer's MoE params (w1/w3/w2 [E, ...]); xs: [N, D]
    calibration activations. Returns [E, E] mean cosine similarity."""
    def one_expert(w1, w3, w2):
        h = jax.nn.silu(xs.astype(jnp.float32) @ w1.astype(jnp.float32))
        g = xs.astype(jnp.float32) @ w3.astype(jnp.float32)
        return (h * g) @ w2.astype(jnp.float32)        # [N, D]

    outs = jax.vmap(one_expert)(moe_params["w1"], moe_params["w3"],
                                moe_params["w2"])      # [E, N, D]
    norms = jnp.linalg.norm(outs, axis=-1) + 1e-8      # [E, N]
    unit = outs / norms[..., None]
    sim = jnp.einsum("end,fnd->ef", unit, unit) / xs.shape[0]
    return np.asarray(sim)


def collect_layer_inputs(cfg, params, tokens, layer_of_interest=None):
    """Calibration activations per MoE layer: the post-attention, pre-MoE
    normalized hidden states. Returns [L, N, D] (N = batch*seq)."""
    from repro.models import transformer

    captured = []

    # cheap approach: rerun forward and capture via aux recording of x?
    # Instead reuse the embedding stream: run the stack group-by-group with
    # a hook. For the 2-group-free moe family the stack is one scan; easiest
    # faithful capture is a python re-implementation over layers.
    from repro.configs.base import ATTN_MOE
    from repro.models.common import rmsnorm
    from repro.models import attention as A

    x = params["embed"][tokens]
    b, s, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    gp = params["groups"][0]
    n_layers = jax.tree.leaves(gp)[0].shape[0]
    for li in range(n_layers):
        lp = jax.tree.map(lambda a: a[li], gp)
        xn = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        h = A.attn_forward(lp["attn"], xn, positions,
                           num_heads=cfg.num_heads,
                           num_kv_heads=cfg.num_kv_heads,
                           head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
                           window=cfg.sliding_window)
        x = x + h
        xn = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        captured.append(xn.reshape(-1, d))
        from repro.models import moe as M
        y, _ = M.moe_forward(lp["moe"], xn, cfg.moe, capacity_factor=4.0)
        x = x + y
    return jnp.stack(captured)


def all_layer_similarities(cfg, params, tokens, max_tokens: int = 512):
    """[L, E, E] output-similarity matrices from a calibration batch."""
    xs = collect_layer_inputs(cfg, params, tokens)
    sims = []
    gp = params["groups"][0]
    n_layers = xs.shape[0]
    for li in range(n_layers):
        lp = jax.tree.map(lambda a: a[li], gp)
        sims.append(expert_output_similarity(lp["moe"], xs[li][:max_tokens]))
    return np.stack(sims)
