"""Algorithm 1 — Buddy Expert Substitution (reference jnp implementation).

The Pallas TPU kernel version lives in ``repro.kernels.buddy_substitute``;
this module is the oracle and the default path on CPU. Semantics follow the
paper exactly:

  for each token t, for each top-k slot k (in rank order):
    e = S[t, k]
    if e not resident and token passes gates and budget rho not exhausted:
      pick the eligible buddy maximizing Psi(j | e, t) among the first H
      ranked buddies; eligible = resident AND not already in U_t.
      Psi = q_{j|e} * (1 + eta * zhat_j(t)) * (1 - kappa * hop(j))   (Eq. 3)
      (with eta = kappa = 0 this is exactly "first resident unused buddy in
      table order", i.e. Algorithm 1.)
    if no eligible buddy: serve from the resident quant-replica tier when
    the caller's per-expert ``quant_ok`` mask allows it ('degraded'),
    otherwise fall back ('fetch' or 'drop' — recorded, decided by the
    caller via the returned masks).

Uniqueness (b not in U_t) subsumes the paper's multiplicative reuse penalty:
a buddy already claimed for token t can never be picked again for t.

Unified cost mode (policy.miss_policy='cost', runtime/costs.py): instead of
the fixed precedence above, every missed slot picks the ARGMIN of the five
outcome costs on one stall-seconds scale —

  buddy     stall_per_quality * (1 - Psi_best)   (gates/budget still apply)
  degraded  fid_cost[e]   caller-prepared stall_per_quality * fidelity
  peer      peer_cost[e]  caller-prepared expected stall of borrowing the
            full-precision expert from a peer device's HBM over ICI
            (multi-device meshes; inf where no peer holds it)
  fetch     fetch_cost[e] caller-prepared expected stall (in-flight ETA or
            modeled cold transfer)
  drop      stall_per_quality * drop_loss

so a high-q buddy beats a low-fidelity replica and vice versa, a
nearly-landed prefetch beats both, and the fetch/drop choice is per-slot.
Ties break toward the earlier outcome (buddy, then degraded, then peer):
at equal cost the transfer-free reroute wins, and a peer borrow beats an
equally-priced host fetch — the outcome codes match
``runtime.costs.{BUDDY,DEGRADED,PEER,FETCH,DROP}``. Single-device callers
never pass ``peer_cost``/``peer_ok`` and compile the exact four-outcome
graph this module always had.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.policy import BuddyPolicy


class SubstituteResult(NamedTuple):
    indices: jax.Array      # [T, K] int32 — possibly rewritten expert ids
    substituted: jax.Array  # [T, K] bool  — slot was replaced by a buddy
    missed: jax.Array       # [T, K] bool  — non-resident, resolved by fetch
    #                         (or the global fallback in precedence mode)
    allowed: jax.Array      # [T]   bool  — token passed TAE gate
    dist_ok: jax.Array      # []    bool  — batch passed distribution gate
    degraded: jax.Array = None  # [T, K] bool — miss served by the resident
    #                             quant-replica tier (excluded from missed)
    dropped: jax.Array = None   # [T, K] bool — miss dropped + renormalized
    #                             by the cost argmin (cost mode only; the
    #                             precedence drop path stays on ``missed``
    #                             with policy.fallback='drop')
    peered: jax.Array = None    # [T, K] bool — miss served by borrowing the
    #                             full-precision expert from a peer device's
    #                             HBM over ICI (multi-device meshes only;
    #                             excluded from ``missed``)


def _outcome_argmin(cost_b, cost_d, cost_f, cost_r, cost_p=None):
    """Per-slot argmin over the outcome costs, ties to the EARLIER outcome
    (buddy, then degraded, then peer, then fetch, then drop) so an equally
    priced transfer-free reroute always wins and a peer borrow beats an
    equally-priced host fetch. Returns the CANONICAL int codes [T]
    (runtime.costs numbering, 0..4) whether or not a peer row exists:
    without ``cost_p`` only four costs are stacked — single-device graphs
    stay four-wide — and the argmin is mapped through [0, 1, 3, 4]."""
    if cost_p is None:
        costs = jnp.stack([cost_b, cost_d, cost_f, cost_r], axis=-1)
        codes = jnp.asarray([0, 1, 3, 4], jnp.int32)
    else:
        costs = jnp.stack([cost_b, cost_d, cost_p, cost_f, cost_r], axis=-1)
        codes = jnp.asarray([0, 1, 2, 3, 4], jnp.int32)
    return jnp.take(codes, jnp.argmin(costs, axis=-1))


def substitute(indices: jax.Array,
               topk_logits: jax.Array,
               resident: jax.Array,
               buddy_table: jax.Array,
               buddy_q: jax.Array,
               policy: BuddyPolicy,
               router_logits: Optional[jax.Array] = None,
               hop: Optional[jax.Array] = None,
               quant_ok: Optional[jax.Array] = None,
               fid_cost: Optional[jax.Array] = None,
               fetch_cost: Optional[jax.Array] = None,
               peer_ok: Optional[jax.Array] = None,
               peer_cost: Optional[jax.Array] = None) -> SubstituteResult:
    """indices [T, K] int32; topk_logits [T, K] f32 (for TAE);
    resident [E] bool; buddy_table [E, R] int32 (-1 padded, sorted by q desc);
    buddy_q [E, R] f32; router_logits [T, E] (optional, for eta term);
    hop [E] int32 ICI hops to each expert's cache slot (optional; negative
    values are the cache's "not resident" sentinel and are clamped to 0 —
    eligible buddies are always resident so the clamp never changes Psi of
    a pickable candidate);
    quant_ok [E] bool (optional, precedence mode) — experts whose miss the
    runtime decided to serve from the resident quant-replica tier this step
    (the degraded fallback sits between buddy substitution and fetch/drop,
    and unlike substitution it is NOT subject to the TAE/distribution gates
    — it is a miss-path fallback, not a rerouting decision);
    fid_cost [E] f32 (cost mode) — stall_per_quality * replica fidelity
    error, inf where no replica is usable (runtime/costs.py);
    fetch_cost [E] f32 (cost mode) — expected stall seconds of fetching
    (in-flight ETA or modeled cold transfer), inf to forbid fetching;
    peer_ok [E] bool (optional, precedence mode) — experts resident in some
    peer device's HBM, whose miss the runtime serves by an ICI borrow
    (slots in between degraded and fetch in the precedence chain);
    peer_cost [E] f32 (cost mode) — expected stall seconds of the peer
    borrow (MissCostModel.peer_eta), inf where no peer holds the expert.
    Omitting both peer args (every single-device caller) compiles the
    pre-mesh four-outcome graph unchanged."""
    from repro.core import gates

    t_n, k_n = indices.shape
    e_n, r_n = buddy_table.shape
    h_n = min(policy.H, r_n)
    cost_mode = policy.miss_policy == "cost"
    # fetching is always physically possible, so an absent fetch_cost must
    # not default to +inf — the argmin would silently turn every residual
    # miss into a lossy drop (drop cost is always finite)
    assert not cost_mode or fetch_cost is not None, \
        "miss_policy='cost' requires fetch_cost [E] (expected fetch stall " \
        "per expert — runtime/costs.MissCostModel.fetch_eta)"
    xr = policy.stall_per_quality
    inf_e = jnp.full((e_n,), jnp.inf, jnp.float32)
    d_cost = (fid_cost.astype(jnp.float32) if fid_cost is not None
              else inf_e)
    f_cost = (fetch_cost.astype(jnp.float32) if fetch_cost is not None
              else inf_e)
    p_cost = (peer_cost.astype(jnp.float32) if peer_cost is not None
              else None)
    r_cost = jnp.float32(xr * policy.drop_loss)
    if hop is not None:
        # mask the cache's non-resident sentinel (-1): kappa must not turn
        # "absent" into a Psi *bonus*; eligibility already excludes them
        hop = jnp.maximum(hop, 0)

    allowed = gates.token_gate(topk_logits, policy.tau, policy.temperature,
                               policy.margin_gamma)                      # [T]
    dist_ok = gates.distribution_gate(indices, resident, policy.beta)    # []

    def _split_degraded(miss, experts):
        """(residual_miss, degraded): route quant_ok misses to the tier."""
        if quant_ok is None:
            return miss, jnp.zeros_like(miss)
        deg = miss & quant_ok[experts]
        return miss & ~deg, deg

    def _split_peer(miss, experts):
        """(residual_miss, peered): route peer-resident misses to an ICI
        borrow. Sits after degraded in the precedence chain: a zero-stall
        resident replica beats a (cheap but nonzero) peer transfer."""
        if peer_ok is None:
            return miss, jnp.zeros_like(miss)
        peer = miss & peer_ok[experts]
        return miss & ~peer, peer

    if policy.mode == "none":
        miss = ~resident[indices] & True
        if cost_mode:
            # no rerouting: argmin over degraded / peer / fetch / drop
            out = _outcome_argmin(jnp.full(indices.shape, jnp.inf),
                                  d_cost[indices], f_cost[indices],
                                  jnp.full(indices.shape, r_cost),
                                  None if p_cost is None
                                  else p_cost[indices])
            deg = miss & (out == 1)
            drp = miss & (out == 4)
            return SubstituteResult(indices, jnp.zeros_like(miss),
                                    miss & (out == 3), allowed, dist_ok,
                                    deg, drp, miss & (out == 2))
        miss, deg = _split_degraded(miss, indices)
        miss, peer = _split_peer(miss, indices)
        return SubstituteResult(indices, jnp.zeros_like(miss), miss,
                                allowed, dist_ok, deg,
                                jnp.zeros_like(miss), peer)

    gate = allowed & dist_ok                                             # [T]

    if policy.eta != 0.0 and router_logits is not None:
        zr = router_logits.astype(jnp.float32)
        zhat = (zr - zr.mean(-1, keepdims=True)) / (zr.std(-1, keepdims=True) + 1e-6)
    else:
        zhat = None

    new_idx = indices
    substituted = jnp.zeros((t_n, k_n), bool)
    missed = jnp.zeros((t_n, k_n), bool)
    degraded = jnp.zeros((t_n, k_n), bool)
    dropped = jnp.zeros((t_n, k_n), bool)
    peered = jnp.zeros((t_n, k_n), bool)
    budget = jnp.where(gate, policy.rho, 0).astype(jnp.int32)            # [T]

    for k in range(k_n):
        e = new_idx[:, k]                                                # [T]
        miss_k = ~resident[e]                                            # [T]
        can_sub = gate & (budget > 0)                                    # [T]

        cand = buddy_table[e][:, :h_n]                                   # [T, H]
        q = buddy_q[e][:, :h_n].astype(jnp.float32)                      # [T, H]
        valid = cand >= 0
        cand_safe = jnp.maximum(cand, 0)
        elig = valid & resident[cand_safe]                               # [T, H]
        # uniqueness: candidate must not already be assigned to this token
        in_row = (cand_safe[:, :, None] == new_idx[:, None, :]).any(-1)  # [T, H]
        elig = elig & ~in_row

        psi = q
        if zhat is not None:
            psi = psi * (1.0 + policy.eta * jnp.take_along_axis(
                zhat, cand_safe, axis=1))
        if policy.kappa != 0.0 and hop is not None:
            psi = psi * (1.0 - policy.kappa * hop[cand_safe].astype(jnp.float32))
        # strictly-descending tie-break so argmax == lowest rank on equal Psi
        psi = psi - jnp.arange(h_n, dtype=jnp.float32) * 1e-7
        psi = jnp.where(elig, psi, -jnp.inf)

        best = jnp.argmax(psi, axis=-1)                                  # [T]
        found = jnp.take_along_axis(elig, best[:, None], 1)[:, 0]        # [T]
        buddy = jnp.take_along_axis(cand_safe, best[:, None], 1)[:, 0]   # [T]
        psi_best = jnp.take_along_axis(psi, best[:, None], 1)[:, 0]      # [T]

        if cost_mode:
            # unified argmin: the buddy option carries its Psi quality loss,
            # the others the caller-prepared per-expert costs
            cost_b = jnp.where(can_sub & found,
                               xr * (1.0 - jnp.clip(psi_best, 0.0, 1.0)),
                               jnp.inf)
            out = _outcome_argmin(cost_b, d_cost[e], f_cost[e],
                                  jnp.full((t_n,), r_cost),
                                  None if p_cost is None else p_cost[e])
            do_sub = miss_k & (out == 0)
            deg_col = miss_k & (out == 1)
            peer_col = miss_k & (out == 2)
            res_miss = miss_k & (out == 3)
            dropped = dropped.at[:, k].set(miss_k & (out == 4))
            new_col = jnp.where(do_sub, buddy, e)
        else:
            do_sub = miss_k & can_sub & found
            new_col = jnp.where(do_sub, buddy, e)
            res_miss = (~resident[new_col]) & ~do_sub
            res_miss, deg_col = _split_degraded(res_miss, new_col)
            res_miss, peer_col = _split_peer(res_miss, new_col)
        new_idx = new_idx.at[:, k].set(new_col)
        substituted = substituted.at[:, k].set(do_sub)
        missed = missed.at[:, k].set(res_miss)
        degraded = degraded.at[:, k].set(deg_col)
        peered = peered.at[:, k].set(peer_col)
        budget = budget - do_sub.astype(jnp.int32)

    return SubstituteResult(new_idx, substituted, missed, allowed, dist_ok,
                            degraded, dropped, peered)


def make_random_table(key, num_experts: int, r_max: int) -> tuple:
    """Random-substitution baseline: each expert's 'buddy list' is a uniform
    random permutation of the other experts (uninformed comparison point)."""
    def row(k, i):
        perm = jax.random.permutation(k, num_experts)
        perm = perm[perm != i][:r_max]
        return perm
    keys = jax.random.split(key, num_experts)
    table = jnp.stack([row(keys[i], i) for i in range(num_experts)])
    q = jnp.full(table.shape, 1.0 / max(num_experts - 1, 1), jnp.float32)
    return table.astype(jnp.int32), q
