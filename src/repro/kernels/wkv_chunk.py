"""Pallas TPU kernel: chunkwise-parallel WKV (RWKV6 time-mix hot loop).

Computes, per (batch x head) lane and chunk n (sequential grid axis — the
recurrent state is carried across grid steps in the output ref):

    o_n   = r~_n @ S  +  [lower(r~_n k~_n^T) + diag(dg_n)] @ v_n
    S     = exp(laE_n) * S + k_end_n^T @ v_n

Inputs are the decay-factorized tensors produced by
``models/rwkv.wkv_chunked`` (r~ = r*exp(la_{t-1}), k~ = k*exp(-la),
k_end = k*exp(la_C - la)). Blocks are [C, D] with C = chunk (32) and
D = head_dim (64..128): a handful of KiB — the whole working set sits in
VMEM and both matmuls hit the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(rt_ref, kt_ref, v_ref, ke_ref, lae_ref, dg_ref, s0_ref,
            o_ref, s_ref, *, chunk: int):
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        s_ref[...] = s0_ref[...]

    s = s_ref[0]                                   # [D, D]
    rt = rt_ref[0, 0]                              # [C, D]
    kt = kt_ref[0, 0]
    v = v_ref[0, 0]
    ke = ke_ref[0, 0]
    lae = lae_ref[0, 0]                            # [D]
    dg = dg_ref[0, 0]                              # [C]

    o_inter = jnp.dot(rt, s, preferred_element_type=jnp.float32)
    scores = jnp.dot(rt, kt.T, preferred_element_type=jnp.float32)
    c_iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(r_iota < c_iota, scores, 0.0)   # strictly lower
    o_intra = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    o_ref[0, 0] = o_inter + o_intra + dg[:, None] * v

    s_new = jnp.exp(lae)[:, None] * s + jnp.dot(
        ke.T, v, preferred_element_type=jnp.float32)
    s_ref[...] = s_new[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_chunk_pallas(rt, kt, v, ke, lae, dg, s0, *, interpret: bool = False):
    """rt/kt/v/ke: [BH, N, C, D] f32; lae: [BH, N, D]; dg: [BH, N, C];
    s0: [BH, D, D]. Returns (o [BH, N, C, D], s_final [BH, D, D])."""
    bh, n, c, d = rt.shape
    out, s_fin = pl.pallas_call(
        functools.partial(_kernel, chunk=c),
        grid=(bh, n),
        in_specs=[
            pl.BlockSpec((1, 1, c, d), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, 1, c, d), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, 1, c, d), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, 1, c, d), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1, c), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, d, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, d), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, d, d), lambda b, i: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, c, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, d, d), jnp.float32),
        ],
        interpret=interpret,
    )(rt.astype(jnp.float32), kt.astype(jnp.float32), v.astype(jnp.float32),
      ke.astype(jnp.float32), lae.astype(jnp.float32),
      dg.astype(jnp.float32), s0.astype(jnp.float32))
    return out, s_fin
