"""Pallas TPU kernel: fused MoE router gate.

Fuses, per token tile, in one VMEM pass over the router logits:
  top-k selection (iterative max, K statically unrolled),
  renormalized top-k probabilities,
  Token Activating Entropy (Eq. 1) and the TAE gate (TAE > tau).

This is the hot prologue of every MoE layer in the serving path; fusing it
avoids materializing softmax(logits) [T, E] plus three follow-up elementwise
passes in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TOKEN_BLOCK = 256
NEG = -1e30


def _kernel(z_ref, tau_ref, idx_ref, val_ref, prob_ref, tae_ref, allow_ref,
            *, k_n: int):
    z = z_ref[...].astype(jnp.float32)          # [T, E]
    tau = tau_ref[0]
    t_n, e_n = z.shape

    zm = z
    idxs, vals = [], []
    iota = jax.lax.broadcasted_iota(jnp.int32, (t_n, e_n), 1)
    for _ in range(k_n):
        v = jnp.max(zm, axis=1)                                   # [T]
        is_max = (zm == v[:, None])
        # first argmax: smallest index among maxima
        i = jnp.min(jnp.where(is_max, iota, e_n), axis=1).astype(jnp.int32)
        sel = (iota == i[:, None])
        zm = jnp.where(sel, NEG, zm)
        idxs.append(i)
        vals.append(v)
    idx = jnp.stack(idxs, axis=1)                                 # [T, K]
    val = jnp.stack(vals, axis=1)

    # renormalized top-k softmax
    mx = val[:, 0:1]
    p = jnp.exp(val - mx)
    p = p / jnp.maximum(p.sum(axis=1, keepdims=True), 1e-20)

    if k_n > 1:
        ent = -jnp.sum(p * jnp.log(jnp.maximum(p, 1e-20)), axis=1)
        tae = ent / math.log(k_n)
    else:
        tae = jnp.zeros((t_n,), jnp.float32)

    idx_ref[...] = idx
    val_ref[...] = val
    prob_ref[...] = p
    tae_ref[...] = tae
    allow_ref[...] = (tae > tau).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_gate_pallas(logits, tau, *, k: int, interpret: bool = False):
    """logits [T, E] f32; tau scalar. Returns (idx [T,K] i32, vals [T,K] f32,
    probs [T,K] f32, tae [T] f32, allow [T] bool)."""
    t_n, e_n = logits.shape
    tb = min(TOKEN_BLOCK, t_n)
    pad = (-t_n) % tb
    zp = jnp.pad(logits, ((0, pad), (0, 0)))
    grid = (zp.shape[0] // tb,)
    tau_arr = jnp.asarray([tau], jnp.float32)

    outs = pl.pallas_call(
        functools.partial(_kernel, k_n=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, e_n), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tb, k), lambda i: (i, 0)),
            pl.BlockSpec((tb, k), lambda i: (i, 0)),
            pl.BlockSpec((tb, k), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((zp.shape[0], k), jnp.int32),
            jax.ShapeDtypeStruct((zp.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((zp.shape[0], k), jnp.float32),
            jax.ShapeDtypeStruct((zp.shape[0],), jnp.float32),
            jax.ShapeDtypeStruct((zp.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(zp.astype(jnp.float32), tau_arr)
    idx, val, prob, tae, allow = outs
    return (idx[:t_n], val[:t_n], prob[:t_n], tae[:t_n],
            allow[:t_n].astype(bool))
