"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU; see ops.py for jit'd wrappers and ref.py for the oracles):

  buddy_substitute — Algorithm 1 (the paper's CUDA kernel, TPU-adapted)
  topk_gate        — fused router top-k + renorm + TAE gate
  expert_ffn       — grouped expert SwiGLU over dispatch buffers
  quant_ffn        — fused dequant + SwiGLU over int8/int4 tier replicas
  grouped_ffn      — single-dispatch four-way miss outcome (full-precision
                     + buddy + degraded in ONE launch; dropped slots never
                     binned)
  wkv_chunk        — chunkwise-parallel RWKV6 WKV (§Perf B1 hot loop)
"""
