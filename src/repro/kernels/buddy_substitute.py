"""Pallas TPU kernel for Algorithm 1 — Buddy Expert Substitution.

TPU adaptation of the paper's CUDA kernel (see DESIGN.md §3): the paper maps
one thread block per token and one thread per top-k slot, with shared-memory +
atomic CAS for the uniqueness set. The TPU has no independent threads or
atomics, so we invert the parallelization:

  * the TOKEN axis is tiled across the Pallas grid and fully vectorized
    across VPU lanes within a block;
  * the K slots (<= 8) and buddy ranks (<= H <= 16) are *statically unrolled
    sequential* loops inside the kernel body — because slot k+1 sees slot k's
    substitution in VREGs, the uniqueness constraint needs no CAS at all;
  * expert-indexed lookups (residency M[e], buddy row B[e, r]) are expressed
    as one-hot matmul selects over the (small, E <= 256) expert axis so the
    whole body lowers to VPU ops — no dynamic gathers.

The residency mask, buddy profile and q-values fit in VMEM for every
assigned arch (E <= 64, R <= 16: < 10 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TOKEN_BLOCK = 256


def _onehot_select(ids, table_col):
    """Vectorized gather table_col[ids] via one-hot matmul.

    ids: [T] int32 in [0, E); table_col: [E] (f32). Returns [T] f32.
    """
    e = table_col.shape[0]
    onehot = (ids[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, e), 1))
    return jnp.sum(onehot.astype(jnp.float32) * table_col[None, :], axis=1)


def _kernel(s_ref, gate_ref, m_ref, b_ref, q_ref, out_ref, sub_ref, miss_ref,
            *, k_n: int, h_n: int, rho: int):
    s = s_ref[...]                      # [T, K] int32
    gate = gate_ref[...]                # [T] int32 (1 = substitution allowed)
    m = m_ref[...].astype(jnp.float32)  # [E] residency (1 = GPU)
    b = b_ref[...]                      # [E, R] int32 buddy ids (-1 pad)
    q = q_ref[...].astype(jnp.float32)  # [E, R]

    t_n = s.shape[0]
    budget = jnp.where(gate > 0, rho, 0).astype(jnp.int32)   # [T]
    new_s = s
    sub = jnp.zeros((t_n, k_n), jnp.int32)
    miss = jnp.zeros((t_n, k_n), jnp.int32)

    for k in range(k_n):
        e = new_s[:, k]                                       # [T]
        res_e = _onehot_select(e, m) > 0.5                    # [T]
        need = (~res_e) & (gate > 0) & (budget > 0)           # [T]

        best_psi = jnp.full((t_n,), -jnp.inf, jnp.float32)
        best_b = jnp.full((t_n,), -1, jnp.int32)
        for r in range(h_n):
            b_r = _onehot_select(e, b[:, r].astype(jnp.float32)).astype(jnp.int32)
            q_r = _onehot_select(e, q[:, r])
            valid = b_r >= 0
            b_safe = jnp.maximum(b_r, 0)
            res_b = _onehot_select(b_safe, m) > 0.5
            in_row = jnp.zeros((t_n,), bool)
            for kk in range(k_n):
                in_row = in_row | (new_s[:, kk] == b_safe)
            elig = valid & res_b & (~in_row)
            psi = q_r - r * 1e-7                              # rank tie-break
            better = elig & (psi > best_psi)
            best_psi = jnp.where(better, psi, best_psi)
            best_b = jnp.where(better, b_safe, best_b)

        do_sub = need & (best_b >= 0)
        new_col = jnp.where(do_sub, best_b, e)
        new_s = jnp.concatenate(
            [new_s[:, :k], new_col[:, None], new_s[:, k + 1:]], axis=1)
        sub = jnp.concatenate(
            [sub[:, :k], do_sub.astype(jnp.int32)[:, None], sub[:, k + 1:]], axis=1)
        miss_col = ((~res_e) & (~do_sub)).astype(jnp.int32)
        miss = jnp.concatenate(
            [miss[:, :k], miss_col[:, None], miss[:, k + 1:]], axis=1)
        budget = budget - do_sub.astype(jnp.int32)

    out_ref[...] = new_s
    sub_ref[...] = sub
    miss_ref[...] = miss


@functools.partial(jax.jit, static_argnames=("h", "rho", "interpret"))
def buddy_substitute_pallas(s, gate, resident, table, q, *, h: int = 8,
                            rho: int = 3, interpret: bool = False):
    """s [T, K] int32; gate [T] bool; resident [E] bool;
    table [E, R] int32; q [E, R] f32.
    Returns (s' [T, K], substituted [T, K] bool, missed [T, K] bool)."""
    t_n, k_n = s.shape
    e_n, r_n = table.shape
    h_n = min(h, r_n)

    tb = min(TOKEN_BLOCK, t_n)
    pad = (-t_n) % tb
    sp = jnp.pad(s, ((0, pad), (0, 0)))
    gp = jnp.pad(gate.astype(jnp.int32), (0, pad))
    grid = (sp.shape[0] // tb,)

    kernel = functools.partial(_kernel, k_n=k_n, h_n=h_n, rho=rho)
    out, sub, miss = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, k_n), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((e_n,), lambda i: (0,)),
            pl.BlockSpec((e_n, r_n), lambda i: (0, 0)),
            pl.BlockSpec((e_n, r_n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, k_n), lambda i: (i, 0)),
            pl.BlockSpec((tb, k_n), lambda i: (i, 0)),
            pl.BlockSpec((tb, k_n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(sp.shape, jnp.int32),
            jax.ShapeDtypeStruct(sp.shape, jnp.int32),
            jax.ShapeDtypeStruct(sp.shape, jnp.int32),
        ],
        interpret=interpret,
    )(sp, gp, resident.astype(jnp.int32), table, q.astype(jnp.float32))
    return out[:t_n], sub[:t_n].astype(bool), miss[:t_n].astype(bool)
