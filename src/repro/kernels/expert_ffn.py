"""Pallas TPU kernel: grouped expert SwiGLU FFN over dispatch buffers.

Computes, per expert e:  out[e] = (silu(x[e] @ w1[e]) * (x[e] @ w3[e])) @ w2[e]

This is THE compute hot-spot of offloaded MoE inference (the expert forward
the paper's substitutions keep on-device). Tiling (MXU-aligned, multiples of
128):

  grid = (E, C/BC, F/BF)   — expert, token-chunk tile, hidden tile
  x    block [1, BC, D]    — revisited across the F axis (stays in VMEM)
  w1/w3 blocks [1, D, BF], w2 block [1, BF, D]
  out  block [1, BC, D] accumulated in f32 across the F-tile axis
  (SwiGLU's elementwise product is local to each F tile, so the second
  matmul's F-contraction can be accumulated tile-by-tile.)

VMEM @ (BC, BF, D) = (128, 256, 4096), bf16 weights:
  x 1 MiB + w1/w3 4 MiB + w2 2 MiB + out(f32) 2 MiB ~= 9 MiB < 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w1_ref, w3_ref, w2_ref, out_ref, *, n_f_tiles: int):
    f_idx = pl.program_id(2)

    @pl.when(f_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[0]                        # [BC, D]
    w1 = w1_ref[0]                      # [D, BF]
    w3 = w3_ref[0]
    w2 = w2_ref[0]                      # [BF, D]
    h = jax.nn.silu(jnp.dot(x, w1, preferred_element_type=jnp.float32))
    g = jnp.dot(x, w3, preferred_element_type=jnp.float32)
    hg = (h * g).astype(x.dtype)
    out_ref[0] += jnp.dot(hg, w2, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "interpret"))
def expert_ffn_pallas(x, w1, w3, w2, *, block_c: int = 128,
                      block_f: int = 256, interpret: bool = False):
    """x [E, C, D]; w1/w3 [E, D, F]; w2 [E, F, D]. Returns [E, C, D] (x.dtype)."""
    e_n, c_n, d_n = x.shape
    f_n = w1.shape[2]
    bc = min(block_c, c_n)
    bf = min(block_f, f_n)
    pad_c = (-c_n) % bc
    pad_f = (-f_n) % bf
    xp = jnp.pad(x, ((0, 0), (0, pad_c), (0, 0)))
    w1p = jnp.pad(w1, ((0, 0), (0, 0), (0, pad_f)))
    w3p = jnp.pad(w3, ((0, 0), (0, 0), (0, pad_f)))
    w2p = jnp.pad(w2, ((0, 0), (0, pad_f), (0, 0)))
    n_c, n_f = xp.shape[1] // bc, w1p.shape[2] // bf
    grid = (e_n, n_c, n_f)

    out = pl.pallas_call(
        functools.partial(_kernel, n_f_tiles=n_f),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, d_n), lambda e, c, f: (e, c, 0)),
            pl.BlockSpec((1, d_n, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, d_n, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, bf, d_n), lambda e, c, f: (e, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d_n), lambda e, c, f: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((e_n, xp.shape[1], d_n), jnp.float32),
        interpret=interpret,
    )(xp, w1p, w3p, w2p)
    return out[:, :c_n].astype(x.dtype)
