"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python/XLA-CPU for correctness validation; on TPU they compile
via Mosaic. ``interpret`` is chosen automatically from the backend.
"""
from __future__ import annotations

import jax

from repro.kernels.buddy_substitute import buddy_substitute_pallas
from repro.kernels.expert_ffn import expert_ffn_pallas
from repro.kernels.grouped_ffn import grouped_ffn_pallas
from repro.kernels.quant_ffn import quant_ffn_pallas
from repro.kernels.topk_gate import topk_gate_pallas
from repro.kernels.wkv_chunk import wkv_chunk_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def buddy_substitute(s, gate, resident, table, q, *, h: int = 8, rho: int = 3):
    return buddy_substitute_pallas(s, gate, resident, table, q, h=h, rho=rho,
                                   interpret=_interpret())


def topk_gate(logits, tau, *, k: int):
    return topk_gate_pallas(logits, tau, k=k, interpret=_interpret())


def expert_ffn(x, w1, w3, w2, *, block_c: int = 128, block_f: int = 256):
    return expert_ffn_pallas(x, w1, w3, w2, block_c=block_c, block_f=block_f,
                             interpret=_interpret())


def quant_ffn(x, w1_q, w1_s, w3_q, w3_s, w2_q, w2_s, *,
              block_c: int = 128, block_f: int = 256):
    """Fused dequant + grouped SwiGLU over int8/int4 tier replicas."""
    return quant_ffn_pallas(x, w1_q, w1_s, w3_q, w3_s, w2_q, w2_s,
                            block_c=block_c, block_f=block_f,
                            interpret=_interpret())


def grouped_ffn(x, w1, w3, w2, w1_q, w1_s, w3_q, w3_s, w2_q, w2_s, *,
                block_c: int = 128, block_f: int = 256):
    """Single-dispatch four-way miss outcome: x [2E, C, D] binned by
    (resolved expert, outcome class) — groups [0, E) full-precision/buddy,
    [E, 2E) degraded (quant replica, post-matmul dequant). Dropped slots
    are never binned. Returns [2E, C, D]."""
    return grouped_ffn_pallas(x, w1, w3, w2, w1_q, w1_s, w3_q, w3_s,
                              w2_q, w2_s, block_c=block_c, block_f=block_f,
                              interpret=_interpret())


def wkv_chunk(rt, kt, v, ke, lae, dg, s0):
    return wkv_chunk_pallas(rt, kt, v, ke, lae, dg, s0,
                            interpret=_interpret())
