"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import BuddyPolicy
from repro.core.substitute import substitute as _core_substitute


def ref_buddy_substitute(s, gate, resident, table, q, *, h: int = 8,
                         rho: int = 3):
    """Oracle for kernels.buddy_substitute. Wraps the core (Alg. 1) reference
    with the gate supplied externally (the kernel takes gate as an input)."""
    import numpy as np
    s = np.asarray(s)
    gate = np.asarray(gate)
    resident = np.asarray(resident)
    table = np.asarray(table)
    q = np.asarray(q)
    t_n, k_n = s.shape
    h_n = min(h, table.shape[1])

    out = s.copy()
    sub = np.zeros_like(s, bool)
    miss = np.zeros_like(s, bool)
    for t in range(t_n):
        budget = rho if gate[t] else 0
        for k in range(k_n):
            e = out[t, k]
            if resident[e]:
                continue
            if not gate[t] or budget <= 0:
                miss[t, k] = True
                continue
            # argmax Psi == first eligible in table order (q sorted desc,
            # rank tie-break)
            best, best_psi = -1, -np.inf
            for r in range(h_n):
                b = table[e, r]
                if b < 0:
                    continue
                if not resident[b]:
                    continue
                if b in out[t]:
                    continue
                psi = q[e, r] - r * 1e-7
                if psi > best_psi:
                    best_psi, best = psi, b
            if best >= 0:
                out[t, k] = best
                sub[t, k] = True
                budget -= 1
            else:
                miss[t, k] = True
    return (jnp.asarray(out), jnp.asarray(sub), jnp.asarray(miss))


def ref_topk_gate(logits, tau, *, k: int):
    """Oracle for kernels.topk_gate (jax.lax.top_k based)."""
    z = logits.astype(jnp.float32)
    vals, idx = jax.lax.top_k(z, k)
    p = jax.nn.softmax(vals, axis=-1)
    if k > 1:
        ent = -jnp.sum(p * jnp.log(jnp.maximum(p, 1e-20)), axis=-1)
        tae = ent / jnp.log(float(k))
    else:
        tae = jnp.zeros(z.shape[:-1], jnp.float32)
    return idx.astype(jnp.int32), vals, p, tae, tae > tau


def ref_expert_ffn(x, w1, w3, w2):
    """Oracle for kernels.expert_ffn: grouped SwiGLU, f32 accumulation."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w1,
                               preferred_element_type=jnp.float32))
    g = jnp.einsum("ecd,edf->ecf", x, w3, preferred_element_type=jnp.float32)
    hg = (h * g).astype(x.dtype)
    return jnp.einsum("ecf,efd->ecd", hg, w2,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def dequant_swiglu(x, w1_q, w1_s, w3_q, w3_s, w2_q, w2_s):
    """THE dequant+SwiGLU reference — shared by the quant_ffn / grouped_ffn
    oracles AND the model's degraded fallback (models.moe), so the oracle
    and the in-model path cannot drift.

    x [..., C, D] f32-castable; w1_q/w3_q [..., D, F] int8 with scales
    [..., F]; w2_q [..., F, D] int8 with scales [..., D]. Leading dims
    broadcast through jnp.matmul (e.g. [E, C, D] binned buffers or
    [N, 1, D] per-slot rows). Scales are per OUTPUT channel and applied
    post-matmul (they commute with the contraction). Returns [..., C, D]
    f32."""
    xf = x.astype(jnp.float32)
    h = jax.nn.silu((xf @ w1_q.astype(jnp.float32)) * w1_s[..., None, :])
    g = (xf @ w3_q.astype(jnp.float32)) * w3_s[..., None, :]
    return ((h * g) @ w2_q.astype(jnp.float32)) * w2_s[..., None, :]


def ref_quant_ffn(x, w1_q, w1_s, w3_q, w3_s, w2_q, w2_s):
    """Oracle for kernels.quant_ffn: dequantize per output channel, then the
    grouped SwiGLU in f32 (same post-matmul scale placement as the kernel)."""
    out = dequant_swiglu(x, w1_q, w1_s, w3_q, w3_s, w2_q, w2_s)
    return out.astype(x.dtype)


def ref_grouped_ffn(x, w1, w3, w2, w1_q, w1_s, w3_q, w3_s, w2_q, w2_s):
    """Oracle for kernels.grouped_ffn — the single-dispatch four-way miss
    kernel. x [2E, C, D]: groups [0, E) are the full-precision class
    (expert g, expert_ffn numerics — buddy-substituted and fetch-resolved
    slots land here at their resolved id) and groups [E, 2E) the degraded
    class (expert g - E against the quant replica, quant_ffn numerics).
    Dropped slots are never binned, so their rows are zero on both sides.
    Returns [2E, C, D] in x.dtype."""
    e_n = w1.shape[0]
    assert x.shape[0] == 2 * e_n, \
        f"ref_grouped_ffn: expected {2 * e_n} groups, got {x.shape[0]}"
    full = ref_expert_ffn(x[:e_n], w1, w3, w2).astype(jnp.float32)
    deg = dequant_swiglu(x[e_n:], w1_q, w1_s, w3_q, w3_s, w2_q, w2_s)
    return jnp.concatenate([full, deg], axis=0).astype(x.dtype)


def ref_wkv_chunk(rt, kt, v, ke, lae, dg, s0):
    """Oracle for kernels.wkv_chunk: sequential chunk loop in jnp.
    rt/kt/v/ke [BH, N, C, D]; lae [BH, N, D]; dg [BH, N, C]; s0 [BH, D, D].
    """
    bh, n, c, d = rt.shape
    mask = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)

    def step(s, inp):
        r_, k_, v_, ke_, laE, dg_ = inp
        o_inter = jnp.einsum("bcd,bde->bce", r_, s)
        scores = jnp.einsum("bcd,bsd->bcs", r_, k_) * mask[None]
        o = o_inter + jnp.einsum("bcs,bse->bce", scores, v_) \
            + dg_[..., None] * v_
        s_new = jnp.exp(laE)[..., None] * s + jnp.einsum("bsd,bse->bde",
                                                         ke_, v_)
        return s_new, o

    import jax
    swap = lambda x: jnp.swapaxes(x, 0, 1)  # noqa: E731
    s_fin, out = jax.lax.scan(
        step, s0.astype(jnp.float32),
        (swap(rt), swap(kt), swap(v), swap(ke), swap(lae), swap(dg)))
    return swap(out), s_fin
