"""Pallas TPU kernel: fused dequant + grouped expert SwiGLU over the
int8/int4 resident replica tier.

Computes, per expert e (weights quantized per output channel, scales f32):

  h   = silu((x[e] @ w1q[e]) * s1[e])        # dequant applied POST-matmul
  g   = (x[e] @ w3q[e]) * s3[e]              # (scale depends only on the
  out = ((h * g) @ w2q[e]) * s2[e]           #  output channel, so it
                                             #  commutes with the matmul)

This is the degraded-fallback compute path of the tiered expert store: a
prefetch miss whose buddy search failed executes against the always-resident
low-precision replica instead of stalling on PCIe. Reading int8 weights also
halves (int8) or quarters (int4-payload) the HBM traffic of the miss path vs
streaming bf16 — the tier is cheaper to COMPUTE from, not just to store.

Tiling mirrors kernels/expert_ffn.py (MXU-aligned):

  grid = (E, C/BC, F/BF)   — expert, token-chunk tile, hidden tile
  x     block [1, BC, D]   — revisited across the F axis (stays in VMEM)
  w1q/w3q blocks [1, D, BF] int8; s1/s3 blocks [1, 1, BF] f32
  w2q   block [1, BF, D] int8;    s2 block [1, 1, D] f32
  out   block [1, BC, D] accumulated in f32 across the F-tile axis

VMEM @ (BC, BF, D) = (128, 256, 4096): int8 w1/w3/w2 halve the 6 MiB the
bf16 kernel streams per tile — the quant tier's whole point on-chip too.

int4 replicas arrive as int8 values in [-7, 7] (core/quantize.py stores them
unpacked); the kernel is precision-agnostic past the value range.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w1_ref, s1_ref, w3_ref, s3_ref, w2_ref, s2_ref, out_ref):
    f_idx = pl.program_id(2)

    @pl.when(f_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[0].astype(jnp.float32)            # [BC, D]
    w1 = w1_ref[0].astype(jnp.float32)          # [D, BF] dequant via s1
    w3 = w3_ref[0].astype(jnp.float32)
    w2 = w2_ref[0].astype(jnp.float32)          # [BF, D] dequant via s2
    s1 = s1_ref[0]                              # [1, BF]
    s3 = s3_ref[0]
    s2 = s2_ref[0]                              # [1, D]
    h = jax.nn.silu(jnp.dot(x, w1, preferred_element_type=jnp.float32) * s1)
    g = jnp.dot(x, w3, preferred_element_type=jnp.float32) * s3
    out_ref[0] += jnp.dot(h * g, w2, preferred_element_type=jnp.float32) * s2


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "interpret"))
def quant_ffn_pallas(x, w1_q, w1_s, w3_q, w3_s, w2_q, w2_s, *,
                     block_c: int = 128, block_f: int = 256,
                     interpret: bool = False):
    """x [E, C, D] (f32/bf16); w1_q/w3_q [E, D, F] int8 with scales [E, F];
    w2_q [E, F, D] int8 with scales [E, D]. Returns [E, C, D] in x.dtype."""
    e_n, c_n, d_n = x.shape
    f_n = w1_q.shape[2]
    bc = min(block_c, c_n)
    bf = min(block_f, f_n)
    pad_c = (-c_n) % bc
    pad_f = (-f_n) % bf
    xp = jnp.pad(x, ((0, 0), (0, pad_c), (0, 0)))
    w1p = jnp.pad(w1_q, ((0, 0), (0, 0), (0, pad_f)))
    w3p = jnp.pad(w3_q, ((0, 0), (0, 0), (0, pad_f)))
    w2p = jnp.pad(w2_q, ((0, 0), (0, pad_f), (0, 0)))
    # padded hidden channels have zero weights -> zero contribution; pad the
    # scales with ones so the dequant multiply stays finite
    s1p = jnp.pad(w1_s, ((0, 0), (0, pad_f)), constant_values=1.0)[:, None, :]
    s3p = jnp.pad(w3_s, ((0, 0), (0, pad_f)), constant_values=1.0)[:, None, :]
    s2p = w2_s[:, None, :]                                       # [E, 1, D]
    n_c, n_f = xp.shape[1] // bc, w1p.shape[2] // bf
    grid = (e_n, n_c, n_f)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, d_n), lambda e, c, f: (e, c, 0)),
            pl.BlockSpec((1, d_n, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, 1, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, d_n, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, 1, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, bf, d_n), lambda e, c, f: (e, f, 0)),
            pl.BlockSpec((1, 1, d_n), lambda e, c, f: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d_n), lambda e, c, f: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((e_n, xp.shape[1], d_n), jnp.float32),
        interpret=interpret,
    )(xp, w1p, s1p, w3p, s3p, w2p, s2p)
    return out[:, :c_n].astype(x.dtype)
