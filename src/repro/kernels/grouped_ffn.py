"""Pallas TPU kernel: ONE grouped expert launch for the four-way miss
outcome (full-precision / buddy / degraded / dropped).

The decode step used to pay for outcome diversity with dispatch diversity:
full-precision experts ran through ``expert_ffn``, buddy-substituted slots
through the gathered-replica einsum, and degraded (quant-tier) slots through
a separate jnp dequant pass over EVERY slot — while ``quant_ffn`` sat off
the dispatch path entirely. This kernel computes all compute-bearing
outcome classes in a single fused ``pallas_call`` so the megastep stays one
launch as outcome classes multiply (fidelity-ladder rungs, peer-fetch).

Group layout (the mask/operand contract — see README "Kernels"):

  x [2E, C, D] — tokens binned by (resolved expert, outcome class):
    group g in [0, E)    full-precision class: slots whose expert id g is
                         served at full precision. Buddy-substituted slots
                         land here too — substitution REWRITES the expert
                         id, so a buddy slot is just a full-precision slot
                         at the substituted id. Fetch-resolved misses also
                         land here (the engine models their stall on the
                         transfer timeline; compute is full-precision).
    group g in [E, 2E)   degraded class: slots computed against expert
                         (g - E)'s always-resident quant replica, dequant
                         applied POST-matmul exactly as quant_ffn_pallas.
    dropped slots        are never binned (their mixture weight is zero and
                         renormalized away) — the scatter skips them and
                         the gather back to token order fills zeros.

  weights as operands by outcome class: both halves of the grid index the
  weight tables at expert e = g mod E; the class bit (g >= E) selects the
  fp table (w1/w3/w2) or the quant pair ((w_q, scale) triplets) inside the
  kernel via predicated execution — one matmul chain runs per grid step.

Tiling mirrors expert_ffn/quant_ffn (MXU-aligned):

  grid = (2E, C/BC, F/BF)  — group, token-chunk tile, hidden tile
  x block [1, BC, D]; fp w1/w3 [1, D, BF], w2 [1, BF, D]
  w1q/w3q [1, D, BF] int8 + scales [1, 1, BF]; w2q [1, BF, D] + [1, 1, D]
  out block [1, BC, D] accumulated in f32 across the F-tile axis

Numerics per class are IDENTICAL to the standalone kernels: the fp class
follows expert_ffn (matmuls in x.dtype, f32 accumulation, hg cast back to
x.dtype between the two matmuls); the degraded class follows quant_ffn
(all-f32 with per-output-channel scales applied post-matmul, which commutes
because the scale depends only on the output channel).

Bandwidth note: BlockSpec streams BOTH the fp and the quant block of the
group's expert each grid step even though only one is consumed (Pallas
block fetches are spec-driven, not predicate-driven). The overhead is
bounded by the replica's size — int8 adds <=50% of the fp bytes, int4
payload <=25% — and only on this fused path; a scalar-prefetch variant
that skips the dead operand per group is the known follow-up.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w1_ref, w3_ref, w2_ref,
            w1q_ref, s1_ref, w3q_ref, s3_ref, w2q_ref, s2_ref, out_ref,
            *, e_n: int):
    g = pl.program_id(0)
    f_idx = pl.program_id(2)
    is_deg = g >= e_n

    @pl.when(f_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(jnp.logical_not(is_deg))
    def _full_precision():          # expert_ffn numerics
        x = x_ref[0]                                   # [BC, D]
        h = jax.nn.silu(jnp.dot(x, w1_ref[0],
                                preferred_element_type=jnp.float32))
        gp = jnp.dot(x, w3_ref[0], preferred_element_type=jnp.float32)
        hg = (h * gp).astype(x.dtype)
        out_ref[0] += jnp.dot(hg, w2_ref[0],
                              preferred_element_type=jnp.float32)

    @pl.when(is_deg)
    def _degraded():                # quant_ffn numerics (post-matmul dequant)
        x = x_ref[0].astype(jnp.float32)
        w1 = w1q_ref[0].astype(jnp.float32)
        w3 = w3q_ref[0].astype(jnp.float32)
        w2 = w2q_ref[0].astype(jnp.float32)
        h = jax.nn.silu(jnp.dot(x, w1, preferred_element_type=jnp.float32)
                        * s1_ref[0])
        gp = jnp.dot(x, w3, preferred_element_type=jnp.float32) * s3_ref[0]
        out_ref[0] += jnp.dot(h * gp, w2,
                              preferred_element_type=jnp.float32) * s2_ref[0]


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "interpret"))
def grouped_ffn_pallas(x, w1, w3, w2,
                       w1_q, w1_s, w3_q, w3_s, w2_q, w2_s, *,
                       block_c: int = 128, block_f: int = 256,
                       interpret: bool = False):
    """x [2E, C, D] binned by (expert, class) — groups [0, E) full
    precision, [E, 2E) degraded; w1/w3 [E, D, F], w2 [E, F, D] (fp);
    w1_q/w3_q [E, D, F] int8 with scales [E, F]; w2_q [E, F, D] int8 with
    scales [E, D]. Returns [2E, C, D] in x.dtype."""
    g_n, c_n, d_n = x.shape
    e_n, _, f_n = w1.shape
    assert g_n == 2 * e_n, \
        f"grouped_ffn: x must carry 2E groups (fp + degraded), got " \
        f"{g_n} groups for E={e_n}"
    assert w1_q.shape == w1.shape and w2_q.shape == w2.shape
    bc = min(block_c, c_n)
    bf = min(block_f, f_n)
    pad_c = (-c_n) % bc
    pad_f = (-f_n) % bf
    xp = jnp.pad(x, ((0, 0), (0, pad_c), (0, 0)))
    w1p = jnp.pad(w1, ((0, 0), (0, 0), (0, pad_f)))
    w3p = jnp.pad(w3, ((0, 0), (0, 0), (0, pad_f)))
    w2p = jnp.pad(w2, ((0, 0), (0, pad_f), (0, 0)))
    w1qp = jnp.pad(w1_q, ((0, 0), (0, 0), (0, pad_f)))
    w3qp = jnp.pad(w3_q, ((0, 0), (0, 0), (0, pad_f)))
    w2qp = jnp.pad(w2_q, ((0, 0), (0, pad_f), (0, 0)))
    # padded hidden channels have zero weights -> zero contribution; pad
    # scales with ones so the dequant multiply stays finite
    s1p = jnp.pad(w1_s, ((0, 0), (0, pad_f)), constant_values=1.0)[:, None, :]
    s3p = jnp.pad(w3_s, ((0, 0), (0, pad_f)), constant_values=1.0)[:, None, :]
    s2p = w2_s[:, None, :]                                      # [E, 1, D]
    n_c, n_f = xp.shape[1] // bc, w1p.shape[2] // bf
    grid = (g_n, n_c, n_f)

    # weight operands are indexed at expert g mod E — the same expert's fp
    # and quant blocks serve both halves of the group axis
    def _w_in(g, c, f):
        return (g % e_n, 0, f)

    def _w_out(g, c, f):
        return (g % e_n, f, 0)

    def _s_in(g, c, f):
        return (g % e_n, 0, f)

    def _s_out(g, c, f):
        return (g % e_n, 0, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, e_n=e_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, d_n), lambda g, c, f: (g, c, 0)),
            pl.BlockSpec((1, d_n, bf), _w_in),
            pl.BlockSpec((1, d_n, bf), _w_in),
            pl.BlockSpec((1, bf, d_n), _w_out),
            pl.BlockSpec((1, d_n, bf), _w_in),
            pl.BlockSpec((1, 1, bf), _s_in),
            pl.BlockSpec((1, d_n, bf), _w_in),
            pl.BlockSpec((1, 1, bf), _s_in),
            pl.BlockSpec((1, bf, d_n), _w_out),
            pl.BlockSpec((1, 1, d_n), _s_out),
        ],
        out_specs=pl.BlockSpec((1, bc, d_n), lambda g, c, f: (g, c, 0)),
        out_shape=jax.ShapeDtypeStruct((g_n, xp.shape[1], d_n), jnp.float32),
        interpret=interpret,
    )(xp, w1p, w3p, w2p, w1qp, s1p, w3qp, s3p, w2qp, s2p)
    return out[:, :c_n].astype(x.dtype)
