"""Attention: GQA + RoPE, causal/sliding-window, flash-style chunking,
ring-buffer KV cache for decode, and cross-attention (VLM image layers).

Shapes: x [B, S, D]; q [B, S, H, hd]; k,v [B, S, KV, hd]; GQA group g = H//KV.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, shard

NEG_INF = -1e30


def init_attn(key, d_model: int, num_heads: int, num_kv_heads: int,
              head_dim: int, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, num_heads * head_dim, dtype),
        "wk": dense_init(kk, d_model, num_kv_heads * head_dim, dtype),
        "wv": dense_init(kv, d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ko, num_heads * head_dim, d_model, dtype),
    }


def _project_qkv(params, x, x_kv, num_heads, num_kv_heads, head_dim):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(b, s, num_heads, head_dim)
    skv = x_kv.shape[1]
    k = jnp.einsum("bsd,dh->bsh", x_kv, params["wk"]).reshape(b, skv, num_kv_heads, head_dim)
    v = jnp.einsum("bsd,dh->bsh", x_kv, params["wv"]).reshape(b, skv, num_kv_heads, head_dim)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def _flash_attend(q, k, v, q_offset: int, *, causal: bool, window: int,
                  q_chunk: int = 512, kv_chunk: int = 1024):
    """Online-softmax attention, chunked over q (lax.map) and kv (lax.scan).

    q: [B, Sq, H, hd]; k, v: [B, Skv, KV, hd]. Returns [B, Sq, H, hd].
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill: 0 with
    Sq == Skv). ``window`` 0 = unbounded.
    """
    b, sq, h, hd = q.shape
    skv, kv_h = k.shape[1], k.shape[2]
    g = h // kv_h
    scale = hd ** -0.5

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    # pad to multiples
    pad_q = (-sq) % q_chunk
    pad_kv = (-skv) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    nq, nkv = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk

    qp = qp.reshape(b, nq, q_chunk, kv_h, g, hd)
    kp = kp.reshape(b, nkv, kv_chunk, kv_h, hd)
    vp = vp.reshape(b, nkv, kv_chunk, kv_h, hd)
    kv_pos = jnp.arange(nkv * kv_chunk).reshape(nkv, kv_chunk)
    kv_valid = kv_pos < skv

    def per_q_chunk(args):
        qi, q_blk = args                              # q_blk [B, qc, KV, g, hd]
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            acc, m, l = carry                         # acc [B,qc,KV,g,hd]; m,l [B,qc,KV,g]
            k_blk, v_blk, kpos, kval = inp
            s = jnp.einsum("bqkgh,bckh->bqkgc", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            mask = kval[None, :]
            if causal:
                mask = mask & (kpos[None, :] <= q_pos[:, None])
            if window:
                mask = mask & (kpos[None, :] > q_pos[:, None] - window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckh->bqkgh", p, v_blk.astype(jnp.float32))
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, q_chunk, kv_h, g, hd), jnp.float32)
        m0 = jnp.full((b, q_chunk, kv_h, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, kv_h, g), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kp.swapaxes(0, 1), vp.swapaxes(0, 1), kv_pos, kv_valid))
        return acc / jnp.maximum(l[..., None], 1e-30)

    out = jax.lax.map(per_q_chunk, (jnp.arange(nq), qp.swapaxes(0, 1)))
    out = out.swapaxes(0, 1).reshape(b, nq * q_chunk, h, hd)
    return out[:, :sq].astype(q.dtype)


def attn_forward(params, x, positions, *, num_heads, num_kv_heads, head_dim,
                 rope_theta, window: int = 0, cross_embeds: Optional[jax.Array] = None,
                 return_kv: bool = False):
    """Full-sequence attention (train / prefill).

    cross_embeds: [B, Nc, D] -> cross-attention (no RoPE on k, no mask).
    """
    cross = cross_embeds is not None
    x_kv = cross_embeds if cross else x
    q, k, v = _project_qkv(params, x, x_kv, num_heads, num_kv_heads, head_dim)
    if not cross:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    out = _flash_attend(q, k, v, 0, causal=not cross, window=0 if cross else window)
    b, s = x.shape[:2]
    y = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, num_heads * head_dim),
                   params["wo"]).astype(x.dtype)
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# Decode with ring-buffer KV cache
# ---------------------------------------------------------------------------
def init_kv_cache(batch: int, capacity: int, num_kv_heads: int, head_dim: int,
                  dtype) -> dict:
    return {
        "k": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
    }


def attn_decode(params, x_tok, cache, pos, *, num_heads, num_kv_heads, head_dim,
                rope_theta, window: int = 0,
                cross_kv: Optional[tuple] = None):
    """One decode step. x_tok [B, 1, D]; cache k/v [B, C, KV, hd]; pos is the
    absolute position of the new token — a scalar (whole batch in lockstep)
    or a [B] vector (continuous batching: each row at its own position).
    Ring-buffer write at pos % C per row. Returns (y [B, 1, D], new_cache).
    """
    if cross_kv is not None:
        k, v = cross_kv
        b = x_tok.shape[0]
        q = jnp.einsum("bsd,dh->bsh", x_tok, params["wq"]).reshape(
            b, 1, num_heads, head_dim)
        out = _attend_single(q, k, v, None, None, 0, 0)
        y = jnp.einsum("bsh,hd->bsd", out.reshape(b, 1, num_heads * head_dim),
                       params["wo"]).astype(x_tok.dtype)
        return y, cache

    b = x_tok.shape[0]
    cap = cache["k"].shape[1]
    per_row = jnp.ndim(pos) == 1
    q, k_new, v_new = _project_qkv(params, x_tok, x_tok, num_heads,
                                   num_kv_heads, head_dim)
    pos_b = (jnp.asarray(pos, jnp.int32)[:, None] if per_row
             else jnp.full((1, 1), pos, jnp.int32))          # [B,1] | [1,1]
    rope_pos = jnp.broadcast_to(pos_b, (b, 1))
    q = apply_rope(q, rope_pos, rope_theta)
    k_new = apply_rope(k_new, rope_pos, rope_theta)
    # match the cache layout so the update is collective-free
    k_new = shard(k_new, "batch", None, "cache_heads", "cache_hd")
    v_new = shard(v_new, "batch", None, "cache_heads", "cache_hd")
    if per_row:
        # rows write independent slots: batched scatter, O(B) writes (a
        # full-cache select would move O(B*C) every step)
        rows = jnp.arange(b)
        slots = (pos_b[:, 0] % cap).astype(jnp.int32)
        k_cache = cache["k"].at[rows, slots].set(k_new[:, 0])
        v_cache = cache["v"].at[rows, slots].set(v_new[:, 0])
    else:
        slot = jnp.asarray(pos % cap, jnp.int32)
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)

    # Absolute position of each cache slot given the ring buffer has wrapped
    # floor((pos - slot_idx)/cap)*cap + slot_idx -> latest write <= pos.
    # [B, C] (per-row) or [1, C] (lockstep, broadcast over rows).
    idx = jnp.arange(cap)[None, :]
    abs_pos = pos_b - ((pos_b - idx) % cap)
    valid = (abs_pos >= 0) & (abs_pos <= pos_b)
    if window:
        valid = valid & (abs_pos > pos_b - window)

    out = _attend_single(q, k_cache, v_cache, valid, None, num_kv_heads, head_dim)
    # 4-D output projection: contract (kv, g, hd) with wo reshaped to
    # [KV, g, hd, D] and hd sharded like the cache — keeps the whole
    # attention hd-sharded so GSPMD never gathers the KV cache (§Perf B7);
    # the residual all-reduce is just [B, 1, D].
    g = num_heads // num_kv_heads
    d_model = params["wo"].shape[1]
    wo4 = params["wo"].reshape(num_kv_heads, g, head_dim, d_model)
    wo4 = shard(wo4, "cache_heads", None, "cache_hd", None)
    out4 = out.reshape(b, 1, num_kv_heads, g, head_dim)
    y = jnp.einsum("bqkgh,kghd->bqd", out4, wo4,
                   preferred_element_type=jnp.float32).astype(x_tok.dtype)
    return y, {"k": k_cache, "v": v_cache}


def attn_prefill_chunk(params, x_chunk, cache, base_pos, tok_valid, *,
                       num_heads, num_kv_heads, head_dim, rope_theta,
                       window: int = 0):
    """Chunked prefill step: C tokens per row written into the decode cache
    in one fused call. x_chunk [B, C, D]; cache k/v [B, Cap, KV, hd];
    base_pos [B] absolute position of each row's first chunk token;
    tok_valid [B, C] PREFIX validity mask (token j live iff j < count(row)).
    Invalid tokens flow through the fixed-shape graph but write nothing and
    their outputs are garbage the caller discards.

    Ring-buffer caveat: the whole chunk is scattered into the cache before
    any query attends, so a chunk that wraps the ring (base_pos + C > Cap)
    would let early queries see slots already overwritten by later chunk
    tokens. Callers must keep prompts inside the cache capacity during
    chunked prefill (ServeEngine.prefill_rows guards this host-side).

    Returns (y [B, C, D], new_cache). With C == 1 this computes bit-for-bit
    what attn_decode's per-row path computes.
    """
    b, c, _ = x_chunk.shape
    cap = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(params, x_chunk, x_chunk, num_heads,
                                   num_kv_heads, head_dim)
    base = jnp.asarray(base_pos, jnp.int32)
    pos = base[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]    # [B, C]
    q = apply_rope(q, pos, rope_theta)
    k_new = apply_rope(k_new, pos, rope_theta)
    k_new = shard(k_new, "batch", None, "cache_heads", "cache_hd")
    v_new = shard(v_new, "batch", None, "cache_heads", "cache_hd")
    # batched scatter of the whole chunk; invalid tokens aim out of range
    # and are dropped (inactive rows leave their cache untouched)
    rows = jnp.arange(b)[:, None]
    slots = jnp.where(tok_valid, pos % cap, cap)
    k_cache = cache["k"].at[rows, slots].set(k_new, mode="drop")
    v_cache = cache["v"].at[rows, slots].set(v_new, mode="drop")

    # Slot-content positions from each row's LAST write (the chunk is fully
    # written): content <= query position masks out both unwritten slots and
    # the row's own future chunk tokens — per-query causality inside the
    # chunk comes for free.
    count = tok_valid.astype(jnp.int32).sum(axis=1)
    m = (base + jnp.maximum(count, 1) - 1)[:, None]                  # [B, 1]
    idx = jnp.arange(cap, dtype=jnp.int32)[None, :]
    content = m - ((m - idx) % cap)                                  # [B, Cap]
    valid = (content >= 0)[:, None, :] & \
        (content[:, None, :] <= pos[:, :, None])                     # [B, C, Cap]
    if window:
        valid = valid & (content[:, None, :] > pos[:, :, None] - window)

    out = _attend_chunk(q, k_cache, v_cache, valid)
    g = num_heads // num_kv_heads
    d_model = params["wo"].shape[1]
    wo4 = params["wo"].reshape(num_kv_heads, g, head_dim, d_model)
    wo4 = shard(wo4, "cache_heads", None, "cache_hd", None)
    out4 = out.reshape(b, c, num_kv_heads, g, head_dim)
    y = jnp.einsum("bqkgh,kghd->bqd", out4, wo4,
                   preferred_element_type=jnp.float32).astype(x_chunk.dtype)
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Paged KV: block-pool storage + per-row block tables (runtime/paged_kv.py)
# ---------------------------------------------------------------------------
def init_paged_kv_cache(n_blocks: int, block_size: int, num_kv_heads: int,
                        head_dim: int, dtype) -> dict:
    """Shared block pool replacing the per-row ring: k/v [P, bs, KV, hd].
    Which row owns which block lives host-side in ``PagedKVPool.tables``
    and is shipped per step as a ``[B, MB]`` int32 block table (-1 =
    unmapped). One pool serves every row, so blocks freed by a retired
    request are immediately reusable by any other."""
    return {
        "k": jnp.zeros((n_blocks, block_size, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((n_blocks, block_size, num_kv_heads, head_dim), dtype),
    }


def _gather_pages(arr, bt, block_size):
    """arr [P, bs, KV, hd], bt [B, MB] -> per-row views [B, MB*bs, KV, hd].
    Unmapped entries (-1) gather block 0's data; callers mask them out via
    the `mapped` validity term."""
    p, bs, kv_h, hd = arr.shape
    pages = arr[jnp.maximum(bt, 0)]                     # [B, MB, bs, KV, hd]
    b, mb = bt.shape
    return pages.reshape(b, mb * bs, kv_h, hd)


def _paged_scatter(cache, phys, k_new, v_new):
    """Scatter new KV into the flat pool at per-token physical indices.
    ``phys`` must already aim invalid tokens at ``P * bs`` (out of range,
    dropped) — NEVER at -1: JAX wraps negative scatter indices."""
    p, bs = cache["k"].shape[:2]
    kv_h, hd = cache["k"].shape[2:]
    k_flat = cache["k"].reshape(p * bs, kv_h, hd)
    v_flat = cache["v"].reshape(p * bs, kv_h, hd)
    k_flat = k_flat.at[phys].set(k_new, mode="drop")
    v_flat = v_flat.at[phys].set(v_new, mode="drop")
    return {"k": k_flat.reshape(p, bs, kv_h, hd),
            "v": v_flat.reshape(p, bs, kv_h, hd)}


def attn_decode_paged(params, x_tok, cache, pos, block_tables, *, num_heads,
                      num_kv_heads, head_dim, rope_theta):
    """One decode step against paged KV. x_tok [B, 1, D]; cache k/v
    [P, bs, KV, hd]; pos [B] absolute positions (paged decode is always
    per-row — the engine broadcasts a lockstep scalar); block_tables
    [B, MB] int32, -1 = unmapped. Content position of a mapped slot is its
    index (blocks never wrap), so validity is simply mapped & idx <= pos.
    Returns (y [B, 1, D], new_cache)."""
    b = x_tok.shape[0]
    p_blocks, bs = cache["k"].shape[:2]
    bt = jnp.asarray(block_tables, jnp.int32)
    q, k_new, v_new = _project_qkv(params, x_tok, x_tok, num_heads,
                                   num_kv_heads, head_dim)
    pos_b = jnp.asarray(pos, jnp.int32)[:, None]                     # [B, 1]
    rope_pos = jnp.broadcast_to(pos_b, (b, 1))
    q = apply_rope(q, rope_pos, rope_theta)
    k_new = apply_rope(k_new, rope_pos, rope_theta)
    k_new = shard(k_new, "batch", None, "cache_heads", "cache_hd")
    v_new = shard(v_new, "batch", None, "cache_heads", "cache_hd")
    p0 = pos_b[:, 0]
    blk = bt[jnp.arange(b), p0 // bs]                                # [B]
    phys = jnp.where(blk >= 0, blk * bs + p0 % bs, p_blocks * bs)
    cache = _paged_scatter(cache, phys, k_new[:, 0], v_new[:, 0])

    k_rows = _gather_pages(cache["k"], bt, bs)
    v_rows = _gather_pages(cache["v"], bt, bs)
    idx = jnp.arange(bt.shape[1] * bs, dtype=jnp.int32)[None, :]
    mapped = jnp.repeat(bt >= 0, bs, axis=1)                    # [B, MB*bs]
    valid = mapped & (idx <= pos_b)

    out = _attend_single(q, k_rows, v_rows, valid, None, num_kv_heads,
                         head_dim)
    g = num_heads // num_kv_heads
    d_model = params["wo"].shape[1]
    wo4 = params["wo"].reshape(num_kv_heads, g, head_dim, d_model)
    wo4 = shard(wo4, "cache_heads", None, "cache_hd", None)
    out4 = out.reshape(b, 1, num_kv_heads, g, head_dim)
    y = jnp.einsum("bqkgh,kghd->bqd", out4, wo4,
                   preferred_element_type=jnp.float32).astype(x_tok.dtype)
    return y, cache


def attn_prefill_chunk_paged(params, x_chunk, cache, base_pos, tok_valid,
                             block_tables, *, num_heads, num_kv_heads,
                             head_dim, rope_theta):
    """Chunked prefill into paged KV — the paged twin of attn_prefill_chunk.
    No ring-wrap hazard: a block's slot index IS its content position, so
    arbitrarily long prompts chunk-prefill as long as the pool has blocks
    (the host-side guard moves from ring capacity to pool pressure).
    Returns (y [B, C, D], new_cache)."""
    b, c, _ = x_chunk.shape
    p_blocks, bs = cache["k"].shape[:2]
    bt = jnp.asarray(block_tables, jnp.int32)
    q, k_new, v_new = _project_qkv(params, x_chunk, x_chunk, num_heads,
                                   num_kv_heads, head_dim)
    base = jnp.asarray(base_pos, jnp.int32)
    pos = base[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]    # [B, C]
    q = apply_rope(q, pos, rope_theta)
    k_new = apply_rope(k_new, pos, rope_theta)
    k_new = shard(k_new, "batch", None, "cache_heads", "cache_hd")
    v_new = shard(v_new, "batch", None, "cache_heads", "cache_hd")
    # clamp: invalid tail tokens can point past MB; their reads are voided
    # by the tok_valid sentinel below, but the gather index must be in range
    blk_idx = jnp.clip(pos // bs, 0, bt.shape[1] - 1)
    blk = jnp.take_along_axis(bt, blk_idx, axis=1)                   # [B, C]
    phys = jnp.where(tok_valid & (blk >= 0), blk * bs + pos % bs,
                     p_blocks * bs)
    cache = _paged_scatter(cache, phys, k_new, v_new)

    k_rows = _gather_pages(cache["k"], bt, bs)
    v_rows = _gather_pages(cache["v"], bt, bs)
    idx = jnp.arange(bt.shape[1] * bs, dtype=jnp.int32)[None, :]
    mapped = jnp.repeat(bt >= 0, bs, axis=1)                    # [B, MB*bs]
    # mapped slot content position == slot index; idx <= query position
    # masks unwritten tail slots AND the row's own future chunk tokens
    valid = mapped[:, None, :] & (idx[:, None, :] <= pos[:, :, None])

    out = _attend_chunk(q, k_rows, v_rows, valid)
    g = num_heads // num_kv_heads
    d_model = params["wo"].shape[1]
    wo4 = params["wo"].reshape(num_kv_heads, g, head_dim, d_model)
    wo4 = shard(wo4, "cache_heads", None, "cache_hd", None)
    out4 = out.reshape(b, c, num_kv_heads, g, head_dim)
    y = jnp.einsum("bqkgh,kghd->bqd", out4, wo4,
                   preferred_element_type=jnp.float32).astype(x_chunk.dtype)
    return y, cache


def _attend_chunk(q, k, v, valid):
    """q [B, C, H, hd] vs full cache k, v [B, Cap, KV, hd]; valid [B, C, Cap]
    per-(row, query) slot mask. The C == 1 case reduces elementwise to
    _attend_single (same einsum contractions, one extra unit axis)."""
    b, c_q, h, hd = q.shape
    kv_h = k.shape[2]
    g = h // kv_h
    qg = q.reshape(b, c_q, kv_h, g, hd)
    qg = shard(qg, "batch", None, "cache_heads", None, "cache_hd")
    s = jnp.einsum("bqkgh,bckh->bqkgc", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    s = shard(s, "batch", None, "cache_heads", None, "cache_seq")
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgc,bckh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(b, c_q, h, hd).astype(q.dtype)


def _attend_single(q, k, v, valid, _unused, num_kv_heads, head_dim):
    """q [B,1,H,hd] vs full cache k,v [B,C,KV,hd] (single einsum, no chunking).
    valid: [B, C] (per-row positions) or [1, C] (lockstep) slot-validity mask."""
    b, _, h, hd = q.shape
    kv_h = k.shape[2]
    g = h // kv_h
    qg = q.reshape(b, 1, kv_h, g, hd)
    qg = shard(qg, "batch", None, "cache_heads", None, "cache_hd")
    s = jnp.einsum("bqkgh,bckh->bkgc", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    # scores carry no head_dim axis: pin their layout so a head_dim-sharded
    # cache contracts via partial-sum + small all-reduce instead of an
    # all-gather of the whole KV cache (§Perf iteration: mixtral decode)
    s = shard(s, "batch", "cache_heads", None, "cache_seq")
    if valid is not None:
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)
