"""Shared model building blocks (functional, no flax).

Sharding: model code annotates activations with *logical* axis names via
``shard(x, ...names)``. ``launch/sharding.py`` installs a mapping from logical
names to mesh axes with ``axis_rules(...)``; outside that context the calls
are no-ops, so smoke tests / CPU runs never touch device state.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


def _rules():
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: dict):
    """rules: logical axis name -> mesh axis name (or tuple) or None."""
    prev = _rules()
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def logical_spec(names: Sequence[Optional[str]]) -> P:
    rules = _rules() or {}
    return P(*[rules.get(n) if n is not None else None for n in names])


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names (no-op w/o rules)."""
    rules = _rules()
    if rules is None:
        return x
    spec = logical_spec(names)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = (2.0 / (in_dim + out_dim)) ** 0.5
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., None, :]                 # [..., seq, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    """SwiGLU FFN: (silu(x@w1) * (x@w3)) @ w2, with f32 accumulation."""
    h = jax.nn.silu(jnp.einsum("...d,df->...f", x, w1,
                               preferred_element_type=jnp.float32))
    g = jnp.einsum("...d,df->...f", x, w3, preferred_element_type=jnp.float32)
    h = (h * g).astype(x.dtype)
    h = shard(h, *([None] * (h.ndim - 1)), "dff")
    return jnp.einsum("...f,fd->...d", h, w2,
                      preferred_element_type=jnp.float32).astype(x.dtype)
