"""RWKV6 ("Finch") block — attention-free time-mix with data-dependent decay
[arXiv:2404.05892], plus the RWKV channel-mix FFN.

Per head (dk = dv = head_dim), with data-dependent per-channel decay
w_t in (0,1):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
Train/prefill use lax.scan over time; decode is a single state update.
State per layer: [B, H, dk, dv] (O(1) in sequence length — native long_500k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm, shard


def init_rwkv(key, d_model: int, num_heads: int, head_dim: int, d_ff: int,
              dtype) -> dict:
    dh = num_heads * head_dim
    ks = jax.random.split(key, 10)
    return {
        "mu": (jax.random.uniform(ks[0], (5, d_model)) * 0.5 + 0.25).astype(jnp.float32),
        "wr": dense_init(ks[1], d_model, dh, dtype),
        "wk": dense_init(ks[2], d_model, dh, dtype),
        "wv": dense_init(ks[3], d_model, dh, dtype),
        "wg": dense_init(ks[4], d_model, dh, dtype),
        "ww": dense_init(ks[5], d_model, dh, dtype),
        "w_bias": jnp.zeros((dh,), jnp.float32),
        "u": (jax.random.normal(ks[6], (num_heads, head_dim)) * 0.1).astype(jnp.float32),
        "wo": dense_init(ks[7], dh, d_model, dtype),
        # channel mix
        "mu_c": (jax.random.uniform(ks[8], (2, d_model)) * 0.5 + 0.25).astype(jnp.float32),
        "ck": dense_init(ks[9], d_model, d_ff, dtype),
        "cr": dense_init(jax.random.fold_in(key, 11), d_model, d_model, dtype),
        "cv": dense_init(jax.random.fold_in(key, 12), d_ff, d_model, dtype),
        "ln_x": jnp.ones((dh,), jnp.float32),
    }


def _token_shift(x, x_prev):
    """shift(x)[t] = x[t-1]; x_prev is the last token of the previous chunk
    ([B, 1, D]) or zeros."""
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def _time_mix_projections(params, x, x_shift, num_heads, head_dim):
    mu = params["mu"]
    def mix(i):
        return x * mu[i] + x_shift * (1.0 - mu[i])
    b, s, _ = x.shape
    r = (mix(0) @ params["wr"]).reshape(b, s, num_heads, head_dim)
    k = (mix(1) @ params["wk"]).reshape(b, s, num_heads, head_dim)
    v = (mix(2) @ params["wv"]).reshape(b, s, num_heads, head_dim)
    g = (mix(3) @ params["wg"]).reshape(b, s, num_heads, head_dim)
    w_raw = (mix(4) @ params["ww"]).astype(jnp.float32) + params["w_bias"]
    # data-dependent decay in (0, 1): exp(-softplus(.)) — bounded, stable
    w = jnp.exp(-jax.nn.softplus(w_raw)).reshape(b, s, num_heads, head_dim)
    return r, k, v, g, w


def wkv_chunked(r, k, v, w, u, state, chunk: int = 32):
    """Chunkwise-parallel WKV (flash-linear-attention style) — §Perf it. 4.

    The sequential scan writes the [B, H, dk, dv] state every step; the
    chunked form factorizes the data-dependent decay so each chunk is two
    MXU matmuls + one state update, cutting state HBM traffic by ~chunk x:

      within chunk (la_t = cumulative log-decay, la_0 = 0):
        r~_t = r_t * exp(la_{t-1})         k~_s = k_s * exp(-la_s)
        o_t  = r~_t @ S_0  +  [lower(r~ k~^T) + diag(r.(u*k))] @ v
        S'   = exp(la_C) * S_0 + (exp(la_C - la_s) k_s)^T v

    exp(-la_s) grows within a chunk; chunk=32 with the softplus-bounded
    decay keeps it in f32 range (validated against the scan oracle).
    """
    b, s, h, dk = r.shape
    assert s % chunk == 0, f"seq {s} % chunk {chunk}"
    n = s // chunk
    f32 = jnp.float32

    def resh(x):
        return x.astype(f32).reshape(b, n, chunk, h, dk).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)   # [N, B, H, C, dk]
    la = jnp.cumsum(jnp.log(jnp.maximum(wc, 1e-8)), axis=3)   # [N,B,H,C,dk]
    la_prev = la - jnp.log(jnp.maximum(wc, 1e-8))             # la_{t-1}
    la_end = la[:, :, :, -1:, :]                              # [N,B,H,1,dk]

    r_t = rc * jnp.exp(la_prev)
    k_t = kc * jnp.exp(-la)
    k_end = kc * jnp.exp(la_end - la)                         # for state update
    diag_term = jnp.einsum("nbhcd,nbhcd->nbhc", rc,
                           u[None, None, :, None, :] * kc)

    mask = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1)      # strictly lower

    def step(S, inp):
        r_, k_, v_, ke_, laE, dg = inp
        o_inter = jnp.einsum("bhcd,bhde->bhce", r_, S)
        scores = jnp.einsum("bhcd,bhsd->bhcs", r_, k_) * mask[None, None]
        o_intra = jnp.einsum("bhcs,bhse->bhce", scores, v_) \
            + dg[..., None] * v_
        S_new = jnp.exp(laE[:, :, 0])[..., None] * S \
            + jnp.einsum("bhsd,bhse->bhde", ke_, v_)
        return S_new, o_inter + o_intra

    state, out = jax.lax.scan(step, state.astype(f32),
                              (r_t, k_t, vc, k_end, la_end, diag_term))
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dk)
    return out, state


def wkv_scan(r, k, v, w, u, state):
    """Sequential WKV recurrence. r,k,v,w: [B, S, H, dk]; u: [H, dk];
    state: [B, H, dk, dv]. Returns (out [B, S, H, dv], new_state)."""
    rt = r.swapaxes(0, 1).astype(jnp.float32)
    kt = k.swapaxes(0, 1).astype(jnp.float32)
    vt = v.swapaxes(0, 1).astype(jnp.float32)
    wt = w.swapaxes(0, 1).astype(jnp.float32)

    def step(s, inp):
        r_, k_, v_, w_ = inp                       # [B, H, dk] / [B, H, dv]
        kv = k_[..., :, None] * v_[..., None, :]   # [B, H, dk, dv]
        out = jnp.einsum("bhk,bhkv->bhv", r_, s + u[..., None] * kv)
        s_new = w_[..., None] * s + kv
        return s_new, out

    state, out = jax.lax.scan(step, state.astype(jnp.float32), (rt, kt, vt, wt))
    return out.swapaxes(0, 1), state


def rwkv_time_mix(params, x, state, x_prev, *, num_heads, head_dim):
    """x: [B, S, D]; state [B, H, dk, dv]; x_prev [B, 1, D].
    Returns (y, new_state, new_x_prev)."""
    b, s, d = x.shape
    x_shift = _token_shift(x, x_prev)
    r, k, v, g, w = _time_mix_projections(params, x, x_shift, num_heads, head_dim)
    r = shard(r, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    if s % 32 == 0 and s > 1:
        out, new_state = wkv_chunked(r, k, v, w, params["u"], state)
    else:
        out, new_state = wkv_scan(r, k, v, w, params["u"], state)
    out = out.reshape(b, s, num_heads * head_dim)
    out = rmsnorm(out.astype(x.dtype), params["ln_x"])
    out = out * jax.nn.silu(g.reshape(b, s, -1)).astype(x.dtype)
    y = (out @ params["wo"]).astype(x.dtype)
    return y, new_state.astype(jnp.float32), x[:, -1:]


def rwkv_channel_mix(params, x, x_prev):
    """RWKV channel mix: squared-relu FFN with token shift."""
    mu = params["mu_c"]
    x_shift = _token_shift(x, x_prev)
    xk = x * mu[0] + x_shift * (1.0 - mu[0])
    xr = x * mu[1] + x_shift * (1.0 - mu[1])
    k = jnp.square(jax.nn.relu(xk @ params["ck"]))
    k = shard(k, "batch", None, "dff")
    return (jax.nn.sigmoid(xr @ params["cr"]) * (k @ params["cv"])).astype(x.dtype), x[:, -1:]


def init_rwkv_state(batch: int, num_heads: int, head_dim: int, d_model: int):
    return {
        "wkv": jnp.zeros((batch, num_heads, head_dim, head_dim), jnp.float32),
        "x_tm": jnp.zeros((batch, 1, d_model), jnp.float32),
        "x_cm": jnp.zeros((batch, 1, d_model), jnp.float32),
    }
