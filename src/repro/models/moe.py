"""MoE layer: top-k router, capacity-based dispatch, expert SwiGLU compute,
and the BuddyMoE substitution hook (the paper's runtime layer between the
router and expert execution, §3.4).

Expert parallelism model: experts are tensor-parallel over the `model` mesh
axis (d_ff sharded); tokens are data-parallel. Dispatch is therefore local to
each data shard — no all-to-all on the baseline path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.policy import BuddyPolicy
from repro.core.substitute import SubstituteResult, substitute
from repro.kernels.ref import dequant_swiglu
from repro.models.common import dense_init, shard, swiglu


class BuddyState(NamedTuple):
    """Per-layer runtime state for BuddyMoE (all replicated, tiny)."""
    resident: jax.Array   # [E] bool — GPU residency mask M
    table: jax.Array      # [E, R] int32 — buddy profile B (rank-ordered, -1 pad)
    q: jax.Array          # [E, R] f32 — q_{j|i} per entry
    hop: jax.Array        # [E] int32 — ICI hops to each expert's cache slot
    quant_ok: Any = None  # [E] bool — misses the runtime routed to the
    #                       resident quant-replica tier this step (None when
    #                       no tier is attached; see runtime/tiers.py)
    fid_cost: Any = None  # [E] f32 — stall-equivalent cost of the degraded
    #                       outcome (runtime/costs.py; miss_policy='cost')
    fetch_cost: Any = None  # [E] f32 — expected stall of fetching this step
    #                         (in-flight ETA or modeled cold transfer)
    peer_ok: Any = None   # [E] bool — experts resident in a peer device's
    #                       HBM (precedence mode routes their misses to an
    #                       ICI borrow; None on single-device meshes)
    peer_cost: Any = None  # [E] f32 — expected stall of the peer borrow
    #                        (MissCostModel.peer_eta; miss_policy='cost')


def full_residency(num_experts: int, r_max: int = 8) -> BuddyState:
    return BuddyState(
        resident=jnp.ones((num_experts,), bool),
        table=jnp.full((num_experts, r_max), -1, jnp.int32),
        q=jnp.zeros((num_experts, r_max), jnp.float32),
        hop=jnp.zeros((num_experts,), jnp.int32),
    )


def init_moe(key, d_model: int, cfg: MoEConfig, dtype) -> dict:
    kr, k1, k3, k2, ks = jax.random.split(key, 5)
    e, f = cfg.num_experts, cfg.d_ff
    if cfg.upcycle_noise > 0:
        # sparse upcycling: shared base FFN + per-expert perturbation
        n = cfg.upcycle_noise

        def up(k, shape_in, shape_out, transpose=False):
            base = dense_init(jax.random.fold_in(k, 0), shape_in, shape_out,
                              jnp.float32)
            noise = jax.random.normal(jax.random.fold_in(k, 1),
                                      (e, shape_in, shape_out)) \
                * n * (2.0 / (shape_in + shape_out)) ** 0.5
            return (base[None] + noise).astype(dtype)

        p = {
            "router": dense_init(kr, d_model, e, jnp.float32),
            "w1": up(k1, d_model, f),
            "w3": up(k3, d_model, f),
            "w2": up(k2, f, d_model),
        }
    else:
        p = {
            "router": dense_init(kr, d_model, e, jnp.float32),
            "w1": dense_init(k1, d_model, e * f, dtype).reshape(d_model, e, f).transpose(1, 0, 2),
            "w3": dense_init(k3, d_model, e * f, dtype).reshape(d_model, e, f).transpose(1, 0, 2),
            "w2": dense_init(k2, e * f, d_model, dtype).reshape(e, f, d_model),
        }
    if cfg.num_shared_experts:
        fs = cfg.d_ff * cfg.num_shared_experts
        a, b, c = jax.random.split(ks, 3)
        p["shared"] = {
            "w1": dense_init(a, d_model, fs, dtype),
            "w3": dense_init(b, d_model, fs, dtype),
            "w2": dense_init(c, fs, d_model, dtype),
        }
    return p


class MoEAux(NamedTuple):
    lb_loss: jax.Array        # scalar load-balance loss (Switch-style)
    indices: jax.Array        # [T, K] final expert assignment (post-substitution)
    orig_indices: jax.Array   # [T, K] router's assignment
    topk_probs: jax.Array     # [T, K] renormalized probs
    n_substituted: jax.Array  # [] substituted slots
    n_missed: jax.Array       # [] non-resident slots with no buddy
    n_dropped: jax.Array      # [] tokens dropped by capacity
    miss_per_expert: jax.Array  # [E] miss counts (-> fetch bytes in the ledger)
    sub_slots: jax.Array      # [T, K] bool — per-slot substitution mask (lets
    miss_slots: jax.Array     # [T, K] bool — the serving engine mask out
    #                           inactive batch rows under continuous batching)
    n_degraded: jax.Array     # [] slots served from the quant-replica tier
    deg_slots: jax.Array      # [T, K] bool — per-slot degraded mask
    n_miss_drop: jax.Array    # [] misses the cost argmin dropped
    drop_slots: jax.Array     # [T, K] bool — per-slot cost-drop mask
    #                           (weights renormalized; no transfer, no stall)
    n_peered: jax.Array = None  # [] misses served by a peer-HBM borrow
    peer_slots: jax.Array = None  # [T, K] bool — per-slot peer-borrow mask
    #                               (full weight, fp compute at the true id;
    #                               the engine stalls on the ICI transfer)


def router_topk(router_w, x_flat, top_k: int, jitter_key=None, jitter=0.0):
    """Returns logits [T, E], topk indices [T, K], topk logits, renorm probs."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), router_w)
    if jitter_key is not None and jitter > 0:
        logits = logits + jax.random.uniform(
            jitter_key, logits.shape, minval=-jitter, maxval=jitter)
    topk_logits, topk_idx = jax.lax.top_k(logits, top_k)
    probs = jax.nn.softmax(topk_logits, axis=-1)       # renormalized over S
    return logits, topk_idx.astype(jnp.int32), topk_logits, probs


def _degraded_outputs(quant: dict, x_flat: jax.Array, e_flat: jax.Array):
    """Per-slot SwiGLU against the resident quant-replica tier: [T*K, D] f32.

    Gathers each slot's TRUE expert from the int8/int4 tier so a miss is
    computed immediately at degraded fidelity instead of stalling on PCIe.
    The math lives in kernels/ref.dequant_swiglu — ONE reference shared with
    the quant_ffn / grouped_ffn oracles, so the in-model fallback and the
    kernel oracles cannot drift."""
    xr = jnp.repeat(x_flat.astype(jnp.float32),
                    e_flat.shape[0] // x_flat.shape[0], axis=0)  # [T*K, D]
    return dequant_swiglu(xr[:, None, :],
                          quant["w1_q"][e_flat], quant["w1_s"][e_flat],
                          quant["w3_q"][e_flat], quant["w3_s"][e_flat],
                          quant["w2_q"][e_flat], quant["w2_s"][e_flat])[:, 0]


def _fused_dispatch(params: dict, x_flat, new_idx, degraded, skip,
                    run_degraded: bool, use_kernel: bool, cap: int):
    """The single-dispatch hot path: per-slot outputs [T*K, D] for ALL
    outcome classes in one compute step.

    new_idx [T, K] — resolved expert ids (buddy slots already rewritten to
    the substituted id, so full-precision and buddy slots are the same
    class); degraded [T, K] — slots served from the quant replica at their
    TRUE id; skip [T, K] — slots whose mixture weight is zero (cost-argmin
    drops and fallback='drop' misses): they are never binned/computed.

    use_kernel=False: the jnp megastep — gather each slot's operands once,
    SELECTED by outcome class (fp table at the resolved id, or the dequant-
    scaled replica at the true id), then one SwiGLU einsum chain. This
    replaces fp-compute-over-all-slots PLUS quant-compute-over-all-slots
    with exactly one compute per slot.

    use_kernel=True: bin slots by (expert, class) into a [2E, cap, D]
    buffer and run kernels/grouped_ffn.py — one pallas_call, one scatter,
    one gather. Returns (y_rep [T*K, D], n_capacity_dropped [])."""
    t_n, d = x_flat.shape
    k_n = new_idx.shape[1]
    e_n = params["w1"].shape[0]
    e_flat = new_idx.reshape(-1)                                   # [N]
    n = e_flat.shape[0]
    deg_f = degraded.reshape(-1) if run_degraded \
        else jnp.zeros((n,), bool)
    skip_f = skip.reshape(-1)

    if not use_kernel:
        # -- jnp megastep: weights-as-operands by outcome class ---------
        xr = jnp.repeat(x_flat, k_n, axis=0)                       # [N, D]
        w1s = params["w1"][e_flat]                                 # [N, D, F]
        w3s = params["w3"][e_flat]
        w2s = params["w2"][e_flat]
        if run_degraded:
            q = params["quant"]
            sel = deg_f[:, None, None]
            # per-output-channel scales commute with the contraction, so
            # dequantizing the operand pre-matmul == the reference's
            # post-matmul placement (kernels/ref.dequant_swiglu)
            w1s = jnp.where(sel, (q["w1_q"][e_flat].astype(jnp.float32)
                                  * q["w1_s"][e_flat][:, None, :]
                                  ).astype(w1s.dtype), w1s)
            w3s = jnp.where(sel, (q["w3_q"][e_flat].astype(jnp.float32)
                                  * q["w3_s"][e_flat][:, None, :]
                                  ).astype(w3s.dtype), w3s)
            w2s = jnp.where(sel, (q["w2_q"][e_flat].astype(jnp.float32)
                                  * q["w2_s"][e_flat][:, None, :]
                                  ).astype(w2s.dtype), w2s)
        h = jax.nn.silu(jnp.einsum("td,tdf->tf", xr, w1s,
                                   preferred_element_type=jnp.float32))
        g = jnp.einsum("td,tdf->tf", xr, w3s,
                       preferred_element_type=jnp.float32)
        hg = (h * g).astype(x_flat.dtype)
        hg = shard(hg, None, "dff")
        y_rep = jnp.einsum("tf,tfd->td", hg, w2s,
                           preferred_element_type=jnp.float32
                           ).astype(x_flat.dtype)
        # skipped slots carry zero mixture weight; zero the output too so
        # the megastep's per-slot provenance matches the kernel path
        y_rep = jnp.where(skip_f[:, None], 0.0, y_rep)
        return y_rep, jnp.zeros((), jnp.int32)

    # -- Pallas grouped kernel: bin by (resolved expert, class) ---------
    grp = jnp.where(deg_f, e_flat + e_n, e_flat)
    grp = jnp.where(skip_f, 2 * e_n, grp)          # out of range: unbinned
    onehot = jax.nn.one_hot(grp, 2 * e_n, dtype=jnp.float32)       # [N, 2E]
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1).astype(jnp.int32) - 1
    kept = (pos >= 0) & (pos < cap)
    n_cap_dropped = (pos >= cap).sum()
    pos_safe = jnp.where(kept, pos, cap)
    xr = jnp.repeat(x_flat, k_n, axis=0)                           # [N, D]
    buf = jnp.zeros((2 * e_n, cap, d), x_flat.dtype) \
        .at[grp, pos_safe].set(xr, mode="drop")
    if run_degraded:
        q = params["quant"]
        qargs = (q["w1_q"], q["w1_s"], q["w3_q"], q["w3_s"],
                 q["w2_q"], q["w2_s"])
    else:
        # no tier attached: the degraded half of the grid is empty; feed
        # zero replicas (constant-folded) so the kernel signature is static
        f_n = params["w1"].shape[2]
        qargs = (jnp.zeros((e_n, d, f_n), jnp.int8),
                 jnp.ones((e_n, f_n), jnp.float32),
                 jnp.zeros((e_n, d, f_n), jnp.int8),
                 jnp.ones((e_n, f_n), jnp.float32),
                 jnp.zeros((e_n, f_n, d), jnp.int8),
                 jnp.ones((e_n, d), jnp.float32))
    from repro.kernels import ops as kops
    out_buf = kops.grouped_ffn(buf, params["w1"], params["w3"],
                               params["w2"], *qargs)
    y_rep = out_buf.at[grp, pos_safe].get(mode="fill", fill_value=0)
    return y_rep.astype(x_flat.dtype), n_cap_dropped.astype(jnp.int32)


def moe_forward(params: dict, x: jax.Array, cfg: MoEConfig, *,
                policy: Optional[BuddyPolicy] = None,
                buddy: Optional[BuddyState] = None,
                capacity_factor: float = 1.25,
                jitter_key=None,
                use_kernel: bool = False,
                dropless: bool = False) -> tuple:
    """x: [B, S, D] (or [T, D]). Returns (y, MoEAux).

    ``dropless``: force the capacity-based dispatch path with capacity
    S*K (no token ever dropped, no tiny-batch gather shortcut) — chunked
    prefill needs per-token outputs independent of which other tokens share
    the chunk, so C=1 and C=8 chunks produce identical per-token results.

    Tiered degraded fallback: when ``policy.quant_tier`` is on, the params
    carry a ``quant`` sub-dict, and ``buddy.quant_ok`` marks an expert, a
    missed slot computes against the resident low-precision replica in the
    SAME fused step (mixed-precision dispatch) — zero transfer, bounded
    fidelity loss. With quant_tier='off' this path is compiled out entirely
    and the graph is bit-identical to the pre-tier engine."""
    orig_shape = x.shape
    d = x.shape[-1]
    x_flat = x.reshape(-1, d)
    t_n = x_flat.shape[0]
    e_n, k_n = cfg.num_experts, cfg.top_k

    use_tier = (policy is not None and policy.quant_tier != "off"
                and "quant" in params)
    quant_ok = buddy.quant_ok if (use_tier and buddy is not None) else None
    tier_fid_cost = (buddy.fid_cost
                     if (use_tier and buddy is not None) else None)

    logits, idx, topk_logits, probs = router_topk(
        params["router"], x_flat, k_n, jitter_key, cfg.router_jitter)

    # ---------------- BuddyMoE substitution (Alg. 1) ----------------
    if policy is not None and buddy is not None:
        # substitute() owns the four-way miss split for EVERY mode,
        # including mode='none' (no rerouting, but misses still route to
        # the degraded tier before the fetch/drop fallback). In cost mode
        # the per-expert cost vectors replace the quant_ok precedence mask.
        # tier_fid_cost (not raw buddy.fid_cost): the degraded COMPUTE path
        # below is gated on use_tier, so the argmin's degraded option must
        # be too — a finite fid_cost without quant params would mark slots
        # degraded and then silently compute them at full precision
        res: SubstituteResult = substitute(
            idx, topk_logits, buddy.resident, buddy.table, buddy.q, policy,
            router_logits=logits, hop=buddy.hop, quant_ok=quant_ok,
            fid_cost=tier_fid_cost, fetch_cost=buddy.fetch_cost,
            peer_ok=buddy.peer_ok, peer_cost=buddy.peer_cost)
        new_idx, substituted, missed = res.indices, res.substituted, res.missed
        degraded = res.degraded
        dropped = (res.dropped if res.dropped is not None
                   else jnp.zeros_like(missed))
        peered = (res.peered if res.peered is not None
                  else jnp.zeros_like(missed))
    elif buddy is not None:         # no policy: raw residency miss count
        missed = ~buddy.resident[idx]
        new_idx = idx
        substituted = jnp.zeros_like(missed)
        degraded = jnp.zeros_like(missed)
        dropped = jnp.zeros_like(missed)
        peered = jnp.zeros_like(missed)
    else:
        new_idx = idx
        substituted = jnp.zeros(idx.shape, bool)
        missed = jnp.zeros(idx.shape, bool)
        degraded = jnp.zeros(idx.shape, bool)
        dropped = jnp.zeros(idx.shape, bool)
        peered = jnp.zeros(idx.shape, bool)
    run_degraded = use_tier and (quant_ok is not None
                                 or tier_fid_cost is not None)

    weights = probs
    if policy is not None and policy.fallback == "drop":
        # missed slots are skipped; renormalize over the surviving set
        weights = jnp.where(missed, 0.0, weights)
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    if policy is not None and policy.miss_policy == "cost":
        # slots the cost argmin chose to drop: skip + renormalize (per-slot
        # counterpart of the global fallback='drop' above)
        weights = jnp.where(dropped, 0.0, weights)
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # ---------------- single-dispatch fused hot path ----------------------
    # One compute step for the whole four-way miss outcome: full-precision
    # and buddy slots read the fp table at the RESOLVED id, degraded slots
    # read the quant replica at the TRUE id, dropped slots (zero mixture
    # weight) are skipped entirely. Replaces the three-dispatch split below
    # (expert_ffn path + buddy-replica einsum + separate degraded pass).
    if policy is not None and policy.use_fused_dispatch:
        # slots whose mixture weight was zeroed above — never computed
        skip = dropped
        if policy.fallback == "drop":
            skip = skip | missed
        if dropless or (x.ndim == 3 and x.shape[1] == 1):
            cap = t_n * k_n                       # decode / chunked prefill
        else:
            cap = int(max(k_n, t_n * k_n / e_n * capacity_factor))
            cap = min(t_n * k_n, -(-cap // 8) * 8)
        y_rep, n_dropped = _fused_dispatch(
            params, x_flat, new_idx, degraded, skip,
            run_degraded, use_kernel, cap)
        y = (y_rep.reshape(t_n, k_n, d)
             * weights[..., None].astype(x.dtype)).sum(1)
        if cfg.num_shared_experts and "shared" in params:
            y = y + swiglu(x_flat, params["shared"]["w1"],
                           params["shared"]["w3"], params["shared"]["w2"])
        p_mean = jax.nn.softmax(logits, axis=-1).mean(0)
        onehot_f = jax.nn.one_hot(new_idx.reshape(-1), e_n,
                                  dtype=jnp.float32)
        f_frac = onehot_f.reshape(t_n, k_n, e_n).sum(1).mean(0)
        lb = e_n * jnp.sum(f_frac * p_mean)
        miss_per_expert = jnp.zeros((e_n,), jnp.int32) \
            .at[idx.reshape(-1)].add(missed.reshape(-1).astype(jnp.int32))
        aux = MoEAux(lb, new_idx, idx, probs, substituted.sum(),
                     missed.sum(), n_dropped, miss_per_expert,
                     substituted, missed, degraded.sum(), degraded,
                     dropped.sum(), dropped, peered.sum(), peered)
        return y.reshape(orig_shape), aux

    # ---------------- active-expert gather (tiny-batch decode) -----------
    # When the whole batch selects fewer expert-slots than there are experts
    # (long-context decode, B*K < E), gathering the selected experts' weight
    # rows reads only the ACTIVE experts from HBM — the dense dispatch path
    # below streams all E experts' weights every step. §Perf iteration 6.
    if not dropless and x.ndim == 3 and x.shape[1] == 1 and t_n * k_n < e_n:
        e_flat = new_idx.reshape(-1)                               # [T*K]
        w1s = params["w1"][e_flat]                                 # [T*K, D, F]
        w3s = params["w3"][e_flat]
        w2s = params["w2"][e_flat]
        xr = jnp.repeat(x_flat, k_n, axis=0)                       # [T*K, D]
        h = jax.nn.silu(jnp.einsum("td,tdf->tf", xr, w1s,
                                   preferred_element_type=jnp.float32))
        g = jnp.einsum("td,tdf->tf", xr, w3s,
                       preferred_element_type=jnp.float32)
        hg = (h * g).astype(x.dtype)
        hg = shard(hg, None, "dff")
        y_rep = jnp.einsum("tf,tfd->td", hg, w2s,
                           preferred_element_type=jnp.float32).astype(x.dtype)
        if run_degraded:
            y_deg = _degraded_outputs(params["quant"], x_flat, e_flat)
            y_rep = jnp.where(degraded.reshape(-1)[:, None],
                              y_deg.astype(x.dtype), y_rep)
        y = (y_rep.reshape(t_n, k_n, d)
             * weights[..., None].astype(x.dtype)).sum(1)
        if cfg.num_shared_experts and "shared" in params:
            y = y + swiglu(x_flat, params["shared"]["w1"],
                           params["shared"]["w3"], params["shared"]["w2"])
        p_mean = jax.nn.softmax(logits, axis=-1).mean(0)
        onehot_f = jax.nn.one_hot(e_flat, e_n, dtype=jnp.float32)
        f_frac = onehot_f.reshape(t_n, k_n, e_n).sum(1).mean(0)
        lb = e_n * jnp.sum(f_frac * p_mean)
        miss_per_expert = jnp.zeros((e_n,), jnp.int32).at[idx.reshape(-1)].add(
            missed.reshape(-1).astype(jnp.int32))
        aux = MoEAux(lb, new_idx, idx, probs, substituted.sum(), missed.sum(),
                     jnp.zeros((), jnp.int32), miss_per_expert,
                     substituted, missed, degraded.sum(), degraded,
                     dropped.sum(), dropped, peered.sum(), peered)
        return y.reshape(orig_shape), aux

    # ---------------- capacity-based dispatch (row-local) ----------------
    # Dispatch independently per batch row so that with the batch sharded
    # over `data` the scatter/gather and expert compute are collective-free
    # (tokens never cross data shards; experts are TP-sharded on d_ff).
    rows = x.shape[0] if x.ndim == 3 else 1
    s_n = t_n // rows
    row_e = new_idx.reshape(rows, s_n * k_n)                        # [B, S*K]
    onehot = jax.nn.one_hot(row_e, e_n, dtype=jnp.float32)          # [B, S*K, E]
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1).astype(jnp.int32) - 1
    if dropless:
        cap = s_n * k_n
    else:
        cap = int(max(k_n, s_n * k_n / e_n * capacity_factor))
        cap = min(s_n * k_n, -(-cap // 8) * 8)
    kept = pos < cap
    n_dropped = (~kept).sum()
    pos_safe = jnp.where(kept, pos, cap)                            # cap -> dropped

    x_rep = jnp.repeat(x_flat.reshape(rows, s_n, d), k_n, axis=1)   # [B, S*K, D]

    def _row_scatter(xr, er, pr):
        return jnp.zeros((e_n, cap, d), x.dtype).at[er, pr].set(xr, mode="drop")

    # vmap -> scatter with operand batching dims: GSPMD keeps it data-local
    buf = jax.vmap(_row_scatter)(x_rep, row_e, pos_safe)            # [B, E, C, D]
    buf = shard(buf, "batch", "expert", None, None)

    if use_kernel:
        from repro.kernels import ops as kops
        flat = buf.transpose(1, 0, 2, 3).reshape(e_n, rows * cap, d)
        out = kops.expert_ffn(flat, params["w1"], params["w3"], params["w2"])
        out_buf = out.reshape(e_n, rows, cap, d).transpose(1, 0, 2, 3)
    else:
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["w1"],
                                   preferred_element_type=jnp.float32))
        g = jnp.einsum("becd,edf->becf", buf, params["w3"],
                       preferred_element_type=jnp.float32)
        hg = (h * g).astype(x.dtype)
        hg = shard(hg, "batch", "expert", None, "dff")
        out_buf = jnp.einsum("becf,efd->becd", hg, params["w2"],
                             preferred_element_type=jnp.float32).astype(x.dtype)

    def _row_gather(ob, er, pr):
        return ob.at[er, pr].get(mode="fill", fill_value=0)

    y_rep = jax.vmap(_row_gather)(out_buf, row_e, pos_safe)         # [B, S*K, D]
    yk = y_rep.reshape(t_n, k_n, d)                                 # [T, K, D]
    if run_degraded:
        y_deg = _degraded_outputs(params["quant"], x_flat,
                                  new_idx.reshape(-1))
        yk = jnp.where(degraded[..., None],
                       y_deg.reshape(t_n, k_n, d).astype(x.dtype), yk)
    y = (yk * weights[..., None].astype(x.dtype)).sum(1)

    if cfg.num_shared_experts and "shared" in params:
        y = y + swiglu(x_flat, params["shared"]["w1"], params["shared"]["w3"],
                       params["shared"]["w2"])

    # ---------------- load-balance loss (Switch-style) ----------------
    p_mean = jax.nn.softmax(logits, axis=-1).mean(0)               # [E]
    f_frac = onehot.reshape(t_n, k_n, e_n).sum(1).mean(0)          # [E]
    lb = e_n * jnp.sum(f_frac * p_mean)

    miss_per_expert = jnp.zeros((e_n,), jnp.int32).at[idx.reshape(-1)].add(
        missed.reshape(-1).astype(jnp.int32))

    aux = MoEAux(lb, new_idx, idx, probs,
                 substituted.sum(), missed.sum(), n_dropped, miss_per_expert,
                 substituted, missed, degraded.sum(), degraded,
                 dropped.sum(), dropped, peered.sum(), peered)
    return y.reshape(orig_shape), aux
