"""Mamba2 (SSD) block for the zamba2 hybrid [arXiv:2411.15242 / Mamba2].

Scalar-per-head decay SSD recurrence with causal depthwise conv and gating:
    h_t = a_t * h_{t-1} + dt_t * (x_t outer B_t)        a_t = exp(-softplus(A) dt_t)
    y_t = h_t @ C_t + D * x_t ;  y = y * silu(z)
State per layer: conv tail [B, conv_dim-1, inner] + SSM state [B, H, hd, N].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.common import dense_init, shard


def init_mamba(key, d_model: int, cfg: SSMConfig, dtype) -> dict:
    inner = cfg.expand * d_model
    n_heads = inner // cfg.head_dim
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * inner + 2 * cfg.state_dim + n_heads, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_dim, inner)) * 0.1).astype(dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "out_proj": dense_init(ks[2], inner, d_model, dtype),
    }


def _split_proj(z, inner, state_dim, n_heads):
    xz, b, c, dt = jnp.split(z, [2 * inner, 2 * inner + state_dim,
                                 2 * inner + 2 * state_dim], axis=-1)
    x, gate = jnp.split(xz, 2, axis=-1)
    return x, gate, b, c, dt


def _causal_conv(x, conv_w, tail):
    """Depthwise causal conv. x [B, S, inner]; conv_w [W, inner];
    tail [B, W-1, inner] (previous inputs). Returns (y, new_tail)."""
    w = conv_w.shape[0]
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * conv_w[i] for i in range(w))
    return jax.nn.silu(y), xp[:, -(w - 1):]


def ssd_chunked(a, xh, bt, ct, dt, h0, chunk: int = 32):
    """Chunkwise-parallel SSD scan (Mamba2) — §Perf iteration 4.

    a [B,S,H] per-head scalar decay; xh [B,S,H,hd]; bt/ct [B,S,N];
    dt [B,S,H]; h0 [B,H,hd,N]. Returns (y_ssm [B,S,H,hd], hN).

      la_t = cumsum log a;  c~_t = c_t exp(la_t);  b~_s = b_s dt_s exp(-la_s)
      o_t = exp(la_t) (h_0 @ c_t) + [lower_incl(c~ b~^T)] @ x
      h'  = exp(la_C) h_0 + (exp(la_C - la_s) dt_s b_s)^T x_s
    """
    b_sz, s_len, h_n = a.shape
    hd = xh.shape[-1]
    n_dim = bt.shape[-1]
    n = s_len // chunk
    f32 = jnp.float32

    ac = a.reshape(b_sz, n, chunk, h_n).transpose(1, 0, 3, 2)        # [N,B,H,C]
    dtc = dt.reshape(b_sz, n, chunk, h_n).transpose(1, 0, 3, 2)
    xc = xh.reshape(b_sz, n, chunk, h_n, hd).transpose(1, 0, 3, 2, 4)
    bc = bt.reshape(b_sz, n, chunk, n_dim).transpose(1, 0, 2, 3)     # [N,B,C,Nd]
    cc = ct.reshape(b_sz, n, chunk, n_dim).transpose(1, 0, 2, 3)

    la = jnp.cumsum(jnp.log(jnp.maximum(ac, 1e-12)), axis=3)         # [N,B,H,C]
    la_end = la[:, :, :, -1:]

    # decay-weighted b/c (b/c are head-shared; decay is per-head -> expand)
    c_dec = cc[:, :, None] * jnp.exp(la)[..., None]                  # [N,B,H,C,Nd]
    b_dec = bc[:, :, None] * (dtc * jnp.exp(-la))[..., None]
    b_end = bc[:, :, None] * (dtc * jnp.exp(la_end - la))[..., None]

    mask = jnp.tril(jnp.ones((chunk, chunk), f32))                   # incl diag

    def step(h, inp):
        c_d, b_d, b_e, x_, laE = inp          # laE [B,H]
        o_inter = jnp.einsum("bhdn,bhcn->bhcd", h, c_d)
        scores = jnp.einsum("bhcn,bhsn->bhcs", c_d, b_d) * mask[None, None]
        o_intra = jnp.einsum("bhcs,bhsd->bhcd", scores, x_)
        h_new = jnp.exp(laE)[..., None, None] * h \
            + jnp.einsum("bhsn,bhsd->bhdn", b_e, x_)
        return h_new, o_inter + o_intra

    hN, out = jax.lax.scan(step, h0,
                           (c_dec, b_dec, b_end, xc, la_end[:, :, :, 0]))
    out = out.transpose(1, 0, 3, 2, 4).reshape(b_sz, s_len, h_n, hd)
    return out, hN


def mamba_forward(params, x, state, cfg: SSMConfig, d_model: int):
    """x: [B, S, D]; state: dict(conv [B,W-1,inner], ssm [B,H,hd,N]).
    Returns (y [B,S,D], new_state)."""
    inner = cfg.expand * d_model
    n_heads = inner // cfg.head_dim
    b_sz, s_len, _ = x.shape

    z = x @ params["in_proj"]
    xi, gate, b, c, dt = _split_proj(z, inner, cfg.state_dim, n_heads)
    xi, conv_tail = _causal_conv(xi, params["conv_w"], state["conv"])
    xi = shard(xi, "batch", None, "dff")

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # [B,S,H]
    a = jnp.exp(-jax.nn.softplus(params["A_log"]) * dt)               # [B,S,H]
    xh = xi.reshape(b_sz, s_len, n_heads, cfg.head_dim).astype(jnp.float32)
    bt = b.astype(jnp.float32)                                        # [B,S,N]
    ct = c.astype(jnp.float32)

    h0 = state["ssm"].astype(jnp.float32)
    if s_len % 32 == 0 and s_len > 1:
        y_ssm, hN = ssd_chunked(a, xh, bt, ct, dt, h0)
    else:
        def step(h, inp):
            a_, x_, b_, dt_ = inp   # [B,H], [B,H,hd], [B,N], [B,H]
            dx = (dt_[..., None] * x_)[..., None] * b_[:, None, None, :]
            h_new = a_[..., None, None] * h + dx
            return h_new, h_new

        hN, hs = jax.lax.scan(step, h0,
                              (a.swapaxes(0, 1), xh.swapaxes(0, 1),
                               bt.swapaxes(0, 1), dt.swapaxes(0, 1)))
        y_ssm = jnp.einsum("sbhdn,bsn->bshd", hs, ct)
    # y_t = h_t @ C_t + D * x_t
    y = y_ssm + params["D"][:, None] * xh
    y = y.reshape(b_sz, s_len, inner).astype(x.dtype)
    y = y * jax.nn.silu(gate).astype(x.dtype)
    out = (y @ params["out_proj"]).astype(x.dtype)
    return out, {"conv": conv_tail.astype(jnp.float32), "ssm": hN}


def init_mamba_state(batch: int, d_model: int, cfg: SSMConfig):
    inner = cfg.expand * d_model
    n_heads = inner // cfg.head_dim
    return {
        "conv": jnp.zeros((batch, cfg.conv_dim - 1, inner), jnp.float32),
        "ssm": jnp.zeros((batch, n_heads, cfg.head_dim, cfg.state_dim), jnp.float32),
    }
