"""Composable decoder stack for all six assigned families.

The stack is compiled from ``cfg.stack()`` into scan *groups*: params for each
group are stacked on a leading axis and the group executes as one
``lax.scan`` — HLO size stays ~constant in depth (essential for the 512-device
dry-run compiles).

Three entry points (shared layer code):
  forward_train(params, cfg, tokens, ...)  -> logits [B, S, V], aux
  prefill(params, cfg, tokens, ...)        -> logits [B, S, V], caches
  decode_step(params, cfg, token, caches, pos, ...) -> logits [B, V], caches

Serving state for MoE archs: ``buddies`` is a BuddyState with leading layer
axis [L_moe, ...]; ``policy`` (static) selects Original / Random / BuddyMoE.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN_DENSE, ATTN_MOE, CROSS_DENSE, MAMBA2,
                                RWKV, ModelConfig)
from repro.core.policy import BuddyPolicy
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models import rwkv as rw
from repro.models.common import dense_init, embed_init, rmsnorm, shard, swiglu


# ===========================================================================
# Init
# ===========================================================================
def _init_dense_ffn(key, d_model, d_ff, dtype):
    k1, k3, k2 = jax.random.split(key, 3)
    return {"w1": dense_init(k1, d_model, d_ff, dtype),
            "w3": dense_init(k3, d_model, d_ff, dtype),
            "w2": dense_init(k2, d_ff, d_model, dtype)}


def _init_attn_block(key, cfg: ModelConfig, dtype, moe: bool):
    ka, kf = jax.random.split(key)
    p = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
         "ln2": jnp.ones((cfg.d_model,), jnp.float32),
         "attn": attn.init_attn(ka, cfg.d_model, cfg.num_heads,
                                cfg.num_kv_heads, cfg.head_dim, dtype)}
    if moe:
        p["moe"] = moe_mod.init_moe(kf, cfg.d_model, cfg.moe, dtype)
    else:
        p["ffn"] = _init_dense_ffn(kf, cfg.d_model, cfg.d_ff, dtype)
    return p


def _init_block(key, kind: str, cfg: ModelConfig, dtype):
    if kind in (ATTN_DENSE, CROSS_DENSE):
        return _init_attn_block(key, cfg, dtype, moe=False)
    if kind == ATTN_MOE:
        return _init_attn_block(key, cfg, dtype, moe=True)
    if kind == RWKV:
        s = cfg.ssm
        p = rw.init_rwkv(key, cfg.d_model, s.num_heads, s.head_dim, cfg.d_ff, dtype)
        p["ln1"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
        return p
    if kind == MAMBA2:
        p = {"ln": jnp.ones((cfg.d_model,), jnp.float32),
             "mamba": mb.init_mamba(key, cfg.d_model, cfg.ssm, dtype)}
        return p
    if kind == "hybrid_super":
        keys = jax.random.split(key, cfg.attn_every)
        return {"mamba": _stack([_init_block(k, MAMBA2, cfg, dtype) for k in keys]),
                "ln_attn": jnp.ones((cfg.d_model,), jnp.float32)}
    if kind == "vlm_super":
        n_self = cfg.cross_attn_every - 1
        keys = jax.random.split(key, n_self + 1)
        return {"self": _stack([_init_block(k, ATTN_DENSE, cfg, dtype)
                                for k in keys[:n_self]]),
                "cross": _init_block(keys[-1], CROSS_DENSE, cfg, dtype)}
    raise ValueError(kind)


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: dict = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)}
    if cfg.num_cond_tokens:
        params["cond_proj"] = dense_init(keys[1], cfg.cond_dim, cfg.d_model, dtype)
    groups = []
    for gi, (kind, repeat) in enumerate(cfg.stack()):
        gkey = jax.random.fold_in(keys[2], gi)
        blocks = [_init_block(jax.random.fold_in(gkey, i), kind, cfg, dtype)
                  for i in range(repeat)]
        groups.append(_stack(blocks))
    params["groups"] = tuple(groups)
    if cfg.family == "hybrid":
        # zamba2 shared attention block — ONE param set reused at every
        # application (the defining trick of the arch)
        params["shared_attn"] = _init_attn_block(keys[3], cfg, dtype, moe=False)
    params["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[4], cfg.d_model, cfg.vocab_size, dtype)
    return params


# ===========================================================================
# Block forward (mode: "full" = train/prefill over S tokens; "step" = decode)
# ===========================================================================
class StepCtx(NamedTuple):
    cfg: ModelConfig
    mode: str                      # "full" | "step" | "chunk"
    window: int                    # effective attention window (0 = full)
    policy: Optional[BuddyPolicy]
    positions: Any                 # [B, S] (full), scalar/[B] pos (step),
    #                                or [B] per-row base positions (chunk)
    rng: Any                       # router jitter key or None
    record: bool
    remat: bool = False            # checkpoint each scanned block (training)
    tok_valid: Any = None          # [B, C] prefix validity mask (chunk mode)
    block_tables: Any = None       # [B, MB] paged-KV block table (None = ring)


def _attn_kwargs(cfg: ModelConfig):
    return dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.head_dim, rope_theta=cfg.rope_theta)


def _self_attn(p, x, cache, ctx: StepCtx):
    if ctx.mode == "full":
        y = attn.attn_forward(p, x, ctx.positions, window=ctx.window,
                              **_attn_kwargs(ctx.cfg))
        return y, cache
    if ctx.mode == "chunk":
        if ctx.block_tables is not None:
            return attn.attn_prefill_chunk_paged(
                p, x, cache, ctx.positions, ctx.tok_valid, ctx.block_tables,
                **_attn_kwargs(ctx.cfg))
        return attn.attn_prefill_chunk(p, x, cache, ctx.positions,
                                       ctx.tok_valid, window=ctx.window,
                                       **_attn_kwargs(ctx.cfg))
    if ctx.block_tables is not None:
        return attn.attn_decode_paged(p, x, cache, ctx.positions,
                                      ctx.block_tables,
                                      **_attn_kwargs(ctx.cfg))
    y, cache = attn.attn_decode(p, x, cache, ctx.positions,
                                window=ctx.window, **_attn_kwargs(ctx.cfg))
    return y, cache


def _zero_moe_aux(cfg: ModelConfig):
    e = cfg.moe.num_experts if cfg.is_moe else 1
    return {"lb": jnp.zeros((), jnp.float32),
            "n_sub": jnp.zeros((), jnp.int32),
            "n_miss": jnp.zeros((), jnp.int32),
            "n_drop": jnp.zeros((), jnp.int32),
            "n_degraded": jnp.zeros((), jnp.int32),
            "n_miss_drop": jnp.zeros((), jnp.int32),
            "n_peer": jnp.zeros((), jnp.int32),
            "miss_per_expert": jnp.zeros((e,), jnp.int32)}


def _moe_aux_dict(cfg, aux: moe_mod.MoEAux, record: bool):
    d = {"lb": aux.lb_loss, "n_sub": aux.n_substituted.astype(jnp.int32),
         "n_miss": aux.n_missed.astype(jnp.int32),
         "n_drop": aux.n_dropped.astype(jnp.int32),
         "n_degraded": aux.n_degraded.astype(jnp.int32),
         "n_miss_drop": aux.n_miss_drop.astype(jnp.int32),
         "n_peer": aux.n_peered.astype(jnp.int32),
         "miss_per_expert": aux.miss_per_expert}
    if record:
        d["indices"] = aux.orig_indices
        d["probs"] = aux.topk_probs
        d["substituted"] = aux.sub_slots
        d["missed"] = aux.miss_slots
        d["degraded"] = aux.deg_slots
        d["dropped"] = aux.drop_slots
        d["peered"] = aux.peer_slots
    return d


def block_forward(kind: str, p, x, cache, ctx: StepCtx, buddy=None,
                  shared_attn_params=None):
    """Returns (x_out, new_cache, aux_dict_or_None)."""
    cfg = ctx.cfg
    aux = None
    if kind in (ATTN_DENSE, ATTN_MOE):
        h, cache_kv = _self_attn(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                                 cache["kv"] if cache else None, ctx)
        x = x + h
        xn = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if kind == ATTN_MOE:
            y, moe_aux = moe_mod.moe_forward(
                p["moe"], xn, cfg.moe, policy=ctx.policy, buddy=buddy,
                jitter_key=ctx.rng,
                capacity_factor=2.0 if ctx.mode == "step" else 1.25,
                dropless=ctx.mode == "chunk")
            aux = _moe_aux_dict(cfg, moe_aux, ctx.record)
        else:
            y = swiglu(xn, p["ffn"]["w1"], p["ffn"]["w3"], p["ffn"]["w2"])
        x = x + y
        return x, {"kv": cache_kv} if cache else None, aux

    if kind == CROSS_DENSE:
        raise ValueError("cross-attn blocks run inside vlm_super groups")

    if kind == RWKV:
        if ctx.mode == "full":
            st = cache or rw.init_rwkv_state(x.shape[0], cfg.ssm.num_heads,
                                             cfg.ssm.head_dim, cfg.d_model)
        else:
            st = cache
        h, wkv, x_tm = rw.rwkv_time_mix(
            p, rmsnorm(x, p["ln1"], cfg.norm_eps), st["wkv"],
            st["x_tm"].astype(x.dtype), num_heads=cfg.ssm.num_heads,
            head_dim=cfg.ssm.head_dim)
        x = x + h
        h, x_cm = rw.rwkv_channel_mix(p, rmsnorm(x, p["ln2"], cfg.norm_eps),
                                      st["x_cm"].astype(x.dtype))
        x = x + h
        new_cache = {"wkv": wkv, "x_tm": x_tm.astype(jnp.float32),
                     "x_cm": x_cm.astype(jnp.float32)}
        return x, new_cache, aux

    if kind == MAMBA2:
        st = cache or mb.init_mamba_state(x.shape[0], cfg.d_model, cfg.ssm)
        h, new_st = mb.mamba_forward(p["mamba"], rmsnorm(x, p["ln"], cfg.norm_eps),
                                     st, cfg.ssm, cfg.d_model)
        return x + h, new_st, aux

    raise ValueError(kind)


# ===========================================================================
# Group execution (scan over stacked blocks)
# ===========================================================================
def _run_group(kind: str, gparams, x, gcache, ctx: StepCtx, gbuddy=None,
               shared_attn=None, cross_embeds=None):
    """Scan one homogeneous group. gparams stacked [R, ...]."""
    cfg = ctx.cfg
    has_cache = gcache is not None

    if kind == "hybrid_super":
        def body(carry, inp):
            x = carry
            lp, lc = inp
            mcaches = []
            for i in range(cfg.attn_every):
                blk = jax.tree.map(lambda a: a[i], lp["mamba"])
                mc = jax.tree.map(lambda a: a[i], lc["mamba"]) if has_cache else None
                x, nc, _ = block_forward(MAMBA2, blk, x, mc, ctx)
                mcaches.append(nc if has_cache else None)
            # shared attention application
            h, kv = _self_attn(shared_attn["attn"],
                               rmsnorm(x, lp["ln_attn"], cfg.norm_eps),
                               lc["kv"] if has_cache else None, ctx)
            x = x + h
            xn = rmsnorm(x, shared_attn["ln2"], cfg.norm_eps)
            x = x + swiglu(xn, shared_attn["ffn"]["w1"], shared_attn["ffn"]["w3"],
                           shared_attn["ffn"]["w2"])
            new_cache = None
            if has_cache:
                new_cache = {"mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *mcaches),
                             "kv": kv}
            return x, new_cache

        if ctx.remat:
            body = jax.checkpoint(body)
        x, new_caches = jax.lax.scan(body, x, (gparams, gcache))
        return x, new_caches, _zero_moe_aux(cfg)

    if kind == "vlm_super":
        n_self = cfg.cross_attn_every - 1

        def body(carry, inp):
            x = carry
            lp, lc = inp

            # inner scan over the self-attn layers (a python loop slicing
            # the stacked caches makes GSPMD gather them — §Perf B7)
            def self_body(xc, sinp):
                blk, skv = sinp
                xc, nc, _ = block_forward(
                    ATTN_DENSE, blk, xc,
                    {"kv": skv} if has_cache else None, ctx)
                return xc, (nc["kv"] if has_cache else None)

            x, new_self_kv = jax.lax.scan(
                self_body, x,
                (lp["self"], lc["self_kv"] if has_cache else None),
                length=n_self)
            # cross-attention block
            cp = lp["cross"]
            cross_kv = lc.get("cross_kv") if has_cache else None
            x = _cross_block(cp, x, ctx, cross_embeds, cross_kv)
            new_cache = None
            if has_cache:
                new_cache = {"self_kv": new_self_kv,
                             "cross_kv": lc["cross_kv"]}
            return x, new_cache

        if ctx.remat:
            body = jax.checkpoint(body)
        x, new_caches = jax.lax.scan(body, x, (gparams, gcache))
        return x, new_caches, _zero_moe_aux(cfg)

    # homogeneous group
    def body(carry, inp):
        x, rng = carry
        lp, lc, lb, li = inp
        lctx = ctx._replace(rng=jax.random.fold_in(rng, li) if rng is not None else None)
        x, nc, aux = block_forward(kind, lp, x, lc, lctx, buddy=lb)
        if aux is None:
            aux = _zero_moe_aux(cfg)
        return (x, rng), (nc, aux)

    if ctx.remat:
        body = jax.checkpoint(body)
    r = jax.tree.leaves(gparams)[0].shape[0]
    li = jnp.arange(r)
    (x, _), (new_caches, auxs) = jax.lax.scan(
        body, (x, ctx.rng), (gparams, gcache, gbuddy, li))
    # reduce aux over layers; keep per-layer stacks when recording
    red = {k: auxs[k].sum(0) for k in
           ("lb", "n_sub", "n_miss", "n_drop", "n_degraded", "n_miss_drop",
            "n_peer", "miss_per_expert")}
    if ctx.record:
        red["per_layer"] = {k: v for k, v in auxs.items()
                            if k in ("indices", "probs", "n_sub", "n_miss",
                                     "miss_per_expert", "substituted",
                                     "missed", "degraded", "dropped",
                                     "peered")}
    return x, new_caches, red


def _cross_block(cp, x, ctx: StepCtx, cross_embeds, cross_kv):
    cfg = ctx.cfg
    xn = rmsnorm(x, cp["ln1"], cfg.norm_eps)
    if ctx.mode == "full":
        h = attn.attn_forward(cp["attn"], xn, ctx.positions,
                              cross_embeds=cross_embeds, **_attn_kwargs(cfg))
    else:
        h, _ = attn.attn_decode(cp["attn"], xn, None, ctx.positions,
                                cross_kv=cross_kv, **_attn_kwargs(cfg))
    x = x + h
    xn = rmsnorm(x, cp["ln2"], cfg.norm_eps)
    return x + swiglu(xn, cp["ffn"]["w1"], cp["ffn"]["w3"], cp["ffn"]["w2"])


# ===========================================================================
# Cache init
# ===========================================================================
def effective_window(cfg: ModelConfig, seq_len: int,
                     long_context: bool = False) -> int:
    """Attention window for decode: native SWA if set; long-context decode on
    full-attention archs falls back to the SWA variant (DESIGN.md §4)."""
    if cfg.sliding_window:
        return cfg.sliding_window
    if long_context:
        return 8192
    return 0


def _kv_capacity(cfg: ModelConfig, seq_len: int, window: int) -> int:
    total = seq_len + cfg.num_cond_tokens
    return min(total, window) if window else total


def init_caches(cfg: ModelConfig, batch: int, seq_len: int, *,
                window: int = 0, dtype=None, cond_embeds=None, params=None):
    """Decode caches for every group (stacked on group's repeat axis)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    cap = _kv_capacity(cfg, seq_len, window)

    def kv(b=batch):
        return attn.init_kv_cache(b, cap, cfg.num_kv_heads, cfg.head_dim, dtype)

    caches = []
    for kind, repeat in cfg.stack():
        if kind in (ATTN_DENSE, ATTN_MOE):
            c = {"kv": _stack_n(kv, repeat)}
        elif kind == RWKV:
            c = _stack_n(lambda: rw.init_rwkv_state(
                batch, cfg.ssm.num_heads, cfg.ssm.head_dim, cfg.d_model), repeat)
        elif kind == MAMBA2:
            c = _stack_n(lambda: mb.init_mamba_state(batch, cfg.d_model, cfg.ssm), repeat)
        elif kind == "hybrid_super":
            c = {"mamba": _stack_n(lambda: _stack_n(
                    lambda: mb.init_mamba_state(batch, cfg.d_model, cfg.ssm),
                    cfg.attn_every), repeat),
                 "kv": _stack_n(kv, repeat)}
        elif kind == "vlm_super":
            n_self = cfg.cross_attn_every - 1
            nc = cfg.num_cond_tokens
            cross_kv = (jnp.zeros((batch, nc, cfg.num_kv_heads, cfg.head_dim), dtype),
                        jnp.zeros((batch, nc, cfg.num_kv_heads, cfg.head_dim), dtype))
            c = {"self_kv": _stack_n(lambda: _stack_n(kv, n_self), repeat),
                 "cross_kv": _stack_n(lambda: cross_kv, repeat)}
        else:
            raise ValueError(kind)
        caches.append(c)
    return tuple(caches)


def init_paged_caches(cfg: ModelConfig, n_blocks: int, block_size: int, *,
                      dtype=None):
    """Paged decode caches: one shared [P, bs, KV, hd] block pool per layer
    (stacked on the group repeat axis like init_caches), addressed through
    the host-side PagedKVPool block tables instead of a per-row ring.
    Attention-only stacks (same restriction as chunked prefill — SSM state
    is sequential and has no pages)."""
    assert all(k in (ATTN_DENSE, ATTN_MOE) for k, _ in cfg.stack()), \
        f"paged KV supports attention stacks only, got {cfg.stack()}"
    dtype = dtype or jnp.dtype(cfg.dtype)

    def kv():
        return attn.init_paged_kv_cache(n_blocks, block_size,
                                        cfg.num_kv_heads, cfg.head_dim, dtype)

    return tuple({"kv": _stack_n(kv, repeat)} for _, repeat in cfg.stack())


def _stack_n(fn, n):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *[fn() for _ in range(n)])


# ===========================================================================
# Entry points
# ===========================================================================
def _embed(params, cfg: ModelConfig, tokens, cond_embeds):
    x = params["embed"][tokens]
    x = shard(x, "batch", None, None)
    if cfg.num_cond_tokens and cfg.family == "audio":
        # audio: stubbed codec frame embeddings as a causal prefix
        pre = (cond_embeds @ params["cond_proj"]).astype(x.dtype)
        x = jnp.concatenate([pre, x], axis=1)
    return x


def _project_cross(params, cfg, cond_embeds):
    if cfg.family == "vlm" and cond_embeds is not None:
        return (cond_embeds @ params["cond_proj"]).astype(jnp.dtype(cfg.dtype))
    return None


def _logits(params, cfg: ModelConfig, x):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,dv->...v", x, head,
                        preferred_element_type=jnp.float32)
    return shard(logits, "batch", *([None] * (logits.ndim - 2)), "vocab")


def _iter_groups(params, cfg, caches, buddies):
    """Yields (kind, gparams, gcache, gbuddy) with moe buddy slices."""
    moe_off = 0
    for gi, (kind, repeat) in enumerate(cfg.stack()):
        gp = params["groups"][gi]
        gc = caches[gi] if caches is not None else None
        gb = None
        if kind == ATTN_MOE and buddies is not None:
            gb = jax.tree.map(lambda a: a[moe_off:moe_off + repeat], buddies)
            moe_off += repeat
        elif kind == ATTN_MOE:
            gb = _stack_n(lambda: moe_mod.full_residency(cfg.moe.num_experts), repeat)
        yield kind, gp, gc, gb


def forward_train(params, cfg: ModelConfig, tokens, *, cond_embeds=None,
                  policy: Optional[BuddyPolicy] = None, buddies=None,
                  rng=None, record: bool = False, window: int = -1,
                  remat: bool = False):
    """Full-sequence forward. Returns (logits [B, S_tok, V], aux)."""
    if window < 0:
        window = cfg.sliding_window
    b, s = tokens.shape
    x = _embed(params, cfg, tokens, cond_embeds)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), (b, x.shape[1]))
    cross = _project_cross(params, cfg, cond_embeds)
    ctx = StepCtx(cfg, "full", window, policy, positions, rng, record, remat)

    total_aux = _zero_moe_aux(cfg)
    rec = []
    for kind, gp, gc, gb in _iter_groups(params, cfg, None, buddies):
        x, _, aux = _run_group(kind, gp, x, None, ctx, gbuddy=gb,
                               shared_attn=params.get("shared_attn"),
                               cross_embeds=cross)
        if aux:
            for k in total_aux:
                total_aux[k] = total_aux[k] + aux.get(k, 0)
            if record and aux.get("per_layer"):
                rec.append(aux["per_layer"])
    if cfg.family == "audio" and cfg.num_cond_tokens:
        x = x[:, cfg.num_cond_tokens:]
    logits = _logits(params, cfg, x)
    if record:
        total_aux["recorded"] = rec
    return logits, total_aux


def decode_step(params, cfg: ModelConfig, token, caches, pos, *,
                cond_embeds=None, policy: Optional[BuddyPolicy] = None,
                buddies=None, rng=None, window: int = -1,
                record: bool = False, block_tables=None):
    """One-token decode. token [B] int32; pos int32 — a scalar (lockstep
    batch) or a [B] vector of per-row absolute positions (continuous
    batching), including any audio conditioning prefix. block_tables
    [B, MB] routes attention through the paged-KV pool instead of the
    per-row ring (pos must then be [B]). Returns
    (logits [B, V], new_caches, aux)."""
    if window < 0:
        window = cfg.sliding_window
    x = params["embed"][token][:, None, :]            # [B, 1, D]
    if cfg.family == "audio" and cfg.num_cond_tokens:
        pos = pos + cfg.num_cond_tokens
    ctx = StepCtx(cfg, "step", window, policy, pos, rng, record,
                  block_tables=block_tables)

    total_aux = _zero_moe_aux(cfg)
    rec = []
    new_caches = []
    for gi, (kind, gp, gc, gb) in enumerate(
            _iter_groups(params, cfg, caches, buddies)):
        if kind == "vlm_super":
            x, nc, aux = _run_group(kind, gp, x, gc, ctx)
        else:
            x, nc, aux = _run_group(kind, gp, x, gc, ctx, gbuddy=gb,
                                    shared_attn=params.get("shared_attn"))
        new_caches.append(nc)
        if aux:
            for k in total_aux:
                total_aux[k] = total_aux[k] + aux.get(k, 0)
            if record and aux.get("per_layer"):
                rec.append(aux["per_layer"])
    logits = _logits(params, cfg, x[:, 0])
    if record:
        total_aux["recorded"] = rec
    return logits, tuple(new_caches), total_aux


def prefill_chunk(params, cfg: ModelConfig, tokens, caches, base_pos,
                  tok_valid, *, policy: Optional[BuddyPolicy] = None,
                  buddies=None, rng=None, window: int = -1,
                  record: bool = False, block_tables=None):
    """Fused multi-token step for chunked prefill (continuous batching).

    tokens [B, C] int32; base_pos [B] int32 — absolute position of each
    row's first chunk token; tok_valid [B, C] bool PREFIX mask — row b's
    tokens j < count(b) are live, the rest ride the fixed-shape graph and
    write nothing. A decode row joins the step as a 1-valid-token chunk, so
    one launch serves prefill and decode rows together (no barrier).

    Returns (logits [B, C, V], new_caches, aux). aux token axes are the
    flattened [B*C] chunk (row-major) — mask host-side with tok_valid.
    MoE dispatch is dropless in this mode, so per-token outputs (and the
    cache entries derived from them) are independent of chunk size.
    """
    assert all(k in (ATTN_DENSE, ATTN_MOE) for k, _ in cfg.stack()), \
        "chunked prefill supports attention stacks only (KV caches; " \
        f"SSM/hybrid/VLM states are sequential), got {cfg.stack()}"
    if window < 0:
        window = cfg.sliding_window
    x = params["embed"][tokens]                       # [B, C, D]
    x = shard(x, "batch", None, None)
    base_pos = jnp.asarray(base_pos, jnp.int32)
    if cfg.family == "audio" and cfg.num_cond_tokens:
        base_pos = base_pos + cfg.num_cond_tokens
    ctx = StepCtx(cfg, "chunk", window, policy, base_pos, rng, record,
                  tok_valid=tok_valid, block_tables=block_tables)

    total_aux = _zero_moe_aux(cfg)
    rec = []
    new_caches = []
    for kind, gp, gc, gb in _iter_groups(params, cfg, caches, buddies):
        x, nc, aux = _run_group(kind, gp, x, gc, ctx, gbuddy=gb)
        new_caches.append(nc)
        if aux:
            for k in total_aux:
                total_aux[k] = total_aux[k] + aux.get(k, 0)
            if record and aux.get("per_layer"):
                rec.append(aux["per_layer"])
    logits = _logits(params, cfg, x)                  # [B, C, V]
    if record:
        total_aux["recorded"] = rec
    return logits, tuple(new_caches), total_aux


def prefill(params, cfg: ModelConfig, tokens, *, cond_embeds=None,
            window: int = -1):
    """Full-sequence forward (the prefill compute). Decode-cache
    construction is handled by the serving engine, which prefills prompts
    through decode_step (engine.py) — the monolithic fused
    prefill+cache-build is what prefill_32k dry-runs lower via
    forward_train."""
    if window < 0:
        window = cfg.sliding_window
    logits, _ = forward_train(params, cfg, tokens, cond_embeds=cond_embeds,
                              window=window)
    return logits
