"""BuddyMoE serving engine — batched decode with an offloaded expert cache.

Mirrors the paper's Fig. 3 pipeline on the simulation substrate:

  step t:  jitted decode_step runs with the CURRENT residency mask (experts
           whose transfers have ARRIVED — in-flight prefetches are misses);
           the in-graph BuddyMoE layer substitutes/flags per-slot (Alg. 1)
  between: the host cache manager replays the step on the event-driven PCIe
           timeline (runtime/transfers.py): compute advances layer by layer,
           in-flight transfers overlap the compute of earlier layers, a miss
           stalls only the layer that needs it, and prefetches for layer
           l+lookahead are issued while layer l computes.

Stall attribution (the Fig. 8 / Tables 2-4 measurement substrate):
  demand stall        cold miss, nothing in flight — full fetch wait
  late-prefetch stall predicted but not yet arrived — the transfer is
                      escalated to demand priority and the layer waits only
                      for its remaining tail (buddy substitution absorbs
                      these misses entirely under policy=buddy)
  overlapped          transfer time hidden under compute — bytes, no latency
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import quantize
from repro.core.buddies import BuddyTables
from repro.core.policy import BuddyPolicy
from repro.models import transformer
from repro.models.moe import BuddyState
from repro.runtime.cache import ExpertCache
from repro.runtime.costs import MissCostModel, best_resident_q
from repro.runtime.memory import (DEFAULT_HW, HardwareModel, TransferLedger,
                                  expert_nbytes)
from repro.runtime.paged_kv import PagedKVPool
from repro.runtime.telemetry import ExpertStats, Telemetry
from repro.runtime.tiers import TIER_BITS, TieredExpertStore
from repro.runtime.transfers import TransferScheduler, make_ici_links
from repro.serving.prefix import PrefixTree


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens: int = 0
    sim_time_s: float = 0.0
    compute_s: float = 0.0
    stall_s: float = 0.0
    n_sub: int = 0
    n_miss_fetch: int = 0
    n_hit: int = 0
    n_late_prefetch: int = 0
    n_prefetch_issued: int = 0
    n_prefetch_cancelled: int = 0
    n_miss_drop: int = 0        # misses the cost argmin dropped (renorm)
    n_upgrade_issued: int = 0   # degraded-then-upgrade background fetches

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.sim_time_s if self.sim_time_s else 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *,
                 tables: Optional[BuddyTables] = None,
                 policy: BuddyPolicy = BuddyPolicy(),
                 cache: Optional[ExpertCache] = None,
                 predictor=None,
                 prefetch_k: int = 0,
                 lookahead: int = 1,
                 hw: HardwareModel = DEFAULT_HW,
                 window: int = -1,
                 seed: int = 0,
                 latency_cfg: Optional[ModelConfig] = None,
                 tier: Optional[TieredExpertStore] = None,
                 upgrade_degraded: Optional[bool] = None,
                 prefetch_min_saving: Optional[float] = None,
                 telemetry: Optional[Telemetry] = None,
                 n_devices: int = 1,
                 ici_gbps: Optional[float] = None,
                 peer_borrow: bool = True,
                 paged_kv: bool = False,
                 kv_block: int = 16,
                 kv_blocks: Optional[int] = None,
                 prefix_cache: bool = False,
                 placement=None):
        """latency_cfg: full-scale config whose expert sizes / active params
        drive the transfer + compute latency model (the accuracy testbed can
        be a reduced model while latencies reflect the deployment target —
        e.g. the real DeepSeek-V2-Lite). Defaults to cfg itself.

        tier: a TieredExpertStore enabling the degraded miss fallback. The
        engine quantizes every MoE expert into the tier's precision (the
        replicas ride the params pytree as a ``quant`` sub-dict), calibrates
        the per-expert fidelity scores, and uses the tier's displaced-budget
        cache. ``policy.quant_tier`` must name the same precision (it is the
        static jit switch for the mixed-precision dispatch).

        upgrade_degraded: degraded-then-upgrade — every slot served from the
        quant tier enqueues a background 'upgrade' transfer of the TRUE
        expert (prefetch priority, exempt from stale-prediction cancels), so
        later steps compute it at full precision once it lands. None (auto)
        enables it exactly when the unified cost policy is on
        (policy.miss_policy='cost') and a tier is attached; precedence-mode
        engines keep the pre-upgrade behavior bit-identical.

        prefetch_min_saving: with cost-ranked prefetch (miss_policy='cost' +
        a predict_proba predictor), candidates whose expected stall saved —
        P(use) x unified miss cost — is <= this many seconds are not worth
        their PCIe bytes and are skipped; the count of worthwhile candidates
        feeds (and can cap) the adaptive budget controller. None (auto): 1%
        of a full expert transfer — a prefetch occupies the link for
        ~transfer_time, so a saving far below that cannot pay for its own
        bytes (misses a good buddy or replica absorbs score ~stall_per_
        quality x their tiny quality loss and fall under this bar).

        n_devices: expert-parallel mesh size (1-8). Each layer's experts
        are sharded round-robin across the devices (owner[e] = e % D);
        device 0 is the simulated compute device, devices 1..D-1 are peer
        HBM pools reachable over per-device ICI links (one TransferScheduler
        per link, hop-priced on a 2D grid). A miss on an expert a peer
        holds can be resolved by BORROWING it over ICI — the fifth miss
        outcome, usually ~100x cheaper than a host PCIe fetch — and the
        borrowed expert is inserted into device 0's cache on landing.
        n_devices=1 (default) is bit-identical to the single-device engine.

        ici_gbps: ICI per-link bandwidth override in GB/s (None: hw.ici_bw).

        peer_borrow: gate the peer-borrow outcome (mesh misses fall back to
        the four single-device outcomes when False) — the ablation arm of
        the mesh benchmark.

        telemetry: an optional runtime.telemetry.Telemetry bundle. When
        attached, the engine emits flight-recorder spans on the simulated
        clock, maintains per-expert hit/miss/degraded EMAs, records miss-
        cost calibration samples (predicted vs realized stall per outcome
        class), and feeds the prefetch precision/recall meter — all read-
        only observers of engine state (no PRNG draws, no timeline
        mutation), so a telemetry=None run is bit-identical.

        paged_kv: replace the per-slot ring-buffer KV with a shared pool of
        fixed-size blocks (runtime/paged_kv.py) addressed through per-row
        block tables. Attention-only stacks, no sliding window. paged_kv=
        False (default) is bit-identical to the pre-paged engine (frozen-
        capture test in tests/test_paged.py).

        kv_block: tokens per KV block (paged mode). kv_blocks: pool size
        override; None sizes the pool to exactly the ring footprint —
        batch x ceil(capacity / kv_block) blocks — so paged-vs-ring A/Bs
        run at equal HBM.

        prefix_cache: radix-tree prefix reuse over the paged pool
        (serving/prefix.py): ContinuousScheduler admission matches each
        prompt against previously-served prefixes, adopts the shared block
        chain (refcount bump + copy-on-write at the write frontier), and
        prefills only the novel suffix. Requires paged_kv.

        placement: an optional runtime.placement.PlacementController — the
        live traffic→placement loop. When attached, the engine feeds it
        per-layer activity each step and ticks it on the simulated clock
        every refresh_interval_s: tier coverage re-picks, background
        'replicate' fetches of persistently-hot experts, and (D>1) pushes
        of hot experts to underloaded peers. placement=None (default) is
        bit-identical to the pre-placement engine (frozen-capture test in
        tests/test_placement.py)."""
        assert cfg.is_moe, "ServeEngine's expert cache applies to MoE archs"
        assert lookahead >= 1, "lookahead: layers ahead to prefetch (>= 1)"
        self.cfg = cfg
        self.policy = policy
        self.num_moe_layers = sum(r for k, r in cfg.stack() if k == "attn_moe")
        e = cfg.moe.num_experts
        self.tier = tier
        if tier is not None:
            assert policy.quant_tier != "off", \
                "a TieredExpertStore needs policy.quant_tier='int8'/'int4'"
            assert TIER_BITS[policy.quant_tier] == tier.bits, \
                f"policy tier {policy.quant_tier} != store bits {tier.bits}"
            assert cache is None or cache is tier.cache, \
                "pass the cache through the tier (it owns the budget split)"
            params, fid = quantize.attach_quant_tier(cfg, params, tier.bits)
            tier.attach_fidelity(fid)
            cache = tier.cache
        else:
            assert policy.quant_tier == "off", \
                "policy.quant_tier is on but no TieredExpertStore was given"
        self.params = params
        self.cache = cache or ExpertCache(self.num_moe_layers, e, 1.0)
        self.predictor = predictor
        self.prefetch_k = prefetch_k
        self.lookahead = lookahead
        self.hw = hw
        self.ledger = TransferLedger(hw)
        self.scheduler = TransferScheduler(hw)
        # residency commits and byte counts are driven by the same timeline
        self.scheduler.add_listener(self.cache.on_transfer_event)
        self.ledger.attach(self.scheduler)
        assert 1 <= int(n_devices) <= 8, "n_devices: 1-8 device mesh"
        self.n_devices = int(n_devices)
        self.peer_borrow = bool(peer_borrow)
        self._ici_bw = (hw.ici_bw if ici_gbps is None
                        else float(ici_gbps) * 1e9)
        self.cache.enable_mesh(self.n_devices)
        self.peer_links = self._build_peer_links()
        self._n_peer_borrow = 0
        if tier is not None:
            self.ledger.tier_upload(tier.quant_bytes)
        self.stats = EngineStats()
        self.window = window
        ref_cfg = latency_cfg or cfg
        self._expert_bytes = expert_nbytes(ref_cfg.d_model, ref_cfg.moe.d_ff)
        self._latency_cfg = ref_cfg
        self._active_params = ref_cfg.active_param_count()
        self._key = jax.random.PRNGKey(seed)
        self._last_used: dict = {}
        self._cost_mode = policy.miss_policy == "cost"
        self.costs = MissCostModel(
            self.num_moe_layers, e, expert_bytes=self._expert_bytes, hw=hw,
            stall_per_quality=policy.stall_per_quality,
            drop_loss=policy.drop_loss)
        self.upgrade_degraded = (self._cost_mode and tier is not None
                                 if upgrade_degraded is None
                                 else bool(upgrade_degraded))
        if prefetch_min_saving is None:
            prefetch_min_saving = 0.01 * hw.transfer_time(self._expert_bytes)
        self.prefetch_min_saving = float(prefetch_min_saving)
        self.last_prefetch_worthwhile: Optional[int] = None
        self._step_worthwhile: Optional[int] = None
        self.telemetry = telemetry
        self._wire_telemetry()
        self.placement = placement
        if placement is not None:
            placement.attach(self)

        self._paged = bool(paged_kv)
        self._kv_block = int(kv_block)
        self._kv_blocks = kv_blocks
        self._prefix_on = bool(prefix_cache)
        self.kv_pool = None
        self.prefix_tree = None
        self._prefix_hits = 0
        self._prefix_hit_tokens = 0
        self._prefix_novel_tokens = 0
        if self._paged:
            assert self._kv_block >= 1, "kv_block must be >= 1"
            assert all(k in ("attn_dense", "attn_moe")
                       for k, _ in cfg.stack()), \
                f"paged KV needs an attention-only stack, got {cfg.stack()}"
            assert cfg.sliding_window == 0 and self.window <= 0, \
                "paged KV blocks never wrap; sliding-window decode is " \
                "ring-only"
            assert cfg.num_cond_tokens == 0, \
                "paged KV does not model conditioning-prefix positions"
        else:
            assert not prefix_cache, "prefix_cache requires paged_kv"

        if tables is None:
            r = 8
            self._table = np.full((self.num_moe_layers, e, r), -1, np.int32)
            self._q = np.zeros((self.num_moe_layers, e, r), np.float32)
        else:
            self._table = np.asarray(tables.table)
            self._q = np.asarray(tables.q)
        if self.cache.buddy_table is None and tables is not None:
            # buddy-aware eviction: prefer victims whose misses buddies absorb
            self.cache.buddy_table = self._table

        self._step_fn = jax.jit(
            functools.partial(transformer.decode_step, cfg=self.cfg,
                              policy=self.policy, record=True,
                              window=self.window),
            static_argnames=())
        self._chunk_fn = jax.jit(
            functools.partial(transformer.prefill_chunk, cfg=self.cfg,
                              policy=self.policy, record=True,
                              window=self.window),
            static_argnames=())

    # ------------------------------------------------------------------
    def _build_peer_links(self) -> dict:
        """One ICI TransferScheduler per peer device (empty at D=1). Every
        link shares the engine's event clock, commits borrowed experts into
        device 0's cache on completion (same listener protocol as the host
        PCIe link), and books its bytes/stalls into the shared ledger."""
        if self.n_devices <= 1:
            return {}
        links = make_ici_links(self.n_devices, self.hw, ici_bw=self._ici_bw)
        for link in links.values():
            link.add_listener(self._on_peer_link_event)
            self.ledger.attach(link)
        return links

    def _on_peer_link_event(self, kind: str, t) -> None:
        """ICI-link cache listener: a borrow lands in DEVICE 0's cache, but
        a placement controller's 'replicate' push lands in the TARGET
        PEER's HBM (peer_insert flips that mask at submit time), so it must
        not touch device 0's residency or in-flight state."""
        if t.cause == "replicate":
            return
        self.cache.on_transfer_event(kind, t)

    def advance_clock(self, to_time: float) -> None:
        """Advance EVERY link of the mesh (host PCIe + all ICI links) to the
        same simulated instant — the single event clock the serving
        schedulers use for idle time between steps. At D=1 this is exactly
        ``scheduler.advance``."""
        self.scheduler.advance(to_time)
        for link in self.peer_links.values():
            link.advance(to_time)

    def _links_busy_s(self) -> float:
        return (self.scheduler.busy_s
                + sum(l.busy_s for l in self.peer_links.values()))

    # ------------------------------------------------------------------
    def _wire_telemetry(self) -> None:
        """Attach the (optional) telemetry bundle to the CURRENT scheduler —
        called from __init__ and again by reset_runtime (which rebuilds the
        scheduler, so the trace hook and prefetch-meter listener must be
        re-registered). A replacement bundle can be installed between runs
        with ``eng.telemetry = Telemetry(...); eng.reset_runtime()``.
        No-op when telemetry is None: the off path stays bit-identical."""
        tele = self.telemetry
        if tele is None:
            return
        self.scheduler.trace = tele.trace
        self.scheduler.add_listener(tele.prefetch.on_transfer_event)
        for link in self.peer_links.values():
            # per-link trace lanes ("transfers:ici<d>"); peer borrows are
            # demand-class, so the prefetch meter does not listen here
            link.trace = tele.trace
        if self.tier is not None:
            self.tier.telemetry = tele
        if tele.expert_stats is None:
            tele.expert_stats = ExpertStats(self.num_moe_layers,
                                            self.cfg.moe.num_experts)

    def _miss_eta(self) -> np.ndarray:
        """[L, E] expected stall of fetching each expert on a miss THIS step:
        a cold miss pays the full modeled transfer; an in-flight prefetch
        only its optimistic remaining tail (TransferScheduler.eta_s)."""
        return self.costs.fetch_eta(self.scheduler)

    def _tier_fidelity(self) -> Optional[np.ndarray]:
        """[L, E] calibrated replica error with uncovered experts at inf,
        or None when no tier is attached."""
        return None if self.tier is None else self.tier.effective_fidelity()

    def _buddy_state(self) -> BuddyState:
        res = self.cache.residency_mask()
        hop = np.stack([self.cache.hop_vector(l)
                        for l in range(self.num_moe_layers)])
        quant_ok = fid_cost = fetch_cost = peer_ok = peer_cost = None
        peer_on = self.peer_borrow and bool(self.peer_links)
        if self._cost_mode:
            # unified cost mode: the in-graph argmin consumes per-expert
            # stall-equivalent costs instead of the precedence quant_ok mask
            eta = self._miss_eta()
            fid_cost = jnp.asarray(self.costs.degraded_cost(
                self._tier_fidelity(), shape=eta.shape), jnp.float32)
            fetch_cost = jnp.asarray(eta, jnp.float32)
            if peer_on:
                # peer-borrow priced from the owning links' live queues
                peer_cost = jnp.asarray(self.costs.peer_eta(
                    self.peer_links, self.cache.peer_resident), jnp.float32)
        else:
            if self.tier is not None:
                quant_ok = jnp.asarray(
                    self.tier.degraded_ok(res, self._miss_eta()))
            if peer_on:
                # precedence mode: any expert a peer holds is borrowable
                # (chain: buddy -> degraded -> peer -> fetch/drop)
                peer_ok = jnp.asarray(self.cache.peer_resident.any(axis=0))
        return BuddyState(resident=jnp.asarray(res),
                          table=jnp.asarray(self._table),
                          q=jnp.asarray(self._q),
                          hop=jnp.asarray(hop),
                          quant_ok=quant_ok,
                          fid_cost=fid_cost,
                          fetch_cost=fetch_cost,
                          peer_ok=peer_ok,
                          peer_cost=peer_cost)

    def init_caches(self, batch: int, seq_len: int):
        if self._paged:
            bs = self._kv_block
            cap = seq_len + self.cfg.num_cond_tokens
            max_blocks = -(-cap // bs)
            n_blocks = (int(self._kv_blocks) if self._kv_blocks
                        else batch * max_blocks)
            self.kv_pool = PagedKVPool(n_blocks, bs, batch, max_blocks)
            self._prefix_hits = 0
            self._prefix_hit_tokens = 0
            self._prefix_novel_tokens = 0
            if self._prefix_on:
                self.prefix_tree = PrefixTree(self.kv_pool)
            return transformer.init_paged_caches(self.cfg, n_blocks, bs)
        return transformer.init_caches(
            self.cfg, batch, seq_len,
            window=0 if self.window < 0 else self.window)

    def _apply_kv_copies(self, caches):
        """Batched device copy of the pool's pending CoW pairs — must land
        before the next scatter so a shared block's content survives the
        remap. Leaves are [repeat, P, bs, KV, hd]: block axis 1."""
        pairs = self.kv_pool.drain_copies()
        if not pairs:
            return caches
        src = jnp.asarray([p[0] for p in pairs], jnp.int32)
        dst = jnp.asarray([p[1] for p in pairs], jnp.int32)
        return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), caches)

    # ------------------------------------------------------------------
    def step(self, token, caches, pos, active: Optional[np.ndarray] = None):
        """One decode step for the whole batch. token [B] int32 device array;
        pos scalar (lockstep batch) or [B] per-row positions (continuous
        batching). ``active`` is a bool [B] slot mask: inactive rows still
        flow through the fixed-shape jitted graph, but are excluded from all
        expert-usage, transfer, and throughput accounting.
        Returns (logits [B, V], new_caches)."""
        buddies = self._buddy_state()
        self._key, sub = jax.random.split(self._key)
        kw = {}
        if self._paged:
            b = int(token.shape[0])
            # paged decode is always per-row: broadcast a lockstep scalar
            pos = np.broadcast_to(np.asarray(pos, np.int32), (b,))
            act = (np.ones(b, bool) if active is None
                   else np.asarray(active, bool))
            for i in np.flatnonzero(act):
                p = int(pos[i])
                self.kv_pool.ensure_range(i, p, p + 1)
            caches = self._apply_kv_copies(caches)
            kw["block_tables"] = jnp.asarray(self.kv_pool.tables)
        logits, caches, aux = self._step_fn(
            params=self.params, token=token, caches=caches,
            pos=jnp.asarray(pos, jnp.int32), buddies=buddies, rng=sub, **kw)
        if active is None:
            active = np.ones(int(token.shape[0]), bool)
        self._account(aux, active=np.asarray(active, bool))
        return logits, caches

    def prefill_rows(self, tokens, rows, caches, base_pos, tok_valid=None):
        """Fused chunked-prefill step: ingest up to C tokens per row in ONE
        jitted launch and ONE timeline replay (vs C decode steps).

        tokens [B, C] int32; ``rows`` bool [B] marks live slots (others ride
        the fixed-shape graph masked out of all accounting); base_pos [B] is
        each row's absolute position of chunk token 0; tok_valid [B, C] is a
        PREFIX validity mask (default: every token of a live row). A decode
        row joins as a 1-valid-token chunk, so decode rows keep stepping
        while a neighbour slot prefills.

        The chunk is compute-dense on the simulated clock — all its tokens
        share one weight-streaming pass in ``hw.decode_compute_time`` — so
        per-layer compute slices are ~C× longer and hide proportionally more
        PCIe transfer time. The chunk's dense expert activations feed the
        predictor/cache (``_observe_layer``) as a high-confidence warm-up
        for the request's first decode steps.

        Returns (logits [B, C, V], new_caches); row i's next-token logits
        sit at index ``tok_valid[i].sum() - 1``."""
        tokens = jnp.asarray(tokens, jnp.int32)
        b, c = tokens.shape
        rows = np.asarray(rows, bool)
        if tok_valid is None:
            tok_valid = np.repeat(rows[:, None], c, axis=1)
        tok_valid = np.asarray(tok_valid, bool) & rows[:, None]
        base = np.asarray(base_pos, np.int32)
        counts = tok_valid.sum(axis=1)
        kw = {}
        if self._paged:
            # no ring to wrap: a block's slot index IS its content position.
            # Map/CoW the write range of every live row, land pending block
            # copies, and ship the block table with the launch.
            for i in np.flatnonzero(counts > 0):
                self.kv_pool.ensure_range(int(i), int(base[i]),
                                          int(base[i] + counts[i]))
            caches = self._apply_kv_copies(caches)
            kw["block_tables"] = jnp.asarray(self.kv_pool.tables)
        else:
            # ring-wrap guard: a multi-token chunk is scattered into the KV
            # cache before its queries attend, so it must not wrap the ring
            # buffer (attn_prefill_chunk); single-token rows are plain
            # decode writes
            cap = jax.tree.leaves(caches)[0].shape[2]
            multi = counts > 1
            assert not multi.any() or \
                int((base[multi] + counts[multi]).max()) <= cap, \
                "chunked prefill would wrap the KV ring buffer: size " \
                "caches to the full prompt (prompt end %d > capacity %d)" % (
                    int((base[multi] + counts[multi]).max()), cap)

        buddies = self._buddy_state()
        self._key, sub = jax.random.split(self._key)
        logits, caches, aux = self._chunk_fn(
            params=self.params, tokens=tokens, caches=caches,
            base_pos=jnp.asarray(base, jnp.int32),
            tok_valid=jnp.asarray(tok_valid), buddies=buddies, rng=sub, **kw)
        self._account(aux, active=tok_valid.reshape(-1))
        return logits, caches

    # -- per-layer step timeline ---------------------------------------
    def _account(self, aux, active: np.ndarray) -> None:
        """Replay the step on the transfer timeline, layer by layer.
        ``active`` is a flat [T] TOKEN mask (T = B for decode steps, B*C
        row-major for chunk steps) — pad rows (StaticBatcher), empty decode
        slots, and invalid chunk tokens must not generate expert traffic or
        count as served tokens. Per-step compute is
        ``hw.decode_compute_time(active_params, n_valid_tokens)``: every
        valid token pays the FLOPs term but the weight-streaming memory term
        is paid once per step — which is exactly why a fused prefill chunk
        beats C single-token steps on the clock."""
        n_active = int(active.sum())
        if n_active == 0:
            return
        self._step_worthwhile = None    # fresh per-step aggregate
        tele = self.telemetry
        trace = tele.trace if tele is not None else None
        sched = self.scheduler
        step_t0 = sched.now
        busy0 = self._links_busy_s()
        compute_total = self.hw.decode_compute_time(
            self._active_params, n_active)
        per_layer = compute_total / max(1, self.num_moe_layers)
        cursor = step_t0
        step_stall = 0.0

        layer_off = 0
        e_n = self.cfg.moe.num_experts
        for rec in aux.get("recorded", []):
            idx = np.asarray(rec["indices"])                  # [L, T, K]
            sub_sl = np.asarray(rec["substituted"])           # [L, T, K]
            miss_sl = np.asarray(rec["missed"])               # [L, T, K]
            deg_sl = (np.asarray(rec["degraded"])             # [L, T, K]
                      if "degraded" in rec else None)
            drop_sl = (np.asarray(rec["dropped"])             # [L, T, K]
                       if "dropped" in rec else None)
            peer_sl = (np.asarray(rec["peered"])              # [L, T, K]
                       if "peered" in rec else None)
            for li in range(idx.shape[0]):
                layer = layer_off + li
                # transfers in flight overlap all earlier layers' compute
                self.advance_clock(cursor)
                rows = idx[li][active]                        # [T_act, K]
                used = rows.reshape(-1)
                self._observe_layer(layer, used)
                res_used = np.unique(used[self.cache.resident[layer, used]])
                self.cache.pin(layer, res_used)
                self.stats.n_hit += int(len(res_used))

                n_sub = int(sub_sl[li][active].sum())
                self.stats.n_sub += n_sub
                self.ledger.buddy_hit(n_sub)
                n_deg = n_dr = 0
                if deg_sl is not None:
                    # misses served by the resident quant tier: no transfer,
                    # no stall — only the degraded-token accounting
                    n_deg = int(deg_sl[li][active].sum())
                    if n_deg:
                        self.ledger.degraded(n_deg)
                        if self.tier is not None:
                            self.tier.note_degraded(n_deg)
                        if self.upgrade_degraded:
                            self._upgrade_degraded(
                                layer, rows[deg_sl[li][active]])
                if drop_sl is not None:
                    # misses the cost argmin dropped: renormalized in-graph,
                    # no transfer, no stall — event accounting only
                    n_dr = int(drop_sl[li][active].sum())
                    if n_dr:
                        self.ledger.drop(n_dr)
                        self.stats.n_miss_drop += n_dr
                miss_row = np.bincount(rows[miss_sl[li][active]],
                                       minlength=e_n)
                peer_row = None
                n_peer = 0
                if peer_sl is not None and peer_sl[li][active].any():
                    # slots the argmin resolved by peer-HBM borrow: a
                    # demand-class ICI transfer from the owning device
                    peer_row = np.bincount(rows[peer_sl[li][active]],
                                           minlength=e_n)
                    n_peer = int(peer_row.sum())
                if tele is not None:
                    self._record_layer_telemetry(
                        layer, rows, used, res_used, miss_row, cursor,
                        n_sub=n_sub, n_deg=n_deg, n_dr=n_dr, n_peer=n_peer,
                        sub_slots=sub_sl[li][active],
                        deg_slots=(deg_sl[li][active]
                                   if deg_sl is not None else None))
                if self.placement is not None:
                    # the controller owns its own ExpertStats so live
                    # placement works with or without a telemetry bundle
                    self.placement.observe_layer(
                        layer, np.unique(used), res_used,
                        np.flatnonzero(miss_row > 0),
                        (np.unique(rows[deg_sl[li][active]])
                         if n_deg else None))
                stall_t0 = cursor
                stall = 0.0
                if peer_row is not None:
                    cursor, pstall = self._resolve_peer(layer, peer_row,
                                                        cursor)
                    stall += pstall
                cursor, fstall = self._resolve_misses(layer, miss_row,
                                                      cursor)
                stall += fstall
                step_stall += stall
                if trace is not None:
                    if stall > 0.0:
                        trace.span("layers", layer, "stall", "stall",
                                   stall_t0, cursor, stall_s=stall,
                                   n_fetch=int((miss_row > 0).sum()))
                    trace.span("layers", layer, "compute", "compute",
                               cursor, cursor + per_layer)
                cursor += per_layer          # this layer's compute slice
                self._issue_prefetches(layer, used)
                self.cache.unpin(layer)
            layer_off += idx.shape[0]

        self.advance_clock(cursor)          # drain overlap to end of step
        step_time = cursor - step_t0
        overlapped = max(0.0, (self._links_busy_s() - busy0) - step_stall)
        self.ledger.overlapped(overlapped)

        self.stats.steps += 1
        self.stats.tokens += n_active
        self.stats.compute_s += compute_total
        self.stats.stall_s += step_stall
        self.stats.sim_time_s += step_time

        if tele is not None:
            m = tele.metrics
            m.ema("step_time_s", alpha=0.05).update(step_time)
            m.histogram("step_stall_s").observe(step_stall)
            m.gauge("inflight_transfers").set(sched.n_in_flight)
            if trace is not None:
                trace.span("engine", 0, "step",
                           f"step{self.stats.steps - 1}", step_t0, cursor,
                           tokens=n_active, stall_s=step_stall,
                           overlapped_s=overlapped)
        if self.placement is not None:
            # placement ticks ride the step loop on the SIMULATED clock
            # (interval-gated, so the continuous scheduler's feedback hook
            # ticking it as well never double-fires a window)
            self.placement.maybe_tick(self)

    def _observe_layer(self, layer: int, used: np.ndarray) -> None:
        self.cache.touch(layer, used)
        if self.predictor is not None:
            if hasattr(self.predictor, "observe_transition") and layer > 0:
                self.predictor.observe_transition(
                    layer, self._last_used.get(layer - 1, []), used)
            self.predictor.observe(layer, used)
        self._last_used[layer] = used

    def _record_layer_telemetry(self, layer: int, rows, used, res_used,
                                miss_row, t_layer: float, *, n_sub: int,
                                n_deg: int, n_dr: int, sub_slots,
                                deg_slots, n_peer: int = 0) -> None:
        """Per-(layer, step) telemetry: the miss-outcome breakdown (trace
        instant + counters), per-expert EMA updates, the prefetch meter's
        used-in-time credit, and the zero-stall calibration rows for the
        transfer-free outcomes (buddy/degraded/drop) with their cost-model
        quality price — the ``stall_per_quality`` calibration signal. The
        fetch-outcome calibration rows are recorded in _resolve_misses,
        where predicted ETA and realized stall are both in hand. Pure
        observer: touches no engine/cache/scheduler state."""
        tele = self.telemetry
        missing = np.flatnonzero(miss_row > 0)
        uniq_used = np.unique(used)
        deg_e = (np.unique(rows[deg_slots]) if n_deg else None)
        if tele.expert_stats is not None:
            tele.expert_stats.update(layer, uniq_used, res_used, missing,
                                     deg_e)
        tele.prefetch.note_used(layer, uniq_used)
        m = tele.metrics
        m.counter("slots", outcome="hit").inc(len(res_used))
        for outcome, n in (("buddy", n_sub), ("degraded", n_deg),
                           ("peer", n_peer),
                           ("fetch", int(miss_row.sum())), ("drop", n_dr)):
            if n:
                m.counter("slots", outcome=outcome).inc(n)
        cal = tele.calibration
        if n_sub:
            # buddy: zero stall by construction; the quality price is the
            # cost model's host-side estimate at the substituted experts
            # (the in-graph argmin recomputes Psi per token)
            bq = best_resident_q(self._table[layer], self._q[layer],
                                 self.cache.resident[layer])
            bc = self.costs.buddy_cost(bq)[np.asarray(rows[sub_slots])]
            bc = bc[np.isfinite(bc)]
            cal.record("buddy", 0.0, 0.0, n=n_sub,
                       quality_cost=float(bc.mean()) if bc.size else 0.0)
        if n_deg:
            dc = self.costs.degraded_cost(self._tier_fidelity())[layer][deg_e]
            dc = dc[np.isfinite(dc)]
            cal.record("degraded", 0.0, 0.0, n=n_deg,
                       quality_cost=float(dc.mean()) if dc.size else 0.0)
        if n_dr:
            cal.record("drop", 0.0, 0.0, n=n_dr,
                       quality_cost=self.costs.drop_cost())
        if tele.trace is not None:
            extra = {"peer": n_peer} if self.n_devices > 1 else {}
            tele.trace.instant(
                "layers", layer, "outcomes", f"L{layer}", t_layer,
                hit=len(res_used), buddy=n_sub, degraded=n_deg,
                fetch=int(miss_row.sum()), drop=n_dr, **extra)

    def _resolve_misses(self, layer: int, miss_row: np.ndarray,
                        cursor: float):
        """Residual misses (post-substitution) block THIS layer only. An
        in-flight prefetch is escalated and waited for its tail (late
        prefetch); otherwise a demand fetch pays the full transfer."""
        missing = np.flatnonzero(miss_row > 0)
        tele = self.telemetry
        if self.policy.fallback != "fetch":
            n_dropped = int(miss_row.sum())
            self.ledger.drop(n_dropped)
            if tele is not None and n_dropped:
                tele.calibration.record("drop", 0.0, 0.0, n=n_dropped,
                                        quality_cost=self.costs.drop_cost())
            return cursor, 0.0
        sched = self.scheduler
        stall = 0.0
        for e in missing:
            e = int(e)
            if self.cache.resident[layer, e]:
                # arrived after this step's mask snapshot — already on device
                continue
            t = sched.in_flight(layer, e)
            # calibration: the cost model's predicted stall for the fetch
            # outcome AT DECISION TIME — the in-flight optimistic tail, or
            # the modeled cold transfer (same quantities fetch_eta feeds the
            # argmin) — recorded against the realized stall below
            predicted = None
            if tele is not None:
                predicted = (sched.eta_s(t) if t is not None else
                             self.hw.transfer_time(self._expert_bytes))
                if t is None:
                    tele.prefetch.note_uncovered_miss(layer, e)
            if t is not None:
                sched.escalate(t)
                if t.cause in ("upgrade", "replicate"):
                    # an upgrade (or a placement replica copy) is not a
                    # prediction: waiting on one is a demand-class stall
                    # (the cost model priced it at the COLD transfer; the
                    # in-flight bytes are just reused) — booking it as
                    # late-prefetch would feed a false lateness signal to
                    # the adaptive budget controller
                    kind = "demand"
                else:
                    kind = "late_prefetch"
                    self.stats.n_late_prefetch += 1
            else:
                t = sched.submit(layer, e, self._expert_bytes, "demand")
                kind = "demand"
            done = sched.run_until_done(t)
            s = max(0.0, done - cursor)
            self.ledger.stall(kind, s)      # ledger owns the breakdown
            if tele is not None:
                tele.calibration.record("fetch", predicted, s)
                tele.metrics.histogram("stall_s", kind=kind).observe(s)
            stall += s
            cursor = max(cursor, done)
            self.stats.n_miss_fetch += 1
        return cursor, stall

    def _resolve_peer(self, layer: int, peer_row: np.ndarray,
                      cursor: float):
        """Peer-HBM borrows block THIS layer until the ICI transfer lands.
        Each borrowed expert is fetched from the cheapest live holder's
        link (priced exactly as costs.peer_eta: in-flight tail, else queue
        backlog + hop-priced transfer) and inserted into device 0's cache
        on completion via the link's cache listener — a hot borrowed expert
        converges to a plain hit. Experts no reachable peer holds fall back
        to a host demand fetch so the slot is never silently lost."""
        tele = self.telemetry
        stall = 0.0
        for e in np.flatnonzero(peer_row > 0):
            e = int(e)
            if self.cache.resident[layer, e]:
                # landed after this step's mask snapshot — already on device
                continue
            best_d = best_eta = t = None
            for d in self.cache.peer_holders(layer, e):
                link = self.peer_links.get(int(d))
                if link is None:
                    continue
                tf = link.in_flight(layer, e)
                eta = (link.eta_s(tf) if tf is not None else
                       link.backlog_s()
                       + link.transfer_time(self._expert_bytes))
                if best_eta is None or eta < best_eta:
                    best_d, best_eta, t = int(d), eta, tf
            if best_d is None:
                # raced out of every peer (eviction churn): demand-fetch
                t = self.scheduler.submit(layer, e, self._expert_bytes,
                                          "demand")
                done = self.scheduler.run_until_done(t)
                s = max(0.0, done - cursor)
                self.ledger.stall("demand", s)
                stall += s
                cursor = max(cursor, done)
                self.stats.n_miss_fetch += 1
                continue
            link = self.peer_links[best_d]
            if t is None:
                t = link.submit(layer, e, self._expert_bytes, "peer")
            done = link.run_until_done(t)
            s = max(0.0, done - cursor)
            self.ledger.stall("peer", s)
            if tele is not None:
                tele.calibration.record("peer", best_eta, s)
                tele.metrics.histogram("stall_s", kind="peer").observe(s)
            stall += s
            cursor = max(cursor, done)
            self._n_peer_borrow += 1
        return cursor, stall

    def _upgrade_degraded(self, layer: int, experts: np.ndarray) -> None:
        """Degraded-then-upgrade: background-fetch the TRUE experts that the
        quant tier just served, so later steps compute them at full
        precision. 'upgrade' cause: prefetch priority (never preempts a
        stalled layer), exempt from stale-prediction cancellation, bytes
        ledgered separately. The residency snapshot for THIS step was taken
        before the upgrade lands, so already-computed tokens keep their
        degraded outputs and accounting — an upgrade only changes future
        steps. Duplicate submissions return the in-flight transfer, so an
        expert degraded on many tokens/steps pays its bytes once."""
        for e_up in np.unique(np.asarray(experts, np.int64)):
            e_up = int(e_up)
            if self.cache.resident[layer, e_up] or \
                    self.scheduler.in_flight(layer, e_up) is not None:
                continue
            self.scheduler.submit(layer, e_up, self._expert_bytes, "upgrade")
            self.stats.n_upgrade_issued += 1

    def _rank_prefetch(self, tgt: int, used: np.ndarray):
        """Expected-stall-saved prefetch ranking (runtime/costs.py):
        score[e] = P(use e at the target layer) x the unified miss cost the
        runtime would pay without it (lateness risk on the current
        timeline).

        Returns (want, worthwhile). ``want`` is the keep/submit list (best
        first, positive-saving only, capped at prefetch_k): it INCLUDES
        still-attractive in-flight experts, because the caller also feeds
        it to cancel_stale_prefetches — dropping them would cancel our own
        unfinished prefetches every step and ping-pong issue/cancel (the
        submit loop skips resident/in-flight entries anyway). ``worthwhile``
        counts candidates whose saving justifies NEW bytes (in-flight ones
        are already paid for) — the adaptive budget controller's cap."""
        p_use = np.asarray(self.predictor.predict_proba(
            tgt, lookahead=self.lookahead, context=used), np.float64)
        # rank at the COLD fetch cost: the ranking asks "is this expert
        # worth having in flight at all", so an already-running transfer
        # must not discount its own score (the in-flight ETA would sink it
        # below fresh candidates and rotate it out of the keep-set — the
        # same ping-pong as zeroing it). The in-flight discount belongs to
        # the wait-vs-degrade argmin (_buddy_state), not here.
        eta = np.full(self.cfg.moe.num_experts,
                      self.hw.transfer_time(self._expert_bytes))
        fid_row = (None if self.tier is None
                   else self.tier.effective_fidelity(tgt))
        # mode 'none' never reroutes: the in-graph argmin prices buddies at
        # inf there, and the ranking must agree or it understates the stall
        # a miss will actually pay
        best_q = (None if self.policy.mode == "none" else
                  best_resident_q(self._table[tgt], self._q[tgt],
                                  self.cache.resident[tgt]))
        # a mesh miss a peer can absorb over ICI is cheap — its prefetch
        # saving shrinks to the peer ETA, freeing PCIe bytes for experts
        # only the host can supply
        peer_row = (self.costs.peer_eta(self.peer_links,
                                        self.cache.peer_resident)[tgt]
                    if self.peer_borrow and self.peer_links else None)
        risk = self.costs.miss_cost(eta, fid_row, best_q, peer_eta=peer_row)
        score = self.costs.prefetch_scores(p_use, risk,
                                           self.cache.resident[tgt])
        new_score = np.where(self.cache.inflight[tgt], 0.0, score)
        worthwhile = int((new_score > self.prefetch_min_saving).sum())
        order = np.argsort(-score, kind="stable")
        want = [int(e) for e in order[:self.prefetch_k]
                if score[e] > self.prefetch_min_saving]
        # stash for the prefetch meter: _issue_prefetches credits the score
        # (expected stall saved) of each NEW submission to the telemetry
        # bundle — plain attribute, no behavioral effect when telemetry off
        self._last_rank_scores = score
        return want, worthwhile

    def _issue_prefetches(self, layer: int, used: np.ndarray) -> None:
        """While ``layer`` computes, line up transfers for layer
        ``layer + lookahead`` (wrapping into the next step). Predictions
        that changed since the last issue are cancelled if still unserved.
        Under the unified cost policy (and a predict_proba predictor) the
        candidates are ranked by expected stall saved instead of the
        predictor's raw top-k."""
        if self.predictor is None or self.prefetch_k <= 0:
            return
        tgt = (layer + self.lookahead) % self.num_moe_layers
        scores = None
        if self._cost_mode and hasattr(self.predictor, "predict_proba"):
            want, w = self._rank_prefetch(tgt, used)
            scores = self._last_rank_scores
            # the controller clamps the GLOBAL budget from this signal, so
            # report the step's MAX across target layers — a point sample
            # from one fully-resident layer would starve every other layer
            # for a whole controller window
            self._step_worthwhile = (w if self._step_worthwhile is None
                                     else max(self._step_worthwhile, w))
            self.last_prefetch_worthwhile = self._step_worthwhile
        else:
            want = self.predictor.predict_ahead(
                tgt, self.prefetch_k, lookahead=self.lookahead, context=used)
            want = [int(e) for e in np.atleast_1d(want)]
        self.stats.n_prefetch_cancelled += \
            self.scheduler.cancel_stale_prefetches(tgt, want)
        for e in want:
            if self.cache.resident[tgt, e] or self.cache.inflight[tgt, e]:
                continue
            self.scheduler.submit(tgt, e, self._expert_bytes, "prefetch")
            self.stats.n_prefetch_issued += 1
            if self.telemetry is not None and scores is not None:
                self.telemetry.prefetch.add_expected_saving(scores[e])

    # ------------------------------------------------------------------
    def reset_runtime(self, cache: Optional[ExpertCache] = None,
                      predictor=None) -> None:
        """Fresh serving state (clock, ledger, cache, predictor, stats) on
        the same compiled model — e.g. after a measurement probe, or to
        reuse one engine across benchmark runs without re-jitting."""
        e = self.cfg.moe.num_experts
        if cache is None:
            old = self.cache
            cache = ExpertCache(self.num_moe_layers, e, old.capacity / e,
                                policy=old.policy,
                                num_partitions=old.num_partitions,
                                buddy_table=old.buddy_table,
                                buddy_candidates=old.buddy_candidates)
        self.cache = cache
        if self.tier is not None:
            # the tier's replicas are static; repoint its cache at the fresh
            # one (same displaced capacity) and re-pay the one-time upload
            self.tier.cache = cache
            self.tier.reset_counters()
        if predictor is None and self.predictor is not None:
            # carry the predictor's configuration (accuracy/seed/decay/...)
            # into the fresh instance — a bare type(...)(L, E) silently reset
            # every knob to its default between benchmark runs
            if hasattr(self.predictor, "clone_fresh"):
                predictor = self.predictor.clone_fresh()
            else:
                predictor = type(self.predictor)(self.num_moe_layers, e)
        self.predictor = predictor
        self.ledger = TransferLedger(self.hw)
        self.scheduler = TransferScheduler(self.hw)
        self.scheduler.add_listener(self.cache.on_transfer_event)
        self.ledger.attach(self.scheduler)
        if self.cache.n_devices != self.n_devices:
            # a caller-supplied cache arrives un-sharded: seed the home
            # shard and peer pools exactly as __init__ did
            self.cache.enable_mesh(self.n_devices)
        self.peer_links = self._build_peer_links()
        self._n_peer_borrow = 0
        if self.tier is not None:
            self.ledger.tier_upload(self.tier.quant_bytes)
        self.stats = EngineStats()
        self._last_used = {}
        self.last_prefetch_worthwhile = None
        self._step_worthwhile = None
        # an attached telemetry bundle keeps accumulating across resets
        # (swap it first to start a fresh one); the scheduler was just
        # rebuilt, so its trace hook + meter listener must be re-registered
        self._wire_telemetry()
        if self.placement is not None:
            # fresh per-run placement state (streaks, replica sets,
            # counters) on the controller's UNCHANGED configuration
            self.placement.attach(self)

    def reset_rows(self, caches, rows):
        """Free the decode caches of ``rows`` (batch indices) so a freed slot
        can be re-used by a newly admitted request. Ring mode zeroes the
        rows' cache slices; paged mode releases the rows' block-table
        entries back to the pool (shared prefix blocks survive via their
        radix-tree refcounts) and leaves device storage untouched. Only
        attention-stack caches keep batch on axis 1 of every ring leaf
        ([repeat, B, ...]); super groups (hybrid/vlm) nest another layer
        axis first, so guard rather than silently zero the wrong axis."""
        assert all(k in ("attn_dense", "attn_moe") for k, _ in
                   self.cfg.stack()), \
            "reset_rows assumes [repeat, B, ...] cache leaves (attention " \
            f"stacks only), got {self.cfg.stack()}"
        if self._paged:
            for r in np.atleast_1d(rows):
                self.kv_pool.free_row(int(r))
            return caches
        rows = jnp.asarray(np.atleast_1d(rows), jnp.int32)
        return jax.tree.map(lambda a: a.at[:, rows].set(0), caches)

    def release_kv_row(self, row: int) -> None:
        """Return a row's KV pages to the pool without touching the caches —
        the preemption hook (ContinuousScheduler.preempt). No-op on the
        ring path, where the row's slots are zeroed on re-admission."""
        if self._paged:
            self.kv_pool.free_row(int(row))

    # -- prefix cache ---------------------------------------------------
    def adopt_prefix(self, row: int, prompt) -> int:
        """Match ``prompt`` against the radix tree and map the longest
        cached prefix into ``row``'s block table (refcount bump; CoW of the
        shared tail happens lazily in ensure_range before the first write).
        Returns the number of adopted tokens m — the scheduler then feeds
        prompt[m] first and chunk-prefills only the novel suffix."""
        tree = self.prefix_tree
        assert tree is not None, "adopt_prefix needs prefix_cache=True"
        toks = [int(t) for t in prompt]
        m, blocks = tree.match(toks, cap=len(toks) - 1)
        if m > 0:
            self.kv_pool.adopt(row, blocks)
            self._prefix_hits += 1
        self._prefix_hit_tokens += m
        self._prefix_novel_tokens += len(toks) - m
        tele = self.telemetry
        if tele is not None:
            tele.metrics.counter("prefix_tokens", kind="hit").inc(m)
            tele.metrics.counter("prefix_tokens",
                                 kind="novel").inc(len(toks) - m)
            self._prefix_gauges(tele)
            if m > 0 and tele.trace is not None:
                tele.trace.instant("engine", 0, "prefix_hit", f"row{row}",
                                   self.scheduler.now, row=int(row),
                                   hit_tokens=int(m),
                                   novel_tokens=int(len(toks) - m))
        return m

    def insert_prefix(self, row: int, prompt) -> None:
        """Donate a fully-prefilled row's prompt KV to the radix tree,
        trimmed to full blocks — the final partial block stays private so
        the donor keeps decoding into it without a CoW."""
        tree = self.prefix_tree
        if tree is None:
            return
        bs = self.kv_pool.block_size
        covered = (len(prompt) // bs) * bs
        if covered == 0:
            return
        toks = [int(t) for t in prompt[:covered]]
        tree.insert(toks, self.kv_pool.row_blocks(row, covered))
        if self.telemetry is not None:
            self._prefix_gauges(self.telemetry)

    def _prefix_gauges(self, tele) -> None:
        occ = self.kv_pool.occupancy()
        tele.metrics.gauge("kv_pool_used_blocks").set(occ["used_blocks"])
        tele.metrics.gauge("kv_pool_free_blocks").set(occ["free_blocks"])
        if self.prefix_tree is not None:
            tele.metrics.gauge("prefix_tree_nodes").set(
                self.prefix_tree.n_nodes)

    def sample_tokens(self, logits, greedy: bool, temperature: float = 1.0):
        """Next-token choice from [B, V] logits: argmax, or seeded temperature
        sampling from the engine's PRNG stream (greedy=False)."""
        if greedy:
            return np.asarray(jnp.argmax(logits, axis=-1))
        assert temperature > 0.0, "temperature must be > 0 for sampling"
        self._key, sub = jax.random.split(self._key)
        scaled = logits.astype(jnp.float32) / temperature
        return np.asarray(jax.random.categorical(sub, scaled, axis=-1))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 greedy: bool = True, temperature: float = 1.0,
                 row_mask: Optional[np.ndarray] = None) -> np.ndarray:
        """Teacher-free batched generation. prompts [B, P] int32.
        ``row_mask`` [B] marks real rows — StaticBatcher pad rows (rid=-1
        copies) are stepped for shape but excluded from throughput/transfer
        accounting. greedy=False samples with ``temperature`` from the
        engine's seeded PRNG."""
        b, p_len = prompts.shape
        total = p_len + max_new_tokens
        caches = self.init_caches(b, total)
        out = np.zeros((b, total), np.int64)
        out[:, :p_len] = prompts
        tok = jnp.asarray(prompts[:, 0], jnp.int32)
        logits = None
        for pos in range(total - 1):
            logits, caches = self.step(tok, caches, pos, active=row_mask)
            if pos + 1 < p_len:
                tok = jnp.asarray(prompts[:, pos + 1], jnp.int32)
            else:
                nxt = self.sample_tokens(logits, greedy, temperature)
                out[:, pos + 1] = nxt
                tok = jnp.asarray(nxt, jnp.int32)
        return out

    def teacher_forced_nll(self, tokens: np.ndarray,
                           row_mask: Optional[np.ndarray] = None) -> float:
        """Mean next-token NLL under the engine's policy (accuracy metric).
        ``row_mask`` [B] excludes pad rows from the mean."""
        b, s = tokens.shape
        mask = (np.ones(b, bool) if row_mask is None
                else np.asarray(row_mask, bool))
        caches = self.init_caches(b, s)
        nll, n = 0.0, 0
        for pos in range(s - 1):
            tok = jnp.asarray(tokens[:, pos], jnp.int32)
            logits, caches = self.step(tok, caches, pos, active=mask)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            tgt = tokens[:, pos + 1]
            row_nll = -np.take_along_axis(np.asarray(logp), tgt[:, None], 1)[:, 0]
            nll += float(row_nll[mask].sum())
            n += int(mask.sum())
        return nll / n

    def stall_breakdown(self) -> dict:
        """Single source of truth: the ledger's event-timeline attribution.
        The peer key appears only on a mesh (D>1) so single-device
        summaries stay bit-identical to the pre-mesh engine."""
        d = {
            "demand_stall_s": self.ledger.demand_stall_s,
            "late_prefetch_stall_s": self.ledger.late_prefetch_stall_s,
            "overlapped_s": self.ledger.overlapped_s,
        }
        if self.n_devices > 1:
            d["peer_stall_s"] = self.ledger.peer_stall_s
        return d

    def summary(self) -> dict:
        s = {
            "policy": dataclasses.asdict(self.policy),
            "cache_rate": self.cache.capacity / self.cfg.moe.num_experts,
            "stats": dataclasses.asdict(self.stats),
            "tokens_per_s": self.stats.tokens_per_s,
            "stall_breakdown": self.stall_breakdown(),
            "ledger": self.ledger.summary(),
        }
        if self.tier is not None:
            # only present with a tier attached: with quant_tier off the
            # summary stays bit-identical to the pre-tier engine
            s["tier"] = self.tier.summary()
        if self._cost_mode:
            # only present under the unified cost policy: precedence-mode
            # summaries stay bit-identical to the pre-cost engine
            s["cost_policy"] = {
                "stall_per_quality": self.policy.stall_per_quality,
                "drop_loss": self.policy.drop_loss,
                "n_miss_drop": self.stats.n_miss_drop,
                "n_upgrade_issued": self.stats.n_upgrade_issued,
                "upgrade_degraded": self.upgrade_degraded,
                "prefetch_worthwhile_last": self.last_prefetch_worthwhile,
            }
        if self.n_devices > 1:
            # only present on a mesh: n_devices=1 summaries stay
            # bit-identical to the pre-mesh engine
            total = self.stats.n_hit + self.stats.n_sub + \
                self.stats.n_miss_fetch + self._n_peer_borrow
            s["mesh"] = {
                "n_devices": self.n_devices,
                "ici_bw": self._ici_bw,
                "peer_borrow": self.peer_borrow,
                "n_peer_borrow": self._n_peer_borrow,
                "peer_share": (self._n_peer_borrow / total if total else 0.0),
                "peer_stall_s": self.ledger.peer_stall_s,
                "links": [self.peer_links[d].utilization()
                          for d in sorted(self.peer_links)],
            }
        if self._paged:
            # only present in paged mode: paged_kv=off summaries stay
            # bit-identical to the pre-paged engine
            s["prefix"] = {
                "paged_kv": True,
                "kv_block": self._kv_block,
                "prefix_cache": self._prefix_on,
                "pool": (self.kv_pool.occupancy()
                         if self.kv_pool is not None else None),
                "hits": self._prefix_hits,
                "hit_tokens": self._prefix_hit_tokens,
                "novel_tokens": self._prefix_novel_tokens,
            }
            if self.prefix_tree is not None:
                s["prefix"]["tree"] = self.prefix_tree.stats()
        if self.placement is not None:
            # only present with a placement controller attached:
            # placement=None summaries stay bit-identical to the
            # pre-placement engine
            s["placement"] = self.placement.summary()
        if self.telemetry is not None:
            # only present with a telemetry bundle attached: telemetry=off
            # summaries stay bit-identical to the pre-telemetry engine
            s["telemetry"] = self.telemetry.summary()
        return s
