"""BuddyMoE serving engine — batched decode with an offloaded expert cache.

Mirrors the paper's Fig. 3 pipeline on the simulation substrate:

  step t:  jitted decode_step runs with the CURRENT residency mask; the
           in-graph BuddyMoE layer substitutes/flags per-slot (Alg. 1 + gates)
  between: the host cache manager (a) accounts transfers in the ledger —
           buddy hits cost nothing, residual misses are synchronous fetches,
           (b) feeds the predictor with this step's routing, (c) issues
           prefetches for the next step (overlappable transfers).

Timing model (CPU container — see runtime/memory.py): per-step latency =
modeled device compute + synchronous stalls + non-overlappable prefetch excess.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.buddies import BuddyTables
from repro.core.policy import BuddyPolicy
from repro.models import transformer
from repro.models.moe import BuddyState
from repro.runtime.cache import ExpertCache
from repro.runtime.memory import (DEFAULT_HW, HardwareModel, TransferLedger,
                                  expert_nbytes)


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens: int = 0
    sim_time_s: float = 0.0
    compute_s: float = 0.0
    stall_s: float = 0.0
    n_sub: int = 0
    n_miss_fetch: int = 0
    n_hit: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.sim_time_s if self.sim_time_s else 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *,
                 tables: Optional[BuddyTables] = None,
                 policy: BuddyPolicy = BuddyPolicy(),
                 cache: Optional[ExpertCache] = None,
                 predictor=None,
                 prefetch_k: int = 0,
                 hw: HardwareModel = DEFAULT_HW,
                 window: int = -1,
                 seed: int = 0,
                 latency_cfg: Optional[ModelConfig] = None):
        """latency_cfg: full-scale config whose expert sizes / active params
        drive the transfer + compute latency model (the accuracy testbed can
        be a reduced model while latencies reflect the deployment target —
        e.g. the real DeepSeek-V2-Lite). Defaults to cfg itself."""
        assert cfg.is_moe, "ServeEngine's expert cache applies to MoE archs"
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.num_moe_layers = sum(r for k, r in cfg.stack() if k == "attn_moe")
        e = cfg.moe.num_experts
        self.cache = cache or ExpertCache(self.num_moe_layers, e, 1.0)
        self.predictor = predictor
        self.prefetch_k = prefetch_k
        self.hw = hw
        self.ledger = TransferLedger(hw)
        self.stats = EngineStats()
        self.window = window
        ref_cfg = latency_cfg or cfg
        self._expert_bytes = expert_nbytes(ref_cfg.d_model, ref_cfg.moe.d_ff)
        self._latency_cfg = ref_cfg
        self._key = jax.random.PRNGKey(seed)
        self._last_used: dict = {}

        if tables is None:
            r = 8
            self._table = np.full((self.num_moe_layers, e, r), -1, np.int32)
            self._q = np.zeros((self.num_moe_layers, e, r), np.float32)
        else:
            self._table = np.asarray(tables.table)
            self._q = np.asarray(tables.q)

        self._step_fn = jax.jit(
            functools.partial(transformer.decode_step, cfg=self.cfg,
                              policy=self.policy, record=True,
                              window=self.window),
            static_argnames=())

        self._compute_s = hw.decode_compute_time(
            ref_cfg.active_param_count(), 1)

    # ------------------------------------------------------------------
    def _buddy_state(self) -> BuddyState:
        res = self.cache.residency_mask()
        hop = np.stack([self.cache.hop_vector(l)
                        for l in range(self.num_moe_layers)])
        return BuddyState(resident=jnp.asarray(res),
                          table=jnp.asarray(self._table),
                          q=jnp.asarray(self._q),
                          hop=jnp.asarray(hop))

    def init_caches(self, batch: int, seq_len: int):
        return transformer.init_caches(
            self.cfg, batch, seq_len,
            window=0 if self.window < 0 else self.window)

    # ------------------------------------------------------------------
    def step(self, token, caches, pos):
        """One decode step for the whole batch. token [B] int32 device array.
        Returns (logits [B, V], new_caches)."""
        buddies = self._buddy_state()
        self._key, sub = jax.random.split(self._key)
        logits, caches, aux = self._step_fn(
            params=self.params, token=token, caches=caches,
            pos=jnp.asarray(pos, jnp.int32), buddies=buddies, rng=sub)
        self._account(aux, batch=int(token.shape[0]))
        return logits, caches

    def _account(self, aux, batch: int) -> None:
        rec_groups = aux.get("recorded", [])
        step_sync = 0.0
        step_prefetch = 0.0
        layer_off = 0
        for rec in rec_groups:
            idx = np.asarray(rec["indices"])                  # [L, T, K]
            n_sub = np.asarray(rec["n_sub"])                  # [L]
            miss_pe = np.asarray(rec["miss_per_expert"])      # [L, E]
            l_n = idx.shape[0]
            for li in range(l_n):
                layer = layer_off + li
                used = idx[li].reshape(-1)
                self.cache.touch(layer, used)
                if self.predictor is not None:
                    if hasattr(self.predictor, "observe_transition") and layer > 0:
                        self.predictor.observe_transition(
                            layer, self._last_used.get(layer - 1, []), used)
                    self.predictor.observe(layer, used)
                self._last_used[layer] = used

                self.stats.n_sub += int(n_sub[li])
                self.ledger.buddy_hit(int(n_sub[li]))
                missing = np.flatnonzero(miss_pe[li] > 0)
                if self.policy.fallback == "fetch":
                    for e in missing:
                        self.ledger.sync_fetch(self._expert_bytes)
                        step_sync += self.hw.transfer_time(self._expert_bytes)
                        self.cache.insert(layer, int(e))
                        self.stats.n_miss_fetch += 1
                else:
                    self.ledger.drop(int(miss_pe[li].sum()))
                # prefetch for next step
                if self.predictor is not None and self.prefetch_k > 0:
                    want = self.predictor.predict(layer, self.prefetch_k)
                    inserted = self.cache.prefetch_to(layer, want)
                    if inserted:
                        nb = self._expert_bytes * len(inserted)
                        self.ledger.prefetch(nb, len(inserted))
                        step_prefetch += len(inserted) * \
                            self.hw.transfer_time(self._expert_bytes)
            layer_off += l_n

        compute = self._compute_s * max(1, batch) ** 0.0  # batch amortized
        self.stats.steps += 1
        self.stats.tokens += batch
        self.stats.compute_s += compute
        self.stats.stall_s += step_sync
        self.stats.sim_time_s += compute + step_sync + max(
            0.0, step_prefetch - compute)

    # ------------------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 greedy: bool = True) -> np.ndarray:
        """Teacher-free batched generation. prompts [B, P] int32."""
        b, p_len = prompts.shape
        total = p_len + max_new_tokens
        caches = self.init_caches(b, total)
        out = np.zeros((b, total), np.int64)
        out[:, :p_len] = prompts
        tok = jnp.asarray(prompts[:, 0], jnp.int32)
        logits = None
        for pos in range(total - 1):
            logits, caches = self.step(tok, caches, pos)
            if pos + 1 < p_len:
                tok = jnp.asarray(prompts[:, pos + 1], jnp.int32)
            else:
                nxt = np.asarray(jnp.argmax(logits, axis=-1))
                out[:, pos + 1] = nxt
                tok = jnp.asarray(nxt, jnp.int32)
        return out

    def teacher_forced_nll(self, tokens: np.ndarray) -> float:
        """Mean next-token NLL under the engine's policy (accuracy metric)."""
        b, s = tokens.shape
        caches = self.init_caches(b, s)
        nll, n = 0.0, 0
        for pos in range(s - 1):
            tok = jnp.asarray(tokens[:, pos], jnp.int32)
            logits, caches = self.step(tok, caches, pos)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            tgt = tokens[:, pos + 1]
            nll += float(-np.take_along_axis(np.asarray(logp), tgt[:, None], 1).sum())
            n += b
        return nll / n

    def summary(self) -> dict:
        return {
            "policy": dataclasses.asdict(self.policy),
            "cache_rate": self.cache.capacity / self.cfg.moe.num_experts,
            "stats": dataclasses.asdict(self.stats),
            "tokens_per_s": self.stats.tokens_per_s,
            "ledger": self.ledger.summary(),
        }
