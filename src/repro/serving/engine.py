"""BuddyMoE serving engine — batched decode with an offloaded expert cache.

Mirrors the paper's Fig. 3 pipeline on the simulation substrate:

  step t:  jitted decode_step runs with the CURRENT residency mask (experts
           whose transfers have ARRIVED — in-flight prefetches are misses);
           the in-graph BuddyMoE layer substitutes/flags per-slot (Alg. 1)
  between: the host cache manager replays the step on the event-driven PCIe
           timeline (runtime/transfers.py): compute advances layer by layer,
           in-flight transfers overlap the compute of earlier layers, a miss
           stalls only the layer that needs it, and prefetches for layer
           l+lookahead are issued while layer l computes.

Stall attribution (the Fig. 8 / Tables 2-4 measurement substrate):
  demand stall        cold miss, nothing in flight — full fetch wait
  late-prefetch stall predicted but not yet arrived — the transfer is
                      escalated to demand priority and the layer waits only
                      for its remaining tail (buddy substitution absorbs
                      these misses entirely under policy=buddy)
  overlapped          transfer time hidden under compute — bytes, no latency
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.buddies import BuddyTables
from repro.core.policy import BuddyPolicy
from repro.models import transformer
from repro.models.moe import BuddyState
from repro.runtime.cache import ExpertCache
from repro.runtime.memory import (DEFAULT_HW, HardwareModel, TransferLedger,
                                  expert_nbytes)
from repro.runtime.transfers import TransferScheduler


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens: int = 0
    sim_time_s: float = 0.0
    compute_s: float = 0.0
    stall_s: float = 0.0
    n_sub: int = 0
    n_miss_fetch: int = 0
    n_hit: int = 0
    n_late_prefetch: int = 0
    n_prefetch_issued: int = 0
    n_prefetch_cancelled: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.sim_time_s if self.sim_time_s else 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *,
                 tables: Optional[BuddyTables] = None,
                 policy: BuddyPolicy = BuddyPolicy(),
                 cache: Optional[ExpertCache] = None,
                 predictor=None,
                 prefetch_k: int = 0,
                 lookahead: int = 1,
                 hw: HardwareModel = DEFAULT_HW,
                 window: int = -1,
                 seed: int = 0,
                 latency_cfg: Optional[ModelConfig] = None):
        """latency_cfg: full-scale config whose expert sizes / active params
        drive the transfer + compute latency model (the accuracy testbed can
        be a reduced model while latencies reflect the deployment target —
        e.g. the real DeepSeek-V2-Lite). Defaults to cfg itself."""
        assert cfg.is_moe, "ServeEngine's expert cache applies to MoE archs"
        assert lookahead >= 1, "lookahead: layers ahead to prefetch (>= 1)"
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.num_moe_layers = sum(r for k, r in cfg.stack() if k == "attn_moe")
        e = cfg.moe.num_experts
        self.cache = cache or ExpertCache(self.num_moe_layers, e, 1.0)
        self.predictor = predictor
        self.prefetch_k = prefetch_k
        self.lookahead = lookahead
        self.hw = hw
        self.ledger = TransferLedger(hw)
        self.scheduler = TransferScheduler(hw)
        # residency commits and byte counts are driven by the same timeline
        self.scheduler.add_listener(self.cache.on_transfer_event)
        self.ledger.attach(self.scheduler)
        self.stats = EngineStats()
        self.window = window
        ref_cfg = latency_cfg or cfg
        self._expert_bytes = expert_nbytes(ref_cfg.d_model, ref_cfg.moe.d_ff)
        self._latency_cfg = ref_cfg
        self._active_params = ref_cfg.active_param_count()
        self._key = jax.random.PRNGKey(seed)
        self._last_used: dict = {}

        if tables is None:
            r = 8
            self._table = np.full((self.num_moe_layers, e, r), -1, np.int32)
            self._q = np.zeros((self.num_moe_layers, e, r), np.float32)
        else:
            self._table = np.asarray(tables.table)
            self._q = np.asarray(tables.q)
        if self.cache.buddy_table is None and tables is not None:
            # buddy-aware eviction: prefer victims whose misses buddies absorb
            self.cache.buddy_table = self._table

        self._step_fn = jax.jit(
            functools.partial(transformer.decode_step, cfg=self.cfg,
                              policy=self.policy, record=True,
                              window=self.window),
            static_argnames=())

    # ------------------------------------------------------------------
    def _buddy_state(self) -> BuddyState:
        res = self.cache.residency_mask()
        hop = np.stack([self.cache.hop_vector(l)
                        for l in range(self.num_moe_layers)])
        return BuddyState(resident=jnp.asarray(res),
                          table=jnp.asarray(self._table),
                          q=jnp.asarray(self._q),
                          hop=jnp.asarray(hop))

    def init_caches(self, batch: int, seq_len: int):
        return transformer.init_caches(
            self.cfg, batch, seq_len,
            window=0 if self.window < 0 else self.window)

    # ------------------------------------------------------------------
    def step(self, token, caches, pos):
        """One decode step for the whole batch. token [B] int32 device array.
        Returns (logits [B, V], new_caches)."""
        buddies = self._buddy_state()
        self._key, sub = jax.random.split(self._key)
        logits, caches, aux = self._step_fn(
            params=self.params, token=token, caches=caches,
            pos=jnp.asarray(pos, jnp.int32), buddies=buddies, rng=sub)
        self._account(aux, batch=int(token.shape[0]))
        return logits, caches

    # -- per-layer step timeline ---------------------------------------
    def _account(self, aux, batch: int) -> None:
        """Replay the step on the transfer timeline, layer by layer."""
        sched = self.scheduler
        step_t0 = sched.now
        busy0 = sched.busy_s
        compute_total = self.hw.decode_compute_time(
            self._active_params, max(1, batch))
        per_layer = compute_total / max(1, self.num_moe_layers)
        cursor = step_t0
        step_stall = 0.0

        layer_off = 0
        for rec in aux.get("recorded", []):
            idx = np.asarray(rec["indices"])                  # [L, T, K]
            n_sub = np.asarray(rec["n_sub"])                  # [L]
            miss_pe = np.asarray(rec["miss_per_expert"])      # [L, E]
            for li in range(idx.shape[0]):
                layer = layer_off + li
                # transfers in flight overlap all earlier layers' compute
                sched.advance(cursor)
                used = idx[li].reshape(-1)
                self._observe_layer(layer, used)
                res_used = np.unique(used[self.cache.resident[layer, used]])
                self.cache.pin(layer, res_used)
                self.stats.n_hit += int(len(res_used))

                self.stats.n_sub += int(n_sub[li])
                self.ledger.buddy_hit(int(n_sub[li]))
                cursor, stall = self._resolve_misses(layer, miss_pe[li],
                                                     cursor)
                step_stall += stall
                cursor += per_layer          # this layer's compute slice
                self._issue_prefetches(layer, used)
                self.cache.unpin(layer)
            layer_off += idx.shape[0]

        sched.advance(cursor)               # drain overlap to end of step
        step_time = cursor - step_t0
        overlapped = max(0.0, (sched.busy_s - busy0) - step_stall)
        self.ledger.overlapped(overlapped)

        self.stats.steps += 1
        self.stats.tokens += batch
        self.stats.compute_s += compute_total
        self.stats.stall_s += step_stall
        self.stats.sim_time_s += step_time

    def _observe_layer(self, layer: int, used: np.ndarray) -> None:
        self.cache.touch(layer, used)
        if self.predictor is not None:
            if hasattr(self.predictor, "observe_transition") and layer > 0:
                self.predictor.observe_transition(
                    layer, self._last_used.get(layer - 1, []), used)
            self.predictor.observe(layer, used)
        self._last_used[layer] = used

    def _resolve_misses(self, layer: int, miss_row: np.ndarray,
                        cursor: float):
        """Residual misses (post-substitution) block THIS layer only. An
        in-flight prefetch is escalated and waited for its tail (late
        prefetch); otherwise a demand fetch pays the full transfer."""
        missing = np.flatnonzero(miss_row > 0)
        if self.policy.fallback != "fetch":
            self.ledger.drop(int(miss_row.sum()))
            return cursor, 0.0
        sched = self.scheduler
        stall = 0.0
        for e in missing:
            e = int(e)
            if self.cache.resident[layer, e]:
                # arrived after this step's mask snapshot — already on device
                continue
            t = sched.in_flight(layer, e)
            if t is not None:
                sched.escalate(t)
                kind = "late_prefetch"
                self.stats.n_late_prefetch += 1
            else:
                t = sched.submit(layer, e, self._expert_bytes, "demand")
                kind = "demand"
            done = sched.run_until_done(t)
            s = max(0.0, done - cursor)
            self.ledger.stall(kind, s)      # ledger owns the breakdown
            stall += s
            cursor = max(cursor, done)
            self.stats.n_miss_fetch += 1
        return cursor, stall

    def _issue_prefetches(self, layer: int, used: np.ndarray) -> None:
        """While ``layer`` computes, line up transfers for layer
        ``layer + lookahead`` (wrapping into the next step). Predictions
        that changed since the last issue are cancelled if still unserved."""
        if self.predictor is None or self.prefetch_k <= 0:
            return
        tgt = (layer + self.lookahead) % self.num_moe_layers
        want = self.predictor.predict_ahead(
            tgt, self.prefetch_k, lookahead=self.lookahead, context=used)
        want = [int(e) for e in np.atleast_1d(want)]
        self.stats.n_prefetch_cancelled += \
            self.scheduler.cancel_stale_prefetches(tgt, want)
        for e in want:
            if self.cache.resident[tgt, e] or self.cache.inflight[tgt, e]:
                continue
            self.scheduler.submit(tgt, e, self._expert_bytes, "prefetch")
            self.stats.n_prefetch_issued += 1

    # ------------------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 greedy: bool = True) -> np.ndarray:
        """Teacher-free batched generation. prompts [B, P] int32."""
        b, p_len = prompts.shape
        total = p_len + max_new_tokens
        caches = self.init_caches(b, total)
        out = np.zeros((b, total), np.int64)
        out[:, :p_len] = prompts
        tok = jnp.asarray(prompts[:, 0], jnp.int32)
        logits = None
        for pos in range(total - 1):
            logits, caches = self.step(tok, caches, pos)
            if pos + 1 < p_len:
                tok = jnp.asarray(prompts[:, pos + 1], jnp.int32)
            else:
                nxt = np.asarray(jnp.argmax(logits, axis=-1))
                out[:, pos + 1] = nxt
                tok = jnp.asarray(nxt, jnp.int32)
        return out

    def teacher_forced_nll(self, tokens: np.ndarray) -> float:
        """Mean next-token NLL under the engine's policy (accuracy metric)."""
        b, s = tokens.shape
        caches = self.init_caches(b, s)
        nll, n = 0.0, 0
        for pos in range(s - 1):
            tok = jnp.asarray(tokens[:, pos], jnp.int32)
            logits, caches = self.step(tok, caches, pos)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            tgt = tokens[:, pos + 1]
            nll += float(-np.take_along_axis(np.asarray(logp), tgt[:, None], 1).sum())
            n += b
        return nll / n

    def stall_breakdown(self) -> dict:
        """Single source of truth: the ledger's event-timeline attribution."""
        return {
            "demand_stall_s": self.ledger.demand_stall_s,
            "late_prefetch_stall_s": self.ledger.late_prefetch_stall_s,
            "overlapped_s": self.ledger.overlapped_s,
        }

    def summary(self) -> dict:
        return {
            "policy": dataclasses.asdict(self.policy),
            "cache_rate": self.cache.capacity / self.cfg.moe.num_experts,
            "stats": dataclasses.asdict(self.stats),
            "tokens_per_s": self.stats.tokens_per_s,
            "stall_breakdown": self.stall_breakdown(),
            "ledger": self.ledger.summary(),
        }
