"""Continuous-batching request scheduler on the transfer timeline.

The paper's llama.cpp harness serves fixed static batches: a batch forms,
decodes in lockstep to completion, and only then does the next batch start.
BuddyMoE's value — hiding PCIe transfers behind compute and absorbing late
prefetches with buddies — only shows up under realistic serving load, where
requests arrive continuously and queueing interacts with expert-transfer
stalls. This module turns the repro into a traffic-serving simulator:

  ArrivalProcess     Poisson / bursty (Markov-modulated) / trace-replay
                     request arrival generators, all in SIMULATED seconds on
                     the same clock the TransferScheduler advances.
  ServeRequest       per-request SLO state: arrival, admission, TTFT, TPOT,
                     deadline, per-token emission timestamps.
  RequestQueue       FCFS backlog with optional SLO-aware admission: a
                     request whose deadline cannot be met given the current
                     service-time estimate is shed instead of admitted.
  ContinuousScheduler token-level continuous batching over ServeEngine's
                     per-layer step timeline: requests join free decode
                     slots mid-stream (per-row positions — no global
                     barrier), retire the step their budget completes, and
                     the freed slot is re-used immediately. Prefetch budget
                     adapts to queue depth + stall attribution through
                     runtime.prefetch.AdaptiveBudgetController.
  StaticServer       the llama.cpp-style baseline on the same clock:
                     batch formation barrier, left-padded prompts, lockstep
                     decode, stragglers hold the whole batch.

Both servers report p50/p95/p99 TTFT / TPOT / end-to-end latency and
goodput (SLO-satisfying requests and tokens per simulated second), next to
``ServeEngine.summary()``'s stall attribution — the measurement substrate
for the serving-load experiments.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.runtime.prefetch import AdaptiveBudgetController

# Request lifecycle states
WAITING = "waiting"
RUNNING = "running"
FINISHED = "finished"
REJECTED = "rejected"           # shed by SLO-aware admission


# ===========================================================================
# Arrival processes (simulated-clock seconds)
# ===========================================================================
class ArrivalProcess:
    def times(self, n: int) -> np.ndarray:
        raise NotImplementedError


class PoissonArrivals(ArrivalProcess):
    """Memoryless arrivals at ``rate`` requests per simulated second."""

    def __init__(self, rate: float, seed: int = 0, start_s: float = 0.0):
        assert rate > 0
        self.rate = rate
        self.seed = seed
        self.start_s = start_s

    def times(self, n: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        gaps = rng.exponential(1.0 / self.rate, n)
        return self.start_s + np.cumsum(gaps)


class BurstyArrivals(ArrivalProcess):
    """Markov-modulated Poisson: bursts of ~``burst_size`` requests arrive at
    ``burstiness`` x the base rate, separated by long quiet gaps, with the
    same long-run mean rate as PoissonArrivals(rate)."""

    def __init__(self, rate: float, burst_size: int = 4,
                 burstiness: float = 8.0, seed: int = 0, start_s: float = 0.0):
        assert rate > 0 and burst_size >= 1 and burstiness > 1.0
        self.rate = rate
        self.burst_size = burst_size
        self.burstiness = burstiness
        self.seed = seed
        self.start_s = start_s

    def times(self, n: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        # inside a burst: gaps at burstiness*rate; between bursts: the gap is
        # stretched so the long-run mean matches `rate`
        out, t = [], self.start_s
        fast = 1.0 / (self.rate * self.burstiness)
        # mean time per burst cycle must be burst_size/rate:
        slow = self.burst_size / self.rate - (self.burst_size - 1) * fast
        while len(out) < n:
            size = max(1, int(rng.geometric(1.0 / self.burst_size)))
            t += rng.exponential(slow)
            for _ in range(min(size, n - len(out))):
                out.append(t)
                t += rng.exponential(fast)
        return np.asarray(out[:n])


class ReplayArrivals(ArrivalProcess):
    """Replay recorded arrival timestamps (sorted)."""

    def __init__(self, times_s: Sequence[float]):
        self._times = np.sort(np.asarray(times_s, np.float64))

    def times(self, n: int) -> np.ndarray:
        assert n <= len(self._times), "trace shorter than request count"
        return self._times[:n].copy()


def load_trace(path: str) -> List[dict]:
    """Load a serving trace: JSONL rows of
    ``{"t_arrival": <simulated s>, "prompt_len": P, "max_new_tokens": M}``
    (blank lines and ``#`` comments skipped). Rows may carry optional
    ``session`` / ``parent`` ints so a recorded trace can express prefix
    structure (multi-turn sessions sharing a system prompt; ``parent`` is
    the previous turn's row). Rows are returned sorted by arrival time —
    the ReplayArrivals contract."""
    rows = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            try:
                d = json.loads(line)
                row = {"t_arrival": float(d["t_arrival"]),
                       "prompt_len": int(d["prompt_len"]),
                       "max_new_tokens": int(d["max_new_tokens"])}
                if "session" in d:
                    row["session"] = int(d["session"])
                if "parent" in d:
                    row["parent"] = int(d["parent"])
                rows.append(row)
            except (KeyError, TypeError, ValueError) as e:
                # TypeError covers valid-JSON non-object rows ('[0.1, 5, 3]')
                raise ValueError(f"{path}:{ln}: bad trace row {line!r}") from e
    assert rows, f"empty trace file: {path}"
    assert all(r["prompt_len"] >= 1 and r["max_new_tokens"] >= 1
               for r in rows), "trace rows need prompt_len/max_new_tokens >= 1"
    rows.sort(key=lambda r: r["t_arrival"])
    return rows


def requests_from_trace(path: str, sample_prompt: Callable[[int], np.ndarray],
                        slo: Optional[SLOConfig] = None,
                        limit: Optional[int] = None) -> List[ServeRequest]:
    """Workload replay from a recorded trace file (ROADMAP follow-up):
    arrivals are replayed verbatim and every request carries its OWN token
    budget from the trace row. ``sample_prompt(P)`` supplies prompt tokens of
    the recorded length (real traces record lengths, not content)."""
    rows = load_trace(path)
    if limit is not None:
        rows = rows[:limit]
    prompts = [np.asarray(sample_prompt(r["prompt_len"])) for r in rows]
    for p, r in zip(prompts, rows):
        assert p.ndim == 1 and len(p) == r["prompt_len"], \
            f"sample_prompt returned {p.shape} for prompt_len {r['prompt_len']}"
    reqs = make_requests(prompts,
                         ReplayArrivals([r["t_arrival"] for r in rows]),
                         [r["max_new_tokens"] for r in rows], slo)
    for q, r in zip(reqs, rows):
        q.session = r.get("session")
        q.parent = r.get("parent")
    return reqs


# ===========================================================================
# Requests + SLO state
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Per-request service-level objectives (simulated seconds). ``None``
    disables a term. ``deadline_s`` is relative to arrival."""
    ttft_s: Optional[float] = None
    tpot_s: Optional[float] = None
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray                  # [P] int tokens
    max_new_tokens: int
    arrival_s: float
    slo: Optional[SLOConfig] = None
    session: Optional[int] = None       # shared-prefix session id (traces)
    parent: Optional[int] = None        # previous turn's rid in the session
    # -- runtime state (filled by the scheduler) ------------------------
    state: str = WAITING
    admitted_s: float = -1.0
    first_token_s: float = -1.0
    finished_s: float = -1.0
    rejected_s: float = -1.0            # shed time (SLO admission)
    cursor: int = 0                     # next prompt token to feed
    prefix_hit_tokens: int = 0          # prompt tokens served from the cache
    tokens: list = dataclasses.field(default_factory=list)
    token_times: list = dataclasses.field(default_factory=list)

    # -- metrics --------------------------------------------------------
    def ttft(self) -> float:
        return self.first_token_s - self.arrival_s

    def tpot(self) -> float:
        """Mean time per output token after the first."""
        if len(self.token_times) < 2:
            return 0.0
        return ((self.token_times[-1] - self.token_times[0])
                / (len(self.token_times) - 1))

    def e2e(self) -> float:
        return self.finished_s - self.arrival_s

    def token_gaps(self) -> List[float]:
        """Per-token latency: arrival->first token, then inter-token gaps."""
        ts = [self.arrival_s] + list(self.token_times)
        return [b - a for a, b in zip(ts, ts[1:])]

    def slo_ok(self) -> bool:
        if self.state != FINISHED:
            return False
        if self.slo is None:
            return True
        s = self.slo
        if s.ttft_s is not None and self.ttft() > s.ttft_s:
            return False
        if s.tpot_s is not None and len(self.token_times) > 1 \
                and self.tpot() > s.tpot_s:
            return False
        if s.deadline_s is not None and self.e2e() > s.deadline_s:
            return False
        return True


def make_requests(prompts: Sequence[np.ndarray], arrivals: ArrivalProcess,
                  max_new_tokens, slo: Optional[SLOConfig] = None
                  ) -> List[ServeRequest]:
    """Zip prompts with an arrival process into a workload. ``max_new_tokens``
    is an int or a per-request sequence."""
    n = len(prompts)
    ts = arrivals.times(n)
    if np.isscalar(max_new_tokens):
        max_new_tokens = [int(max_new_tokens)] * n
    return [ServeRequest(rid=i, prompt=np.asarray(p, np.int64),
                         max_new_tokens=int(m), arrival_s=float(t), slo=slo)
            for i, (p, m, t) in enumerate(zip(prompts, max_new_tokens, ts))]


# ===========================================================================
# Request queue with SLO-aware admission
# ===========================================================================
class RequestQueue:
    """FCFS backlog on the simulated clock. ``admission="slo"`` sheds
    requests at pop time when the service-time estimate says their deadline
    is already unreachable — serving them would waste slots that later
    requests could still use (goodput-aware load shedding)."""

    def __init__(self, requests: Sequence[ServeRequest],
                 admission: str = "fcfs"):
        assert admission in ("fcfs", "slo")
        self.admission = admission
        self.total = len(requests)          # offered workload size
        self._future = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        self._pending: List[ServeRequest] = []
        self.rejected: List[ServeRequest] = []
        self.peak_depth = 0

    # -- clock-driven release -------------------------------------------
    def release_until(self, now: float) -> None:
        while self._future and self._future[0].arrival_s <= now:
            self._pending.append(self._future.pop(0))
        self.peak_depth = max(self.peak_depth, len(self._pending))

    def depth(self, now: Optional[float] = None) -> int:
        if now is not None:
            self.release_until(now)
        return len(self._pending)

    def next_arrival(self) -> Optional[float]:
        return self._future[0].arrival_s if self._future else None

    @property
    def exhausted(self) -> bool:
        return not self._future and not self._pending

    def max_context(self) -> int:
        rs = self._future + self._pending
        return max((len(r.prompt) + r.max_new_tokens for r in rs), default=1)

    # -- admission ------------------------------------------------------
    def pop(self, now: float,
            est_service_fn: Optional[Callable[[ServeRequest], float]] = None
            ) -> Optional[ServeRequest]:
        """Next admissible request, shedding doomed ones under ``slo``."""
        self.release_until(now)
        while self._pending:
            r = self._pending.pop(0)
            if (self.admission == "slo" and est_service_fn is not None
                    and r.slo is not None and r.slo.deadline_s is not None):
                est_finish = now + est_service_fn(r)
                if est_finish > r.arrival_s + r.slo.deadline_s:
                    r.state = REJECTED
                    r.rejected_s = now
                    self.rejected.append(r)
                    continue
            return r
        return None

    def requeue(self, r: ServeRequest) -> None:
        """Put a preempted request back at the FRONT of the backlog (it is
        the oldest admitted work, so FCFS order is preserved)."""
        self._pending.insert(0, r)


# ===========================================================================
# Request-lifecycle trace spans (flight recorder, "requests" track)
# ===========================================================================
def emit_request_spans(trace, requests: Sequence[ServeRequest],
                       emitted: set) -> int:
    """Emit each request's lifecycle onto the recorder's "requests" track:
    arrive instant, queued span (arrival -> admit/shed), prefill span
    (admit -> first token), decode span (first token -> retire), per-token
    instants, and a retire/shed terminal instant. Lane = request id.

    Requests still WAITING/RUNNING (truncated run) get only the events whose
    timestamps exist, so a mid-step-truncated trace is still loadable.
    ``emitted`` (a set of rids owned by the caller) makes the call
    idempotent — summary() can run more than once without duplicating
    spans. Returns the number of requests newly emitted."""
    n = 0
    for r in requests:
        if r.rid in emitted:
            continue
        emitted.add(r.rid)
        n += 1
        trace.instant("requests", r.rid, "arrive", f"req{r.rid}",
                      r.arrival_s, prompt_len=len(r.prompt),
                      max_new_tokens=r.max_new_tokens)
        if r.state == REJECTED:
            end = r.rejected_s if r.rejected_s >= 0 else r.arrival_s
            trace.span("requests", r.rid, "queued", "queued",
                       r.arrival_s, end)
            trace.instant("requests", r.rid, "shed", "shed", end,
                          reason="slo_admission")
            continue
        if r.admitted_s < 0:
            continue                        # never admitted (truncated run)
        trace.span("requests", r.rid, "queued", "queued",
                   r.arrival_s, r.admitted_s)
        if r.prefix_hit_tokens > 0:
            # admit-with-prefix-hit: this many prompt tokens were adopted
            # from the radix cache instead of being prefilled
            trace.instant("requests", r.rid, "prefix_hit", "prefix_hit",
                          r.admitted_s, hit_tokens=r.prefix_hit_tokens,
                          prompt_len=len(r.prompt))
        if r.first_token_s >= 0:
            trace.span("requests", r.rid, "prefill", "prefill",
                       r.admitted_s, r.first_token_s)
        end = r.finished_s if r.finished_s >= 0 else (
            r.token_times[-1] if r.token_times else r.admitted_s)
        if r.first_token_s >= 0:
            trace.span("requests", r.rid, "decode", "decode",
                       r.first_token_s, end, tokens=len(r.tokens))
        for k, t in enumerate(r.token_times):
            trace.instant("requests", r.rid, "token", f"tok{k}", t)
        if r.state == FINISHED:
            trace.instant("requests", r.rid, "retire", "retire", end,
                          ttft_s=r.ttft(), e2e_s=r.e2e(),
                          slo_ok=r.slo_ok())
    return n


# ===========================================================================
# Percentile / summary helpers
# ===========================================================================
def percentiles(xs: Sequence[float]) -> dict:
    """p50/p95/p99/mean with linear interpolation (empty -> zeros)."""
    if not len(xs):
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0}
    a = np.asarray(xs, np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean())}


def _summarize(label: str, requests: Sequence[ServeRequest],
               rejected: Sequence[ServeRequest], elapsed_s: float,
               engine, extra: Optional[dict] = None,
               total: Optional[int] = None) -> dict:
    """``total`` is the offered workload size — requests still waiting or
    running when a run truncates must count against the SLO fraction."""
    done = [r for r in requests if r.state == FINISHED]
    ok = [r for r in done if r.slo_ok()]
    gaps = [g for r in done for g in r.token_gaps()]
    tok_ok = sum(len(r.tokens) for r in ok)
    tok_all = sum(len(r.tokens) for r in done)
    el = max(elapsed_s, 1e-12)
    if total is None:
        total = len(requests) + len(rejected)
    out = {
        "mode": label,
        "num_requests": total,
        "completed": len(done),
        "rejected": len(rejected),
        "slo_met": len(ok),
        "slo_met_frac": len(ok) / max(1, total),
        "elapsed_s": elapsed_s,
        "ttft_s": percentiles([r.ttft() for r in done]),
        "tpot_s": percentiles([r.tpot() for r in done if len(r.tokens) > 1]),
        "e2e_s": percentiles([r.e2e() for r in done]),
        "token_latency_s": percentiles(gaps),
        "goodput_rps": len(ok) / el,
        "goodput_tok_s": tok_ok / el,
        "throughput_tok_s": tok_all / el,
        "engine": engine.summary(),
    }
    if extra:
        out.update(extra)
    return out


# ===========================================================================
# Continuous batching
# ===========================================================================
class ContinuousScheduler:
    """Token-level continuous batching over a ServeEngine.

    ``slots`` decode rows step together in one fixed-shape jitted graph, but
    each row carries its own position (per-row ring-buffer KV) so a new
    prompt joins the step after a slot frees — prefill tokens of one request
    interleave with decode tokens of the others, no global barrier. A row
    retires the step its budget completes and the slot is re-admitted from
    the queue before the next step.

    ``prefill_chunk`` > 1 switches admission to CHUNKED prefill: a joining
    prompt is ingested in ⌈P/C⌉ fused multi-token steps
    (``ServeEngine.prefill_rows``) instead of P token-by-token decode steps.
    Decode rows ride the same fused step as 1-valid-token chunks, so they
    keep emitting while a neighbour slot prefills (each fused step is one
    step on the clock — the chunked-prefill interference trade-off: slightly
    longer steps for everyone vs a far shorter prefill phase and TTFT).
    """

    def __init__(self, engine, slots: int, *,
                 greedy: bool = True, temperature: float = 1.0,
                 controller: Optional[AdaptiveBudgetController] = None,
                 max_steps: int = 1_000_000, prefill_chunk: int = 1,
                 adaptive_chunk: bool = False):
        assert slots >= 1
        assert prefill_chunk >= 1
        self.engine = engine
        self.slots = slots
        self.greedy = greedy
        self.temperature = temperature
        self.controller = controller
        self.max_steps = max_steps
        self.prefill_chunk = prefill_chunk
        # shrink a joining prompt's chunk while co-resident decode rows are
        # under TPOT pressure (carried-over ROADMAP item); off by default —
        # the fixed-chunk path is byte-identical
        self.adaptive_chunk = adaptive_chunk
        self.completed: List[ServeRequest] = []
        self.occupancy: List[int] = []
        self.steps = 0
        self._trace_emitted: set = set()    # rids already on the trace
        # live references into the running loop's slot/pos/tok state so
        # preempt() can be driven mid-run (e.g. from a controller)
        self._slot: Optional[List[Optional[ServeRequest]]] = None
        self._pos: Optional[np.ndarray] = None
        self._tok: Optional[np.ndarray] = None

    # -- service-time estimate for SLO-aware admission ------------------
    def _est_service(self, r: ServeRequest, est_step_s: float) -> float:
        prefill_steps = -(-len(r.prompt) // self.prefill_chunk)
        return (prefill_steps + r.max_new_tokens) * est_step_s

    def _effective_chunk(self, slot, pos) -> int:
        """Per-step prefill chunk size. With ``adaptive_chunk``, halve the
        chunk while the EWMA step time exceeds the tightest TPOT budget of
        a co-resident DECODE row — a joining prompt's long fused steps are
        exactly what inflates its neighbours' inter-token gaps. Step time
        is ~linear in fed tokens at the margin (the weight-streaming term
        is per-step), so each halving roughly halves the projection;
        power-of-two shrinks also bound jit retraces to log2(C) shapes."""
        c = self.prefill_chunk
        if not self.adaptive_chunk or c <= 1:
            return c
        budgets = [r.slo.tpot_s for i, r in enumerate(slot)
                   if r is not None and r.slo is not None
                   and r.slo.tpot_s is not None and pos[i] >= len(r.prompt)]
        if not budgets:
            return c
        budget, est = min(budgets), self._est_step_s
        while c > 1 and est > budget:
            c //= 2
            est /= 2.0
        return max(1, c)

    # -- scaffolding shared by the token-by-token and chunked loops -----
    def _admit(self, queue: RequestQueue, slot, pos, tok, caches):
        """Fill free slots from the backlog at the current clock; reset the
        decode caches of reused rows. Returns (caches, active mask)."""
        eng = self.engine
        now = eng.scheduler.now
        newly = []
        for i in range(self.slots):
            if slot[i] is not None:
                continue
            r = queue.pop(now,
                          lambda rq: self._est_service(rq, self._est_step_s))
            if r is None:
                break
            r.state = RUNNING
            r.admitted_s = now
            r.cursor = 1
            slot[i] = r
            pos[i] = 0
            tok[i] = int(r.prompt[0])
            newly.append(i)
        if newly:
            caches = eng.reset_rows(caches, newly)
        if getattr(eng, "prefix_tree", None) is not None:
            # radix-cache admission: adopt the longest cached prefix and
            # start prefill at the first novel token — a full-prefix hit
            # leaves exactly one token to feed (near-zero TTFT)
            for i in newly:
                r = slot[i]
                m = eng.adopt_prefix(i, r.prompt)
                if m > 0:
                    r.prefix_hit_tokens = m
                    pos[i] = m
                    tok[i] = int(r.prompt[m])
                    r.cursor = m + 1
        return caches, np.array([s is not None for s in slot], bool)

    def _tick(self, t0: float, n_active: int) -> float:
        """Post-step bookkeeping: refine the EWMA step estimate, count the
        step and its occupancy. Returns the step's completion time."""
        t1 = self.engine.scheduler.now
        self._est_step_s = 0.9 * self._est_step_s + 0.1 * max(t1 - t0, 1e-12)
        self.steps += 1
        self.occupancy.append(n_active)
        return t1

    def _emit(self, slot, i: int, nxt: int, t1: float, tok) -> None:
        """Record a sampled token for slot ``i``; mid-step retirement frees
        the slot the step its budget completes."""
        r = slot[i]
        r.tokens.append(nxt)
        r.token_times.append(t1)
        if r.first_token_s < 0:
            r.first_token_s = t1
        tok[i] = nxt
        if len(r.tokens) >= r.max_new_tokens:
            r.state = FINISHED
            r.finished_s = t1
            self.completed.append(r)
            slot[i] = None
            # paged KV: hand the retired row's pages back immediately (its
            # donated prefix blocks stay alive via radix-tree refcounts);
            # no-op for a ring engine
            self.engine.release_kv_row(i)

    def _maybe_insert_prefix(self, i: int, r: ServeRequest) -> None:
        """Donate a row's prompt KV to the radix cache the step its prefill
        completes (both serving loops call this exactly once per request —
        the step where pos crosses len(prompt))."""
        if getattr(self.engine, "prefix_tree", None) is not None:
            self.engine.insert_prefix(i, r.prompt)

    def preempt(self, i: int, queue: RequestQueue) -> None:
        """Evict a still-PREFILLING row under pressure: release its KV pages
        (the prefix it donated — or matched — stays warm in the radix tree)
        and put the request back at the head of the backlog for
        re-admission, where the prefix cache makes the lost work cheap to
        recover. Only callable mid-run (run()/._run_chunked stash live
        state); rows that have emitted tokens cannot be preempted — their
        sampled continuation would be lost."""
        assert self._slot is not None, "preempt() only applies mid-run"
        r = self._slot[i]
        assert r is not None, f"slot {i} is empty"
        assert not r.tokens, "cannot preempt a decoding row (tokens emitted)"
        self.engine.release_kv_row(i)
        r.state = WAITING
        r.admitted_s = -1.0
        r.cursor = 0
        r.prefix_hit_tokens = 0
        self._slot[i] = None
        self._pos[i] = 0
        self._tok[i] = 0
        queue.requeue(r)

    def _feedback(self, queue: RequestQueue) -> None:
        """Resize the prefetch budget from stall attribution + queue depth
        (and, with cost-ranked prefetch, the count of candidates whose
        expected stall saved was worth the bytes). The engine's placement
        controller rides the same step loop: its tick is interval-gated on
        the simulated clock, so calling it here AND from the engine's
        step accounting never double-fires a window."""
        if self.controller is not None:
            self.controller.observe_step(
                self.engine.stall_breakdown(),
                queue.depth(self.engine.scheduler.now),
                worthwhile=getattr(self.engine,
                                   "last_prefetch_worthwhile", None))
            self.controller.apply(self.engine)
        placement = getattr(self.engine, "placement", None)
        if placement is not None:
            placement.maybe_tick(self.engine)

    def run(self, queue: RequestQueue,
            max_context: Optional[int] = None) -> dict:
        if self.prefill_chunk > 1:
            return self._run_chunked(queue, max_context)
        eng = self.engine
        b = self.slots
        ctx = max_context or queue.max_context()
        caches = eng.init_caches(b, ctx)
        slot: List[Optional[ServeRequest]] = [None] * b
        pos = np.zeros(b, np.int32)
        tok = np.zeros(b, np.int64)
        self._slot, self._pos, self._tok = slot, pos, tok
        t_start = eng.scheduler.now
        # seed the step-time estimate from the hardware model (refined online)
        self._est_step_s = eng.hw.decode_compute_time(eng._active_params, b)

        while self.steps < self.max_steps:
            caches, active = self._admit(queue, slot, pos, tok, caches)
            if not active.any():
                nxt = queue.next_arrival()
                if nxt is None:
                    break                       # drained: all work done
                eng.advance_clock(max(eng.scheduler.now, nxt))
                continue

            # ---- one fused step: prefill + decode rows together -------
            t0 = eng.scheduler.now
            logits, caches = eng.step(jnp.asarray(tok, jnp.int32), caches,
                                      pos.copy(), active=active)
            t1 = self._tick(t0, int(active.sum()))

            sampled = eng.sample_tokens(logits, self.greedy, self.temperature)
            for i in range(b):
                r = slot[i]
                if r is None:
                    continue
                pos[i] += 1
                if r.cursor < len(r.prompt):    # still prefilling this row
                    tok[i] = int(r.prompt[r.cursor])
                    r.cursor += 1
                    continue
                if pos[i] == len(r.prompt):     # prefill just completed
                    self._maybe_insert_prefix(i, r)
                self._emit(slot, i, int(sampled[i]), t1, tok)
            self._feedback(queue)

        return self.summary(queue, t_start)

    def _run_chunked(self, queue: RequestQueue,
                     max_context: Optional[int] = None) -> dict:
        """Chunked-prefill serving loop. Per-row state is just ``pos`` (next
        position to feed): pos < len(prompt) means the row is prefilling and
        the step feeds prompt[pos : pos+C]; otherwise it feeds the last
        sampled token. A fused step only launches while some row prefills —
        pure-decode steps use the cheaper single-token graph."""
        eng = self.engine
        b = self.slots
        ctx = max_context or queue.max_context()
        caches = eng.init_caches(b, ctx)
        slot: List[Optional[ServeRequest]] = [None] * b
        pos = np.zeros(b, np.int32)
        tok = np.zeros(b, np.int64)
        self._slot, self._pos, self._tok = slot, pos, tok
        t_start = eng.scheduler.now
        self._est_step_s = eng.hw.decode_compute_time(eng._active_params, b)

        while self.steps < self.max_steps:
            caches, active = self._admit(queue, slot, pos, tok, caches)
            if not active.any():
                nxt = queue.next_arrival()
                if nxt is None:
                    break                       # drained: all work done
                eng.advance_clock(max(eng.scheduler.now, nxt))
                continue

            # ---- one fused step: prefill chunks + decode rows ---------
            t0 = eng.scheduler.now
            n_feed = np.zeros(b, np.int32)
            prefilling = any(slot[i] is not None
                             and pos[i] < len(slot[i].prompt)
                             for i in range(b))
            if prefilling:
                chunk = self._effective_chunk(slot, pos)
                tokens = np.zeros((b, chunk), np.int64)
                valid = np.zeros((b, chunk), bool)
                for i in range(b):
                    r = slot[i]
                    if r is None:
                        continue
                    if pos[i] < len(r.prompt):
                        n = min(chunk, len(r.prompt) - pos[i])
                        tokens[i, :n] = r.prompt[pos[i]:pos[i] + n]
                    else:
                        n = 1
                        tokens[i, 0] = tok[i]
                    valid[i, :n] = True
                    n_feed[i] = n
                logits, caches = eng.prefill_rows(
                    jnp.asarray(tokens, jnp.int32), active, caches,
                    base_pos=pos.copy(), tok_valid=valid)
                step_logits = logits[jnp.arange(b),
                                     jnp.maximum(n_feed - 1, 0)]
            else:
                n_feed[active] = 1
                step_logits, caches = eng.step(
                    jnp.asarray(tok, jnp.int32), caches, pos.copy(),
                    active=active)
            t1 = self._tick(t0, int(active.sum()))

            sampled = eng.sample_tokens(step_logits, self.greedy,
                                        self.temperature)
            for i in range(b):
                r = slot[i]
                if r is None:
                    continue
                pos[i] += n_feed[i]
                if pos[i] < len(r.prompt):      # still prefilling this row
                    continue
                if pos[i] == len(r.prompt):     # prefill just completed
                    self._maybe_insert_prefix(i, r)
                self._emit(slot, i, int(sampled[i]), t1, tok)
            self._feedback(queue)

        return self.summary(queue, t_start)

    def summary(self, queue: RequestQueue, t_start: float = 0.0) -> dict:
        elapsed = self.engine.scheduler.now - t_start
        tele = getattr(self.engine, "telemetry", None)
        if tele is not None and tele.trace is not None:
            emit_request_spans(tele.trace, self.completed,
                               self._trace_emitted)
            emit_request_spans(tele.trace, queue.rejected,
                               self._trace_emitted)
        extra = {
            "steps": self.steps,
            "slots": self.slots,
            "mean_occupancy": float(np.mean(self.occupancy))
            if self.occupancy else 0.0,
            "queue_peak_depth": queue.peak_depth,
        }
        if self.controller is not None:
            extra["budget"] = dataclasses.asdict(self.controller.budget)
            extra["budget_trace"] = list(self.controller.trace)
        return _summarize("continuous", self.completed, queue.rejected,
                          elapsed, self.engine, extra, total=queue.total)


# ===========================================================================
# Static-batching baseline on the same clock
# ===========================================================================
class StaticServer:
    """The llama.cpp-style harness: batches form in arrival order (a batch
    waits for its LAST member to arrive), prompts are left-padded to a common
    length, all rows decode in lockstep for the batch-max token budget, and
    the next batch cannot start until every straggler finishes."""

    def __init__(self, engine, batch_size: int, *, greedy: bool = True,
                 temperature: float = 1.0):
        self.engine = engine
        self.batch_size = batch_size
        self.greedy = greedy
        self.temperature = temperature
        self.completed: List[ServeRequest] = []

    def run(self, requests: Sequence[ServeRequest]) -> dict:
        from repro.serving.requests import Request, StaticBatcher
        eng = self.engine
        reqs = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        by_rid = {r.rid: r for r in reqs}
        # StaticBatcher owns the llama.cpp-harness padding semantics
        # (rid=-1 pad copies, left-pad to common length, row mask)
        shadow = [Request(rid=r.rid, prompt=r.prompt,
                          max_new_tokens=r.max_new_tokens) for r in reqs]
        t_start = eng.scheduler.now
        for bchunk, mat, mask in StaticBatcher(self.batch_size).batches(
                shadow):
            chunk = [by_rid[q.rid] for q in bchunk if q.rid >= 0]
            # batch-formation barrier: wait for the last member
            form_t = max(r.arrival_s for r in chunk)
            eng.advance_clock(max(eng.scheduler.now, form_t))

            plen = mat.shape[1]
            max_new = max(q.max_new_tokens for q in bchunk)
            total = plen + max_new
            caches = eng.init_caches(self.batch_size, total)

            tok = jnp.asarray(mat[:, 0], jnp.int32)
            live = mask.copy()      # rows whose budget is not yet exhausted
            for p in range(total - 1):
                logits, caches = eng.step(tok, caches, p, active=live)
                t1 = eng.scheduler.now
                if p + 1 < plen:
                    tok = jnp.asarray(mat[:, p + 1], jnp.int32)
                    continue
                nxt = eng.sample_tokens(logits, self.greedy, self.temperature)
                for i, r in enumerate(chunk):
                    if len(r.tokens) >= r.max_new_tokens:
                        continue                     # straggler row idles
                    r.tokens.append(int(nxt[i]))
                    r.token_times.append(t1)
                    if r.first_token_s < 0:
                        r.first_token_s = t1
                        r.admitted_s = form_t
                    if len(r.tokens) >= r.max_new_tokens:
                        live[i] = False      # done: stop counting its tokens
                if not live.any():           # every real row has finished
                    break
                tok = jnp.asarray(nxt, jnp.int32)
            for r in chunk:
                r.state = FINISHED
                r.finished_s = r.token_times[-1] if r.token_times \
                    else eng.scheduler.now
                self.completed.append(r)
        elapsed = eng.scheduler.now - t_start
        return _summarize("static", self.completed, [], elapsed, eng,
                          {"batch_size": self.batch_size})
