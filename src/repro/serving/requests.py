"""Request / batch plumbing for the serving example.

StaticBatcher is the paper's llama.cpp-style harness: fixed-size batches, a
global barrier between them. The continuous-batching scheduler that replaces
it under live traffic lives in ``repro.serving.scheduler``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, List

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [P] int tokens
    max_new_tokens: int = 32
    output: np.ndarray = None   # filled by the scheduler


class StaticBatcher:
    """Pads a stream of requests into fixed-size batches (static batching —
    what the paper's llama.cpp harness does). Prompts are left-padded to a
    common length with token 0.

    Pad rows (rid=-1 copies of the first request, needed to keep the jitted
    step shape fixed) are flagged False in the yielded row mask so the engine
    excludes them from throughput, transfer, and NLL accounting."""

    def __init__(self, batch_size: int, pad_id: int = 0):
        self.batch_size = batch_size
        self.pad_id = pad_id

    def batches(self, requests: Iterable[Request]):
        """Yields (chunk, token matrix [B, P], row mask [B])."""
        it = iter(requests)
        while True:
            chunk: List[Request] = list(itertools.islice(it, self.batch_size))
            if not chunk:
                return
            while len(chunk) < self.batch_size:   # pad with a copy
                chunk.append(Request(rid=-1, prompt=chunk[0].prompt.copy(),
                                     max_new_tokens=chunk[0].max_new_tokens))
            plen = max(len(r.prompt) for r in chunk)
            mat = np.full((len(chunk), plen), self.pad_id, np.int64)
            for i, r in enumerate(chunk):
                mat[i, plen - len(r.prompt):] = r.prompt
            mask = np.array([r.rid >= 0 for r in chunk], bool)
            yield chunk, mat, mask
