"""Radix-tree prefix cache over paged KV blocks.

Maps token-id prefixes to shared chains of ``PagedKVPool`` blocks so a
request whose prompt starts with a previously-served prefix can adopt the
cached KV instead of recomputing it (``ContinuousScheduler._admit`` →
``ServeEngine.adopt_prefix``).  A full-prefix hit admits with near-zero
TTFT: chunked prefill touches only the novel suffix.

Ownership model (the part that makes mid-block divergence safe):

  * Each node's edge covers token positions ``[start, start + len(key))``
    and lists the physical blocks covering that WHOLE range — including a
    *straddling* block at a non-block-aligned ``start``.  The straddle
    block is the inserting row's own copy, which holds the shared tokens
    before ``start`` (the row adopted-then-CoW'd them) plus this branch's
    continuation after it.  On a match walk, a deeper node's listing for a
    block index supersedes its parent's: both agree on content up to the
    branch point, and only the deeper copy continues down the matched path.
  * Donated chains are trimmed to *full* blocks (``ServeEngine
    .insert_prefix`` cuts at ``floor(P / bs) * bs`` tokens): the donor keeps
    decoding into its final partial block, and a block being appended to
    can never be shared.
  * Every listed block holds one pool refcount per listing node (plus one
    per row table mapping it — see ``PagedKVPool.check``).  A node split
    re-refs the straddling block once, since it then appears in both
    halves.  Adoption bumps refcounts again (``pool.adopt``); the adopting
    row copy-on-writes before appending, so tree contents are immutable.

Eviction: under pool pressure (``PagedKVPool.evict_cb``) the
least-recently-matched *leaf* is dropped and its listings released —
interior nodes stay, so shorter shared prefixes survive longer, LRU order
refreshed by every match/insert walk.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.runtime.paged_kv import PagedKVPool


def _common_prefix(a: Sequence[int], b: Sequence[int]) -> int:
    n = min(len(a), len(b))
    k = 0
    while k < n and a[k] == b[k]:
        k += 1
    return k


class _Node:
    __slots__ = ("key", "start", "blocks", "children", "parent",
                 "last_access", "order")

    def __init__(self, key: Tuple[int, ...], start: int, blocks: List[int],
                 parent: Optional["_Node"], order: int) -> None:
        self.key = key
        self.start = start          # absolute token offset of key[0]
        self.blocks = blocks        # covers block idx floor(start/bs)..
        self.children: Dict[int, _Node] = {}
        self.parent = parent
        self.last_access = order
        self.order = order

    def block_lo(self, bs: int) -> int:
        return self.start // bs


class PrefixTree:
    """Token-id radix tree whose edges carry paged-KV block chains."""

    def __init__(self, pool: PagedKVPool) -> None:
        self.pool = pool
        self.root = _Node((), 0, [], None, 0)
        self._clock = 0
        self.n_nodes = 0
        self.n_evicted = 0
        pool.evict_cb = self.evict_lru_leaf

    # -- match -----------------------------------------------------------
    def match(self, tokens: Sequence[int], cap: int) -> Tuple[int, List[int]]:
        """Longest cached prefix of ``tokens``, capped at ``cap`` tokens
        (callers pass ``len(prompt) - 1`` so at least one token remains to
        feed).  Returns ``(m, chain)`` where ``chain`` lists the physical
        blocks covering positions ``[0, ceil(m / bs) * bs)`` — the last one
        shared mid-fill, so the adopter must CoW before writing."""
        bs = self.pool.block_size
        self._clock += 1
        found: Dict[int, int] = {}
        cur, offset = self.root, 0
        while offset < len(tokens):
            child = cur.children.get(tokens[offset])
            if child is None:
                break
            k = _common_prefix(child.key, tokens[offset:])
            if k == 0:
                break
            child.last_access = self._clock
            lo = child.block_lo(bs)
            n_cov = -(-(child.start + k) // bs) - lo
            for i in range(n_cov):
                found[lo + i] = child.blocks[i]   # deeper listing wins
            offset += k
            if k < len(child.key):
                break
            cur = child
        m = min(offset, cap)
        if m <= 0:
            return 0, []
        need = -(-m // bs)
        return m, [found[j] for j in range(need)]

    # -- insert ----------------------------------------------------------
    def insert(self, tokens: Sequence[int], row_blocks: Sequence[int]) -> int:
        """Donate a fully-prefilled prompt's block chain.  ``tokens`` and
        ``row_blocks`` must already be trimmed to full blocks (``len(tokens)
        == len(row_blocks) * bs``).  Refcounts are bumped for every new
        listing; returns the number of newly-listed blocks."""
        bs = self.pool.block_size
        assert len(tokens) == len(row_blocks) * bs, "insert not block-aligned"
        if not tokens:
            return 0
        self._clock += 1
        tokens = tuple(int(t) for t in tokens)
        cur, offset = self.root, 0
        while offset < len(tokens):
            child = cur.children.get(tokens[offset])
            if child is None:
                return self._attach(cur, tokens, offset, row_blocks)
            k = _common_prefix(child.key, tokens[offset:])
            child.last_access = self._clock
            if k < len(child.key):
                self._split(child, k)
                offset += k
                if offset < len(tokens):
                    return self._attach(child, tokens, offset, row_blocks)
                return 0
            offset += k
            cur = child
        return 0   # whole prompt already cached

    def _attach(self, parent: _Node, tokens: Tuple[int, ...], offset: int,
                row_blocks: Sequence[int]) -> int:
        bs = self.pool.block_size
        blocks = [int(b) for b in row_blocks[offset // bs:]]
        for b in blocks:
            self.pool.ref(b)
        node = _Node(tokens[offset:], offset, blocks, parent, self._clock)
        parent.children[tokens[offset]] = node
        self.n_nodes += 1
        return len(blocks)

    def _split(self, node: _Node, k: int) -> None:
        """Split ``node`` at key offset ``k``: the node keeps ``key[:k]``
        and the blocks covering it; a new child takes the rest.  A block
        straddling the cut lands in both listings and gains a ref."""
        bs = self.pool.block_size
        cut = node.start + k
        lo = node.block_lo(bs)
        n_par = -(-cut // bs) - lo           # parent listing length
        child = _Node(node.key[k:], cut, node.blocks[cut // bs - lo:],
                      node, self._clock)
        child.children = node.children
        child.last_access = node.last_access
        for gc in child.children.values():
            gc.parent = child
        if cut % bs:                          # straddle now listed twice
            self.pool.ref(node.blocks[n_par - 1])
        node.key = node.key[:k]
        node.blocks = node.blocks[:n_par]
        node.children = {child.key[0]: child}
        self.n_nodes += 1

    # -- eviction --------------------------------------------------------
    def evict_lru_leaf(self) -> bool:
        """Release the least-recently-matched leaf's listings (pool pressure
        hook).  Returns False when nothing is evictable."""
        leaf: Optional[_Node] = None
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root and not n.children:
                if leaf is None or ((n.last_access, n.order) <
                                    (leaf.last_access, leaf.order)):
                    leaf = n
            stack.extend(n.children[t] for t in sorted(n.children,
                                                       reverse=True))
        if leaf is None:
            return False
        for b in leaf.blocks:
            self.pool.release(b)
        assert leaf.parent is not None
        del leaf.parent.children[leaf.key[0]]
        self.n_nodes -= 1
        self.n_evicted += 1
        return True

    # -- digests ---------------------------------------------------------
    def block_holders(self) -> Dict[int, int]:
        """Physical block -> number of tree listings (for
        ``PagedKVPool.check``)."""
        holders: Dict[int, int] = {}
        stack = [self.root]
        while stack:
            n = stack.pop()
            for b in n.blocks:
                holders[b] = holders.get(b, 0) + 1
            stack.extend(n.children.values())
        return holders

    def stats(self) -> dict:
        listings = sum(self.block_holders().values())
        return {"nodes": self.n_nodes, "block_listings": listings,
                "evicted": self.n_evicted}
