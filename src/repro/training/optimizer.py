"""AdamW + cosine schedule (hand-rolled; no optax dependency)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"lr": lr, "grad_norm": gnorm}
