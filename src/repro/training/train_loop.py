"""Training loop: loss, train_step, and the jit wiring.

``train_step`` is the function lowered by the multi-pod dry-run for the
``train_4k`` shape; it is also what examples/train_moe_100m.py runs on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.training.optimizer import (AdamWConfig, OptState, apply_updates,
                                      init_opt_state)


def lm_loss(params, cfg: ModelConfig, tokens, targets, *, cond_embeds=None,
            rng=None, lb_coef: float = 0.01, remat: bool = False):
    logits, aux = transformer.forward_train(params, cfg, tokens,
                                            cond_embeds=cond_embeds, rng=rng,
                                            remat=remat)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    ce = nll.mean()
    n_moe = max(sum(r for k, r in cfg.stack() if k == "attn_moe"), 1)
    loss = ce + lb_coef * aux["lb"] / n_moe
    return loss, {"ce": ce, "lb": aux["lb"] / n_moe}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    cond_shape=None, lb_coef: float = 0.01,
                    remat: bool = False):
    def train_step(params, opt_state: OptState, tokens, targets, rng,
                   cond_embeds=None):
        (loss, metrics), grads = jax.value_and_grad(lm_loss, has_aux=True)(
            params, cfg, tokens, targets, cond_embeds=cond_embeds, rng=rng,
            lb_coef=lb_coef, remat=remat)
        params, opt_state, opt_metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics
    return train_step


def train(cfg: ModelConfig, opt_cfg: AdamWConfig, data_iter, *,
          seed: int = 0, log_every: int = 10, recorder=None,
          lb_coef: float = 0.01, log_fn=print):
    """CPU-scale training driver (examples + accuracy benchmarks)."""
    key = jax.random.PRNGKey(seed)
    params = transformer.init_params(cfg, key)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, lb_coef=lb_coef))
    history = []
    for i, batch in enumerate(data_iter):
        tokens, targets = batch[:, :-1], batch[:, 1:]
        rng = jax.random.fold_in(key, i + 1)
        params, opt_state, m = step_fn(params, opt_state,
                                       jnp.asarray(tokens), jnp.asarray(targets), rng)
        if i % log_every == 0:
            m = {k: float(v) for k, v in m.items()}
            history.append({"step": i, **m})
            log_fn(f"step {i:4d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                   f"lb {m['lb']:.4f} gnorm {m['grad_norm']:.2f}")
    return params, history
