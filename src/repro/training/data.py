"""Synthetic LM data pipeline.

A first-order Markov language with block structure: the vocabulary is split
into topical blocks; within a block transitions are peaked, with occasional
block switches. This gives (a) learnable structure so training loss falls and
(b) *specializable* token sub-manifolds so MoE routers develop the uneven
activation / co-activation patterns the paper exploits (Figs. 6/7/9).
"""
from __future__ import annotations

import numpy as np


class MarkovLM:
    def __init__(self, vocab_size: int, num_blocks: int = 8,
                 peak: float = 0.85, switch_p: float = 0.03, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.vocab_size = vocab_size
        self.num_blocks = num_blocks
        self.block_size = vocab_size // num_blocks
        self.switch_p = switch_p
        # per-block sparse transition: each token has ~8 likely successors
        self.succ = rng.integers(0, self.block_size,
                                 size=(vocab_size, 8)).astype(np.int64)
        self.peak = peak
        self._rng = rng

    def _block_of(self, tok):
        return np.minimum(tok // self.block_size, self.num_blocks - 1)

    def sample(self, batch: int, seq_len: int, rng=None) -> np.ndarray:
        rng = rng or self._rng
        out = np.empty((batch, seq_len), np.int64)
        tok = rng.integers(0, self.vocab_size, size=batch)
        for t in range(seq_len):
            out[:, t] = tok
            blk = self._block_of(tok)
            switch = rng.random(batch) < self.switch_p
            blk = np.where(switch,
                           rng.integers(0, self.num_blocks, size=batch), blk)
            peaked = rng.random(batch) < self.peak
            nxt_in = self.succ[tok, rng.integers(0, 8, size=batch)]
            nxt_rand = rng.integers(0, self.block_size, size=batch)
            nxt = np.where(peaked, nxt_in, nxt_rand)
            tok = blk * self.block_size + (nxt % self.block_size)
            tok = np.minimum(tok, self.vocab_size - 1)
        return out

    def batches(self, batch: int, seq_len: int, steps: int, seed: int = 1):
        rng = np.random.default_rng(seed)
        for _ in range(steps):
            yield self.sample(batch, seq_len + 1, rng)


def split_inputs_targets(tokens: np.ndarray):
    return tokens[:, :-1], tokens[:, 1:]
