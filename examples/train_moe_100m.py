"""End-to-end training driver: a ~100M-parameter DeepSeek-V2-Lite-family
MoE trained for a few hundred steps on the synthetic LM pipeline.

This is the (b)-deliverable end-to-end driver. The default config below is
~100M params — tune --steps/--batch for your patience on CPU; the model
architecture, optimizer, data pipeline and checkpointing are the same ones
the full-scale dry-run lowers onto the 16x16 mesh.

Run:  PYTHONPATH=src python examples/train_moe_100m.py --steps 200
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import ModelConfig, MoEConfig
from repro.checkpoint.io import save_pytree
from repro.training.data import MarkovLM
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def config_100m(reduced: bool) -> ModelConfig:
    if reduced:   # CI-scale variant (~20M)
        return ModelConfig(
            arch_id="moe-100m-reduced", family="moe", source="example",
            num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
            d_ff=1024, vocab_size=4096,
            moe=MoEConfig(num_experts=8, top_k=2, d_ff=512,
                          num_shared_experts=1, upcycle_noise=0.25))
    return ModelConfig(
        arch_id="moe-100m", family="moe", source="example",
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
        d_ff=2048, vocab_size=16384,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff=1024,
                      num_shared_experts=1, upcycle_noise=0.25))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="~20M variant for quick runs")
    ap.add_argument("--save", default="results/example_moe.npz")
    args = ap.parse_args()

    cfg = config_100m(args.reduced)
    from repro.models import transformer
    import jax
    n = sum(x.size for x in jax.tree.leaves(
        transformer.init_params(cfg, jax.random.PRNGKey(0))))
    print(f"training {cfg.arch_id}: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    lm = MarkovLM(cfg.vocab_size, num_blocks=16, seed=0)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(10, args.steps // 20))
    params, hist = train(cfg, opt,
                         lm.batches(args.batch, args.seq, args.steps),
                         log_every=10)
    if args.save:
        save_pytree(args.save, params)
        print(f"checkpoint -> {args.save}")
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    assert hist[-1]["loss"] < hist[0]["loss"], "training did not converge"


if __name__ == "__main__":
    main()
