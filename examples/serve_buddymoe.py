"""Serving example: batched requests through the BuddyMoE engine at a
configurable cache rate, with the full request/batcher plumbing.

Run:  PYTHONPATH=src python examples/serve_buddymoe.py --cache-rate 0.5
      PYTHONPATH=src python examples/serve_buddymoe.py --continuous \
          --arrival-rate 400 --prefill-chunk 8
      PYTHONPATH=src python examples/serve_buddymoe.py --continuous \
          --telemetry on --trace-out serve_trace.json   # -> ui.perfetto.dev
      PYTHONPATH=src python examples/serve_buddymoe.py --n-devices 4 \
          --cache-rate 0.5      # expert-parallel mesh: peer-HBM borrowing
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks import common
from repro.core import BuddyPolicy
from repro.runtime.cache import ExpertCache
from repro.runtime.placement import PlacementController
from repro.runtime.prefetch import AdaptiveBudgetController, PrevStepPredictor
from repro.runtime.telemetry import Telemetry
from repro.runtime.tiers import TIER_BITS, TieredExpertStore
from repro.runtime.trace import export_trace
from repro.serving.engine import ServeEngine
from repro.serving.requests import Request, StaticBatcher
from repro.serving.scheduler import (ContinuousScheduler, PoissonArrivals,
                                     RequestQueue, SLOConfig, make_requests)


def build_engine(args):
    cfg, params, lm = common.get_model()
    rec, q = common.get_profile(cfg, params, lm)
    tables = common.get_tables(cfg, q, rec, 0.95, 16)

    kw = dict(quant_tier=args.quant_tier, miss_policy=args.miss_policy,
              stall_per_quality=args.stall_per_quality)
    policy = (BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8, **kw)
              if args.policy == "buddy"
              else BuddyPolicy(mode="none", **kw))
    tier = None
    cache = None
    if args.quant_tier != "off":
        # split the HBM budget: int8/int4 replicas of the covered experts
        # stay resident; leftover budget becomes full-precision cache slots
        tier = TieredExpertStore(
            cfg.num_layers, cfg.moe.num_experts, args.cache_rate,
            bits=TIER_BITS[args.quant_tier], d_model=cfg.d_model,
            d_ff=cfg.moe.d_ff, seed=0, coverage=args.tier_coverage)
        if args.tier_coverage < 1.0:
            # top-P(use) experts per layer from the profiling activity
            tier.set_coverage(rec.A)
    else:
        cache = ExpertCache(cfg.num_layers, cfg.moe.num_experts,
                            args.cache_rate, seed=0)
    tele = None
    if args.telemetry == "on" or args.trace_out:
        make = Telemetry.with_trace if args.trace_out else Telemetry
        tele = make(predictor_label="prev_step", num_layers=cfg.num_layers,
                    num_experts=cfg.moe.num_experts)
    placement = None
    if args.placement == "live":
        placement = PlacementController(
            refresh_interval_s=args.placement_interval_ms * 1e-3,
            hot_windows=args.placement_hot_windows)
    eng = ServeEngine(
        cfg, params, tables=tables, policy=policy, cache=cache, tier=tier,
        predictor=PrevStepPredictor(cfg.num_layers, cfg.moe.num_experts),
        prefetch_k=args.prefetch, lookahead=args.lookahead, seed=0,
        telemetry=tele, n_devices=args.n_devices,
        ici_gbps=args.ici_gbps if args.ici_gbps > 0 else None,
        paged_kv=args.paged_kv, kv_block=args.kv_block,
        kv_blocks=args.kv_blocks if args.kv_blocks > 0 else None,
        prefix_cache=args.prefix_cache, placement=placement)
    return cfg, lm, eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache-rate", type=float, default=0.5)
    ap.add_argument("--policy", choices=["buddy", "none"], default="buddy")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prefetch", type=int, default=8)
    ap.add_argument("--lookahead", type=int, default=1,
                    help="prefetch depth: issue layer l+k while l computes")
    ap.add_argument("--continuous", action="store_true",
                    help="serve a Poisson arrival stream with continuous "
                         "batching instead of static batches")
    ap.add_argument("--arrival-rate", type=float, default=300.0,
                    help="requests per simulated second (--continuous)")
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help="prompt tokens per fused step when a request joins "
                         "(--continuous; 1 = token-by-token)")
    ap.add_argument("--quant-tier", choices=["off", "int8", "int4"],
                    default="off",
                    help="resident compressed replicas of every expert: a "
                         "buddy-less miss computes degraded instead of "
                         "stalling on PCIe (displaces cache slots from the "
                         "--cache-rate budget)")
    ap.add_argument("--tier-coverage", type=float, default=1.0,
                    help="fraction of experts per layer with a resident "
                         "replica (top-P(use) from profiling); freed bytes "
                         "become full cache slots")
    ap.add_argument("--miss-policy", choices=["precedence", "cost"],
                    default="precedence",
                    help="'cost': unified expected-cost argmin over buddy/"
                         "degraded/fetch/drop (runtime/costs.py) instead of "
                         "the fixed precedence chain")
    ap.add_argument("--stall-per-quality", type=float, default=0.05,
                    help="seconds of stall worth one unit of quality loss "
                         "(the cost model's single exchange rate)")
    ap.add_argument("--n-devices", type=int, default=1,
                    help="expert-parallel mesh size (1-8): shard experts "
                         "across devices and resolve misses on peer-owned "
                         "experts by borrowing over ICI (1 = single device)")
    ap.add_argument("--ici-gbps", type=float, default=0.0,
                    help="per-ICI-link bandwidth in GB/s (0: model default)")
    ap.add_argument("--paged-kv", action="store_true",
                    help="block-granular paged KV cache (runtime/paged_kv.py)"
                         " instead of per-slot ring buffers: ref-counted "
                         "fixed-size blocks, copy-on-write, per-row block "
                         "tables (off = the exact ring-buffer code path)")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="paged-KV block size in tokens (--paged-kv)")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="total pool blocks (--paged-kv; 0 sizes the pool "
                         "to the exact ring-buffer HBM footprint)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prefix cache over the paged pool "
                         "(--continuous): admission matches prompts against "
                         "retired requests' block chains and prefills only "
                         "the novel suffix (requires --paged-kv)")
    ap.add_argument("--adaptive-chunk", action="store_true",
                    help="shrink the prefill chunk while co-resident decode "
                         "rows are under TPOT pressure (--continuous)")
    ap.add_argument("--placement", choices=["off", "live"], default="off",
                    help="live traffic->placement loop (runtime/placement."
                         "py): tier coverage re-picks + background "
                         "replication of persistently-hot experts, driven "
                         "by per-expert activity EMAs on the simulated "
                         "clock ('off' is bit-identical pre-placement)")
    ap.add_argument("--placement-interval-ms", type=float, default=1.0,
                    help="simulated ms between placement ticks")
    ap.add_argument("--placement-hot-windows", type=int, default=3,
                    help="hysteresis: consecutive hot windows before an "
                         "expert earns a replica")
    ap.add_argument("--telemetry", choices=["off", "on"], default="off",
                    help="attach the flight recorder: calibration + prefetch "
                         "meters printed after the run ('off' is the exact "
                         "pre-telemetry code path — bit-identical)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="event-log export (implies --telemetry on): "
                         "'*.jsonl' = JSONL, else Chrome/Perfetto "
                         "trace_event JSON for https://ui.perfetto.dev")
    args = ap.parse_args()
    if args.prefix_cache and not args.paged_kv:
        ap.error("--prefix-cache shares KV at block granularity: "
                 "it requires --paged-kv")

    cfg, lm, eng = build_engine(args)
    rng = np.random.default_rng(0)
    prompts = [lm.sample(1, int(rng.integers(4, 9)))[0]
               for _ in range(args.num_requests)]

    if args.continuous:
        slo = SLOConfig(ttft_s=20e-3, tpot_s=5e-3)
        reqs = make_requests(prompts, PoissonArrivals(args.arrival_rate,
                                                      seed=1),
                             args.max_new, slo)
        ctrl = None
        if args.prefetch > 0:       # no prefetch -> nothing to adapt
            ctrl = AdaptiveBudgetController(
                prefetch_k=args.prefetch, lookahead=args.lookahead,
                max_k=2 * args.prefetch,
                max_lookahead=max(4, args.lookahead))
        sched = ContinuousScheduler(eng, slots=args.batch_size,
                                    controller=ctrl,
                                    prefill_chunk=args.prefill_chunk,
                                    adaptive_chunk=args.adaptive_chunk)
        s = sched.run(RequestQueue(reqs))
        print(f"\ncontinuous: {s['completed']}/{s['num_requests']} done, "
              f"{s['steps']} steps (prefill chunk {args.prefill_chunk}), "
              f"mean occupancy {s['mean_occupancy']:.2f}/{args.batch_size}")
        if "prefix" in s["engine"]:
            px = s["engine"]["prefix"]
            occ = px["pool"]
            print(f"paged KV: block {px['kv_block']}, pool "
                  f"{occ['used_blocks']}/{occ['n_blocks']} blocks used, "
                  f"{occ['cow_copies']} CoW copies"
                  + (f"; prefix cache: {px['hits']} hits, "
                     f"{px['hit_tokens']} tokens adopted, tree "
                     f"{px['tree']['nodes']} nodes"
                     if px.get("tree") is not None else ""))
        print(f"TTFT p50/p95/p99: {s['ttft_s']['p50']*1e3:.2f}/"
              f"{s['ttft_s']['p95']*1e3:.2f}/{s['ttft_s']['p99']*1e3:.2f}ms")
        print(f"goodput {s['goodput_rps']:.1f} req/s "
              f"({s['goodput_tok_s']:.0f} tok/s), SLO-met "
              f"{s['slo_met_frac']*100:.0f}%")
        bd = s["engine"]["stall_breakdown"]
    else:
        requests = [Request(rid=i, prompt=p, max_new_tokens=args.max_new)
                    for i, p in enumerate(prompts)]
        batcher = StaticBatcher(args.batch_size)
        done = 0
        for chunk, mat, mask in batcher.batches(requests):
            out = eng.generate(mat, max_new_tokens=args.max_new,
                               row_mask=mask)
            for i, r in enumerate(chunk):
                if r.rid >= 0:
                    r.output = out[i]
                    done += 1
            print(f"batch done ({done}/{args.num_requests} requests)")

        s = eng.summary()
        print(f"\npolicy={args.policy} cache_rate={args.cache_rate}")
        print(f"tokens/s (modeled, pad rows excluded): "
              f"{s['tokens_per_s']:.1f}")
        print(f"substitutions: {s['stats']['n_sub']}  "
              f"sync fetches: {s['stats']['n_miss_fetch']}  "
              f"late prefetches: {s['stats']['n_late_prefetch']}"
              + (f"  degraded: {s['tier']['degraded_tokens']}"
                 if "tier" in s else ""))
        print(f"PCIe bytes: {s['ledger']['total_bytes']/1e6:.1f}MB  "
              f"stall: {s['ledger']['sync_stall_s']*1e3:.1f}ms")
        bd = s["stall_breakdown"]
    print(f"stall breakdown: demand {bd['demand_stall_s']*1e3:.1f}ms  "
          f"late-prefetch {bd['late_prefetch_stall_s']*1e3:.1f}ms  "
          f"overlapped {bd['overlapped_s']*1e3:.1f}ms"
          + (f"  peer {bd['peer_stall_s']*1e3:.1f}ms"
             if "peer_stall_s" in bd else ""))

    # per-link utilization digest: the host PCIe link plus every ICI link,
    # with each link's bytes split by transfer cause
    es = s.get("engine", s)
    links = [eng.scheduler.utilization()] + \
        [eng.peer_links[d].utilization() for d in sorted(eng.peer_links)]
    print("link utilization:")
    for u in links:
        by = ", ".join(f"{k} {v/1e6:.2f}MB"
                       for k, v in u["bytes_by_cause"].items())
        print(f"  {u['name']}: busy {u['busy_s']*1e3:.2f}ms  queue "
              f"{u['queue_depth']}  {by or 'idle'}")
    if "mesh" in es:
        m = es["mesh"]
        print(f"mesh: {m['n_devices']} devices, {m['n_peer_borrow']} "
              f"peer borrows ({m['peer_share']*100:.1f}% of served slots)")
    if "placement" in es:
        p = es["placement"]
        print(f"placement: {p['n_ticks']} ticks, {p['coverage_repicks']} "
              f"coverage re-picks, {p['replicas_issued']} replicas "
              f"({p['replicas_reclaimed']} reclaimed), "
              f"{p['peer_pushes']} peer pushes")

    if eng.telemetry is not None:
        cal = eng.telemetry.calibration.summary()
        pf = eng.telemetry.prefetch.summary()
        print("telemetry calibration: " + "; ".join(
            f"{o} n={c['n']}"
            + (f" |resid| {c['residual_abs_mean_s']*1e3:.3f}ms"
               if c["n"] else "") for o, c in cal.items()))
        print(f"telemetry prefetch: precision {pf['precision']:.3f} recall "
              f"{pf['recall']:.3f} issued {pf['issued']} late {pf['late']}")
        if args.trace_out:
            n = export_trace(eng.telemetry.trace, args.trace_out)
            print(f"wrote {n} trace events to {args.trace_out} "
                  f"(load at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
