"""Serving example: batched requests through the BuddyMoE engine at a
configurable cache rate, with the full request/batcher plumbing.

Run:  PYTHONPATH=src python examples/serve_buddymoe.py --cache-rate 0.5
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks import common
from repro.core import BuddyPolicy
from repro.runtime.cache import ExpertCache
from repro.runtime.prefetch import PrevStepPredictor
from repro.serving.engine import ServeEngine
from repro.serving.requests import Request, StaticBatcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache-rate", type=float, default=0.5)
    ap.add_argument("--policy", choices=["buddy", "none"], default="buddy")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prefetch", type=int, default=8)
    ap.add_argument("--lookahead", type=int, default=1,
                    help="prefetch depth: issue layer l+k while l computes")
    args = ap.parse_args()

    cfg, params, lm = common.get_model()
    rec, q = common.get_profile(cfg, params, lm)
    tables = common.get_tables(cfg, q, rec, 0.95, 16)

    policy = (BuddyPolicy(tau=0.1, beta=0.9, rho=3, H=8)
              if args.policy == "buddy" else BuddyPolicy(mode="none"))
    eng = ServeEngine(
        cfg, params, tables=tables, policy=policy,
        cache=ExpertCache(cfg.num_layers, cfg.moe.num_experts,
                          args.cache_rate, seed=0),
        predictor=PrevStepPredictor(cfg.num_layers, cfg.moe.num_experts),
        prefetch_k=args.prefetch, lookahead=args.lookahead, seed=0)

    rng = np.random.default_rng(0)
    requests = [Request(rid=i, prompt=lm.sample(1, int(rng.integers(4, 9)))[0],
                        max_new_tokens=args.max_new)
                for i in range(args.num_requests)]
    batcher = StaticBatcher(args.batch_size)
    done = 0
    for chunk, prompts in batcher.batches(requests):
        out = eng.generate(prompts, max_new_tokens=args.max_new)
        for i, r in enumerate(chunk):
            if r.rid >= 0:
                r.output = out[i]
                done += 1
        print(f"batch done ({done}/{args.num_requests} requests)")

    s = eng.summary()
    print(f"\npolicy={args.policy} cache_rate={args.cache_rate}")
    print(f"tokens/s (modeled): {s['tokens_per_s']:.1f}")
    print(f"substitutions: {s['stats']['n_sub']}  "
          f"sync fetches: {s['stats']['n_miss_fetch']}  "
          f"late prefetches: {s['stats']['n_late_prefetch']}")
    print(f"PCIe bytes: {s['ledger']['total_bytes']/1e6:.1f}MB  "
          f"stall: {s['ledger']['sync_stall_s']*1e3:.1f}ms")
    bd = s["stall_breakdown"]
    print(f"stall breakdown: demand {bd['demand_stall_s']*1e3:.1f}ms  "
          f"late-prefetch {bd['late_prefetch_stall_s']*1e3:.1f}ms  "
          f"overlapped {bd['overlapped_s']*1e3:.1f}ms")


if __name__ == "__main__":
    main()
