"""Quickstart: the full BuddyMoE pipeline in ~60 seconds on CPU.

  1. build a small DeepSeek-V2-Lite-family MoE (64 experts, top-6),
  2. profile expert co-activations on synthetic data (offline phase),
  3. build CFT buddy lists (Eqs. 5-6),
  4. serve batched requests under memory pressure (c = 0.5) with buddy
     substitution, and compare against the on-demand baseline.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.deepseek_v2_lite_buddy import profiling
from repro.core import (BuddyPolicy, CoactivationRecorder, build_buddy_lists,
                        calibrate_tau, tae_from_probs)
from repro.models import transformer
from repro.runtime.cache import ExpertCache
from repro.serving.engine import ServeEngine
from repro.training.data import MarkovLM


def main():
    cfg = profiling()
    print(f"model: {cfg.arch_id} — {cfg.moe.num_experts} experts, "
          f"top-{cfg.moe.top_k} (the paper's §5.1 regime)")
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    lm = MarkovLM(cfg.vocab_size, seed=0)

    # ---- offline phase: co-activation profiling (§3.2) ----
    rec = CoactivationRecorder(cfg.num_layers, cfg.moe.num_experts)
    fwd = jax.jit(lambda p, t: transformer.forward_train(p, cfg, t,
                                                         record=True))
    taes = []
    for _ in range(4):
        _, aux = fwd(params, jnp.asarray(lm.sample(4, 64)))
        for l, (idx, probs) in enumerate(
                zip(aux["recorded"][0]["indices"],
                    aux["recorded"][0]["probs"])):
            rec.update(l, np.asarray(idx), np.asarray(probs))
            taes.append(np.asarray(tae_from_probs(probs)))
        rec.step_done()
    tau = calibrate_tau(np.concatenate(taes), percentile=15)
    print(f"calibrated TAE gate tau (p15): {tau:.3f}")

    # ---- buddy lists via CFT (§3.3) ----
    q = np.stack([rec.conditional(l) for l in range(cfg.num_layers)])
    tables = build_buddy_lists(q, alpha=0.9, k_max=8, activity=rec.A)
    print(f"buddy list sizes: mean {tables.sizes.mean():.1f}, "
          f"max {tables.sizes.max()}")

    # ---- online phase: serve with half the experts offloaded ----
    def serve(policy):
        eng = ServeEngine(cfg, params, tables=tables, policy=policy,
                          cache=ExpertCache(cfg.num_layers,
                                            cfg.moe.num_experts, 0.5, seed=1),
                          seed=1)
        out = eng.generate(lm.sample(4, 8), max_new_tokens=16)
        return eng

    eng_buddy = serve(BuddyPolicy(tau=tau, beta=0.9, rho=3, H=8))
    eng_base = serve(BuddyPolicy(mode="none"))

    print("\n                    buddy      on-demand")
    print(f"substitutions    {eng_buddy.stats.n_sub:8d} {0:12d}")
    print(f"sync fetches     {eng_buddy.stats.n_miss_fetch:8d} "
          f"{eng_base.stats.n_miss_fetch:12d}")
    print(f"PCIe bytes       {eng_buddy.ledger.total_bytes/1e6:7.1f}M "
          f"{eng_base.ledger.total_bytes/1e6:11.1f}M")
    print(f"tokens/s (model) {eng_buddy.stats.tokens_per_s:8.1f} "
          f"{eng_base.stats.tokens_per_s:12.1f}")
    speedup = eng_buddy.stats.tokens_per_s / max(eng_base.stats.tokens_per_s,
                                                 1e-9)
    print(f"\nBuddyMoE throughput gain: {speedup:.2f}x "
          f"(paper reports up to 1.10x on real hardware)")


if __name__ == "__main__":
    main()
