"""Offline phase walkthrough (§3.2-§3.3): router traces -> co-activation
matrix -> conditional q_{j|i} -> CFT buddy lists -> serialized tables.

Shows the paper's empirical regularities on a trained model:
  * uneven activation (Fig. 6),
  * concentrated co-activation (Figs. 7/9),
  * compact buddy lists (|B| stats),
  * expert output similarity (the redundancy being exploited).

Run:  PYTHONPATH=src python examples/profile_and_build_buddies.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import alpha_schedule, build_buddy_lists, save_tables
from repro.core.buddies import list_size_stats
from repro.core.similarity import all_layer_similarities


def main():
    cfg, params, lm = common.get_model()
    rec, q = common.get_profile(cfg, params, lm)

    print("\n--- Fig. 6: uneven activation ---")
    for l in range(cfg.num_layers):
        s = rec.activation_skew(l)
        print(f"layer {l}: gini {s['gini']:.3f}  top-1 share "
              f"{s['top1_share']:.3f}  top-8 share {s['top8_share']:.3f} "
              f"(uniform would be {8 / cfg.moe.num_experts:.3f})")

    print("\n--- Figs. 7/9: co-activation concentration ---")
    for l in range(cfg.num_layers):
        print(f"layer {l}: top-8 peers cover "
              f"{rec.topr_coverage(l, 8).mean():.1%} of co-activation mass "
              f"(uniform: {8 / (cfg.moe.num_experts - 1):.1%})")

    print("\n--- expert output similarity (the redundancy, Fig. 4) ---")
    sims = all_layer_similarities(cfg, params,
                                  jnp.asarray(lm.sample(4, 64)))
    for l in range(cfg.num_layers):
        off = sims[l][~np.eye(cfg.moe.num_experts, dtype=bool)]
        print(f"layer {l}: mean pairwise output cosine {off.mean():.3f}, "
              f"p90 {np.percentile(off, 90):.3f}")

    print("\n--- CFT buddy lists (Eqs. 5-6), per-layer alpha schedule ---")
    alphas = alpha_schedule(cfg.num_layers, early=0.95, late=0.85)
    tables = build_buddy_lists(q, alpha=alphas, k_max=16, activity=rec.A,
                               output_sim=sims)
    print(f"alpha schedule: {np.round(alphas, 3).tolist()}")
    print(f"list sizes: {list_size_stats(tables)}")
    out = os.path.join(common.CACHE_DIR, "buddy_tables_example.npz")
    save_tables(out, tables)
    print(f"serialized buddy tables -> {out} "
          f"(ships alongside the checkpoint, §3.4)")


if __name__ == "__main__":
    main()
